"""Mixture-of-Experts layer with two routers and explicit EP dispatch.

Routers:
* ``topk``    — softmax top-k with load-balancing aux loss (Switch/GShard);
* ``sigmoid`` — DeepSeek-V3 style sigmoid scores + selection bias, gates
                renormalised over the selected experts;
* ``hash``    — **the paper's technique**: BinomialHash consistent routing of
                token-ids to experts ("Hash Layers" style).  Balance comes
                from the paper's Eq. (3) bound instead of an aux loss, and
                monotonicity gives elastic expert scaling: growing E moves
                only ~k/E of the token assignments (benchmarked).

Dispatch is sort-based (megablocks-lite): tokens are argsorted by expert id,
ranked within expert via searchsorted offsets, and scattered into a fixed
(E_local, C, D) buffer — no (B, S, E, C) one-hot dispatch tensors.

Distribution: experts are sharded over the ``model`` axis (EP).  Under a
mesh the layer runs inside ``shard_map``: dispatch is device-local (tokens
are replicated over ``model``), expert FFNs run on the local expert slice
(weights optionally ZeRO-3-gathered over ``data``), and partial outputs are
``psum``-combined over ``model`` — the same reduce the TP FFN would need, so
EP costs no extra collective volume beyond ZeRO-3 weight gathers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.binomial_jax import mix32
from repro.core.registry import make_bulk
from repro.models.layers.common import dense_init, init_mlp, apply_mlp
from repro.sharding.rules import current_mesh, expert_layout, logical, shard, shard_map_compat

GOLDEN32 = np.uint32(0x9E3779B9)


def init_moe(key, cfg: ArchConfig):
    m = cfg.moe
    D, E, Fe = cfg.d_model, m.num_experts, m.d_ff_expert
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32, scale=0.006),
        "experts_wi": dense_init(ks[1], (E, D, Fe), dt),
        "experts_wg": dense_init(ks[2], (E, D, Fe), dt),
        "experts_wo": dense_init(ks[3], (E, Fe, D), dt, scale=0.02 / np.sqrt(2 * cfg.num_layers)),
    }
    if m.router == "sigmoid":
        p["router_bias"] = jnp.zeros((E,), jnp.float32)
    if m.shared_experts > 0:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=m.shared_experts * Fe)
    return p


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def route(p, x, token_ids, layer_salt, cfg: ArchConfig):
    """-> expert_ids (B,S,K) int32, gates (B,S,K) f32, aux_loss scalar."""
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    if m.router == "hash":
        # The paper's consistent-hash router: key = mix(token_id, salt, k).
        # layer_salt may be a traced scan counter — mix with jnp ops.
        # All K salted key families are built as one broadcast (B,S,K)
        # tensor and routed by ONE lookup dispatch — the lookup is
        # elementwise over its key operand, so this is bit-exact with the
        # former per-k loop while collapsing K compiled-call dispatches
        # (and K ω-unrolled producers for XLA to fuse) into one.
        keys = token_ids.astype(jnp.uint32)
        salt0 = jnp.asarray(layer_salt, jnp.uint32) * np.uint32(1000003)
        k_salts = (np.arange(K) * 7919 + 1).astype(np.uint32)  # (K,)
        salts = (salt0 + k_salts) * GOLDEN32
        kk = mix32(keys[..., None] ^ salts)  # (B, S, K)
        # which consistent-hash lookup routes tokens is a BULK_ENGINES
        # choice (DESIGN.md §10) — same salted-key construction, pluggable
        # lookup body, so engine comparisons share one dispatch shape
        eng = make_bulk(m.router_hash_engine)
        if m.router_dynamic_n:
            # expert count as a traced operand of the router lookup: when
            # route() runs eagerly (routing sweeps, placement studies) one
            # compiled trace serves every E. Inside a jitted model step E
            # is a static config constant, so this cannot prevent the
            # enclosing step from retracing on resize.
            expert_ids = eng.lookup_dyn(kk, jnp.uint32(E), omega=m.router_hash_omega)
        else:
            expert_ids = eng.lookup_vec(kk, E, omega=m.router_hash_omega)
        gates = jnp.full(expert_ids.shape, 1.0 / K, jnp.float32)
        return expert_ids, gates, jnp.float32(0.0)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    if m.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"]
        _, expert_ids = jax.lax.top_k(sel, K)
        g = jnp.take_along_axis(scores, expert_ids, axis=-1)
        gates = g / jnp.maximum(jnp.sum(g, -1, keepdims=True), 1e-9)
        aux = jnp.float32(0.0)  # DS-V3 is aux-loss-free (bias-based balancing)
    else:  # topk softmax
        probs = jax.nn.softmax(logits, axis=-1)
        g, expert_ids = jax.lax.top_k(probs, K)
        gates = g / jnp.maximum(jnp.sum(g, -1, keepdims=True), 1e-9)
        # Switch-style load-balance loss
        me = jnp.mean(probs.reshape(-1, E), axis=0)
        onehot = jax.nn.one_hot(expert_ids.reshape(-1), E, dtype=jnp.float32)
        ce = jnp.mean(jnp.max(onehot, axis=1)[:, None] * onehot, axis=0) * E
        aux = m.aux_loss_weight * E * jnp.sum(me * ce)
    return expert_ids.astype(jnp.int32), gates.astype(jnp.float32), aux


# ---------------------------------------------------------------------------
# sort-based local dispatch (runs per model-shard on its expert slice)
# ---------------------------------------------------------------------------


def _expert_ffn(buf, wi, wg, wo):
    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, wg)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _routing_plan(expert_ids, gates, e_offset, E_local, C, N, K):
    """Sort-based assignment plan for the local expert slice."""
    flat_e = expert_ids.reshape(-1)
    flat_g = gates.reshape(-1)
    tok = jnp.arange(N * K, dtype=jnp.int32) // K

    local = (flat_e >= e_offset) & (flat_e < e_offset + E_local)
    le = jnp.where(local, flat_e - e_offset, E_local)  # E_local = overflow bin
    order = jnp.argsort(le, stable=True)
    se = le[order]
    stok = tok[order]
    sg = flat_g[order]

    offsets = jnp.searchsorted(se, jnp.arange(E_local, dtype=se.dtype))
    rank = jnp.arange(N * K, dtype=jnp.int32) - offsets[jnp.clip(se, 0, E_local - 1)]
    keep = (se < E_local) & (rank < C)
    slot = jnp.where(keep, se * C + rank, E_local * C)  # last row = dump slot
    return slot, stok, sg, keep


def _scatter_buf(x_flat, slot, stok, keep, E_local, C):
    D = x_flat.shape[-1]
    buf = jnp.zeros((E_local * C + 1, D), x_flat.dtype)
    return buf.at[slot].add(x_flat[stok] * keep[:, None].astype(x_flat.dtype))


def _combine(out_buf_flat, slot, stok, sg, keep, N, dtype):
    D = out_buf_flat.shape[-1]
    contrib = out_buf_flat[jnp.clip(slot, 0, out_buf_flat.shape[0] - 1)]
    w = (sg * keep).astype(dtype)[:, None]
    return jnp.zeros((N, D), dtype).at[stok].add(contrib * w)


def _dispatch_local(x_flat, expert_ids, gates, wi, wg, wo, e_offset, E_local, C):
    """x_flat (N,D); expert_ids/gates (N,K); weights local (E_local,...).

    Gather/scatter touch only the E_local*C buffer rows (the kept
    assignments), not all N*K assignment slots — 10-15x less dispatch
    traffic when this model-shard owns 1/16 of the experts (§Perf cell 3).
    """
    N, D = x_flat.shape
    K = expert_ids.shape[-1]
    slot, stok, sg, keep = _routing_plan(expert_ids, gates, e_offset, E_local, C, N, K)
    # invert slot -> source assignment (kept slots are collision-free)
    src = jnp.full((E_local * C + 1,), -1, jnp.int32)
    src = src.at[slot].set(jnp.arange(N * K, dtype=jnp.int32))[: E_local * C]
    valid = src >= 0
    srcc = jnp.clip(src, 0)
    rows = x_flat[stok[srcc]] * valid[:, None].astype(x_flat.dtype)
    out_buf = _expert_ffn(rows.reshape(E_local, C, D), wi, wg, wo).reshape(E_local * C, D)
    w = (sg[srcc] * valid).astype(x_flat.dtype)
    y = jnp.zeros((N, D), x_flat.dtype)
    return y.at[jnp.where(valid, stok[srcc], N)].add(out_buf * w[:, None], mode="drop")


def _capacity(cfg: ArchConfig, n_local_tokens: int) -> int:
    m = cfg.moe
    return max(1, int(m.capacity_factor * n_local_tokens * m.top_k / m.num_experts))


# ---------------------------------------------------------------------------
# dense GShard path for tiny token counts (decode): the (N,E,C) dispatch
# tensors are trivial at serve batch sizes, and pure einsums let GSPMD keep
# expert weights fully sharded (E over model, D over data) with only
# KB..MB-sized activation psums — no shard_map boundary, no weight motion.
# ---------------------------------------------------------------------------


def _gshard_masks(expert_ids, gates, E: int, C: int):
    """expert_ids/gates (N,K) -> dispatch (N,E,C) bool-ish, combine (N,E,C)."""
    N, K = expert_ids.shape
    oh = jax.nn.one_hot(expert_ids.reshape(-1), E, dtype=jnp.float32)  # (N*K, E)
    pos = jnp.cumsum(oh, axis=0) - oh
    rank = jnp.sum(pos * oh, axis=-1).astype(jnp.int32)  # (N*K,)
    keep = (rank < C).astype(jnp.float32)
    disp = oh * keep[:, None]  # (N*K, E)
    disp_c = disp[:, :, None] * jax.nn.one_hot(jnp.minimum(rank, C - 1), C)[:, None, :]
    dispatch = disp_c.reshape(N, K, E, C).sum(axis=1)
    combine = (disp_c * gates.reshape(-1)[:, None, None]).reshape(N, K, E, C).sum(axis=1)
    return dispatch, combine


def _dense_moe(p, x_flat, expert_ids, gates, cfg: ArchConfig, C: int):
    m = cfg.moe
    E = m.num_experts
    dispatch, combine = _gshard_masks(expert_ids, gates, E, C)
    # pin weight layouts to the ambient expert layout; under "tp" (serving)
    # experts are replicated over model with F sharded — per-expert tensor
    # parallelism, which is what 1-token-per-expert capacities want
    if expert_layout() == "tp":
        wi = shard(p["experts_wi"], None, "fsdp", "tp")
        wg = shard(p["experts_wg"], None, "fsdp", "tp")
        wo = shard(p["experts_wo"], None, "tp", "fsdp")
        espec, hspec = (None, None, "fsdp"), (None, None, "tp")
    else:
        wi = shard(p["experts_wi"], "tp", "fsdp", None)
        wg = shard(p["experts_wg"], "tp", "fsdp", None)
        wo = shard(p["experts_wo"], "tp", None, "fsdp")
        espec, hspec = ("tp", None, "fsdp"), ("tp", None, None)
    buf = jnp.einsum("nec,nd->ecd", dispatch.astype(x_flat.dtype), x_flat)
    buf = shard(buf, *espec)
    # weights as dot LHS: layout shuffles land on the tiny C-sized
    # activations (e,f,c)/(e,d,c), never on the weight streams
    hi = jnp.einsum("edf,ecd->efc", wi, buf)
    hg = jnp.einsum("edf,ecd->efc", wg, buf)
    h = shard(jax.nn.silu(hi) * hg, hspec[0], hspec[2], hspec[1])
    out = jnp.einsum("efd,efc->edc", wo, h)
    out = shard(out, espec[0], espec[2], espec[1])
    y = jnp.einsum("edc,nec->nd", out, combine.astype(x_flat.dtype))
    return y


# ---------------------------------------------------------------------------
# full layer
# ---------------------------------------------------------------------------


def apply_moe(p, x, token_ids, layer_salt, cfg: ArchConfig):
    """x (B,S,D) -> (B,S,D), aux_loss.  token_ids (B,S) int32 (hash router)."""
    m = cfg.moe
    B, S, D = x.shape
    expert_ids, gates, aux = route(p, x, token_ids, layer_salt, cfg)

    mesh = current_mesh()
    if mesh is None:
        C = _capacity(cfg, B * S)
        y = _dispatch_local(
            x.reshape(-1, D), expert_ids.reshape(-1, m.top_k), gates.reshape(-1, m.top_k),
            p["experts_wi"], p["experts_wg"], p["experts_wo"], 0, m.num_experts, C,
        ).reshape(B, S, D)
    else:
        tp = mesh.shape["model"]
        dp_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
        n_local = (B // dp) * S
        C = _capacity(cfg, n_local)
        E_local = m.num_experts // tp
        fsdp_w = logical("tp", "fsdp", None)  # (E, D, Fe) spec
        fsdp_wo = logical("tp", None, "fsdp")
        gathered = fsdp_w[1] is not None
        n_local = (B // dp) * S
        if n_local * m.top_k <= 4 * m.num_experts:
            # Few tokens per expert (decode / small serve batches): a
            # shard_map dispatch would force per-layer weight-slice copies at
            # its boundary and ZeRO-3 gathers would stream the full expert
            # slice (GBs/layer) for a handful of tokens. The dense-GShard
            # einsum path keeps weights fully sharded (E over model, D over
            # data) — only MB-sized activation psums move (§Perf cell 2).
            Cg = max(1, int(m.capacity_factor * B * S * m.top_k / m.num_experts))
            y = _dense_moe(
                p, x.reshape(-1, D), expert_ids.reshape(-1, m.top_k),
                gates.reshape(-1, m.top_k), cfg, Cg,
            ).reshape(B, S, D)
            y = shard(y, "dp", None, None)
        else:

            def body(xs, eids, gs, wi, wg, wo):
                # per-device: xs (Bl,S,D); weights (E_local, D[/data], Fe)
                midx = jax.lax.axis_index("model")
                if gathered:
                    wi = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
                    wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
                    wo = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
                y = _dispatch_local(
                    xs.reshape(-1, D), eids.reshape(-1, m.top_k), gs.reshape(-1, m.top_k),
                    wi, wg, wo, midx * E_local, E_local, C,
                )
                return jax.lax.psum(y, "model").reshape(xs.shape)

            dspec = P(dp_axes, None, None)
            y = shard_map_compat(
                body,
                mesh=mesh,
                in_specs=(dspec, dspec, dspec, fsdp_w, fsdp_w, fsdp_wo),
                out_specs=dspec,
                check_vma=False,
            )(x, expert_ids, gates, p["experts_wi"], p["experts_wg"], p["experts_wo"])

    if m.shared_experts > 0:
        y = y + apply_mlp(p["shared"], x, cfg)
    return y, aux
