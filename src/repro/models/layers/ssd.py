"""Mamba-2 block via the SSD (state-space duality) chunked algorithm.

Per Dao & Gu (2024): the sequence is split into chunks of Q tokens; within a
chunk the SSM is computed in its quadratic "attention-like" dual form (MXU
friendly), and a cheap sequential scan propagates the (H, hd, N) states
between chunks.  Decode keeps O(1) state: the SSM state + conv buffer.

Layout: d_inner = expand * d_model, H = d_inner / head_dim heads,
B/C projections have n_groups groups (broadcast over H/G heads each).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers.common import dense_init, zeros
from repro.sharding.rules import shard


def dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.n_groups, s.d_state, s.head_dim


def init_ssd(key, cfg: ArchConfig):
    s = cfg.ssm
    D = cfg.d_model
    d_inner, H, G, N, hd = dims(cfg)
    conv_dim = d_inner + 2 * G * N
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    dt_init = jnp.exp(
        jax.random.uniform(ks[0], (H,), jnp.float32) * (np.log(0.1) - np.log(1e-3))
        + np.log(1e-3)
    )
    return {
        "in_proj_z": dense_init(ks[1], (D, d_inner), dt),
        "in_proj_x": dense_init(ks[2], (D, d_inner), dt),
        "in_proj_bc": dense_init(ks[3], (D, 2 * G * N), dt),
        "in_proj_dt": dense_init(ks[4], (D, H), dt),
        "conv_w": dense_init(ks[5], (s.conv_width, conv_dim), dt, scale=1.0 / np.sqrt(s.conv_width)),
        "conv_b": zeros((conv_dim,), dt),
        "A_log": jnp.log(jax.random.uniform(ks[6], (H,), jnp.float32, 1.0, 16.0)),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt_init)),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(
            ks[7], (d_inner, D), dt, scale=0.02 / np.sqrt(2 * cfg.num_layers)
        ),
    }


def _causal_conv(x, w, b, state=None):
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(cw)) + b
    new_state = xp[:, -(cw - 1) :] if cw > 1 else jnp.zeros_like(pad)
    return out, new_state


def _project(p, x, cfg: ArchConfig, conv_state=None):
    """x (B,S,D) -> z, xs (B,S,H,hd), Bm/Cm (B,S,G,N), dt (B,S,H) + conv state."""
    d_inner, H, G, N, hd = dims(cfg)
    z = jnp.einsum("bsd,di->bsi", x, p["in_proj_z"])
    xi = jnp.einsum("bsd,di->bsi", x, p["in_proj_x"])
    bc = jnp.einsum("bsd,di->bsi", x, p["in_proj_bc"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["in_proj_dt"])
    xbc = jnp.concatenate([xi, bc], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_inner]
    Bm = xbc[..., d_inner : d_inner + G * N]
    Cm = xbc[..., d_inner + G * N :]
    B_, S = x.shape[:2]
    xs = shard(xs.reshape(B_, S, H, hd), "dp", None, "tp", None)
    Bm = Bm.reshape(B_, S, G, N)
    Cm = Cm.reshape(B_, S, G, N)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    return z, xs, Bm, Cm, dtv, conv_state


def _finish(p, y, z, x_dtype, cfg: ArchConfig):
    """Gated RMSNorm + out-proj. y (B,S,H,hd) f32; z (B,S,d_inner)."""
    d_inner = z.shape[-1]
    B, S = y.shape[:2]
    yf = y.reshape(B, S, d_inner) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"]
    out = jnp.einsum("bsi,id->bsd", yf.astype(x_dtype), p["out_proj"])
    return out


def ssd_scan(p, x, cfg: ArchConfig, state=None, conv_state=None):
    """Full-sequence chunked SSD. x (B,S,D) -> (out, {state, conv})."""
    d_inner, H, G, N, hd = dims(cfg)
    Q = min(cfg.ssm.chunk, x.shape[1])
    B_, S, D = x.shape
    assert S % Q == 0, (S, Q)
    nc = S // Q
    Hg = H // G

    z, xs, Bm, Cm, dtv, conv_state = _project(p, x, cfg, conv_state)
    A = -jnp.exp(p["A_log"])  # (H,)
    dA = dtv * A  # (B,S,H)

    # chunk everything: (B, nc, Q, ...)
    xs_c = xs.reshape(B_, nc, Q, G, Hg, hd)
    B_c = Bm.reshape(B_, nc, Q, G, N)
    C_c = Cm.reshape(B_, nc, Q, G, N)
    dt_c = dtv.reshape(B_, nc, Q, G, Hg)
    dA_c = dA.reshape(B_, nc, Q, G, Hg)
    cum = jnp.cumsum(dA_c, axis=2)  # (B,nc,Q,G,Hg)

    # ---- intra-chunk (quadratic dual form) ----
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None] - cum[:, :, None, :]  # (B,nc,Qi,Qj,G,Hg)
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None, None]
    L = jnp.where(mask, jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcign,bcjgn->bcijg", C_c, B_c, preferred_element_type=jnp.float32)
    w = cb[..., None] * L * dt_c[:, :, None, :, :, :]  # (B,nc,Qi,Qj,G,Hg)
    y_intra = jnp.einsum("bcijgh,bcjghp->bcighp", w.astype(xs_c.dtype), xs_c)

    # ---- chunk-local states ----
    decay_to_end = jnp.exp(cum[:, :, -1:, :, :] - cum)  # (B,nc,Q,G,Hg)
    sl = jnp.einsum(
        "bcqgn,bcqgh,bcqghp->bcghpn",
        B_c,
        (decay_to_end * dt_c).astype(B_c.dtype),
        xs_c,
        preferred_element_type=jnp.float32,
    )  # (B,nc,G,Hg,hd,N)
    chunk_decay = jnp.exp(cum[:, :, -1])  # (B,nc,G,Hg)

    # ---- inter-chunk state scan ----
    if state is None:
        state0 = jnp.zeros((B_, G, Hg, hd, N), jnp.float32)
    else:
        state0 = state.astype(jnp.float32)

    def step(s_prev, ins):
        sl_k, dk = ins  # (B,G,Hg,hd,N), (B,G,Hg)
        s_new = sl_k + dk[..., None, None] * s_prev
        return s_new, s_prev

    s_last, s_prevs = jax.lax.scan(
        step, state0, (jnp.moveaxis(sl, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)  # (B,nc,G,Hg,hd,N)

    y_inter = jnp.einsum(
        "bcqgn,bcqgh,bcghpn->bcqghp",
        C_c,
        jnp.exp(cum).astype(C_c.dtype),
        s_prevs.astype(C_c.dtype),
        preferred_element_type=jnp.float32,
    )
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(B_, S, H, hd)
    y = y + p["D_skip"][None, None, :, None] * xs.reshape(B_, S, H, hd).astype(jnp.float32)
    out = _finish(p, y, z, x.dtype, cfg)
    return out, {"state": s_last.reshape(B_, H, hd, N), "conv": conv_state}


def ssd_decode(p, x, cache, cfg: ArchConfig):
    """One-step decode. x (B,1,D); cache {state (B,H,hd,N) f32, conv}."""
    d_inner, H, G, N, hd = dims(cfg)
    z, xs, Bm, Cm, dtv, conv_state = _project(p, x, cfg, cache["conv"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp((dtv * A)[:, 0])  # (B,H)
    Hg = H // G
    state = cache["state"].reshape(x.shape[0], G, Hg, hd, N)
    xs1 = xs[:, 0].reshape(-1, G, Hg, hd)
    bx = jnp.einsum(
        "bgn,bgh,bghp->bghpn",
        Bm[:, 0],
        dtv[:, 0].reshape(-1, G, Hg),
        xs1.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    state = dA.reshape(-1, G, Hg)[..., None, None] * state + bx
    y = jnp.einsum("bgn,bghpn->bghp", Cm[:, 0].astype(jnp.float32), state)
    y = y.reshape(x.shape[0], 1, H, hd) + p["D_skip"][None, None, :, None] * xs.astype(
        jnp.float32
    )
    out = _finish(p, y, z, x.dtype, cfg)
    return out, {"state": state.reshape(x.shape[0], H, hd, N), "conv": conv_state}
