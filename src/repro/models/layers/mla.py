"""Multi-head Latent Attention (DeepSeek-V2/V3).

Cache stores only the compressed latent c_kv (r_kv per token) plus the shared
rope key (hd_r per token) — the memory advantage that defines MLA.  Decode
uses the weight-absorption trick (fold W_uk into the query, attend directly
against the latent, fold W_uv into the output) so the per-step FLOPs scale
with r_kv, not H*hd.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers.attention import chunked_causal_attention
from repro.models.layers.common import apply_rope, dense_init, rope_cos_sin
from repro.sharding.rules import shard

NEG_INF = -1e30


def init_mla(key, cfg: ArchConfig):
    m = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    wo_scale = 0.02 / np.sqrt(2 * cfg.num_layers)
    return {
        "w_dq": dense_init(ks[0], (D, m.q_lora_rank), dt),
        "q_norm_scale": jnp.ones((m.q_lora_rank,), jnp.float32),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, H, m.qk_nope_head_dim), dt),
        "w_qr": dense_init(ks[2], (m.q_lora_rank, H, m.qk_rope_head_dim), dt),
        "w_dkv": dense_init(ks[3], (D, m.kv_lora_rank), dt),
        "kv_norm_scale": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "w_kr": dense_init(ks[4], (D, m.qk_rope_head_dim), dt),
        "w_uk": dense_init(ks[5], (m.kv_lora_rank, H, m.qk_nope_head_dim), dt),
        "w_uv": dense_init(ks[6], (m.kv_lora_rank, H, m.v_head_dim), dt),
        "wo_attn": dense_init(ks[7], (H, m.v_head_dim, D), dt, scale=wo_scale),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _latents(p, x, cfg: ArchConfig, positions):
    """x (B,S,D) -> q_nope (B,S,H,dn), q_rope (B,S,H,dr), c_kv (B,S,r), k_rope (B,S,dr)."""
    m = cfg.mla
    cq = _rms(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm_scale"])
    q_nope = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_rope = jnp.einsum("bsr,rhk->bshk", cq, p["w_qr"])
    c_kv = _rms(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm_scale"])
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["w_kr"])
    cos, sin = rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_train(p, x, positions, cfg: ArchConfig):
    """Expanded (non-absorbed) path for train/prefill trunks."""
    m = cfg.mla
    H = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _latents(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    B, S = x.shape[:2]
    # fold rope/nope into one head dim; pad v to the same width for the
    # shared chunked kernel, then slice back
    q = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,H,dn+dr)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], q_rope.shape)], -1)
    dq = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = shard(q.reshape(B, S, H, 1, dq), "dp", None, "tp", None, None)
    k = shard(k, "dp", None, "tp", None)
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dq - m.v_head_dim)))
    out = chunked_causal_attention(q, k, vpad, positions, positions, window=cfg.window)
    out = out.reshape(B, S, H, dq)[..., : m.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo_attn"])


def mla_prefill(p, x, positions, cfg: ArchConfig, cache_len: int):
    out = mla_train(p, x, positions, cfg)
    _, _, c_kv, k_rope = _latents(p, x, cfg, positions)
    B, S = x.shape[:2]
    pad = cache_len - S
    cache = {
        "ckv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
        "kr": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
        "pos": jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1),
    }
    return out, cache


def mla_decode(p, x, pos, cache, cfg: ArchConfig):
    """Absorbed decode: attend directly against the latent cache."""
    m = cfg.mla
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c_new, kr_new = _latents(p, x, cfg, positions)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], c_new, pos, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_new, pos, axis=1)
    pc = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions, pos, axis=1
    )
    # absorb W_uk: q_abs (B,1,H,r) = q_nope @ W_uk^T
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (
        jnp.einsum("bshr,btr->bhst", q_abs, ckv, preferred_element_type=jnp.float32)
        + jnp.einsum("bshk,btk->bhst", q_rope, kr, preferred_element_type=jnp.float32)
    ) * scale
    valid = (pc >= 0) & (pc <= pos)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    # attend against the latent, then absorb W_uv
    o_lat = jnp.einsum("bhst,btr->bshr", w.astype(ckv.dtype), ckv)
    out = jnp.einsum("bshr,rhk->bshk", o_lat, p["w_uv"])
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo_attn"])
    return out, {"ckv": ckv, "kr": kr, "pos": pc}
