"""GQA attention: chunked (flash-style) training/prefill path + cached decode.

Memory discipline: scores are never materialised at (S, S) — the kernel-free
JAX implementation scans KV chunks with an online softmax (running max/sum),
so peak score memory is (B, G, R, q_chunk, kv_chunk) fp32.  Sliding-window
archs use a banded variant that only touches the statically-known band of KV
chunks (no wasted FLOPs outside the window).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers.common import (
    apply_rope,
    dense_init,
    mrope_cos_sin,
    rms_head_norm,
    rope_cos_sin,
    zeros,
)
from repro.sharding.rules import shard

NEG_INF = -1e30


def init_attention(key, cfg: ArchConfig):
    """Weights stored FUSED — (D, H*hd) etc. — so the sharded dim is always
    divisible by the 16-way model axis even when H or KVH is not (e.g. 56
    heads, 8 KV heads); activations are reshaped to per-head form in-graph."""
    D, H, KVH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), dt),
        "wk": dense_init(ks[1], (D, KVH * hd), dt),
        "wv": dense_init(ks[2], (D, KVH * hd), dt),
        "wo_attn": dense_init(ks[3], (H * hd, D), dt, scale=0.02 / np.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((H * hd,), dt)
        p["bk"] = zeros((KVH * hd,), dt)
        p["bv"] = zeros((KVH * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _cos_sin(cfg: ArchConfig, positions, hd: int):
    if cfg.pos_emb == "mrope":
        return mrope_cos_sin(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    if cfg.pos_emb == "rope":
        return rope_cos_sin(positions, int(hd * cfg.rope_fraction) // 2 * 2, cfg.rope_theta)
    return None, None


def _project_qkv(p, x, cfg: ArchConfig, positions):
    """x (B,S,D) -> q (B,S,G,R,hd), k/v (B,S,G,hd) with rope applied."""
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    R = H // KVH
    B, S = x.shape[:2]
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    # constrain the fused forms (always 16-divisible), then split heads
    q = shard(q, "dp", None, "tp").reshape(B, S, H, hd)
    k = shard(k, "dp", None, "tp").reshape(B, S, KVH, hd)
    v = shard(v, "dp", None, "tp").reshape(B, S, KVH, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if cfg.pos_emb in ("rope", "mrope"):
        cos, sin = _cos_sin(cfg, positions, hd)
        q = apply_rope(q, cos, sin, cfg.rope_fraction)
        k = apply_rope(k, cos, sin, cfg.rope_fraction)
    q = q.reshape(B, S, KVH, R, hd)
    return q, k, v


def _online_step(q_i, k_j, v_j, mask, carry, scale):
    """One online-softmax step in XLA-natural dot order (B,G,Cq,R,Ck)."""
    m, l, acc = carry
    s = jnp.einsum(
        "bqgrd,bkgd->bgqrk", q_i, k_j, preferred_element_type=jnp.float32
    ) * scale
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bgqrk,bkgd->bgqrd", p.astype(v_j.dtype), v_j)
    acc_new = acc * alpha[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
    return m_new, l_new, acc_new


def attention_sharding_mode(G: int, R: int, S: int, windowed: bool) -> str:
    """Pick how attention internals shard over the model axis (see §Perf):

    head  — KV heads divide tp: q/k/v/scores head-sharded, ZERO attn comm;
    rhead — query-rep heads divide tp: k/v replicated (one gather), q sharded
            on the R dim, scores local;
    seq   — neither divides: q resident-sharded on sequence, k/v replicated
            (one gather per layer) — context parallelism;
    local — no constraints (tiny meshes / no mesh).
    """
    from repro.sharding.rules import current_mesh

    mesh = current_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    if tp <= 1:
        return "local"
    if G % tp == 0:
        return "head"
    if R % tp == 0:
        return "rhead"
    if S % tp == 0 and not windowed:
        return "seq"
    return "local"


def _constrain(q, k, v, qp, mode):
    if mode == "head":
        q = shard(q, "dp", None, "tp", None, None)
        k = shard(k, "dp", None, "tp", None)
        v = shard(v, "dp", None, "tp", None)
    elif mode == "rhead":
        q = shard(q, "dp", None, None, "tp", None)
        k = shard(k, "dp", None, None, None)  # replicated (gathered once)
        v = shard(v, "dp", None, None, None)
    elif mode == "seq":
        q = shard(q, "dp", "tp", None, None, None)
        qp = shard(qp, "dp", "tp")
        k = shard(k, "dp", None, None, None)
        v = shard(v, "dp", None, None, None)
    return q, k, v, qp


def chunked_causal_attention(
    q, k, v, q_positions, kv_positions, *, window=None, q_chunk=512, kv_chunk=512
):
    """Flash-style chunked causal attention (optionally sliding-window).

    q (B,S,G,R,hd); k/v (B,T,G,hd); positions (B,S)/(B,T) absolute.

    Full-causal: q stays RESIDENT (head-, rhead- or sequence-sharded per
    ``attention_sharding_mode``) and a single scan runs over KV chunks with
    an online softmax — no per-chunk resharding, so the only collective is
    the (at most) one KV gather implied by the chosen mode.

    Windowed: double scan (query chunks × the static band of KV chunks), so
    out-of-window work is never computed.
    """
    B, S, G, R, hd = q.shape
    T = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    assert S % q_chunk == 0 and T % kv_chunk == 0
    nq, nk = S // q_chunk, T // kv_chunk
    mode = attention_sharding_mode(G, R, S, window is not None)

    if window is None:
        q, k, v, q_positions = _constrain(q, k, v, q_positions, mode)
        kc = k.reshape(B, nk, kv_chunk, G, hd)
        vc = v.reshape(B, nk, kv_chunk, G, hd)
        kp = kv_positions.reshape(B, nk, kv_chunk)

        def kv_body(carry, xs_kv):
            m, l, acc = carry
            k_j, v_j, kp_j = xs_kv
            mask = (kp_j[:, None, :] <= q_positions[:, :, None])[:, None, :, None, :]
            # q (B,S,G,R,hd) resident; scores in XLA-natural dot order
            # (batch dims b,g; lhs free s,r; rhs free k) -> no transpose inserted
            s = jnp.einsum(
                "bsgrd,bkgd->bgsrk", q, k_j, preferred_element_type=jnp.float32
            ) * scale
            s = jnp.where(mask, s, NEG_INF)  # (B,G,S,R,Ck)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # p in bf16 for the PV matmul (flash-kernel practice): the max is
            # already subtracted so p in [0,1] — bf16 relative error ~1e-2 on a
            # sum of 512 terms, well inside attention tolerance
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bgsrk,bkgd->bgsrd", p.astype(v_j.dtype), v_j)
            acc_new = acc * alpha[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, G, S, R), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, S, R), jnp.float32)
        a0 = jnp.zeros((B, G, S, R, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body,
            (m0, l0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(kp, 1, 0)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,G,S,R,hd)
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B,S,G,R,hd)

    # ---- windowed: banded double scan --------------------------------------
    q, k, v, q_positions = _constrain(q, k, v, q_positions, mode if mode != "seq" else "local")
    qc = q.reshape(B, nq, q_chunk, G, R, hd)
    qp = q_positions.reshape(B, nq, q_chunk)
    kc = k.reshape(B, nk, kv_chunk, G, hd)
    vc = v.reshape(B, nk, kv_chunk, G, hd)
    kp = kv_positions.reshape(B, nk, kv_chunk)
    nband = min(nk, (window + q_chunk - 1) // kv_chunk + 2)

    def q_body(_, xs):
        q_i, qp_i, qi_idx = xs  # q_i (B,Cq,G,R,hd)
        if mode == "head":
            q_i = shard(q_i, "dp", None, "tp", None, None)
        elif mode == "rhead":
            q_i = shard(q_i, "dp", None, None, "tp", None)
        m0 = jnp.full((B, G, q_chunk, R), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, q_chunk, R), jnp.float32)
        a0 = jnp.zeros((B, G, q_chunk, R, hd), jnp.float32)
        start = jnp.clip(qi_idx - (nband - 1), 0, nk - nband)

        def kv_body(carry, off):
            j = start + off
            k_j = jax.lax.dynamic_index_in_dim(kc, j, axis=1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vc, j, axis=1, keepdims=False)
            kp_j = jax.lax.dynamic_index_in_dim(kp, j, axis=1, keepdims=False)
            if mode == "head":
                k_j = shard(k_j, "dp", None, "tp", None)
                v_j = shard(v_j, "dp", None, "tp", None)
            elif mode == "rhead":
                k_j = shard(k_j, "dp", None, None, None)
                v_j = shard(v_j, "dp", None, None, None)
            mask = (kp_j[:, None, :] <= qp_i[:, :, None]) & (
                kp_j[:, None, :] > qp_i[:, :, None] - window
            )
            mask = mask[:, None, :, None, :]  # (B,1,Cq,1,Ck)
            return _online_step(q_i, k_j, v_j, mask, carry, scale), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), jnp.arange(nband))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,G,Cq,R,hd)
        return None, jnp.moveaxis(out, 1, 2)  # (B,Cq,G,R,hd)

    _, outs = jax.lax.scan(
        q_body,
        None,
        (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(qp, 1, 0), jnp.arange(nq)),
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, G, R, hd)
    return out.astype(q.dtype)


def attention_train(p, x, positions, cfg: ArchConfig):
    """Full forward (train / prefill trunk): x (B,S,D) -> (B,S,D)."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    qpos = positions[-1] if cfg.pos_emb == "mrope" else positions  # temporal stream
    out = chunked_causal_attention(q, k, v, qpos, qpos, window=cfg.window)
    B, S = x.shape[:2]
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    out = shard(out.reshape(B, S, H * hd), "dp", None, "tp")
    return jnp.einsum("bse,ed->bsd", out, p["wo_attn"])


def attention_prefill(p, x, positions, cfg: ArchConfig, cache_len: int):
    """Prefill: returns (out, (k_cache, v_cache, cache_positions)) padded to cache_len."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    qpos = positions[-1] if cfg.pos_emb == "mrope" else positions
    out = chunked_causal_attention(q, k, v, qpos, qpos, window=cfg.window)
    B, S = x.shape[:2]
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    out = shard(out.reshape(B, S, H * hd), "dp", None, "tp")
    out = jnp.einsum("bse,ed->bsd", out, p["wo_attn"])
    if cfg.window is not None and cache_len == cfg.window and S >= cache_len:
        # ring-buffer cache: slot = pos % window must hold position pos
        k_keep, v_keep, p_keep = (t[:, -cache_len:] for t in (k, v, qpos))
        roll = S % cache_len
        k_c = jnp.roll(k_keep, roll, axis=1)
        v_c = jnp.roll(v_keep, roll, axis=1)
        p_c = jnp.roll(p_keep, roll, axis=1)
    else:
        pad = cache_len - S
        k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        p_c = jnp.pad(qpos, ((0, 0), (0, pad)), constant_values=-1)
    return out, {"k": k_c, "v": v_c, "pos": p_c}


def attention_decode(p, x, pos, cache, cfg: ArchConfig):
    """One-token decode. x (B,1,D); pos scalar int32; cache dict of
    k/v (B,T,G,hd) and pos (B,T). Returns (out, new_cache)."""
    B = x.shape[0]
    T = cache["k"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.pos_emb == "mrope":
        positions = jnp.broadcast_to(positions, (3, B, 1))
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    slot = pos % T  # ring buffer for windowed caches; plain index otherwise
    k_c = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v_c = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    p_c = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((B, 1), pos, jnp.int32), slot, axis=1
    )
    scale = 1.0 / np.sqrt(cfg.resolved_head_dim)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q, k_c, preferred_element_type=jnp.float32) * scale
    valid = (p_c >= 0) & (p_c <= pos)
    if cfg.window is not None:
        valid = valid & (p_c > pos - cfg.window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w.astype(v_c.dtype), v_c)
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    out = out.reshape(B, 1, H * hd)
    out = jnp.einsum("bse,ed->bsd", out, p["wo_attn"])
    return out, {"k": k_c, "v": v_c, "pos": p_c}
