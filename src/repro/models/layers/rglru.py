"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block structure (per Griffin):
    x -> in_proj_y (D -> W) -> conv1d(width 4) -> RG-LRU -> *
    x -> in_proj_gate (D -> W) -> GeLU          ----------/
    * -> out_proj (W -> D)

RG-LRU recurrence (elementwise over the W channels):
    r_t = sigmoid(x_t @ gate_a + b_a)        recurrence gate
    i_t = sigmoid(x_t @ gate_x + b_x)        input gate
    a_t = exp(c * r_t * log(sigmoid(Λ)))     = a^(c·r_t), a = sigmoid(Λ)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t²) * (i_t * x_t)

Prefill uses an associative scan over (a_t, b_t) pairs; decode is a single
fused step with O(1) state: (h, conv buffer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers.common import dense_init, zeros
from repro.sharding.rules import shard


def _width(cfg: ArchConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def init_rglru(key, cfg: ArchConfig):
    D, W = cfg.d_model, _width(cfg)
    cw = cfg.rglru.conv_width
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    # Λ init so that a = sigmoid(Λ) ∈ [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[0], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / cfg.rglru.c) / (1 - u ** (1.0 / cfg.rglru.c)))
    return {
        "in_proj_y": dense_init(ks[1], (D, W), dt),
        "in_proj_gate": dense_init(ks[2], (D, W), dt),
        "conv_w": dense_init(ks[3], (cw, W), dt, scale=1.0 / np.sqrt(cw)),
        "conv_b": zeros((W,), dt),
        "gate_a": dense_init(ks[4], (W, W), dt),
        "b_a": zeros((W,), jnp.float32),
        "gate_x": dense_init(ks[5], (W, W), dt),
        "b_x": zeros((W,), jnp.float32),
        "lam": lam,
        "out_proj": dense_init(
            jax.random.fold_in(key, 7), (W, D), dt, scale=0.02 / np.sqrt(2 * cfg.num_layers)
        ),
    }


def _causal_conv(x, w, b, state=None):
    """x (B,S,W); w (cw,W) depthwise causal conv.  state (B,cw-1,W) or None."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+cw-1, W)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(cw)) + b
    new_state = xp[:, -(cw - 1) :] if cw > 1 else jnp.zeros_like(pad)
    return out, new_state


def _gates(p, y, cfg: ArchConfig):
    """y (..., W) -> (a_t, beta_t·x gate) in fp32."""
    yf = y.astype(jnp.float32)
    r = jax.nn.sigmoid(yf @ p["gate_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(yf @ p["gate_x"].astype(jnp.float32) + p["b_x"])
    log_a = cfg.rglru.c * r * jax.nn.log_sigmoid(p["lam"])
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, beta * i * yf


def rglru_scan(p, x, cfg: ArchConfig, h0=None, conv_state=None):
    """Full-sequence pass. x (B,S,D) -> (out (B,S,D), (h_last, conv_state))."""
    y = jnp.einsum("bsd,dw->bsw", x, p["in_proj_y"])
    y = shard(y, "dp", None, "tp")
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["in_proj_gate"]))
    y, conv_state = _causal_conv(y, p["conv_w"], p["conv_b"], conv_state)
    a, b = _gates(p, y, cfg)
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    h_last = h[:, -1]
    out = (h.astype(x.dtype) * gate)
    out = shard(out, "dp", None, "tp")
    out = jnp.einsum("bsw,wd->bsd", out, p["out_proj"])
    return out, {"h": h_last, "conv": conv_state}


def rglru_decode(p, x, cache, cfg: ArchConfig):
    """One-step decode. x (B,1,D); cache {h (B,W) f32, conv (B,cw-1,W)}."""
    y = jnp.einsum("bsd,dw->bsw", x, p["in_proj_y"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["in_proj_gate"]))
    y, conv_state = _causal_conv(y, p["conv_w"], p["conv_b"], cache["conv"])
    a, b = _gates(p, y, cfg)  # (B,1,W)
    h = a[:, 0] * cache["h"] + b[:, 0]
    out = (h[:, None].astype(x.dtype) * gate)
    out = jnp.einsum("bsw,wd->bsd", out, p["out_proj"])
    return out, {"h": h, "conv": conv_state}
