"""Shared layer primitives: norms, MLPs, positional embeddings, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.sharding.rules import shard

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ArchConfig, dim: int):
    p = {"scale": ones((dim,), jnp.float32)}
    if cfg.norm == "layernorm" and cfg.norm_bias:
        p["bias"] = zeros((dim,), jnp.float32)
    return p


def apply_norm(p, x, cfg: ArchConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"]
        if "bias" in p:
            out = out + p["bias"]
    return out.astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head RMS norm (qk-norm); scale has shape (head_dim,)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs — swiglu | geglu | gelu
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ArchConfig, d_model: int | None = None, d_ff: int | None = None):
    D = d_model or cfg.d_model
    F = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wi": dense_init(k1, (D, F), dt), "wo_mlp": dense_init(k3, (F, D), dt)}
    if cfg.mlp in ("swiglu", "geglu"):
        p["wg"] = dense_init(k2, (D, F), dt)
    if cfg.mlp_bias:
        p["bi"] = zeros((F,), dt)
        p["bo"] = zeros((D,), dt)
    return p


def apply_mlp(p, x, cfg: ArchConfig):
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if cfg.mlp_bias:
        h = h + p["bi"]
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("...d,df->...f", x, p["wg"])
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(h) * jnp.einsum("...d,df->...f", x, p["wg"])
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "dp", None, "tp")
    out = jnp.einsum("...f,fd->...d", h, p["wo_mlp"])
    if cfg.mlp_bias:
        out = out + p["bo"]
    return out


# ---------------------------------------------------------------------------
# RoPE / M-RoPE / sinusoidal
# ---------------------------------------------------------------------------


def _rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))


def rope_cos_sin(positions, dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, dim/2) in fp32."""
    freqs = jnp.asarray(_rope_freqs(dim, theta))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, fraction: float = 1.0):
    """x (..., S, H, hd); cos/sin (..., S, d2). Rotates the first
    ``fraction`` of the head dim (pairwise split-half convention)."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    d2 = rot // 2
    x1, x2 = xr[..., :d2], xr[..., d2:]
    c = cos[..., :d2][..., :, None, :]  # broadcast over heads
    s = sin[..., :d2][..., :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * c - xf2 * s
    o2 = xf2 * c + xf1 * s
    out = jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


def mrope_cos_sin(positions3, dim: int, theta: float, sections: tuple[int, ...]):
    """M-RoPE (Qwen2-VL): positions3 (3, ..., S); sections sum to dim/2.

    Returns cos/sin (..., S, dim/2) assembled per-section from the three
    (temporal, height, width) position streams.
    """
    freqs = jnp.asarray(_rope_freqs(dim, theta))  # (dim/2,)
    ang = positions3[..., None].astype(jnp.float32) * freqs  # (3, ..., S, dim/2)
    pieces, off = [], 0
    for stream, sec in enumerate(sections):
        pieces.append(ang[stream, ..., off : off + sec])
        off += sec
    ang_sel = jnp.concatenate(pieces, axis=-1)  # (..., S, dim/2)
    return jnp.cos(ang_sel), jnp.sin(ang_sel)


def sinusoidal_pos_emb(positions, dim: int):
    """Classic transformer sinusoid table evaluated at ``positions``."""
    half = dim // 2
    freqs = jnp.asarray(1.0 / (10000.0 ** (np.arange(half, dtype=np.float32) / half)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def init_embeddings(key, cfg: ArchConfig):
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p = {"embedding": dense_init(k1, (cfg.padded_vocab, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.padded_vocab), dt, scale=0.02)
    return p


def embed_tokens(p, tokens, cfg: ArchConfig):
    x = jnp.take(p["embedding"], tokens, axis=0).astype(cfg.dtype)
    return shard(x, "dp", None, None)


def unembed(p, x, cfg: ArchConfig):
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["embedding"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["unembed"])
    return shard(logits.astype(jnp.float32), "dp", None, "tp")
