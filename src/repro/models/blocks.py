"""Decoder block assembly + scanned stacks.

Block kinds:
    attn      — pre-norm attention (GQA or MLA) + pre-norm MLP
    attn_moe  — pre-norm attention + pre-norm MoE
    rec       — pre-norm RG-LRU mixer + pre-norm MLP (Griffin)
    ssd       — pre-norm Mamba-2 SSD mixer (no MLP)

A model is a list of *segments*; each segment is a repeating unit of block
kinds scanned ``count`` times with stacked params (keeps HLO size and compile
time bounded at 512 devices — see DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import attention as attn_mod
from repro.models.layers import mla as mla_mod
from repro.models.layers import moe as moe_mod
from repro.models.layers import rglru as rglru_mod
from repro.models.layers import ssd as ssd_mod
from repro.models.layers.common import apply_mlp, apply_norm, init_mlp, init_norm


@dataclass(frozen=True)
class Segment:
    unit: tuple[str, ...]  # block kinds in one scan step
    count: int  # scan length
    base: int  # absolute index of the first layer in this segment


def build_segments(cfg: ArchConfig) -> list[Segment]:
    kinds = cfg.layer_kinds()
    segs: list[Segment] = []
    if len(cfg.pattern) > 1:
        unit_len = len(cfg.pattern)
        n_super = len(kinds) // unit_len
        if n_super > 0:
            segs.append(Segment(tuple(kinds[:unit_len]), n_super, 0))
        rest = kinds[n_super * unit_len :]
        base = n_super * unit_len
        i = 0
        while i < len(rest):
            j = i
            while j < len(rest) and rest[j] == rest[i]:
                j += 1
            segs.append(Segment((rest[i],), j - i, base + i))
            i = j
        return segs
    # single-kind pattern: group consecutive identical kinds (moe start split)
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        segs.append(Segment((kinds[i],), j - i, i))
        i = j
    return segs


# ---------------------------------------------------------------------------
# single-block init / apply
# ---------------------------------------------------------------------------


def init_block(key, kind: str, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg, cfg.d_model)}
    if kind in ("attn", "attn_moe"):
        if cfg.attention == "mla":
            p["mixer"] = mla_mod.init_mla(ks[0], cfg)
        else:
            p["mixer"] = attn_mod.init_attention(ks[0], cfg)
        p["norm2"] = init_norm(cfg, cfg.d_model)
        if kind == "attn_moe":
            p["moe"] = moe_mod.init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], cfg)
    elif kind == "rec":
        p["mixer"] = rglru_mod.init_rglru(ks[0], cfg)
        p["norm2"] = init_norm(cfg, cfg.d_model)
        p["mlp"] = init_mlp(ks[1], cfg)
    elif kind == "ssd":
        p["mixer"] = ssd_mod.init_ssd(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def block_train(p, kind, x, positions, token_ids, salt, cfg: ArchConfig):
    """-> (x, aux_loss)"""
    aux = jnp.float32(0.0)
    h = apply_norm(p["norm1"], x, cfg)
    if kind in ("attn", "attn_moe"):
        if cfg.attention == "mla":
            mix = mla_mod.mla_train(p["mixer"], h, positions, cfg)
        else:
            mix = attn_mod.attention_train(p["mixer"], h, positions, cfg)
        x = x + mix
        h2 = apply_norm(p["norm2"], x, cfg)
        if kind == "attn_moe":
            y, aux = moe_mod.apply_moe(p["moe"], h2, token_ids, salt, cfg)
        else:
            y = apply_mlp(p["mlp"], h2, cfg)
        x = x + y
    elif kind == "rec":
        mix, _ = rglru_mod.rglru_scan(p["mixer"], h, cfg)
        x = x + mix
        x = x + apply_mlp(p["mlp"], apply_norm(p["norm2"], x, cfg), cfg)
    elif kind == "ssd":
        mix, _ = ssd_mod.ssd_scan(p["mixer"], h, cfg)
        x = x + mix
    return x, aux


def block_prefill(p, kind, x, positions, token_ids, salt, cfg: ArchConfig, cache_len: int):
    """-> (x, cache, aux)"""
    aux = jnp.float32(0.0)
    h = apply_norm(p["norm1"], x, cfg)
    if kind in ("attn", "attn_moe"):
        if cfg.attention == "mla":
            mix, cache = mla_mod.mla_prefill(p["mixer"], h, positions, cfg, cache_len)
        else:
            mix, cache = attn_mod.attention_prefill(p["mixer"], h, positions, cfg, cache_len)
        x = x + mix
        h2 = apply_norm(p["norm2"], x, cfg)
        if kind == "attn_moe":
            y, aux = moe_mod.apply_moe(p["moe"], h2, token_ids, salt, cfg)
        else:
            y = apply_mlp(p["mlp"], h2, cfg)
        x = x + y
    elif kind == "rec":
        mix, cache = rglru_mod.rglru_scan(p["mixer"], h, cfg)
        x = x + mix
        x = x + apply_mlp(p["mlp"], apply_norm(p["norm2"], x, cfg), cfg)
    elif kind == "ssd":
        mix, cache = ssd_mod.ssd_scan(p["mixer"], h, cfg)
        x = x + mix
    return x, cache, aux


def block_decode(p, kind, x, pos, cache, token_ids, salt, cfg: ArchConfig):
    """x (B,1,D) -> (x, new_cache)"""
    h = apply_norm(p["norm1"], x, cfg)
    if kind in ("attn", "attn_moe"):
        if cfg.attention == "mla":
            mix, cache = mla_mod.mla_decode(p["mixer"], h, pos, cache, cfg)
        else:
            mix, cache = attn_mod.attention_decode(p["mixer"], h, pos, cache, cfg)
        x = x + mix
        h2 = apply_norm(p["norm2"], x, cfg)
        if kind == "attn_moe":
            y, _ = moe_mod.apply_moe(p["moe"], h2, token_ids, salt, cfg)
        else:
            y = apply_mlp(p["mlp"], h2, cfg)
        x = x + y
    elif kind == "rec":
        mix, cache = rglru_mod.rglru_decode(p["mixer"], h, cache, cfg)
        x = x + mix
        x = x + apply_mlp(p["mlp"], apply_norm(p["norm2"], x, cfg), cfg)
    elif kind == "ssd":
        mix, cache = ssd_mod.ssd_decode(p["mixer"], h, cache, cfg)
        x = x + mix
    return x, cache


# ---------------------------------------------------------------------------
# empty cache construction (decode entry from scratch / specs)
# ---------------------------------------------------------------------------


def init_block_cache(kind: str, cfg: ArchConfig, batch: int, cache_len: int):
    """Zero/empty cache pytree for one block (no leading layer dim)."""
    dt = jnp.dtype(cfg.dtype)
    if kind in ("attn", "attn_moe"):
        if cfg.attention == "mla":
            m = cfg.mla
            return {
                "ckv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dt),
                "kr": jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dt),
                "pos": jnp.full((batch, cache_len), -1, jnp.int32),
            }
        G, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        return {
            "k": jnp.zeros((batch, cache_len, G, hd), dt),
            "v": jnp.zeros((batch, cache_len, G, hd), dt),
            "pos": jnp.full((batch, cache_len), -1, jnp.int32),
        }
    if kind == "rec":
        W = cfg.rglru.lru_width or cfg.d_model
        cw = cfg.rglru.conv_width
        return {
            "h": jnp.zeros((batch, W), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, W), dt),
        }
    if kind == "ssd":
        d_inner, H, G, N, hd = ssd_mod.dims(cfg)
        conv_dim = d_inner + 2 * G * N
        return {
            "state": jnp.zeros((batch, H, hd, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, conv_dim), dt),
        }
    raise ValueError(kind)


def block_cache_len(kind: str, cfg: ArchConfig, max_len: int) -> int:
    if kind in ("attn", "attn_moe") and cfg.window is not None:
        return min(cfg.window, max_len)
    return max_len
