"""Model: init / train-loss / prefill / decode over scanned segment stacks."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import blocks as B
from repro.models.layers.common import (
    apply_norm,
    dense_init,
    embed_tokens,
    init_embeddings,
    init_norm,
    sinusoidal_pos_emb,
    unembed,
)
from repro.sharding.rules import constrain_params, shard

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig):
    segs = B.build_segments(cfg)
    keys = jax.random.split(key, len(segs) + 3)
    params = {"embed": init_embeddings(keys[0], cfg), "final_norm": init_norm(cfg, cfg.d_model)}
    for i, seg in enumerate(segs):
        seg_p = {}
        for j, kind in enumerate(seg.unit):
            ks = jax.random.split(jax.random.fold_in(keys[i + 1], j), seg.count)
            seg_p[f"sub{j}"] = jax.vmap(lambda k, kind=kind: B.init_block(k, kind, cfg))(ks)
        params[f"seg{i}"] = seg_p
    if cfg.mtp_depth > 0:
        km = keys[-1]
        params["mtp"] = {
            "norm_h": init_norm(cfg, cfg.d_model),
            "norm_e": init_norm(cfg, cfg.d_model),
            "in_proj_mtp": dense_init(
                jax.random.fold_in(km, 0), (2 * cfg.d_model, cfg.d_model), jnp.dtype(cfg.param_dtype)
            ),
            "block": B.init_block(jax.random.fold_in(km, 1), "attn_moe" if cfg.moe else "attn", cfg),
            "final_norm": init_norm(cfg, cfg.d_model),
        }
    return params


def count_params(params) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(params)))


def active_params(cfg: ArchConfig, params) -> int:
    """Parameters touched per token (MoE: top_k + shared experts only)."""
    total = count_params(params)
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = cfg.d_model * m.d_ff_expert * 3
    n_moe_layers = sum(1 for k in cfg.layer_kinds() if k == "attn_moe")
    if cfg.mtp_depth > 0:
        n_moe_layers += 1
    inactive = n_moe_layers * (m.num_experts - m.top_k) * per_expert
    return total - inactive


# ---------------------------------------------------------------------------
# embedding of inputs
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg: ArchConfig):
    """-> x (B,S,D), positions, token_ids (B,S)."""
    if cfg.input_mode == "tokens":
        tokens = batch["tokens"]
        x = embed_tokens(params["embed"], tokens, cfg)
        Bsz, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (Bsz, S))
        token_ids = tokens
    else:
        x = batch["embeds"].astype(cfg.dtype)
        Bsz, S = x.shape[:2]
        token_ids = batch.get("tokens", batch.get("targets", jnp.zeros((Bsz, S), jnp.int32)))
        if cfg.input_mode == "embeds_mrope":
            positions = batch["positions"]
        else:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (Bsz, S))
    if cfg.pos_emb == "sinusoidal":
        pos1 = positions if positions.ndim == 2 else positions[-1]
        x = x + sinusoidal_pos_emb(pos1, cfg.d_model).astype(x.dtype)
    return shard(x, "dp", None, None), positions, token_ids


# ---------------------------------------------------------------------------
# segment scan machinery
# ---------------------------------------------------------------------------


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _scan_segment_train(seg_p, seg: B.Segment, x, positions, token_ids, cfg: ArchConfig):
    seg_p = constrain_params(seg_p)

    def unit_fn(x, params_t, step):
        aux = jnp.float32(0.0)
        for j, kind in enumerate(seg.unit):
            salt = seg.base + step * len(seg.unit) + j
            x, a = B.block_train(params_t[f"sub{j}"], kind, x, positions, token_ids, salt, cfg)
            aux = aux + a
        return x, aux

    unit = _remat(unit_fn, cfg)

    if seg.count == 1:
        params_t = jax.tree.map(lambda a: a[0], seg_p)
        return unit(x, params_t, 0)

    def body(carry, xs):
        x, aux_tot = carry
        params_t, step = xs
        x, aux = unit(x, params_t, step)
        return (x, aux_tot + aux), None

    (x, aux_tot), _ = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (seg_p, jnp.arange(seg.count))
    )
    return x, aux_tot


def trunk_train(params, batch, cfg: ArchConfig):
    """-> (hidden (B,S,D) after final norm, aux_loss, token embedding aux)."""
    x, positions, token_ids = _embed_inputs(params, batch, cfg)
    aux_tot = jnp.float32(0.0)
    for i, seg in enumerate(B.build_segments(cfg)):
        x, aux = _scan_segment_train(params[f"seg{i}"], seg, x, positions, token_ids, cfg)
        aux_tot = aux_tot + aux
    return apply_norm(params["final_norm"], x, cfg), aux_tot, positions, token_ids


# ---------------------------------------------------------------------------
# loss (chunked CE to avoid materialising (B,S,V) fp32 logits)
# ---------------------------------------------------------------------------


def chunked_ce(params, hidden, targets, cfg: ArchConfig, chunk: int = 512):
    """Mean CE over valid (target >= 0) tokens; vocab logits per seq-chunk."""
    Bsz, S, D = hidden.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk

    def chunk_loss(h_c, t_c):
        logits = unembed(params["embed"], h_c, cfg)  # fp32 (B,c,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(t_c, 0)[..., None], axis=-1)[..., 0]
        valid = (t_c >= 0).astype(jnp.float32)
        return jnp.sum((lse - ll) * valid), jnp.sum(valid)

    chunk_loss = _remat(chunk_loss, cfg)

    def body(carry, xs):
        tot, cnt = carry
        h_c, t_c = xs
        l, c = chunk_loss(h_c, t_c)
        return (tot + l, cnt + c), None

    h_r = jnp.moveaxis(hidden.reshape(Bsz, nc, chunk, D), 1, 0)
    t_r = jnp.moveaxis(targets.reshape(Bsz, nc, chunk), 1, 0)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (h_r, t_r))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg: ArchConfig):
    hidden, aux, positions, token_ids = trunk_train(params, batch, cfg)
    targets = batch["targets"]
    ce = chunked_ce(params, hidden, targets, cfg)
    loss = ce + aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_depth > 0 and "mtp" in params:
        mtp = params["mtp"]
        emb_next = jnp.pad(
            embed_tokens(params["embed"], batch["tokens"], cfg)[:, 1:], ((0, 0), (0, 1), (0, 0))
        )
        h_in = jnp.concatenate(
            [apply_norm(mtp["norm_h"], hidden, cfg), apply_norm(mtp["norm_e"], emb_next, cfg)],
            axis=-1,
        )
        h_in = jnp.einsum("bsd,dm->bsm", h_in, mtp["in_proj_mtp"])
        kind = "attn_moe" if cfg.moe else "attn"
        h_mtp, _ = B.block_train(mtp["block"], kind, h_in, positions, token_ids, 9999, cfg)
        h_mtp = apply_norm(mtp["final_norm"], h_mtp, cfg)
        t_mtp = jnp.pad(targets[:, 1:], ((0, 0), (0, 1)), constant_values=-1)
        mtp_ce = chunked_ce(params, h_mtp, t_mtp, cfg)
        loss = loss + 0.3 * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    cache = {"cur": jnp.zeros((), jnp.int32)}
    for i, seg in enumerate(B.build_segments(cfg)):
        seg_c = {}
        for j, kind in enumerate(seg.unit):
            c1 = B.init_block_cache(kind, cfg, batch, B.block_cache_len(kind, cfg, max_len))
            seg_c[f"sub{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (seg.count,) + a.shape), c1
            )
        cache[f"seg{i}"] = seg_c
    return cache


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def prefill(params, batch, cfg: ArchConfig, max_len: int):
    """-> (cache, last-token logits (B, V))."""
    x, positions, token_ids = _embed_inputs(params, batch, cfg)
    Bsz, S = x.shape[:2]
    cache = {"cur": jnp.full((), S, jnp.int32)}
    for i, seg in enumerate(B.build_segments(cfg)):
        seg_p = constrain_params(params[f"seg{i}"])

        def unit_fn(x, params_t, step, seg=seg):
            caches = {}
            for j, kind in enumerate(seg.unit):
                salt = seg.base + step * len(seg.unit) + j
                x, c, _ = B.block_prefill(
                    params_t[f"sub{j}"], kind, x, positions, token_ids, salt, cfg,
                    B.block_cache_len(kind, cfg, max_len),
                )
                caches[f"sub{j}"] = c
            return x, caches

        unit = _remat(unit_fn, cfg)

        if seg.count == 1:
            params_t = jax.tree.map(lambda a: a[0], seg_p)
            x, caches = unit(x, params_t, 0)
            cache[f"seg{i}"] = jax.tree.map(lambda a: a[None], caches)
        else:

            def body(x, xs):
                params_t, step = xs
                x, caches = unit(x, params_t, step)
                return x, caches

            x, caches = jax.lax.scan(body, x, (seg_p, jnp.arange(seg.count)))
            cache[f"seg{i}"] = caches
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], x[:, -1:], cfg)[:, 0]
    return cache, logits


def decode_step(params, cache, batch, cfg: ArchConfig):
    """One token for the whole batch. -> (new_cache, logits (B, V))."""
    pos = cache["cur"]
    if cfg.input_mode == "tokens":
        tokens = batch["tokens"]  # (B, 1)
        x = embed_tokens(params["embed"], tokens, cfg)
        token_ids = tokens
    else:
        x = batch["embeds"].astype(cfg.dtype)
        token_ids = jnp.zeros(x.shape[:2], jnp.int32)
    if cfg.pos_emb == "sinusoidal":
        Bsz = x.shape[0]
        pos1 = jnp.full((Bsz, 1), pos, jnp.int32)
        x = x + sinusoidal_pos_emb(pos1, cfg.d_model).astype(x.dtype)

    new_cache = {"cur": pos + 1}
    for i, seg in enumerate(B.build_segments(cfg)):
        seg_p = params[f"seg{i}"]  # no carry anchor: decode graphs are small and
        # the constraint copies cost more than they save here (§Perf)
        seg_c = cache[f"seg{i}"]

        def unit_fn(x, params_t, caches_t, step, seg=seg):
            new_c = {}
            for j, kind in enumerate(seg.unit):
                salt = seg.base + step * len(seg.unit) + j
                x, c = B.block_decode(
                    params_t[f"sub{j}"], kind, x, pos, caches_t[f"sub{j}"], token_ids, salt, cfg
                )
                new_c[f"sub{j}"] = c
            return x, new_c

        if seg.count == 1:
            params_t = jax.tree.map(lambda a: a[0], seg_p)
            caches_t = jax.tree.map(lambda a: a[0], seg_c)
            x, nc = unit_fn(x, params_t, caches_t, 0)
            new_cache[f"seg{i}"] = jax.tree.map(lambda a: a[None], nc)
        else:

            def body(x, xs):
                params_t, caches_t, step = xs
                x, nc = unit_fn(x, params_t, caches_t, step)
                return x, nc

            x, ncs = jax.lax.scan(body, x, (seg_p, seg_c, jnp.arange(seg.count)))
            new_cache[f"seg{i}"] = ncs
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], x, cfg)[:, 0]
    return new_cache, logits


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation; dry-run contract)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Returns the batch pytree of ShapeDtypeStructs for the given shape."""
    Bsz = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    f = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.input_mode == "tokens":
            return {"tokens": f((Bsz, S), jnp.int32), "targets": f((Bsz, S), jnp.int32)}
        batch = {
            "embeds": f((Bsz, S, cfg.d_model), jnp.bfloat16),
            "targets": f((Bsz, S), jnp.int32),
        }
        if cfg.input_mode == "embeds_mrope":
            batch["positions"] = f((3, Bsz, S), jnp.int32)
        return batch
    if shape.kind == "prefill":
        if cfg.input_mode == "tokens":
            return {"tokens": f((Bsz, S), jnp.int32)}
        batch = {"embeds": f((Bsz, S, cfg.d_model), jnp.bfloat16)}
        if cfg.input_mode == "embeds_mrope":
            batch["positions"] = f((3, Bsz, S), jnp.int32)
        return batch
    # decode
    if cfg.input_mode == "tokens":
        return {"tokens": f((Bsz, 1), jnp.int32)}
    return {"embeds": f((Bsz, 1, cfg.d_model), jnp.bfloat16)}


def cache_specs(cfg: ArchConfig, shape: ShapeConfig):
    return jax.eval_shape(lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
