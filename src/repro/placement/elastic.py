"""Elastic-scaling planners: minimal-migration plans for framework assets.

Three consumers:
* expert-parallel groups — expert -> device placement when the EP group grows
  or shrinks (MoE elastic scaling);
* data hosts — file-shard -> host placement (pipeline rescale, stragglers);
* failure handling — arbitrary node loss via the Memento wrapper.

Everything here is host-side control plane (pure python ints); the device
mesh consumes the resulting placements as sharding metadata.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import MementoWrapper, make
from repro.placement.assignment import Assignment, MovementPlan


@dataclass
class ExpertMigration:
    """Expert -> device migration plan between EP group sizes."""

    plan: MovementPlan
    old_devices: int
    new_devices: int
    num_experts: int

    @property
    def bytes_moved(self) -> int:  # filled by caller with per-expert bytes
        return len(self.plan.moves)


def plan_expert_migration(
    num_experts: int, old_devices: int, new_devices: int, engine: str = "binomial"
) -> ExpertMigration:
    """Place experts on devices consistently; return the minimal migration.

    Monotonicity guarantees that on scale-up only experts moving TO new
    devices migrate, and on scale-down only experts FROM removed devices.
    """
    a = Assignment(list(range(num_experts)), old_devices, engine)
    plan = a.resize(new_devices)
    return ExpertMigration(plan, old_devices, new_devices, num_experts)


def plan_shard_reassignment(
    num_shards: int, old_hosts: int, new_hosts: int, engine: str = "binomial"
) -> MovementPlan:
    """Data file-shard -> host reassignment on pipeline rescale."""
    a = Assignment(list(range(num_shards)), old_hosts, engine)
    return a.resize(new_hosts)


class FailureDomain:
    """Arbitrary-failure placement built on the Memento-style wrapper.

    Used by the serving router and the checkpoint manager: lookups always
    return an alive node; failures/recoveries move only the affected keys.

    ``chain_bits=32`` (with a u32 engine such as ``binomial32``) makes the
    whole lookup+remap path u32 — the word size of the batched device
    datapath (``repro.serving.batch_router.BatchRouter``), which mirrors
    this domain's state on device bit-exactly.

    ``resolve="table"`` switches failure resolution from the rejection
    chain to the constant-time replacement table (DESIGN.md §7) — the
    semantics the batched device datapath implements.
    """

    def __init__(
        self,
        n: int,
        engine: str = "binomial",
        chain_bits: int = 64,
        omega: int | None = None,
        max_chain: int = 4096,
        resolve: str = "chain",
        allow_empty: bool = False,
    ):
        def factory(m: int):
            eng = make(engine, m)
            if omega is not None:
                if not hasattr(eng, "omega"):
                    raise ValueError(f"engine '{engine}' does not take omega")
                eng.omega = omega
            return eng

        self._eng = MementoWrapper(
            factory,
            n,
            max_chain=max_chain,
            chain_bits=chain_bits,
            resolve=resolve,
            allow_empty=allow_empty,
        )

    @property
    def alive_count(self) -> int:
        return self._eng.size

    @property
    def total_count(self) -> int:
        """Total slot space of the base engine (alive + removed)."""
        return self._eng.n_total

    @property
    def removed(self) -> frozenset[int]:
        return frozenset(self._eng.removed)

    def first_alive(self) -> int:
        return self._eng.first_alive()

    @property
    def replacement_table(self):
        """The ``ReplacementTable`` (``resolve="table"`` domains only) —
        the host truth the device copies are uploaded from."""
        if self._eng.table is None:
            raise ValueError("domain was not constructed with resolve='table'")
        return self._eng.table

    def locate(self, key: int) -> int:
        return self._eng.get_bucket(key)

    def fail(self, node: int) -> None:
        self._eng.remove_bucket(node)

    def recover(self, node: int) -> None:
        self._eng.restore_bucket(node)

    def scale_up(self) -> int:
        return self._eng.add_bucket()

    def scale_down(self) -> int:
        return self._eng.remove_bucket()
