"""R-way replicated store placement on top of any ``BULK_ENGINES`` engine
(DESIGN.md §13).

The paper's actual use case is data placement: "distributed storage systems
rely on consistent hashing for scalable and fault-tolerant data
partitioning."  A router maps a key to exactly ONE shard, so a single
failure makes the key's data unreachable until the divert reroutes it —
and the rerouted shard does not *have* the data.  This module turns the
router into a placement system: every key lives on **R distinct alive
shards**, failures degrade reads to the surviving replica set, and
membership changes produce an explicit, bounded migration plan instead of
silent rerouting.

Three layers:

* ``route_replicas_impl`` — the device pass.  R salted key families (the
  same broadcast construction ``models/layers/moe.py`` uses for multi-K
  expert routing) go through ONE fused engine route, then a deterministic
  distinct-resolution pass breaks inter-family collisions: a per-lane used-
  shard bitmask (``n_words`` u32 words, the same select-cascade shape as
  the divert's membership test) detects a duplicate, a re-salt hash picks a
  fresh position in the table's alive prefix, and up to ``max_resalt``
  linear probes (+1 with conditional wrap — no division) settle it.  The
  default bound of ``r`` probes makes distinctness DETERMINISTIC whenever
  ``n_alive > column`` (column ``j`` probes ``j+1`` distinct alive-prefix
  positions, at most ``j`` of which are taken), so every key gets exactly
  ``min(r, n_alive)`` distinct alive shards.  While-free, affine in ``r``,
  u32-closed, zero transfers — certified as ``placement/route_replicas``.

* ``StorePlacement`` — the host control plane: guarded placement with typed
  degradation (``n_alive == 0`` stays ``FleetUnavailableError``;
  ``n_alive < r`` is mode ``"degraded"`` or a ``PlacementDegradedError``
  under ``strict=True``; a too-tight explicit ``max_resalt`` surfaces as
  ``PlacementExhaustedError``, never a silent duplicate), a registry of
  placed keys with their current *holders* (where the data physically is —
  which lags the target placement until repair completes), degraded reads
  from the surviving holder set, and ``plan_migration`` — the old-vs-new
  placement diff as ONE device pass producing per-shard move lists.

* ``PlacementRepairer`` (``repro.serving.lifecycle.manager``) — the repair
  scheduler that drives holders back to the target placement in bounded-
  bandwidth batches after every journaled membership event.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binomial_jax import GOLDEN32, mix32, mulhi32
from repro.core.bulk import FleetState, PlacementSpec, RouterSpec
from repro.kernels import ops
from repro.placement.assignment import MovementPlan
from repro.serving.lifecycle.errors import (
    MODE_DEGRADED,
    MODE_NORMAL,
    FleetUnavailableError,
    PlacementDegradedError,
    PlacementExhaustedError,
)

#: salt seeding the re-salt chain — distinct from every family salt so the
#: resolution probes decorrelate from the base placements they collide with
RESALT_SALT = np.uint32(0x7F4A7C15)

#: sentinel holder id: "this replica column holds no copy anywhere"
NO_HOLDER = -1


def family_salts(r: int) -> np.ndarray:
    """The ``r`` static per-replica salts — the MoE layer's per-k schedule
    ``(k * 7919 + 1) * GOLDEN32`` (``models/layers/moe.py``), so replica
    family 0 is the plain router placement."""
    base = (np.arange(r, dtype=np.uint64) * 7919 + 1).astype(np.uint32)
    return base * np.uint32(GOLDEN32)


# ---------------------------------------------------------------------------
# the device pass
# ---------------------------------------------------------------------------


def route_replicas_impl(
    keys: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    *,
    r: int,
    omega: int,
    n_words: int,
    max_resalt: int,
    route,
) -> tuple[jax.Array, jax.Array]:
    """Place every key on ``r`` distinct alive shards — ONE traced pass.

    keys         (N,) u32 key space (any int dtype; truncated like the
                 scalar oracle)
    packed_mask / table / state — the ``FleetState`` leaves (operand
                 contract of the fused engines; ``n_alive >= 1`` is the
                 caller-guarded precondition, as for ``route_bulk``)
    r            replication factor (static)
    max_resalt   static probe bound per column (``PlacementSpec``
                 resolves ``None`` to ``r``, the distinctness guarantee)
    route        the engine's fused jnp route
                 ``(keys, packed, table, state, omega=, n_words=)``

    Returns ``(replicas, exhausted)``: ``replicas`` is ``(N, r)`` int32,
    every entry an ALIVE shard; column ``j`` is distinct from columns
    ``< j`` whenever ``n_alive > j`` and the probe bound sufficed, and a
    duplicate of an earlier column otherwise (degraded replication — the
    fleet is smaller than ``j+1``).  ``exhausted`` is ``(N,)`` bool, set
    for keys where distinctness was achievable (``n_alive > j``) but
    ``max_resalt`` probes ran out — impossible at the default bound.

    The whole pass is one fused-route call (eqn count independent of
    ``r`` — all families route as one broadcast batch) plus O(r * (n_words
    + max_resalt)) elementwise resolution ops: while-free and affine in
    ``r`` at a fixed probe bound, which is exactly what the certifier's
    ``placement/route_replicas`` target pins.
    """
    keys_u32 = keys.reshape(-1).astype(jnp.uint32)
    n_alive = state[1].astype(jnp.uint32)
    slots = table[0].astype(jnp.uint32)

    # all r salted families through the fused engine as ONE broadcast batch
    fam = mix32(keys_u32[:, None] ^ family_salts(r))  # (N, r) u32
    base = route(
        fam, packed_mask, table, state, omega=omega, n_words=n_words
    ).astype(jnp.uint32)

    # per-lane used-shard bitmask: n_words u32 words, set/tested via the
    # same select cascade the divert uses for the removed mask
    used = [jnp.zeros_like(keys_u32) for _ in range(n_words)]

    def is_used(b):
        w = b >> np.uint32(5)
        word = jnp.zeros_like(b)
        for s in range(n_words):
            word = jnp.where(w == np.uint32(s), used[s], word)
        return ((word >> (b & np.uint32(31))) & np.uint32(1)) != 0

    def mark_used(b):
        w = b >> np.uint32(5)
        bit = jnp.uint32(1) << (b & np.uint32(31))
        for s in range(n_words):
            used[s] = jnp.where(w == np.uint32(s), used[s] | bit, used[s])

    cols = []
    exhausted = jnp.zeros(keys_u32.shape, bool)
    for j in range(r):
        b = base[:, j]
        if j > 0:
            coll = is_used(b)
            # re-salt into the alive-prefix POSITION space (every position
            # < n_alive holds an alive shard by the table's construction),
            # then probe linearly with a conditional-subtract wrap: the
            # probes visit min(max_resalt, n_alive) DISTINCT positions, of
            # which at most j are taken, so max_resalt >= j+1 guarantees a
            # distinct alive shard whenever n_alive > j
            q = mulhi32(mix32(fam[:, j] ^ RESALT_SALT), n_alive)
            for _probe in range(max_resalt):
                cand = slots.at[q].get(mode="promise_in_bounds")
                free = coll & ~is_used(cand)
                b = jnp.where(free, cand, b)
                coll = coll & ~free
                q = q + np.uint32(1)
                q = jnp.where(q >= n_alive, q - n_alive, q)
            # n_alive <= j: a duplicate is the DEFINED degraded answer
            # (j+1 distinct shards cannot exist), not an exhaustion
            exhausted = exhausted | (coll & (np.uint32(j) < n_alive))
        mark_used(b)
        cols.append(b)

    replicas = jnp.stack(cols, axis=-1).astype(jnp.int32)
    return replicas.reshape(*keys.shape, r), exhausted.reshape(keys.shape)


@functools.partial(
    jax.jit, static_argnames=("r", "omega", "n_words", "max_resalt", "route")
)
def _route_replicas_jit(keys, packed, table, state, *, r, omega, n_words,
                        max_resalt, route):
    return route_replicas_impl(
        keys, packed, table, state, r=r, omega=omega, n_words=n_words,
        max_resalt=max_resalt, route=route,
    )


def placement_diff_impl(
    keys, old_packed, old_table, old_state, new_packed, new_table, new_state,
    *, r, omega, n_words, max_resalt, route,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Old-vs-new placement diff — the bulk migration plan, ONE traced pass.

    Routes the keys under BOTH fleet states and marks every (key, column)
    pair whose new shard holds no copy under the old placement:
    ``moved[i, j] = new[i, j] not in old[i, :]`` — membership, not
    positional inequality, because a replica that merely swapped columns
    needs no data transfer.  Returns ``(old, new, moved, exhausted_new)``.
    """
    old, _ = route_replicas_impl(
        keys, old_packed, old_table, old_state, r=r, omega=omega,
        n_words=n_words, max_resalt=max_resalt, route=route,
    )
    new, exhausted = route_replicas_impl(
        keys, new_packed, new_table, new_state, r=r, omega=omega,
        n_words=n_words, max_resalt=max_resalt, route=route,
    )
    moved = jnp.ones(new.shape, bool)
    for k in range(r):
        moved = moved & (new != old[..., k : k + 1])
    return old, new, moved, exhausted


@functools.partial(
    jax.jit, static_argnames=("r", "omega", "n_words", "max_resalt", "route")
)
def _placement_diff_jit(keys, op, ot, os_, np_, nt, ns, *, r, omega, n_words,
                        max_resalt, route):
    return placement_diff_impl(
        keys, op, ot, os_, np_, nt, ns, r=r, omega=omega, n_words=n_words,
        max_resalt=max_resalt, route=route,
    )


# ---------------------------------------------------------------------------
# host plans
# ---------------------------------------------------------------------------


class PlacedBatch(NamedTuple):
    """A placed key batch + the epoch/mode it was computed under (the
    placement tier's mirror of the lifecycle ``RoutedBatch``)."""

    replicas: object  #: (N, r) int32 alive shard ids, distinct per row up
    #: to min(r, n_alive)
    epoch: int
    mode: str  #: MODE_NORMAL, or MODE_DEGRADED when n_alive < r
    n_distinct: int  #: min(r, n_alive) at placement time


@dataclasses.dataclass
class MigrationPlan:
    """The materialised old-vs-new placement diff of one membership change.

    keys   (M,) u32; old/new (M, r) int32 placements; moved (M, r) bool —
    True where ``new[i, j]`` holds no copy under ``old[i, :]`` (a genuine
    data transfer, computed device-side by ``placement_diff_impl``).
    """

    keys: np.ndarray
    old: np.ndarray
    new: np.ndarray
    moved: np.ndarray
    epoch: int = 0

    @property
    def total_pairs(self) -> int:
        return int(self.moved.size)

    @property
    def moved_pairs(self) -> int:
        return int(self.moved.sum())

    @property
    def moved_fraction(self) -> float:
        return self.moved_pairs / max(self.total_pairs, 1)

    def per_shard_moves(self) -> dict[int, list[tuple[int, int]]]:
        """Destination shard -> [(key, source shard)] move lists — the
        worker-facing transfer schedule.  The source is the same-column old
        holder (a shard that had a copy under the old placement; the
        repairer re-picks a *reachable* source at execution time)."""
        out: dict[int, list[tuple[int, int]]] = {}
        for i, j in zip(*np.nonzero(self.moved)):
            out.setdefault(int(self.new[i, j]), []).append(
                (int(self.keys[i]), int(self.old[i, j]))
            )
        return out

    def as_movement_plan(self) -> MovementPlan:
        """The host ``MovementPlan`` view over the device diff (one source
        of truth for movement accounting — ``moved_fraction`` here counts
        transfer pairs, not positional changes)."""
        r = self.new.shape[1]
        return MovementPlan.from_diff(
            np.repeat(self.keys, r),
            self.old.reshape(-1),
            self.new.reshape(-1),
            moved=self.moved.reshape(-1),
        )


# ---------------------------------------------------------------------------
# the placement control plane
# ---------------------------------------------------------------------------


class StorePlacement:
    """R-way replicated placement over a ``BatchRouter``'s fleet.

    Wraps (composition, like ``LifecycleManager``) any router exposing the
    fleet surface — ``spec``, ``domain``, ``_fleet_host``/``_fleet_dev``,
    ``routing_epoch`` — and adds the placement tier: guarded R-way
    ``place``, a registry of placed keys with their physical *holders*
    (which lag the target placement until repair completes), degraded
    reads, and the one-device-pass migration diff.
    """

    def __init__(self, router, r: int = 3, *, max_resalt: int | None = None,
                 strict: bool = False):
        self.router = router
        self.spec = PlacementSpec(router=router.spec, r=r, max_resalt=max_resalt)
        #: strict=True turns an n_alive < r placement into a typed
        #: PlacementDegradedError instead of a degraded-mode batch
        self.strict = strict
        self._keys = np.zeros((0,), np.uint32)
        self._holders = np.zeros((0, r), np.int64)
        #: fleet snapshot the registered holders were last synced against —
        #: the implicit "old" side of plan_migration()
        self._synced_fleet = self._fleet_snapshot()

    # -- fleet state access --------------------------------------------------
    @property
    def r(self) -> int:
        return self.spec.r

    @property
    def epoch(self) -> int:
        return self.router.routing_epoch

    @property
    def n_alive(self) -> int:
        return self.router.domain.alive_count

    def _fleet_snapshot(self) -> FleetState:
        h = self.router._fleet_host
        return FleetState(
            h.packed.copy(), h.table.copy(), h.state.copy(), h.capacity
        )

    def _fleet_dev(self) -> FleetState:
        """The router's pinned device twin (flushing coalesced events)."""
        self.router._check_routable()
        return self.router._fleet_dev

    def _alive_mask(self) -> np.ndarray:
        """(capacity,) bool — slot id alive right now."""
        dom = self.router.domain
        alive = np.zeros(self.router.spec.capacity, bool)
        alive[: dom.total_count] = True
        for s in dom.removed:
            alive[s] = False
        return alive

    # -- guarded placement ---------------------------------------------------
    def _guard(self) -> str:
        n = self.n_alive
        if n == 0:
            raise FleetUnavailableError(epoch=self.epoch)
        if n < self.spec.r:
            if self.strict:
                raise PlacementDegradedError(n, self.spec.r, epoch=self.epoch)
            return MODE_DEGRADED
        return MODE_NORMAL

    def place_keys(self, keys) -> tuple[jax.Array, jax.Array]:
        """Raw device placement: ``(replicas (N, r) i32, exhausted (N,)
        bool)``, no degradation typing (the expert path; ``place`` wraps
        it).  Routability (``n_alive >= 1``) is still enforced."""
        fleet = self._fleet_dev()
        keys_u32 = self.router._coerce_keys(keys)
        return ops.route_replicas_bulk(keys_u32, fleet, self.spec)

    def place(self, keys) -> PlacedBatch:
        """Place keys on ``r`` distinct alive shards, typed and epoch-
        stamped: ``FleetUnavailableError`` at ``n_alive == 0``; fewer alive
        shards than ``r`` degrades (every key on all ``n_alive`` distinct
        shards) or raises under ``strict=True``; an exhausted re-salt chain
        (explicit ``max_resalt`` below the default only) raises
        ``PlacementExhaustedError``."""
        mode = self._guard()
        replicas, exhausted = self.place_keys(keys)
        exhausted = np.asarray(exhausted)
        if exhausted.any():
            raise PlacementExhaustedError(
                int(exhausted.sum()), self.spec.resolved_max_resalt,
                epoch=self.epoch,
            )
        return PlacedBatch(
            np.asarray(replicas), self.epoch, mode,
            min(self.spec.r, self.n_alive),
        )

    # -- the registered store ------------------------------------------------
    @property
    def keys(self) -> np.ndarray:
        """(M,) u32 registered keys."""
        return self._keys

    @property
    def holders(self) -> np.ndarray:
        """(M, r) int64 physical holders per registered key — where copies
        actually are, which lags the target placement until repair
        completes.  ``NO_HOLDER`` marks a column with no copy anywhere."""
        return self._holders

    def register(self, keys) -> PlacedBatch:
        """Place new keys and record them as stored: their holders start at
        the current target placement (writes go to the placement)."""
        batch = self.place(keys)
        keys_u32 = np.asarray(
            np.ascontiguousarray(keys, dtype=np.uint64).astype(np.uint32)
        ).reshape(-1)
        self._keys = np.concatenate([self._keys, keys_u32])
        self._holders = np.concatenate(
            [self._holders, np.asarray(batch.replicas, np.int64)], axis=0
        )
        self._synced_fleet = self._fleet_snapshot()
        return batch

    def reachable_mask(self) -> np.ndarray:
        """(M, r) bool — holder column is a DISTINCT, alive copy (duplicate
        holder entries count once; dead/retired/lost columns are False)."""
        alive = self._alive_mask()
        h = self._holders
        valid = (h >= 0) & (h < alive.size)
        live = np.zeros(h.shape, bool)
        live[valid] = alive[h[valid]]
        # first-occurrence filter: a duplicated shard id is one copy
        first = np.ones(h.shape, bool)
        for j in range(1, h.shape[1]):
            for k in range(j):
                first[:, j] &= h[:, j] != h[:, k]
        return live & first

    def reachable_counts(self) -> np.ndarray:
        """(M,) distinct alive copies per registered key — the durability
        metric the chaos harness asserts on (>= 1 while ``n_alive >= 1``;
        == min(r, n_alive) once repair quiesces)."""
        return self.reachable_mask().sum(axis=1).astype(np.int64)

    def read(self, key_index: int) -> tuple[np.ndarray, str]:
        """Degraded read: the distinct alive holders of one registered key,
        plus the mode they represent.  ``FleetUnavailableError`` when no
        copy is reachable (fleet empty, or — durability lost — every
        holder dead)."""
        if self.n_alive == 0:
            raise FleetUnavailableError(epoch=self.epoch)
        mask = self.reachable_mask()[key_index]
        found = self._holders[key_index][mask]
        if found.size == 0:
            raise FleetUnavailableError(
                f"key {int(self._keys[key_index])} has no reachable replica "
                f"(all holders failed)", epoch=self.epoch,
            )
        mode = MODE_NORMAL if found.size >= min(self.spec.r, self.n_alive) \
            else MODE_DEGRADED
        return found.astype(np.int64), mode

    # -- migration + repair enumeration --------------------------------------
    def plan_migration(self, old_fleet: FleetState | None = None) -> MigrationPlan:
        """Diff the registered keys' placement between ``old_fleet`` (default:
        the snapshot captured at the last register/sync) and the CURRENT
        fleet — ONE device pass over both placements (DESIGN.md §13)."""
        old = old_fleet if old_fleet is not None else self._synced_fleet
        new = self._fleet_dev()
        keys_u32 = self._keys
        o, n, moved, _ = ops.placement_diff_bulk(keys_u32, old, new, self.spec)
        return MigrationPlan(
            keys=keys_u32,
            old=np.asarray(o),
            new=np.asarray(n),
            moved=np.asarray(moved),
            epoch=self.epoch,
        )

    def sync_targets(self) -> list[tuple[int, int, int]]:
        """Recompute the target placement under the current fleet, realign
        the holder rows to it, and return the genuinely missing
        ``(key_index, column, dst_shard)`` repair triples.

        Realignment is pure bookkeeping: a holder whose shard appears in
        the target row moves to that column; surviving *stale* copies (old
        shards no longer in the target) keep occupying the to-be-repaired
        columns so degraded reads still reach them until the repair copy
        overwrites the slot.  Retired slot ids (``>= n_total``: LIFO
        scale-down wiped them) are invalidated to ``NO_HOLDER`` first.
        """
        if self._keys.size == 0 or self.n_alive == 0:
            return []
        replicas, _ = self.place_keys(self._keys)
        target = np.asarray(replicas, np.int64)
        total = self.router.domain.total_count
        h = self._holders
        h[h >= total] = NO_HOLDER
        needed: list[tuple[int, int, int]] = []
        r = self.spec.r
        for i in range(h.shape[0]):
            remaining = list(h[i])
            aligned: list[int | None] = [None] * r
            for j in range(r):
                t = int(target[i, j])
                if t in remaining:
                    remaining.remove(t)
                    aligned[j] = t
            missing = [j for j in range(r) if aligned[j] is None]
            for j, stale in zip(missing, remaining):
                aligned[j] = int(stale)
            for j in missing:
                needed.append((i, j, int(target[i, j])))
            h[i] = aligned
        self._synced_fleet = self._fleet_snapshot()
        return needed

    def repair_source(self, key_index: int) -> int:
        """A reachable copy to repair from: the first distinct alive holder
        of the key, or ``NO_HOLDER`` if durability is already lost."""
        mask = self.reachable_mask()[key_index]
        found = self._holders[key_index][mask]
        return int(found[0]) if found.size else NO_HOLDER

    def complete_repair(self, key_index: int, column: int, dst: int) -> None:
        """Record one finished repair copy: the column now holds ``dst``
        (any stale copy previously occupying it is garbage-collected)."""
        self._holders[key_index, column] = dst
