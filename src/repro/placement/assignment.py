"""Key -> node assignment tables and movement accounting.

The control-plane face of the paper: given a set of logical keys (data
shards, experts, checkpoint shards, sessions) and a cluster size, produce the
assignment and — on resize — the minimal movement plan, with stats that the
tests check against the paper's guarantees (movement fraction ~ delta/n).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable, Sequence

from repro.core import make


@dataclass(frozen=True)
class Move:
    key: int
    src: int
    dst: int


@dataclass
class MovementPlan:
    moves: list[Move]
    total_keys: int

    @property
    def moved_fraction(self) -> float:
        return len(self.moves) / max(self.total_keys, 1)

    def destinations(self) -> set[int]:
        return {m.dst for m in self.moves}

    def sources(self) -> set[int]:
        return {m.src for m in self.moves}


class Assignment:
    """Consistent assignment of a fixed key universe onto n nodes."""

    def __init__(self, keys: Sequence[int], n: int, engine: str = "binomial"):
        self.keys = list(keys)
        self.engine_name = engine
        self.engine = make(engine, n)

    @property
    def n(self) -> int:
        return self.engine.size

    def table(self) -> dict[int, int]:
        return {k: self.engine.get_bucket(k) for k in self.keys}

    def by_node(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {b: [] for b in range(self.n)}
        for k in self.keys:
            out[self.engine.get_bucket(k)].append(k)
        return out

    def resize(self, new_n: int) -> MovementPlan:
        """Scale to new_n (LIFO adds/removes), returning the movement plan."""
        before = self.table()
        while self.engine.size < new_n:
            self.engine.add_bucket()
        while self.engine.size > new_n:
            self.engine.remove_bucket()
        after = self.table()
        moves = [Move(k, before[k], after[k]) for k in self.keys if before[k] != after[k]]
        return MovementPlan(moves, len(self.keys))

    def load(self) -> list[int]:
        return [len(v) for v in self.by_node().values()]
