"""Key -> node assignment tables and movement accounting.

The control-plane face of the paper: given a set of logical keys (data
shards, experts, checkpoint shards, sessions) and a cluster size, produce the
assignment and — on resize — the minimal movement plan, with stats that the
tests check against the paper's guarantees (movement fraction ~ delta/n).

``MovementPlan`` is the ONE movement-accounting type: canonically a thin
view over a before/after placement diff (``from_diff`` — host arrays here,
the device migration diff in ``repro.placement.store``), with the eager
``Move`` list materialised lazily on demand.  The pre-diff eager
constructor ``MovementPlan(moves, total_keys)`` remains as a deprecation
shim (warn-once, like the pre-spec shims in ``repro.kernels.ops``).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core import make

#: deprecation shims that already warned this process (warn once, not per
#: plan; tests reset this to assert the warning fires)
_warned: set[str] = set()


def _warn_once(name: str, hint: str) -> None:
    if name in _warned:
        return
    _warned.add(name)
    warnings.warn(
        f"{name} is deprecated; {hint}", DeprecationWarning, stacklevel=3
    )


@dataclass(frozen=True)
class Move:
    key: int
    src: int
    dst: int


class MovementPlan:
    """Movement accounting over a before/after placement diff.

    Build with ``MovementPlan.from_diff(keys, before, after)`` — arrays in,
    vectorised stats out; ``moves`` materialises the eager ``Move`` list
    only when asked.  ``moved`` defaults to positional inequality (the
    1-way assignment semantics); the R-way device diff passes its
    membership-based transfer mask instead, so both tiers share one
    accounting type.
    """

    def __init__(self, moves=None, total_keys: int | None = None, *,
                 keys=None, before=None, after=None, moved=None):
        if before is not None:
            self._keys = np.asarray(keys)
            self._before = np.asarray(before)
            self._after = np.asarray(after)
            if moved is None:
                moved = self._before != self._after
            self._moved = np.asarray(moved, bool)
            self._moves: list[Move] | None = None
            self.total_keys = int(self._keys.size)
        else:
            _warn_once(
                "MovementPlan(moves, total_keys)",
                "build plans from the placement diff: "
                "MovementPlan.from_diff(keys, before, after)",
            )
            self._moves = list(moves or [])
            self.total_keys = int(total_keys or 0)
            self._keys = self._before = self._after = self._moved = None

    @classmethod
    def from_diff(cls, keys, before, after, moved=None) -> "MovementPlan":
        """The canonical constructor: per-key placements before/after (any
        array-likes of equal length), optional explicit transfer mask."""
        return cls(keys=keys, before=before, after=after, moved=moved)

    # -- accounting ----------------------------------------------------------
    @property
    def moved_count(self) -> int:
        if self._moved is not None:
            return int(self._moved.sum())
        return len(self._moves)

    @property
    def moves(self) -> list[Move]:
        if self._moves is None:
            idx = np.nonzero(self._moved)[0]
            self._moves = [
                Move(int(self._keys[i]), int(self._before[i]),
                     int(self._after[i]))
                for i in idx
            ]
        return self._moves

    @property
    def moved_fraction(self) -> float:
        return self.moved_count / max(self.total_keys, 1)

    def destinations(self) -> set[int]:
        if self._moved is not None:
            return set(np.unique(self._after[self._moved]).tolist())
        return {m.dst for m in self._moves}

    def sources(self) -> set[int]:
        if self._moved is not None:
            return set(np.unique(self._before[self._moved]).tolist())
        return {m.src for m in self._moves}


class Assignment:
    """Consistent assignment of a fixed key universe onto n nodes."""

    def __init__(self, keys: Sequence[int], n: int, engine: str = "binomial"):
        self.keys = list(keys)
        self.engine_name = engine
        self.engine = make(engine, n)

    @property
    def n(self) -> int:
        return self.engine.size

    def table(self) -> dict[int, int]:
        return {k: self.engine.get_bucket(k) for k in self.keys}

    def by_node(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {b: [] for b in range(self.n)}
        for k in self.keys:
            out[self.engine.get_bucket(k)].append(k)
        return out

    def resize(self, new_n: int) -> MovementPlan:
        """Scale to new_n (LIFO adds/removes), returning the movement plan."""
        before = self.table()
        while self.engine.size < new_n:
            self.engine.add_bucket()
        while self.engine.size > new_n:
            self.engine.remove_bucket()
        after = self.table()
        return MovementPlan.from_diff(
            np.asarray(self.keys, dtype=np.uint64),
            np.fromiter((before[k] for k in self.keys), np.int64,
                        count=len(self.keys)),
            np.fromiter((after[k] for k in self.keys), np.int64,
                        count=len(self.keys)),
        )

    def load(self) -> list[int]:
        return [len(v) for v in self.by_node().values()]
