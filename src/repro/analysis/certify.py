"""Layer 1 — the jaxpr certifier: machine-check the O(1) contract of every
registered device engine (DESIGN.md §11).

For each ``BULK_ENGINES`` entry the certifier traces the fused route, the
fused u64-id ingest and the plain dynamic-n lookup — BOTH the pure-jnp
mirrors and the Pallas kernels (the kernel body jaxpr is reached by tracing
the ``interpret=True`` lowering: the ``pallas_call`` equation carries the
body as a sub-jaxpr, so one recursive walk covers wrapper and kernel) — to
closed jaxprs and enforces, per target:

* ``while-free``       — no ``while`` primitive anywhere (incl. ``pjit`` /
  ``cond`` / ``scan`` / ``pallas_call`` sub-jaxprs).  ``scan`` is fine (its
  trip count is static); ``while_loop`` is the primitive whose trip count
  *can* depend on key data — the pre-PR-3 storm-cliff bug class.  Waivable
  via ``repro.analysis.markers.constant_time_waiver`` for paper-faithful
  baselines; the waiver reason lands in the report.
* ``unroll-affine``    — the jaxpr equation count is exactly affine in the
  ω unroll bound: tracing at ω, ω+1, ω+2 must yield equal first
  differences.  This proves the unroll depth is exactly ω (a hidden
  O(ω²) blow-up or a loop keyed on anything else breaks linearity) and
  records the per-iteration op cost; an absolute equation budget bounds
  the constant term.
* ``dtype-closed``     — every equation output dtype stays in the engine's
  allowed set (u32-limb arithmetic: uint32 / int32 / float32 / bool).
  Traced under ``enable_x64`` so a genuine f64 leak or a weak-type
  promotion to 64-bit surfaces instead of being silently clamped to 32-bit
  by the default config.
* ``callback-free``    — no host callbacks (``pure_callback`` /
  ``io_callback`` / ``debug_callback`` / ``debug_print``): a callback is a
  device->host sync, i.e. unbounded latency on the hot path.
* ``transfer-count``   — exactly the declared number of ``device_put``
  equations (0 for every engine: fleet state is pinned at event time, the
  hot path must never re-upload).

The certifier is pure tracing — no compilation, no execution — so it runs
in seconds and gates CI on every push.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Iterator, Optional

import jax
import jax.core as jax_core
import numpy as np

from repro.analysis.markers import waivers_of
from repro.analysis.report import (
    FAIL,
    PASS,
    SKIPPED,
    WAIVED,
    CheckResult,
    Report,
    TargetReport,
)

#: primitives that are host callbacks (device->host syncs) in disguise
_CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback", "debug_print"}

#: primitives that move data between host and device
_TRANSFER_PRIMS = {"device_put"}


@dataclasses.dataclass(frozen=True)
class EngineContract:
    """The declared invariants one engine is certified against.

    omega             the ω unroll bound certification traces at (shared
                      with ``RouterSpec.omega`` — the serving default)
    capacity          fleet slot-space bound used for the trace operands
    batch             number of keys in the traced batch (shape only —
                      values never matter to a trace)
    block_rows        Pallas tiling for the kernel-path trace (small, so
                      the select cascades stay cheap to trace)
    allowed_dtypes    closure set for ``dtype-closed``
    device_transfers  declared ``device_put`` count (0 = hot path never
                      re-uploads state)
    max_eqns          absolute equation budget at ω (catches constant-term
                      blow-ups that affinity alone would pass)
    """

    omega: int = 16
    capacity: int = 64
    batch: int = 2048
    block_rows: int = 8
    allowed_dtypes: frozenset = frozenset({"uint32", "int32", "float32", "bool"})
    device_transfers: int = 0
    max_eqns: int = 8192


#: per-engine overrides of the default contract (empty = every engine is
#: held to the same strict default; a future engine with, say, a declared
#: f32 LUT upload would override ``device_transfers`` HERE, visibly)
CONTRACTS: dict[str, EngineContract] = {}


def contract_for(engine: str) -> EngineContract:
    return CONTRACTS.get(engine, EngineContract())


# ---------------------------------------------------------------------------
# recursive jaxpr walking
# ---------------------------------------------------------------------------


def _sub_jaxprs(params: dict) -> Iterator[jax_core.Jaxpr]:
    """Yield every sub-jaxpr found in an equation's params — covers pjit
    (``jaxpr``), cond (``branches``), while (``cond_jaxpr``/``body_jaxpr``),
    scan (``jaxpr``), pallas_call (``jaxpr`` — the kernel body) and any
    future primitive that follows the same convention."""
    for value in params.values():
        items = value if isinstance(value, (tuple, list)) else (value,)
        for item in items:
            if isinstance(item, jax_core.ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, jax_core.Jaxpr):
                yield item


def iter_eqns(jaxpr: jax_core.Jaxpr) -> Iterator[jax_core.JaxprEqn]:
    """Depth-first walk over every equation, recursing into sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _eqn_dtypes(eqn: jax_core.JaxprEqn) -> Iterator[str]:
    for var in eqn.outvars:
        aval = getattr(var, "aval", None)
        dtype = getattr(aval, "dtype", None)
        if dtype is not None:
            yield str(dtype)


# ---------------------------------------------------------------------------
# per-target certification
# ---------------------------------------------------------------------------


def certify_callable(
    engine: str,
    target: str,
    tracer: Callable[[int], jax_core.ClosedJaxpr],
    *,
    contract: Optional[EngineContract] = None,
    waivers: Optional[dict] = None,
    check_affine: bool = True,
) -> TargetReport:
    """Certify one traced callable against the contract.

    ``tracer(omega)`` must return the closed jaxpr of the target traced at
    that unroll bound (``certify_engine`` builds these per datapath; tests
    hand in fixture engines the same way).  ``waivers`` maps invariant name
    -> allowlist reason (see ``repro.analysis.markers``).
    """
    contract = contract or EngineContract()
    waivers = waivers or {}
    report = TargetReport(engine=engine, target=target)

    with jax.experimental.enable_x64(True):
        base = tracer(contract.omega)
        eqns = list(iter_eqns(base.jaxpr))
        counts = [len(eqns)]
        if check_affine:
            for extra in (1, 2):
                counts.append(
                    sum(1 for _ in iter_eqns(tracer(contract.omega + extra).jaxpr))
                )

    # -- while-free ---------------------------------------------------------
    whiles = [e for e in eqns if e.primitive.name == "while"]
    if not whiles:
        report.checks.append(
            CheckResult("while-free", PASS, "no while primitives in the trace")
        )
    elif "while-free" in waivers:
        report.checks.append(
            CheckResult(
                "while-free",
                WAIVED,
                f"{len(whiles)} while primitive(s), explicitly allowlisted",
                waiver=waivers["while-free"],
            )
        )
    else:
        report.checks.append(
            CheckResult(
                "while-free",
                FAIL,
                f"{len(whiles)} while primitive(s) — trip count may depend "
                "on key data (the storm-cliff bug class); unroll the loop "
                "to a static bound or add an explicit constant_time_waiver",
            )
        )

    # -- unroll-affine ------------------------------------------------------
    if not check_affine:
        report.checks.append(
            CheckResult(
                "unroll-affine", SKIPPED, "target is not ω-parameterised"
            )
        )
    else:
        d1 = counts[1] - counts[0]
        d2 = counts[2] - counts[1]
        if d1 != d2 or d1 < 0:
            report.checks.append(
                CheckResult(
                    "unroll-affine",
                    FAIL,
                    f"eqn counts {counts} at ω={contract.omega}..+2 are not "
                    f"affine (first differences {d1} vs {d2}) — unroll depth "
                    "is not exactly ω",
                )
            )
        elif counts[0] > contract.max_eqns:
            report.checks.append(
                CheckResult(
                    "unroll-affine",
                    FAIL,
                    f"{counts[0]} eqns at ω={contract.omega} exceeds the "
                    f"{contract.max_eqns}-eqn budget",
                )
            )
        else:
            report.checks.append(
                CheckResult(
                    "unroll-affine",
                    PASS,
                    f"{counts[0]} eqns at ω={contract.omega}, exactly "
                    f"+{d1}/iteration",
                )
            )

    # -- dtype-closed -------------------------------------------------------
    bad = sorted(
        {
            f"{e.primitive.name}->{d}"
            for e in eqns
            for d in _eqn_dtypes(e)
            if d not in contract.allowed_dtypes
        }
    )
    if bad:
        report.checks.append(
            CheckResult(
                "dtype-closed",
                FAIL,
                f"dtypes outside {sorted(contract.allowed_dtypes)}: "
                + ", ".join(bad[:8]),
            )
        )
    else:
        report.checks.append(
            CheckResult(
                "dtype-closed",
                PASS,
                f"all outputs in {sorted(contract.allowed_dtypes)} "
                "(traced under x64)",
            )
        )

    # -- callback-free ------------------------------------------------------
    callbacks = sorted(
        {
            e.primitive.name
            for e in eqns
            if e.primitive.name in _CALLBACK_PRIMS
            or "callback" in e.primitive.name
        }
    )
    report.checks.append(
        CheckResult("callback-free", FAIL, f"host callbacks: {callbacks}")
        if callbacks
        else CheckResult("callback-free", PASS, "no host callbacks")
    )

    # -- transfer-count -----------------------------------------------------
    transfers = sum(1 for e in eqns if e.primitive.name in _TRANSFER_PRIMS)
    if transfers != contract.device_transfers:
        report.checks.append(
            CheckResult(
                "transfer-count",
                FAIL,
                f"{transfers} device_put eqns, contract declares "
                f"{contract.device_transfers}",
            )
        )
    else:
        report.checks.append(
            CheckResult(
                "transfer-count",
                PASS,
                f"exactly {contract.device_transfers} device transfers",
            )
        )
    return report


# ---------------------------------------------------------------------------
# engine target construction
# ---------------------------------------------------------------------------


def _fleet_operands(contract: EngineContract):
    """Representative fixed-shape fleet operands (values are irrelevant to
    a trace; shapes/dtypes mirror ``FleetState.pack`` for the capacity)."""
    from repro.core.memento_jax import pack_removed_mask, table_width

    packed = pack_removed_mask([], contract.capacity)
    table = np.zeros((1, table_width(contract.capacity)), np.int32)
    state = np.array(
        [min(8, contract.capacity), min(8, contract.capacity)], np.uint32
    )
    keys = np.zeros((contract.batch,), np.uint32)
    return keys, packed, table, state


def engine_targets(
    engine_name: str, contract: EngineContract
) -> list[tuple[str, Callable[[int], jax_core.ClosedJaxpr], dict]]:
    """(target label, tracer, waivers) for every datapath of one engine —
    jnp mirrors and Pallas kernels (via ``interpret=True`` lowering)."""
    from repro.core.memento_jax import mask_words
    from repro.core.registry import make_bulk

    eng = make_bulk(engine_name)
    keys, packed, table, state = _fleet_operands(contract)
    lo = hi = keys
    n = np.uint32(min(8, contract.capacity))
    n_words = mask_words(contract.capacity)
    n_slots = contract.capacity
    rows = contract.block_rows

    targets = []

    def add(label, fn, tracer):
        if fn is not None:
            targets.append((label, tracer, waivers_of(fn)))

    add(
        "route/jnp",
        eng.route,
        lambda om: jax.make_jaxpr(
            lambda k, p, t, s: eng.route(k, p, t, s, omega=om, n_words=n_words)
        )(keys, packed, table, state),
    )
    add(
        "ingest/jnp",
        eng.ingest,
        lambda om: jax.make_jaxpr(
            lambda a, b, p, t, s: eng.ingest(a, b, p, t, s, omega=om, n_words=n_words)
        )(lo, hi, packed, table, state),
    )
    add(
        "lookup_dyn/jnp",
        eng.lookup_dyn,
        lambda om: jax.make_jaxpr(lambda k, m: eng.lookup_dyn(k, m, omega=om))(keys, n),
    )
    add(
        "route/pallas",
        eng.route_pallas,
        lambda om: jax.make_jaxpr(
            lambda k, p, t, s: eng.route_pallas(
                k, p, t, s, n_words, n_slots, omega=om, block_rows=rows,
                interpret=True,
            )
        )(keys, packed, table, state),
    )
    add(
        "ingest/pallas",
        eng.ingest_pallas,
        lambda om: jax.make_jaxpr(
            lambda a, b, p, t, s: eng.ingest_pallas(
                a, b, p, t, s, n_words, n_slots, omega=om, block_rows=rows,
                interpret=True,
            )
        )(lo, hi, packed, table, state),
    )
    add(
        "lookup_dyn/pallas",
        eng.lookup_dyn_pallas,
        lambda om: jax.make_jaxpr(
            lambda k, m: eng.lookup_dyn_pallas(
                k, m, omega=om, block_rows=rows, interpret=True
            )
        )(keys, n),
    )
    return targets


def certify_engine(
    engine_name: str, contract: Optional[EngineContract] = None
) -> list[TargetReport]:
    """Certify every datapath of one registered ``BULK_ENGINES`` entry."""
    contract = contract or contract_for(engine_name)
    return [
        certify_callable(
            engine_name, label, tracer, contract=contract, waivers=waivers
        )
        for label, tracer, waivers in engine_targets(engine_name, contract)
    ]


def certify_chain_baseline(
    contract: Optional[EngineContract] = None,
) -> TargetReport:
    """Certify the paper-faithful chain-mode remap — the one datapath that
    legitimately carries a ``lax.while_loop``, passing only through its
    explicit ``constant_time_waiver`` (the allowlist mechanism's live
    demonstration: remove the marker and the gate goes red)."""
    from repro.core.memento_jax import memento_remap

    contract = contract or EngineContract()
    keys = np.zeros((contract.batch,), np.uint32)
    buckets = np.zeros((contract.batch,), np.int32)
    mask = np.zeros((contract.capacity,), bool)

    def tracer(_om):
        return jax.make_jaxpr(
            lambda k, b, m, n, f: memento_remap(k, b, m, n, f)
        )(keys, buckets, mask, np.uint32(8), np.uint32(0))

    return certify_callable(
        "binomial",
        "chain/memento_remap",
        tracer,
        contract=contract,
        waivers=waivers_of(memento_remap),
        check_affine=False,  # the chain is while-bounded, not ω-unrolled
    )


def certify_lifecycle_route(
    engine_name: str, contract: Optional[EngineContract] = None
) -> TargetReport:
    """Certify the route entry EXACTLY as the serving tier dispatches it:
    a ``LifecycleManager``-wrapped ``BatchRouter`` with an active storm
    state (tombstones + coalesced recovery already applied).

    The lifecycle layer (detector poll, journaling, coalescing, degradation
    guards) is host-side control plane by design — this target proves it:
    the traced device computation reached through the wrapped router must
    satisfy the same invariants as the bare engine datapaths (no
    data-dependent loops, no host callbacks, zero hot-path uploads), i.e.
    the robustness machinery adds NOTHING to the device hot path.
    """
    contract = contract or contract_for(engine_name)
    keys = np.zeros((contract.batch,), np.uint32)

    def tracer(om):
        from repro.core.bulk import RouterSpec
        from repro.serving.batch_router import BatchRouter
        from repro.serving.lifecycle import LifecycleManager

        spec = RouterSpec(engine=engine_name, capacity=contract.capacity, omega=om)
        router = BatchRouter(8, spec)
        mgr = LifecycleManager(router)
        # a real storm, applied through the manager: tombstones present,
        # one coalesced device refresh behind us — the state the divert
        # path actually runs against
        mgr.apply([("fail", 1), ("fail", 3), ("recover", 1), ("fail", 5)])
        return jax.make_jaxpr(mgr.router.route_keys)(keys)

    return certify_callable(engine_name, "route/lifecycle", tracer, contract=contract)


def certify_streaming_route(
    engine_name: str, contract: Optional[EngineContract] = None
) -> TargetReport:
    """Certify the streaming tier's dispatch EXACTLY as a closed
    micro-batch runs it (DESIGN.md §14).

    The ``serving/streaming`` front end wraps the route in admission
    control, micro-batching, deadline shedding, circuit breakers and a
    placement-repair tick — ALL host-side control plane.  This target
    assembles the full streaming stack (manager + placement store +
    repairer + front end), drives real micro-batches through it into a
    storm state with a non-empty repair backlog, then traces the device
    computation one more closed batch would dispatch: it must be
    while-free, callback-free and transfer-free just like the bare engine
    — the whole streaming apparatus adds NOTHING to the device hot path.
    """
    contract = contract or contract_for(engine_name)
    keys = np.zeros((contract.batch,), np.uint32)

    def tracer(om):
        from repro.core.bulk import RouterSpec
        from repro.placement.store import StorePlacement
        from repro.serving.batch_router import BatchRouter
        from repro.serving.lifecycle import LifecycleManager, PlacementRepairer
        from repro.serving.streaming import (
            StreamConfig,
            StreamingFrontEnd,
            StreamRequest,
            VirtualClockUs,
        )

        spec = RouterSpec(engine=engine_name, capacity=contract.capacity, omega=om)
        router = BatchRouter(8, spec)
        mgr = LifecycleManager(router)
        store = StorePlacement(router, r=3)
        store.register(np.arange(64, dtype=np.uint32) * 2654435761)
        PlacementRepairer(store, mgr, budget_per_tick=4)
        clock = VirtualClockUs()
        fe = StreamingFrontEnd(
            mgr,
            store=store,
            config=StreamConfig(max_batch=8, service_bound_us=10_000),
            clock=clock,
        )
        # a real storm plus live streamed batches: the repairer backlog is
        # non-empty and the breaker board is armed — the state an in-flight
        # stream actually dispatches against
        mgr.apply([("fail", 1), ("fail", 3), ("recover", 1), ("fail", 5)])
        for i in range(8):
            fe.submit(
                StreamRequest(key=i * 40_503, deadline_us=clock.now_us() + 50_000)
            )
        clock.advance_us(2_000)
        fe.pump()
        fe.drain()
        return jax.make_jaxpr(mgr.router.route_keys)(keys)

    return certify_callable(
        engine_name, "serving/streaming", tracer, contract=contract
    )


#: the placement pass certifies affinity in the replication factor R, not ω
#: (ω is a fixed inner parameter of the one fused-route call): ``omega``
#: here is the BASE R the tracer varies — R, R+1, R+2
PLACEMENT_CONTRACT = EngineContract(omega=3)

#: fixed re-salt probe bound for the placement trace — FIXED while R varies,
#: so each additional replica column adds an identical op count (the serving
#: default ``max_resalt=None`` resolves to r, which would make the per-column
#: cost itself grow with r and is certified per-spec by the same tracer)
PLACEMENT_TRACE_MAX_RESALT = 4


def certify_placement_route(
    engine_name: str, contract: Optional[EngineContract] = None
) -> TargetReport:
    """Certify the R-way replicated placement pass (DESIGN.md §13).

    ``placement/route_replicas`` is the device pass of
    ``repro.placement.store``: ONE fused engine route over all R salted key
    families plus the bounded distinct-resolution probes.  The tracer
    varies the REPLICATION factor (R, R+1, R+2 — the contract's ``omega``
    field repurposed as the base R) at a fixed ω and a fixed probe bound:
    while-free, affine in R (each extra replica column adds exactly the
    same resolution op count; the broadcast route call is shape-independent
    in eqn count), u32-closed, zero transfers — the O(1)-per-replica
    contract, machine-checked like every other engine path.
    """
    contract = contract or PLACEMENT_CONTRACT
    from repro.core.memento_jax import mask_words
    from repro.core.registry import make_bulk
    from repro.placement.store import route_replicas_impl

    eng = make_bulk(engine_name)
    keys, packed, table, state = _fleet_operands(contract)
    n_words = mask_words(contract.capacity)

    def tracer(r):
        return jax.make_jaxpr(
            lambda k, p, t, s: route_replicas_impl(
                k, p, t, s, r=r, omega=16, n_words=n_words,
                max_resalt=PLACEMENT_TRACE_MAX_RESALT, route=eng.route,
            )
        )(keys, packed, table, state)

    return certify_callable(
        engine_name, "placement/route_replicas", tracer, contract=contract
    )


def certify_load_pass(
    engine_name: str, contract: Optional[EngineContract] = None
) -> TargetReport:
    """Certify the observability-instrumented route (DESIGN.md §15).

    ``observability/load_pass`` is the device pass of
    ``repro.observability.load``: the engine's fused route plus ONE
    in-bounds bincount accumulating per-shard key counts — the
    instrumented dispatch ``BatchRouter`` runs with a ``LoadMonitor``
    attached.  Traced at the monitor's default bulk-batch config
    (``LoadConfig().sample_shift``) — the exact path (shift 0) is a
    strict sub-graph of it (drop the stride slice).  Same contract as
    the bare route: while-free, ω-affine (the accumulate adds a constant
    term only), dtype-closed, callback-free, zero transfers — proving
    the load accumulator costs one fused reduction and adds NOTHING
    host-visible to the hot path.
    """
    contract = contract or contract_for(engine_name)
    from repro.core.memento_jax import mask_words
    from repro.core.registry import make_bulk
    from repro.observability.load import LoadConfig, route_with_load_impl

    eng = make_bulk(engine_name)
    keys, packed, table, state = _fleet_operands(contract)
    counts = np.zeros((contract.capacity,), np.uint32)
    n_words = mask_words(contract.capacity)
    shift = LoadConfig().sample_shift

    def tracer(om):
        return jax.make_jaxpr(
            lambda k, p, t, s, c: route_with_load_impl(
                k, p, t, s, c, omega=om, n_words=n_words, route=eng.route,
                sample_shift=shift,
            )
        )(keys, packed, table, state, counts)

    return certify_callable(
        engine_name, "observability/load_pass", tracer, contract=contract
    )


def certify_all(
    engines: Optional[Iterable[str]] = None, *, include_chain_baseline: bool = True
) -> Report:
    """Layer-1 certification of every (or the named) registered engine."""
    from repro.core.registry import BULK_ENGINES

    names = list(engines) if engines is not None else sorted(BULK_ENGINES)
    report = Report()
    for name in names:
        report.targets.extend(certify_engine(name))
        report.targets.append(certify_lifecycle_route(name))
        report.targets.append(certify_placement_route(name))
        report.targets.append(certify_streaming_route(name))
        report.targets.append(certify_load_pass(name))
    if include_chain_baseline:
        report.targets.append(certify_chain_baseline())
    return report
