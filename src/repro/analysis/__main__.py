"""CLI for the constant-time certifier: ``python -m repro.analysis``.

Runs the three static-analysis layers (jaxpr certifier, AST lint, HLO
gate) and exits nonzero on any unwaived failure — this is the command the
CI ``static-analysis`` job runs on every push, and the one to run locally
before touching a kernel body (see DESIGN.md §11):

    PYTHONPATH=src python -m repro.analysis --all-engines
    PYTHONPATH=src python -m repro.analysis --engine binomial --skip-hlo
    PYTHONPATH=src python -m repro.analysis --all-engines --report ct.json
"""
from __future__ import annotations

import argparse
import pathlib
import sys


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="machine-check the O(1) contract of every fused engine",
    )
    group = parser.add_mutually_exclusive_group(required=True)
    group.add_argument(
        "--all-engines",
        action="store_true",
        help="certify every registered BULK_ENGINES entry",
    )
    group.add_argument(
        "--engine",
        action="append",
        metavar="NAME",
        help="certify only this engine (repeatable)",
    )
    parser.add_argument(
        "--skip-lint", action="store_true", help="skip the AST lint layer"
    )
    parser.add_argument(
        "--skip-hlo",
        action="store_true",
        help="skip the HLO gate layer (the only layer that compiles)",
    )
    parser.add_argument(
        "--no-chain-baseline",
        action="store_true",
        help="skip the chain-mode memento_remap waiver demonstration target",
    )
    parser.add_argument(
        "--report",
        metavar="PATH",
        help="write the structured JSON report here (the CI artifact)",
    )
    parser.add_argument(
        "--json",
        action="store_true",
        help="print the JSON report to stdout instead of the summary table",
    )
    args = parser.parse_args(argv)

    from repro.analysis.certify import certify_all
    from repro.analysis.hlo_gate import gate_all
    from repro.analysis.lint import lint_paths

    engines = None if args.all_engines else args.engine
    report = certify_all(
        engines, include_chain_baseline=not args.no_chain_baseline
    )
    if not args.skip_lint:
        report.lint = lint_paths()
    if not args.skip_hlo:
        report.hlo = gate_all(engines)

    if args.report:
        path = pathlib.Path(args.report)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(report.to_json() + "\n")
    print(report.to_json() if args.json else report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
