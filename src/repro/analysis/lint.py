"""Layer 2 — AST lint: repo-specific source checks the jaxpr certifier
cannot see (DESIGN.md §11).

A jaxpr only shows what survives tracing; some constant-time hazards live
in the *Python* that builds the trace.  Three rules, scoped to the hot-path
source tree (``src/repro/{core,kernels,serving}`` by default):

``host-sync``
    Host-synchronising calls inside *hot functions* — functions that are
    ``jax.jit``-decorated, or follow the kernel-body naming convention
    (``_kernel*`` / ``*_body``).  Flagged calls: ``.item()`` / ``.tolist()``
    / ``.block_until_ready()`` / ``.bit_length()`` on expressions,
    ``float(...)`` / ``int(...)`` / ``bool(...)`` casts, ``np.asarray`` /
    ``np.array`` materialisation, and ``jax.device_get``.  Each of these
    either blocks on the device or forces a concretisation error at trace
    time; none belongs on a hot path.  A deliberate host-side computation
    on *static* operands (e.g. deriving the power-of-two extent from a
    static ``n``) is annotated in-line with ``# ct: host-ok`` plus a
    reason, which suppresses the finding on that line.

``bare-int``
    Integer literals outside int32 range used directly in arithmetic /
    bitwise expressions inside hot functions.  Under ``enable_x64`` a bare
    wide literal weak-promotes the whole u32-limb expression to 64-bit —
    exactly the promotion the certifier's ``dtype-closed`` invariant
    rejects, caught here at the line that causes it.  Wrapping the literal
    in an explicit dtype cast (``np.uint32(...)``, ``jnp.uint64(...)``,
    ...) keeps the limb discipline and satisfies the rule.

``config-mutation``
    ``jax.config.update(...)`` / ``jax.config.<flag> = ...`` anywhere in
    library source.  Global config flips belong to tests and tools (the
    certifier itself uses the scoped ``enable_x64`` context manager);
    library code mutating process-global config changes numerics for every
    caller.

The lint is intentionally small and calibrated to this codebase — it is a
tripwire for the specific regressions the roofline work keeps catching in
review, not a general-purpose style checker.
"""
from __future__ import annotations

import ast
import pathlib
import re
from typing import Iterable, Optional

from repro.analysis.report import LintFinding

#: default lint scope, relative to the repo/source root
DEFAULT_SCOPE = ("core", "kernels", "serving")

#: in-line waiver token: a line carrying this comment is exempt
WAIVER_TOKEN = "ct: host-ok"

#: hot-function naming convention (kernel bodies / unrolled trace bodies)
_HOT_NAME = re.compile(r"(^_kernel)|(_body$)")

#: attribute calls that synchronise with (or escape to) the host
_SYNC_ATTRS = {"item", "tolist", "block_until_ready", "bit_length"}

#: builtin casts that force concretisation of a traced value
_SYNC_BUILTINS = {"float", "int", "bool"}

#: np.<attr> calls that materialise on host
_NP_MATERIALISE = {"asarray", "array", "frombuffer"}

#: explicit dtype-cast callables that make a wide literal limb-safe
_CAST_NAMES = {
    "uint8", "uint16", "uint32", "uint64",
    "int8", "int16", "int32", "int64",
    "asarray", "array", "full", "constant",
}

_INT32_MAX = 1 << 31


def _dotted(node: ast.AST) -> str:
    """'np.asarray' for Attribute/Name chains, '' for anything else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit_decorated(fn: ast.FunctionDef) -> bool:
    """True for ``@jax.jit`` / ``@jit`` / ``@functools.partial(jax.jit, ...)``
    (and any decorator whose expression mentions a ``jit`` name)."""
    for dec in fn.decorator_list:
        for node in ast.walk(dec):
            name = _dotted(node)
            if name == "jit" or name.endswith(".jit"):
                return True
    return False


def _is_hot(fn: ast.FunctionDef) -> bool:
    return _is_jit_decorated(fn) or bool(_HOT_NAME.search(fn.name))


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.findings: list[LintFinding] = []
        self._hot_depth = 0

    # -- helpers ------------------------------------------------------------

    def _line(self, lineno: int) -> str:
        return self.lines[lineno - 1] if 0 < lineno <= len(self.lines) else ""

    def _waived(self, lineno: int) -> bool:
        return WAIVER_TOKEN in self._line(lineno)

    def _emit(self, node: ast.AST, rule: str, message: str) -> None:
        if not self._waived(node.lineno):
            self.findings.append(
                LintFinding(
                    path=self.path,
                    line=node.lineno,
                    rule=rule,
                    message=message,
                    source=self._line(node.lineno).strip(),
                )
            )

    # -- traversal ----------------------------------------------------------

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        hot = _is_hot(node)
        self._hot_depth += hot
        self.generic_visit(node)
        self._hot_depth -= hot

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        if self._hot_depth:
            if isinstance(node.func, ast.Attribute) and node.func.attr in _SYNC_ATTRS:
                self._emit(
                    node,
                    "host-sync",
                    f".{node.func.attr}() synchronises with the host inside a "
                    "hot function (annotate '# ct: host-ok — <why>' if the "
                    "operand is provably static)",
                )
            elif name in _SYNC_BUILTINS:
                self._emit(
                    node,
                    "host-sync",
                    f"{name}() concretises its operand inside a hot function",
                )
            elif name.startswith("np.") and name[3:] in _NP_MATERIALISE:
                self._emit(
                    node,
                    "host-sync",
                    f"{name}() materialises on host inside a hot function "
                    "(use jnp.asarray for a device-side view)",
                )
            elif name in ("jax.device_get", "device_get"):
                self._emit(node, "host-sync", f"{name}() copies device->host")
        # config mutation is flagged everywhere, hot or not
        if name in ("jax.config.update", "config.update"):
            self._emit(
                node,
                "config-mutation",
                "global jax config mutated in library code — use the scoped "
                "context manager (e.g. jax.experimental.enable_x64) or move "
                "the flip to test/tool setup",
            )
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        for target in node.targets:
            dotted = _dotted(target)
            if dotted.startswith(("jax.config.", "config.jax_")):
                self._emit(
                    node,
                    "config-mutation",
                    f"assignment to {dotted} mutates global jax config in "
                    "library code",
                )
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if self._hot_depth:
            for side in (node.left, node.right):
                if (
                    isinstance(side, ast.Constant)
                    and type(side.value) is int
                    and not -_INT32_MAX <= side.value < _INT32_MAX
                ):
                    self._emit(
                        node,
                        "bare-int",
                        f"bare literal {side.value:#x} exceeds int32 in limb "
                        "arithmetic — weak-promotes the expression to 64-bit "
                        "under x64; wrap it in an explicit dtype cast "
                        "(np.uint32(...) / jnp.uint64(...))",
                    )
        self.generic_visit(node)

def _strip_casts(tree: ast.AST) -> None:
    """Neutralise wide literals that are *arguments of explicit dtype casts*
    so ``visit_BinOp`` never sees them: ``np.uint32(x & 0xFFFFFFFF)`` is the
    sanctioned idiom (the cast pins the dtype before any limb op runs)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _dotted(node.func)
            leaf = name.rsplit(".", 1)[-1]
            if leaf in _CAST_NAMES:
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Constant) and type(sub.value) is int:
                        sub.value = 0


def lint_source(source: str, path: str = "<string>") -> list[LintFinding]:
    """Lint one module's source text; returns findings (empty = clean)."""
    tree = ast.parse(source, filename=path)
    _strip_casts(tree)
    linter = _Linter(path, source)
    linter.visit(tree)
    return sorted(linter.findings, key=lambda f: (f.path, f.line, f.rule))


def lint_paths(
    root: Optional[pathlib.Path] = None,
    scope: Iterable[str] = DEFAULT_SCOPE,
) -> list[LintFinding]:
    """Lint every ``.py`` under ``root/<scope dirs>`` (root defaults to the
    installed ``repro`` package directory)."""
    if root is None:
        import repro

        root = pathlib.Path(repro.__file__).parent
    findings: list[LintFinding] = []
    for sub in scope:
        base = root / sub
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            findings.extend(
                lint_source(py.read_text(), str(py.relative_to(root.parent)))
            )
    return findings
