"""Structured results of the constant-time certifier (DESIGN.md §11).

One ``Report`` aggregates the three layers — jaxpr certification targets,
AST lint findings, HLO gate results — and serializes to the JSON artifact
the CI ``static-analysis`` job uploads.  The JSON is keyed by engine and
invariant (``engines.<engine>.<target>.<invariant>``), so a regression
diff pinpoints exactly which guarantee broke on which datapath.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

PASS = "pass"
FAIL = "fail"
WAIVED = "waived"
SKIPPED = "skipped"


@dataclasses.dataclass(frozen=True)
class CheckResult:
    """One invariant's verdict on one certification target."""

    invariant: str
    status: str  # pass | fail | waived | skipped
    detail: str
    waiver: Optional[str] = None  # the allowlist reason, when status == waived

    def to_dict(self) -> dict:
        d = {"status": self.status, "detail": self.detail}
        if self.waiver:
            d["waiver"] = self.waiver
        return d


@dataclasses.dataclass
class TargetReport:
    """All invariant verdicts for one traced callable of one engine."""

    engine: str
    target: str  # e.g. "route/jnp", "ingest/pallas", "chain/memento_remap"
    checks: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(c.status != FAIL for c in self.checks)

    def failures(self) -> list:
        return [c for c in self.checks if c.status == FAIL]

    def to_dict(self) -> dict:
        return {c.invariant: c.to_dict() for c in self.checks}


@dataclasses.dataclass(frozen=True)
class LintFinding:
    """One AST-lint violation (layer 2)."""

    path: str
    line: int
    rule: str
    message: str
    source: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class HloGateResult:
    """Layer-3 verdicts for one engine's compiled fused route."""

    engine: str
    checks: list = dataclasses.field(default_factory=list)
    op_count: int = 0

    @property
    def ok(self) -> bool:
        return all(c.status != FAIL for c in self.checks)

    def to_dict(self) -> dict:
        return {
            "op_count": self.op_count,
            "checks": {c.invariant: c.to_dict() for c in self.checks},
        }


@dataclasses.dataclass
class Report:
    """The aggregate three-layer certification report."""

    targets: list = dataclasses.field(default_factory=list)
    lint: list = dataclasses.field(default_factory=list)
    hlo: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return (
            all(t.ok for t in self.targets)
            and not self.lint
            and all(h.ok for h in self.hlo)
        )

    def to_dict(self) -> dict:
        engines: dict = {}
        for t in self.targets:
            engines.setdefault(t.engine, {})[t.target] = t.to_dict()
        return {
            "ok": self.ok,
            "engines": engines,
            "lint": [f.to_dict() for f in self.lint],
            "hlo": {h.engine: h.to_dict() for h in self.hlo},
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def render(self) -> str:
        """Human-readable summary table (the CLI's stdout)."""
        lines: list[str] = []
        mark = {PASS: "ok", FAIL: "FAIL", WAIVED: "waived", SKIPPED: "skip"}
        if self.targets:
            lines.append("== jaxpr certifier ==")
            for t in self.targets:
                verdict = "OK" if t.ok else "FAIL"
                lines.append(f"  [{verdict}] {t.engine:<12} {t.target}")
                for c in t.checks:
                    note = f" ({c.waiver})" if c.waiver else ""
                    lines.append(
                        f"      {mark[c.status]:>6}  {c.invariant:<22} {c.detail}{note}"
                    )
        lines.append("== ast lint ==")
        if self.lint:
            lines.extend(f"  FAIL {f}" for f in self.lint)
        else:
            lines.append("  ok (no findings)")
        if self.hlo:
            lines.append("== hlo gate ==")
            for h in self.hlo:
                verdict = "OK" if h.ok else "FAIL"
                lines.append(f"  [{verdict}] {h.engine} ({h.op_count} HLO ops)")
                for c in h.checks:
                    lines.append(
                        f"      {mark[c.status]:>6}  {c.invariant:<22} {c.detail}"
                    )
        lines.append(f"== verdict: {'CERTIFIED' if self.ok else 'FAILED'} ==")
        return "\n".join(lines)
