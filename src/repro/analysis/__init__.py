"""Constant-time certifier — static analysis that machine-checks the
paper's O(1) guarantee for every registered device engine (DESIGN.md §11).

Three layers, one CLI (``python -m repro.analysis``), one CI gate:

* **jaxpr certifier** (``repro.analysis.certify``) — traces every
  ``BULK_ENGINES`` entry's fused route / ingest / dynamic-n lookup (jnp
  mirrors AND Pallas kernel bodies via ``interpret=True`` lowering) to
  closed jaxprs and walks them recursively, enforcing: no ``while_loop``
  (waivable for paper-faithful baselines via
  ``repro.analysis.markers.constant_time_waiver``), equation count affine
  in the ω unroll bound, dtypes closed over the u32-limb arithmetic set
  (traced under x64 so f64 leaks surface), no host callbacks, and exactly
  the declared number of device transfers.
* **AST lint** (``repro.analysis.lint``) — repo-specific source checks
  over ``src/repro/{core,kernels,serving}``: host-sync calls in hot-path
  functions, bare out-of-int32-range literals in limb arithmetic, and
  ``jax.config`` mutation outside tests.
* **HLO gate** (``repro.analysis.hlo_gate``) — compiles the fused route
  per engine and, via the trip-count-aware walker in
  ``repro.roofline.hlo_parse``, asserts every lowered ``while`` has a
  recoverable static trip count and that the compiled program is identical
  across fleet-event severity.

The package ``__init__`` stays import-light (PEP 562 lazy exports) so
``repro.core`` modules can import ``repro.analysis.markers`` without
pulling the engine registry in — the certifier itself imports the registry,
not the other way around.
"""
from __future__ import annotations

_EXPORTS = {
    "certify_all": "repro.analysis.certify",
    "certify_engine": "repro.analysis.certify",
    "certify_callable": "repro.analysis.certify",
    "EngineContract": "repro.analysis.certify",
    "lint_paths": "repro.analysis.lint",
    "lint_source": "repro.analysis.lint",
    "gate_all": "repro.analysis.hlo_gate",
    "gate_engine": "repro.analysis.hlo_gate",
    "constant_time_waiver": "repro.analysis.markers",
    "waivers_of": "repro.analysis.markers",
    "Report": "repro.analysis.report",
    "CheckResult": "repro.analysis.report",
    "TargetReport": "repro.analysis.report",
    "LintFinding": "repro.analysis.report",
    "HloGateResult": "repro.analysis.report",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro.analysis' has no attribute '{name}'")


def __dir__():
    return sorted(set(globals()) | set(__all__))
