"""Layer 3 — the HLO gate: certify the *compiled* fused route (DESIGN.md §11).

The jaxpr certifier (layer 1) proves the trace is constant-time; this layer
proves the property survives XLA.  Per engine, the fused jnp route is
compiled (``jax.jit(...).lower(...).compile()``) for two fleet states at
opposite event-severity extremes — a healthy fleet and a heavy-removal
storm — and the optimized HLO text is parsed with the trip-count-aware
walker from ``repro.roofline.hlo_parse``.  Three checks:

``hlo-while-static``
    Every ``while`` in the optimized module has a *recoverable static* trip
    count (``known_trip_count`` backend config or the canonical counted-
    loop condition).  ``while_trip_counts`` returning ``None`` for any loop
    means XLA emitted control flow whose bound cannot be proven
    data-independent — fail.

``hlo-severity-flat``
    The compiled op-kind histogram is identical for the healthy and the
    storm fleet state.  Fleet state is a runtime operand, so the lowered
    program must not change shape with it; a difference means some Python
    branch specialised the trace on event severity — the O(events) cliff
    the fused datapath exists to rule out.

``hlo-op-budget``
    Total optimized op count stays under the contract's budget — a coarse
    backstop against silent lowering blow-ups (e.g. a gather unrolling
    into per-slot selects).

Compile-time is the cost here (~seconds per engine on CPU), so this layer
runs per-engine on demand and in the CI gate, not inside the test suite's
hot loop.
"""
from __future__ import annotations

import collections
from typing import Iterable, Optional

import jax
import numpy as np

from repro.analysis.report import FAIL, PASS, CheckResult, HloGateResult

#: optimized-HLO op budget for one fused route dispatch (generous: the
#: binomial route compiles to ~1.2k ops at ω=16 today)
DEFAULT_MAX_OPS = 4096


def _fleet_states(capacity: int):
    """(healthy, storm) packed fleet operands for one capacity — identical
    shapes/dtypes, opposite event severity (0 vs capacity/2 removals)."""
    from repro.core.memento_jax import pack_removed_mask, table_width

    width = table_width(capacity)

    def build(removed: list[int]):
        packed = pack_removed_mask(removed, capacity)
        table = np.zeros((1, width), np.int32)
        alive = [s for s in range(capacity) if s not in set(removed)]
        table[0, : len(alive)] = alive
        table[0, len(alive) : capacity] = removed
        state = np.array([capacity, len(alive)], np.uint32)
        return packed, table, state

    healthy = build([])
    storm = build(list(range(1, capacity, 2)))
    return healthy, storm


def _compiled_text(engine, keys, packed, table, state, omega, n_words) -> str:
    fn = jax.jit(
        lambda k, p, t, s: engine.route(k, p, t, s, omega=omega, n_words=n_words)
    )
    return fn.lower(keys, packed, table, state).compile().as_text()


def _op_histogram(comps) -> dict[str, int]:
    hist: dict[str, int] = collections.Counter()
    for comp in comps.values():
        for op in comp.ops:
            hist[op.kind] += 1
    return dict(hist)


def gate_engine(
    engine_name: str,
    *,
    capacity: int = 64,
    batch: int = 2048,
    omega: int = 16,
    max_ops: int = DEFAULT_MAX_OPS,
) -> HloGateResult:
    """Run the three HLO checks on one engine's compiled fused route."""
    from repro.core.memento_jax import mask_words
    from repro.core.registry import make_bulk
    from repro.roofline.hlo_parse import parse_module, while_trip_counts

    eng = make_bulk(engine_name)
    keys = np.zeros((batch,), np.uint32)
    n_words = mask_words(capacity)
    (h_packed, h_table, h_state), (s_packed, s_table, s_state) = _fleet_states(
        capacity
    )

    healthy_text = _compiled_text(
        eng, keys, h_packed, h_table, h_state, omega, n_words
    )
    storm_text = _compiled_text(eng, keys, s_packed, s_table, s_state, omega, n_words)

    healthy_comps, _ = parse_module(healthy_text)
    storm_comps, _ = parse_module(storm_text)
    result = HloGateResult(engine=engine_name)
    result.op_count = sum(len(c.ops) for c in healthy_comps.values())

    # -- hlo-while-static ---------------------------------------------------
    unbounded = [
        (comp, op)
        for comp, op, trips in while_trip_counts(healthy_comps)
        if trips is None
    ]
    loops = while_trip_counts(healthy_comps)
    if unbounded:
        result.checks.append(
            CheckResult(
                "hlo-while-static",
                FAIL,
                "while loops without a recoverable static trip count: "
                + ", ".join(f"{c}/%{o}" for c, o in unbounded),
            )
        )
    else:
        detail = (
            f"{len(loops)} while loop(s), all with static trip counts "
            + str([t for _, _, t in loops])
            if loops
            else "no while loops in the optimized module"
        )
        result.checks.append(CheckResult("hlo-while-static", PASS, detail))

    # -- hlo-severity-flat --------------------------------------------------
    h_hist, s_hist = _op_histogram(healthy_comps), _op_histogram(storm_comps)
    if h_hist != s_hist:
        diff = {
            k: (h_hist.get(k, 0), s_hist.get(k, 0))
            for k in sorted(set(h_hist) | set(s_hist))
            if h_hist.get(k, 0) != s_hist.get(k, 0)
        }
        result.checks.append(
            CheckResult(
                "hlo-severity-flat",
                FAIL,
                f"compiled op histogram differs healthy vs storm: {diff} — "
                "the trace specialised on fleet-event severity",
            )
        )
    else:
        result.checks.append(
            CheckResult(
                "hlo-severity-flat",
                PASS,
                f"op histogram identical across severity "
                f"({result.op_count} ops, {capacity // 2} removals vs 0)",
            )
        )

    # -- hlo-op-budget ------------------------------------------------------
    if result.op_count > max_ops:
        result.checks.append(
            CheckResult(
                "hlo-op-budget",
                FAIL,
                f"{result.op_count} optimized ops exceeds the {max_ops} budget",
            )
        )
    else:
        result.checks.append(
            CheckResult(
                "hlo-op-budget",
                PASS,
                f"{result.op_count} optimized ops within the {max_ops} budget",
            )
        )
    return result


def gate_all(engines: Optional[Iterable[str]] = None) -> list[HloGateResult]:
    from repro.core.registry import BULK_ENGINES

    names = list(engines) if engines is not None else sorted(BULK_ENGINES)
    return [gate_engine(name) for name in names]
