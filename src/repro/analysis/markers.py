"""Allowlist markers for the constant-time certifier (DESIGN.md §11).

The certifier's invariants are deliberately strict — e.g. ``while-free``
rejects EVERY ``lax.while_loop`` it finds, because a data-dependent trip
count is exactly the bug class that produced the pre-PR-3 2.57x
event-storm cliff.  Some callables are *supposed* to carry one anyway: the
paper-faithful chain-mode Memento baseline keeps its bounded rejection
walk as the documented reference semantics.  Those carry an explicit,
reasoned waiver::

    @functools.partial(jax.jit, static_argnames=("max_chain",))
    @constant_time_waiver("paper-faithful chain baseline; trip count is "
                          "bounded by the static max_chain operand")
    def memento_remap(...):
        ...

A waiver downgrades a *specific* invariant's failure to ``waived`` — it
never hides the finding (the structured report records the reason), and it
never transfers: every new engine or callable starts unwaived, so a future
engine drop cannot silently inherit a data-dependent loop.

This module is import-light on purpose (stdlib only): ``repro.core``
modules mark their baselines without pulling the engine registry or any
jax machinery into their import graph.
"""
from __future__ import annotations

from typing import Any, Callable

#: attribute carrying the marker payload on the marked callable
_ATTR = "__ct_waivers__"


def constant_time_waiver(
    reason: str, *, invariant: str = "while-free"
) -> Callable[[Callable], Callable]:
    """Decorator: allowlist one certifier invariant on one callable.

    ``reason`` is mandatory and lands verbatim in the certification report;
    ``invariant`` names the check being waived (default ``while-free`` —
    the data-dependent-control-flow check).  Apply UNDER ``jax.jit`` (the
    certifier follows ``__wrapped__`` chains) or on the bare callable.
    """
    if not reason or not reason.strip():
        raise ValueError("a constant_time_waiver requires a non-empty reason")

    def mark(fn: Callable) -> Callable:
        waivers = dict(getattr(fn, _ATTR, {}))
        waivers[invariant] = reason
        setattr(fn, _ATTR, waivers)
        return fn

    return mark


def waivers_of(fn: Any) -> dict[str, str]:
    """Collect waivers from a callable, following ``__wrapped__`` chains
    (so markers applied under ``jax.jit`` / ``functools.wraps`` are seen).
    Inner (closer to the marked def) entries win over outer ones only when
    the outer layer did not re-declare the invariant."""
    out: dict[str, str] = {}
    seen: set[int] = set()
    while fn is not None and id(fn) not in seen:
        seen.add(id(fn))
        for invariant, reason in getattr(fn, _ATTR, {}).items():
            out.setdefault(invariant, reason)
        fn = getattr(fn, "__wrapped__", None)
    return out
