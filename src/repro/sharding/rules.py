"""Logical-axis sharding rules (MaxText-style) and the mesh context.

Model code annotates activations with *logical* axis names; this module
resolves them against the current mesh (single-pod ``(data, model)`` or
multi-pod ``(pod, data, model)``).  When no mesh is active (CPU unit tests)
every annotation is a no-op, so the same model code runs everywhere.

Logical axes:
    dp      batch                 -> (pod, data) / (data,)
    tp      heads / ff / experts / vocab -> model
    fsdp    weight embed-dim ZeRO-3      -> data (only when cfg.fsdp)
    sp      sequence (long-context)      -> data
"""
from __future__ import annotations

import inspect
import re
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: dict = {"mesh": None, "fsdp": False, "expert_layout": "ep"}


def shard_map_compat(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``shard_map`` across JAX versions.

    ``jax.shard_map`` only exists on newer JAX; older releases ship it as
    ``jax.experimental.shard_map.shard_map``.  The replication-check kwarg
    was also renamed (``check_rep`` -> ``check_vma``) on a different schedule
    than the promotion to ``jax.``, so the kwarg name is picked from the
    actual signature rather than inferred from where the function lives.
    """
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm

    params = inspect.signature(sm).parameters
    if "check_vma" in params:
        kwargs = {"check_vma": check_vma}
    elif "check_rep" in params:
        kwargs = {"check_rep": check_vma}
    else:  # opaque (*args/**kwargs) signature — rely on the default check
        kwargs = {}
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def set_mesh(mesh: Mesh | None, fsdp: bool = False, expert_layout: str = "ep") -> None:
    _ACTIVE["mesh"] = mesh
    _ACTIVE["fsdp"] = fsdp
    _ACTIVE["expert_layout"] = expert_layout


def current_mesh() -> Mesh | None:
    return _ACTIVE["mesh"]


def expert_layout() -> str:
    """"ep" (experts over model — train/prefill) or "tp" (per-expert tensor
    parallelism, experts replicated over model — decode/serving, where
    1-token-per-expert capacities make EP useless; §Perf cell 2)."""
    return _ACTIVE["expert_layout"]


@contextmanager
def mesh_context(mesh: Mesh | None, fsdp: bool = False, expert_layout: str = "ep"):
    prev = (_ACTIVE["mesh"], _ACTIVE["fsdp"], _ACTIVE["expert_layout"])
    set_mesh(mesh, fsdp, expert_layout)
    try:
        yield
    finally:
        set_mesh(*prev)


def _resolve(axis: str | None, mesh: Mesh) -> tuple | str | None:
    names = mesh.axis_names
    if axis is None:
        return None
    if axis == "dp":
        return ("pod", "data") if "pod" in names else ("data",)
    if axis == "tp":
        return "model"
    if axis == "sp":
        return "data"
    if axis == "fsdp":
        return "data" if _ACTIVE["fsdp"] else None
    raise ValueError(f"unknown logical axis {axis!r}")


def logical(*axes: str | None) -> P:
    """Resolve logical axis names to a PartitionSpec for the active mesh."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    return P(*[_resolve(a, mesh) for a in axes])


def fitted(shape, *axes: str | None) -> P:
    """logical() + divisibility guard against a concrete shape."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    return fit_spec([_resolve(a, mesh) for a in axes], shape, mesh)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op if none).
    Axes that don't divide the corresponding dim are dropped."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = fit_spec([_resolve(a, mesh) for a in axes], x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter partition specs, by path-name rules.
#
# Conventions (all stacked params carry a leading layer dim -> None):
#   embedding (V, D)            vocab -> tp, D -> fsdp
#   unembed   (D, V)            D -> fsdp, vocab -> tp
#   wq/wk/wv  (.., D, H, hd)    D -> fsdp, H -> tp
#   wo        (.., H, hd, D)    H -> tp, D -> fsdp
#   mlp wi/wg (.., D, F)        D -> fsdp, F -> tp
#   mlp wo    (.., F, D)        F -> tp, D -> fsdp
#   experts   (.., E, D, F)     E -> tp (expert parallelism)
#   router    (.., D, E)        replicated
#   biases / norms / scalars    replicated
#   ssd/rglru small weights     replicated (elementwise channel params)
# ---------------------------------------------------------------------------

_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    # order matters — first match wins. Specs are for the TRAILING dims
    # (leading scan/layer dims padded with None automatically).
    (r"embedding$", ("tp", "fsdp")),
    (r"unembed$", ("fsdp", "tp")),
    (r"(wq|wk|wv)$", ("fsdp", "tp")),  # fused (D, H*hd)
    (r"wo_attn$", ("tp", "fsdp")),  # fused (H*hd, D)
    (r"(w_dkv|w_dq)$", ("fsdp", None)),  # MLA down-proj (D, r)
    (r"(w_uq|w_uk|w_uv)$", (None, "tp", None)),  # MLA up-proj (r, H, hd)
    (r"w_qr$", (None, "tp", None)),  # MLA rope-q (r, H, hd_r)
    (r"w_kr$", ("fsdp", None)),  # MLA rope-k (D, hd_r)
    (r"(wi|wg)$", ("fsdp", "tp")),
    (r"wo_mlp$", ("tp", "fsdp")),
    (r"experts_(wi|wg)$", ("tp", "fsdp", None)),  # (E, D, Fe) — EP + ZeRO-3
    (r"experts_wo$", ("tp", None, "fsdp")),  # (E, Fe, D)
    (r"router$", (None, None)),
    (r"in_proj(_[a-z]+)?$", ("fsdp", "tp")),  # ssm / rglru in-projections
    (r"out_proj$", ("tp", "fsdp")),
    (r".*", ()),  # everything else fully replicated
]


def _axis_size(entry, mesh) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for e in entry:
            n *= mesh.shape[e]
        return n
    return mesh.shape[entry]


def fit_spec(resolved_axes, shape, mesh) -> P:
    """Drop mesh axes from dims they don't evenly divide (jit boundary
    requires exact divisibility for explicit input shardings)."""
    out = []
    for dim, entry in zip(shape, resolved_axes):
        if entry is not None and dim % _axis_size(entry, mesh) != 0:
            entry = None
        out.append(entry)
    return P(*out)


def _spec_for(path: str, leaf) -> P:
    ndim = getattr(leaf, "ndim", 0)
    shape = getattr(leaf, "shape", ())
    if _ACTIVE["expert_layout"] == "tp" and re.search(r"experts_(wi|wg|wo)$", path):
        trailing = (None, "fsdp", "tp") if re.search(r"experts_(wi|wg)$", path) else (None, "tp", "fsdp")
        t = list(trailing)[-ndim:] if ndim < 3 else list(trailing)
        axes = [None] * (ndim - len(t)) + t
        mesh = current_mesh()
        if mesh is None:
            return P()
        return fit_spec([_resolve(a, mesh) for a in axes], shape, mesh)
    for pat, trailing in _RULES:
        if re.search(pat, path):
            t = [a for a in trailing]
            if len(t) > ndim:
                t = t[-ndim:]
            axes = [None] * (ndim - len(t)) + t
            mesh = current_mesh()
            if mesh is None:
                return P()
            return fit_spec([_resolve(a, mesh) for a in axes], shape, mesh)
    return P()


def params_pspecs(params) -> object:
    """PartitionSpec pytree matching ``params`` (uses the active mesh)."""

    def walk(prefix, tree):
        if isinstance(tree, dict):
            return {k: walk(f"{prefix}/{k}", v) for k, v in tree.items()}
        return _spec_for(prefix, tree)

    return walk("", params)


def constrain_params(params):
    """Pin a (stacked) param subtree to its rule shardings. Anchors scan
    carries: without this the partitioner may choose a different sharding for
    the while-loop weight stacks and re-shard them EVERY layer (§Perf)."""
    mesh = current_mesh()
    if mesh is None:
        return params
    specs = params_pspecs(params)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        params,
        specs,
        is_leaf=lambda x: not isinstance(x, dict),
    )


_CACHE_SPECS = {
    # KV-style caches shard the TIME dim on the model axis (flash-decoding
    # style): kv-head counts (1..8) rarely divide a 16-way axis, while the
    # cache length always does; GSPMD turns the softmax reductions over the
    # sharded time dim into cheap (B,H)-sized collectives.
    "k": ("dp", "tp", None, None),
    "v": ("dp", "tp", None, None),
    "pos": ("dp", "tp"),
    "ckv": ("dp", "tp", None),
    "kr": ("dp", "tp", None),
    "h": ("dp", "tp"),
    "state": ("dp", "tp", None, None),
    "conv": ("dp", None, "tp"),
}


def cache_pspecs(cache_like):
    """PartitionSpec tree for a decode cache (leading stacked-layer dim)."""
    mesh = current_mesh()

    def walk(name, tree):
        if isinstance(tree, dict):
            return {k: walk(k, v) for k, v in tree.items()}
        if mesh is None or name == "cur":
            return P()
        trailing = _CACHE_SPECS.get(name, ())
        ndim = getattr(tree, "ndim", 0)
        axes = [None] * (ndim - len(trailing)) + [_resolve(a, mesh) for a in trailing]
        return fit_spec(axes, getattr(tree, "shape", ()), mesh)

    return walk("", cache_like)


def batch_pspecs(batch_like):
    """PartitionSpec tree for an input batch: batch dim -> dp."""
    mesh = current_mesh()

    def leaf(name, tree):
        if mesh is None:
            return P()
        dp = _resolve("dp", mesh)
        ndim = getattr(tree, "ndim", 0)
        shape = getattr(tree, "shape", ())
        if name == "positions":  # (3, B, S)
            axes = [None, dp] + [None] * (ndim - 2)
        else:
            axes = [dp] + [None] * (ndim - 1)
        return fit_spec(axes, shape, mesh)

    return {k: leaf(k, v) for k, v in batch_like.items()}


def params_shardings(params):
    mesh = current_mesh()
    if mesh is None:
        raise RuntimeError("no active mesh")
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        params_pspecs(params),
        is_leaf=lambda x: isinstance(x, P),
    )
