"""Exposition: JSON snapshots + Prometheus-style text (DESIGN.md §15).

Two read-side renderings of one registry:

* ``snapshot(registry, trace=, monitor=)`` — a plain-dict snapshot (and
  ``to_json`` for the serialized form): every metric series with labels,
  values and µs timestamps, optionally the span ring's retained spans and
  the load monitor's per-shard totals.  Deterministic under a virtual
  clock — two identical runs serialize identically, which is itself a
  chaos-suite invariant.

* ``to_prometheus(registry)`` — the text exposition format scrapers
  expect: ``# TYPE`` headers, ``name{label="v"} value`` samples,
  histograms as cumulative ``_bucket{le=...}`` series plus ``_sum`` /
  ``_count``.
"""
from __future__ import annotations

import json

from repro.observability.metrics import Histogram, MetricsRegistry


def snapshot(registry: MetricsRegistry, trace=None, monitor=None) -> dict:
    """Plain-dict snapshot of the whole telemetry plane."""
    series = []
    for m in registry.collect():
        rec = {
            "name": m.name,
            "kind": m.kind,
            "labels": dict(m.labels),
            "last_update_us": m.last_update_us,
        }
        if isinstance(m, Histogram):
            rec.update(
                count=m.count,
                sum=m.sum,
                bounds=list(m.bounds),
                bucket_counts=list(m.bucket_counts),
            )
        else:
            rec["value"] = m.value
        series.append(rec)
    out: dict = {"metrics": series}
    if trace is not None:
        out["trace"] = {
            "capacity": trace.capacity,
            "recorded": trace.total,
            "dropped": trace.dropped,
            "spans": [
                {
                    "name": s.name,
                    "t_start_us": s.t_start_us,
                    "t_end_us": s.t_end_us,
                    "tenant": s.tenant,
                    "tags": dict(s.tags),
                }
                for s in trace.spans()
            ],
        }
    if monitor is not None:
        out["load"] = {
            "total_keys": monitor.total_keys,
            "drains": monitor.drains,
            "peak_over_mean": monitor.peak_over_mean(),
            "shard_totals": {
                str(s): int(monitor.totals[s])
                for s in range(len(monitor.totals))
                if monitor.totals[s]
            },
        }
    return out


def to_json(registry: MetricsRegistry, trace=None, monitor=None, **dumps_kw) -> str:
    dumps_kw.setdefault("sort_keys", True)
    return json.dumps(
        snapshot(registry, trace=trace, monitor=monitor), **dumps_kw
    )


def _fmt_labels(labels: dict, extra: dict | None = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    body = ",".join(
        f'{k}="{v}"' for k, v in sorted(items.items())
    )
    return "{" + body + "}"


def _fmt_value(v) -> str:
    if isinstance(v, float) and v == int(v):
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)


def to_prometheus(registry: MetricsRegistry) -> str:
    """Prometheus text exposition of every registered series."""
    lines: list[str] = []
    typed: set[str] = set()
    for m in registry.collect():
        if m.name not in typed:
            typed.add(m.name)
            lines.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, Histogram):
            running = 0
            for bound, c in zip(m.bounds, m.bucket_counts):
                running += c
                lines.append(
                    f"{m.name}_bucket"
                    f"{_fmt_labels(m.labels, {'le': bound})} {running}"
                )
            running += m.bucket_counts[-1]
            lines.append(
                f"{m.name}_bucket{_fmt_labels(m.labels, {'le': '+Inf'})} "
                f"{running}"
            )
            lines.append(
                f"{m.name}_sum{_fmt_labels(m.labels)} {_fmt_value(m.sum)}"
            )
            lines.append(f"{m.name}_count{_fmt_labels(m.labels)} {m.count}")
        else:
            lines.append(
                f"{m.name}{_fmt_labels(m.labels)} {_fmt_value(m.value)}"
            )
    return "\n".join(lines) + ("\n" if lines else "")
