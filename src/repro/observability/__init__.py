"""Observability tier: device-rate load telemetry, latency histograms,
span tracing and live theory-bound alarms (DESIGN.md §15).

The paper's headline claims — constant lookup time, minimal-disruption
remapping, near-uniform balance — validated continuously on live traffic
instead of only offline in benchmarks: a lock-free ``MetricsRegistry``
over the streaming µs clocks, a ``LoadMonitor`` whose per-shard bincount
rides inside the router's own fused dispatch (certified as
``observability/load_pass``), ring-buffer ``SpanTrace`` over the request
path, JSON/Prometheus exposition, and typed ``BalanceDriftAlarm`` /
``DisruptionBoundAlarm`` when observed behavior drifts from the proven
bounds.
"""
from repro.observability.alarms import (
    BalanceDriftAlarm,
    DisruptionBoundAlarm,
    ObservabilityAlarm,
    deliver,
)
from repro.observability.export import snapshot, to_json, to_prometheus
from repro.observability.load import (
    DisruptionTracker,
    LoadConfig,
    LoadMonitor,
    disruption_bound,
    expected_peak_over_mean,
    route_with_load_impl,
)
from repro.observability.metrics import (
    DEFAULT_BUCKETS_US,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.observability.trace import (
    SPAN_ADMIT,
    SPAN_BATCH_CLOSE,
    SPAN_DISPATCH,
    SPAN_LIFECYCLE_TICK,
    SPAN_READ,
    SPAN_REQUEST,
    Span,
    SpanTrace,
)

__all__ = [
    "BalanceDriftAlarm",
    "DisruptionBoundAlarm",
    "ObservabilityAlarm",
    "deliver",
    "snapshot",
    "to_json",
    "to_prometheus",
    "DisruptionTracker",
    "LoadConfig",
    "LoadMonitor",
    "disruption_bound",
    "expected_peak_over_mean",
    "route_with_load_impl",
    "DEFAULT_BUCKETS_US",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SPAN_ADMIT",
    "SPAN_BATCH_CLOSE",
    "SPAN_DISPATCH",
    "SPAN_LIFECYCLE_TICK",
    "SPAN_READ",
    "SPAN_REQUEST",
    "Span",
    "SpanTrace",
]
