"""Ring-buffer span tracing for the streaming request path.

The request path is admit → batch close → dispatch → hedge/read →
complete; every stage records a ``Span`` into one shared ``SpanTrace``
(DESIGN.md §15).  Spans carry the tenant they bill to plus small
stage-specific tags (batch size, replica, epoch, hedged), with all
timestamps in integer µs from whatever clock the stack runs on — virtual
spans are deterministic, wall spans are production traces, same pipeline.

The buffer is a fixed-capacity ring: recording is O(1) and allocation-
bounded forever (old spans are overwritten, never accumulated), which is
what lets the tracer stay on in production.  Per-name record totals are
kept monotonically alongside, so invariants like "one ``request`` span
per served request" hold regardless of how many spans the ring has since
recycled (``count`` reads the totals; ``spans`` reads what is retained).
"""
from __future__ import annotations

import dataclasses

#: canonical stage names, in request-path order
SPAN_ADMIT = "admit"
SPAN_BATCH_CLOSE = "batch_close"
SPAN_DISPATCH = "dispatch"
SPAN_READ = "read"
SPAN_REQUEST = "request"
SPAN_LIFECYCLE_TICK = "lifecycle_tick"


@dataclasses.dataclass(frozen=True)
class Span:
    """One completed stage: a named µs interval with tenant + tags."""

    name: str
    t_start_us: int
    t_end_us: int
    tenant: str | None = None
    tags: tuple = ()

    @property
    def duration_us(self) -> int:
        return self.t_end_us - self.t_start_us

    def tag(self, key: str, default=None):
        for k, v in self.tags:
            if k == key:
                return v
        return default


class SpanTrace:
    """Fixed-capacity span ring + monotone per-name record totals."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._ring: list[Span | None] = [None] * self.capacity
        self._next = 0
        self._recorded: dict[str, int] = {}
        self.total = 0

    def record(
        self,
        name: str,
        t_start_us: int,
        t_end_us: int,
        *,
        tenant: str | None = None,
        **tags,
    ) -> Span:
        span = Span(
            name=name,
            t_start_us=int(t_start_us),
            t_end_us=int(t_end_us),
            tenant=tenant,
            tags=tuple(sorted(tags.items())),
        )
        self._ring[self._next % self.capacity] = span
        self._next += 1
        self.total += 1
        self._recorded[name] = self._recorded.get(name, 0) + 1
        return span

    # -- read side -----------------------------------------------------------
    @property
    def dropped(self) -> int:
        """Spans recycled out of the ring (recorded minus retained)."""
        return max(0, self.total - self.capacity)

    def count(self, name: str | None = None) -> int:
        """Monotone record total — survives ring recycling."""
        if name is None:
            return self.total
        return self._recorded.get(name, 0)

    def spans(
        self, name: str | None = None, tenant: str | None = None
    ) -> list[Span]:
        """Retained spans, oldest first, optionally filtered."""
        start = max(0, self._next - self.capacity)
        out = []
        for i in range(start, self._next):
            span = self._ring[i % self.capacity]
            if span is None:
                continue
            if name is not None and span.name != name:
                continue
            if tenant is not None and span.tenant != tenant:
                continue
            out.append(span)
        return out
