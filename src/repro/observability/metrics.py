"""Lock-free counter/gauge/histogram registry with integer-µs timestamps.

The telemetry substrate of the serving stack (DESIGN.md §15).  Every metric
update is a single-writer CPython int/float mutation — no locks anywhere,
so the hot path (admission verdicts, batch closes, hedge outcomes) pays a
dict lookup it can cache away plus one add.  Timestamps come from the
streaming tier's µs clocks (``serving/streaming/clock.py``): a registry
built over a ``VirtualClockUs`` is bit-deterministic run to run (the chaos
suite asserts two identical virtual runs produce identical histogram
contents), and production swaps in ``WallClockUs`` with no other change —
one pipeline for both.

Metrics are identified by ``(name, labels)``: ``registry.counter("x",
tenant="a")`` and ``tenant="b"`` are two series of one *family*.  The
first creation pins a name's kind (and a histogram's bucket bounds);
mismatching re-use is a loud ``ValueError``, never a silent second family.
"""
from __future__ import annotations

import bisect

#: default histogram bounds, µs — geometric from sub-batch-window to
#: seconds-scale, matching where streaming latency actually lands
DEFAULT_BUCKETS_US = (
    50, 100, 200, 400, 800, 1_600, 3_200, 6_400, 12_800,
    25_600, 51_200, 102_400, 409_600, 1_638_400,
)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class Counter:
    """Monotone integer counter."""

    kind = "counter"
    __slots__ = ("name", "labels", "value", "last_update_us", "_clock")

    def __init__(self, name: str, labels: dict, clock):
        self.name = name
        self.labels = dict(labels)
        self.value = 0
        self.last_update_us = clock.now_us()
        self._clock = clock

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counters are monotone; inc({n}) would regress")
        self.value += n
        self.last_update_us = self._clock.now_us()


class Gauge:
    """Last-write-wins float gauge."""

    kind = "gauge"
    __slots__ = ("name", "labels", "value", "last_update_us", "_clock")

    def __init__(self, name: str, labels: dict, clock):
        self.name = name
        self.labels = dict(labels)
        self.value = 0.0
        self.last_update_us = clock.now_us()
        self._clock = clock

    def set(self, value: float) -> None:
        self.value = float(value)
        self.last_update_us = self._clock.now_us()


class Histogram:
    """Fixed-bound histogram: cumulative-style buckets plus count/sum.

    ``bounds`` are inclusive upper edges (Prometheus ``le`` semantics);
    one implicit +inf bucket catches the tail.  Contents are a pure
    function of the observation sequence — no sampling, no decay — which
    is what makes virtual-clock runs reproducible.
    """

    kind = "histogram"
    __slots__ = (
        "name", "labels", "bounds", "bucket_counts", "count", "sum",
        "last_update_us", "_clock",
    )

    def __init__(self, name: str, labels: dict, clock, bounds=None):
        bounds = tuple(bounds) if bounds is not None else DEFAULT_BUCKETS_US
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"histogram bounds must strictly increase: {bounds}")
        self.name = name
        self.labels = dict(labels)
        self.bounds = bounds
        self.bucket_counts = [0] * (len(bounds) + 1)  # +1: the +inf bucket
        self.count = 0
        self.sum = 0.0
        self.last_update_us = clock.now_us()
        self._clock = clock

    def observe(self, value: float) -> None:
        self.bucket_counts[bisect.bisect_left(self.bounds, value)] += 1
        self.count += 1
        self.sum += value
        self.last_update_us = self._clock.now_us()

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Get-or-create home for every metric series, keyed by (name, labels).

    One registry per serving stack: the front end builds one over its own
    clock and threads it through admission, batching, hedging, breakers
    and the load monitor, so ``export.to_prometheus(registry)`` /
    ``export.snapshot(...)`` see the whole stack in one place.
    """

    def __init__(self, clock=None):
        if clock is None:
            from repro.serving.streaming.clock import WallClockUs

            clock = WallClockUs()
        self.clock = clock
        self._metrics: dict[tuple, object] = {}
        self._kinds: dict[str, str] = {}

    def _get_or_make(self, cls, name: str, labels: dict, **kwargs):
        key = (name, _label_key(labels))
        metric = self._metrics.get(key)
        if metric is not None:
            if metric.kind != cls.kind:
                raise ValueError(
                    f"metric {name!r} is a {metric.kind}, requested {cls.kind}"
                )
            return metric
        pinned = self._kinds.setdefault(name, cls.kind)
        if pinned != cls.kind:
            raise ValueError(
                f"metric family {name!r} is pinned to kind {pinned!r}, "
                f"requested {cls.kind!r}"
            )
        metric = cls(name, labels, self.clock, **kwargs)
        self._metrics[key] = metric
        return metric

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_make(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get_or_make(Gauge, name, labels)

    def histogram(self, name: str, bounds=None, **labels) -> Histogram:
        h = self._get_or_make(Histogram, name, labels, bounds=bounds)
        if bounds is not None and tuple(bounds) != h.bounds:
            raise ValueError(
                f"histogram {name!r} already exists with bounds {h.bounds}, "
                f"requested {tuple(bounds)}"
            )
        return h

    # -- read side -----------------------------------------------------------
    def family(self, name: str) -> dict[tuple, object]:
        """Every series of one family: ``{sorted-label-items: metric}``."""
        return {
            key[1]: m for key, m in self._metrics.items() if key[0] == name
        }

    def total(self, name: str, **match) -> int:
        """Sum a counter family, optionally restricted to matching labels."""
        out = 0
        for m in self.family(name).values():
            if all(m.labels.get(k) == v for k, v in match.items()):
                out += m.value
        return out

    def collect(self):
        """Every series, sorted by (name, labels) for stable exposition."""
        return [self._metrics[k] for k in sorted(self._metrics)]
