"""Typed theory-bound alarms — telemetry's contract with operators.

The same idiom as the lifecycle tier's typed errors
(``repro.serving.lifecycle.errors``): each alarm composes a human message
from structured attributes it also carries, so an operator (or a chaos
invariant) can branch on machine-readable fields instead of parsing
strings.  Alarms fire when LIVE telemetry drifts from the paper's PROVEN
bounds — balance peaking past the expected-max-load envelope, or a
membership event moving more keys than the ``delta/n`` disruption bound
allows (DESIGN.md §15).

Delivery is pluggable: components take an ``on_alarm`` callback and
*emit* when one is set (production: page, log, count), or *raise* when
none is (tests, strict deployments).
"""
from __future__ import annotations


class ObservabilityAlarm(RuntimeError):
    """Base class for telemetry drift alarms."""


class BalanceDriftAlarm(ObservabilityAlarm):
    """Observed peak/mean shard load exceeded the configured multiple of the
    expected maximum — the live fleet is more skewed than the balance
    theory (peak/mean ≈ 1 + sqrt(2·n·ln n / m) for m keys over n shards)
    says random keys should ever make it.
    """

    def __init__(
        self,
        peak_over_mean: float,
        expected: float,
        threshold: float,
        *,
        n_alive: int,
        total_keys: int,
        epoch: int | None = None,
    ):
        super().__init__(
            f"balance drift: peak/mean load {peak_over_mean:.3f} exceeds "
            f"threshold {threshold:.3f} (expected {expected:.3f} for "
            f"{total_keys} keys over {n_alive} shards)"
        )
        self.peak_over_mean = peak_over_mean
        self.expected = expected
        self.threshold = threshold
        self.n_alive = n_alive
        self.total_keys = total_keys
        self.epoch = epoch


class DisruptionBoundAlarm(ObservabilityAlarm):
    """Observed moved-key fraction across a membership window exceeded the
    minimal-disruption bound — more keys remapped than ``delta`` events
    over an ``n``-shard fleet can justify (the paper's ``delta/n``
    guarantee, slack-scaled for hash-balance deviation).
    """

    def __init__(
        self,
        moved_fraction: float,
        bound: float,
        *,
        delta_events: int,
        n_before: int,
        n_after: int,
        epoch: int | None = None,
    ):
        super().__init__(
            f"disruption bound breach: moved fraction {moved_fraction:.3f} "
            f"exceeds {bound:.3f} for {delta_events} membership event(s) "
            f"over {n_before}->{n_after} alive shards"
        )
        self.moved_fraction = moved_fraction
        self.bound = bound
        self.delta_events = delta_events
        self.n_before = n_before
        self.n_after = n_after
        self.epoch = epoch


def deliver(alarm: ObservabilityAlarm, on_alarm) -> None:
    """Emit through the callback when one is set, raise otherwise."""
    if on_alarm is not None:
        on_alarm(alarm)
    else:
        raise alarm
