"""Device-side per-shard load accumulation + theory-bound drift tracking.

The paper's balance and minimal-disruption claims, measured on LIVE
traffic (DESIGN.md §15).  Three pieces:

* ``route_with_load_impl`` — the instrumented route: the engine's fused
  lookup+divert pass PLUS a per-shard bincount of the replica vector,
  folded into the SAME traced dispatch over a capacity-length u32
  accumulator that rides along as a traced device operand.  Same
  dispatch-count discipline as the bare router — no host loop over
  replicas, no extra transfer, no second dispatch.  At serving batch
  sizes every key is counted; at bulk-analytics sizes the pass counts a
  deterministic 1/2^``sample_shift`` stride sample and accumulates
  ``2^sample_shift`` per sampled key, so exact and sampled batches mix
  coherently in one accumulator (see ``LoadConfig.exact_cutoff`` for why
  sampling is load-bearing: counting every key of a 1M-key batch costs
  more than the 3 % overhead budget on a single-core host no matter how
  the histogram is phrased).  Like the placement pass, the instrumented
  route is pure-jnp on every backend (elementwise + one reduction — no
  Pallas twin needed).  While-free, affine in ω, dtype-closed, zero
  transfers — certified as ``observability/load_pass``.

* ``LoadMonitor`` — the host control plane: attaches to a ``BatchRouter``
  (``router.attach_load_monitor``), holds the device accumulator across
  batches, and drains it to host on a configurable batch cadence — ONE
  device->host transfer per window, zero host->device uploads (the reset
  re-uses a zeros array pinned once at construction; ``.at[].add`` is
  functional, so the pinned buffer is never clobbered).  Each drain
  updates registry gauges (per-shard counts, peak/mean) and evaluates the
  balance envelope: for m keys over n alive shards the expected peak/mean
  is ≈ 1 + sqrt(2·n·ln n / m), and observed ratios past a configurable
  multiple of that raise (or emit) a ``BalanceDriftAlarm``.

* ``DisruptionTracker`` — moved-fraction telemetry keyed to
  ``routing_epoch``: a fixed probe key set is re-routed whenever a drain
  observes the epoch advanced, and the fraction of probes whose shard
  changed is compared against the minimal-disruption bound
  ``slack · delta / max(n_before, n_after)`` (the paper's ``delta/n``
  per-event guarantee; ``bench_placement.movement_bound``'s r=1 shape).
  A breach raises (or emits) a ``DisruptionBoundAlarm``.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.observability.alarms import (
    BalanceDriftAlarm,
    DisruptionBoundAlarm,
    deliver,
)
from repro.observability.metrics import MetricsRegistry


# ---------------------------------------------------------------------------
# the instrumented device pass
# ---------------------------------------------------------------------------

#: accumulate via the vectorised one-hot comparison-sum up to this many
#: (sampled) replicas — its (capacity, m) intermediate stays cache-resident
#: and XLA CPU runs it several times faster than its serial scatter loop;
#: past it the intermediate blows the cache and the scatter wins
_ONEHOT_MAX = 1 << 17


def route_with_load_impl(
    keys: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    counts: jax.Array,
    *,
    omega: int,
    n_words: int,
    route,
    sample_shift: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Route one batch AND accumulate its per-shard load — ONE traced pass.

    keys          (N,) u32 key space (any int dtype; truncated like the
                  oracle)
    counts        (capacity,) u32 running per-shard key counts (traced
                  device operand — stays resident, never re-uploaded)
    route         the engine's fused jnp route
                  ``(keys, packed, table, state, omega=, n_words=)``
    sample_shift  log2 of the count-sampling stride: 0 counts every key;
                  s > 0 counts replicas ``[::2**s]`` with weight ``2**s``
                  (an unbiased stride estimate in the same key units, so
                  exact and sampled batches share one accumulator)

    Returns ``(replicas, new_counts)``: the same int32 replica ids the
    bare route produces (bit-exact — instrumentation must never change
    routing) and the accumulator advanced by this batch's (possibly
    sampled) bincount.  Replica ids are always in
    ``[0, n_total) ⊆ [0, capacity)``, so the scatter form carries
    ``promise_in_bounds`` and costs no clamp.
    """
    keys_u32 = keys.reshape(-1).astype(jnp.uint32)
    replicas = route(
        keys_u32, packed_mask, table, state, omega=omega, n_words=n_words
    )
    stride = 1 << sample_shift
    sampled = replicas[::stride] if sample_shift else replicas
    weight = np.uint32(stride)
    if sampled.shape[0] <= _ONEHOT_MAX:
        bins = jnp.arange(counts.shape[0], dtype=sampled.dtype)
        hist = jnp.sum(
            sampled[None, :] == bins[:, None], axis=1, dtype=jnp.uint32
        )
        new_counts = counts + hist * weight
    else:
        new_counts = counts.at[sampled].add(weight, mode="promise_in_bounds")
    return replicas.reshape(keys.shape), new_counts


@functools.partial(
    jax.jit, static_argnames=("omega", "n_words", "route", "sample_shift")
)
def _route_with_load_jit(keys, packed, table, state, counts, *, omega,
                         n_words, route, sample_shift=0):
    return route_with_load_impl(
        keys, packed, table, state, counts,
        omega=omega, n_words=n_words, route=route,
        sample_shift=sample_shift,
    )


# ---------------------------------------------------------------------------
# theory envelopes
# ---------------------------------------------------------------------------


def expected_peak_over_mean(total_keys: int, n_alive: int) -> float:
    """Expected max/mean shard load for ``total_keys`` uniform keys over
    ``n_alive`` shards: ≈ 1 + sqrt(2·n·ln n / m) (balls-into-bins maximum
    in the m >> n regime — the envelope ``bench_balance`` plots against)."""
    if n_alive <= 1 or total_keys <= 0:
        return 1.0
    return 1.0 + math.sqrt(
        2.0 * n_alive * math.log(n_alive) / float(total_keys)
    )


def disruption_bound(
    delta_events: int, n_before: int, n_after: int, slack: float
) -> float:
    """Allowed moved fraction for ``delta_events`` membership events: each
    event relocates one shard's share ≈ 1/n of the keys, so the window
    bound is ``slack · delta / max(n_before, n_after)`` capped at 1.  The
    slack absorbs hash-balance deviation of the affected shards' actual
    shares around 1/n (finite probe sets, small fleets)."""
    n = max(1, n_before, n_after)
    return min(1.0, slack * delta_events / n)


# ---------------------------------------------------------------------------
# host control plane
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    """Knobs for the load monitor."""

    #: drain the device accumulator to host every this many batches
    drain_every: int = 64
    #: batches of at most this many keys are counted exactly; bigger
    #: batches are stride-sampled (see ``sample_shift``).  The cutoff
    #: exists because exact counting is O(keys): on a single-core host a
    #: 1M-key histogram costs ~2 ms however it is phrased (scatter,
    #: one-hot reduce, even hand-written C) while the 3 % overhead budget
    #: against the fused route is < 1 ms — so at bulk sizes the counts
    #: must be estimated, and at serving sizes (≤ tens of thousands of
    #: keys per batch) they stay exact
    exact_cutoff: int = 1 << 15
    #: log2 stride for sampled batches: count replicas ``[::2**shift]``
    #: with weight ``2**shift``.  At the default 6, a 1M-key batch is
    #: estimated from 16 384 keys — per-shard relative stderr
    #: ≈ sqrt(2**shift · n / N) (~6 % for 64 shards), far inside the 2×
    #: balance-alarm threshold — for < 1 ms of accumulate work
    sample_shift: int = 6
    #: alarm when observed peak/mean exceeds this multiple of the expected
    #: peak/mean envelope
    balance_mult: float = 2.0
    #: skip the balance alarm below this many drained keys (the envelope
    #: is asymptotic; tiny samples are all noise)
    min_alarm_keys: int = 1_024
    #: slack on the delta/n disruption bound (see ``disruption_bound``)
    disruption_slack: float = 2.0
    #: probe keys the disruption tracker re-routes on epoch advance
    n_probe: int = 512
    probe_seed: int = 0x0B5E11

    def __post_init__(self):
        if self.drain_every < 1:
            raise ValueError(f"drain_every must be >= 1, got {self.drain_every}")
        if self.balance_mult <= 0 or self.disruption_slack <= 0:
            raise ValueError(
                f"need positive balance_mult / disruption_slack, got "
                f"{self.balance_mult} / {self.disruption_slack}"
            )
        if self.n_probe < 1:
            raise ValueError(f"n_probe must be >= 1, got {self.n_probe}")
        if self.sample_shift < 0:
            raise ValueError(
                f"sample_shift must be >= 0, got {self.sample_shift}"
            )
        if self.exact_cutoff < 0:
            raise ValueError(
                f"exact_cutoff must be >= 0, got {self.exact_cutoff}"
            )


class DisruptionTracker:
    """Moved-fraction-vs-bound telemetry, keyed to ``routing_epoch``."""

    def __init__(
        self,
        router,
        config: LoadConfig,
        metrics: MetricsRegistry,
        on_alarm=None,
    ):
        self.router = router
        self.config = config
        self.metrics = metrics
        self.on_alarm = on_alarm
        rng = np.random.default_rng(config.probe_seed)
        self._probe_host = rng.integers(
            0, 1 << 32, size=config.n_probe, dtype=np.uint32
        )
        self._probe_dev = jax.device_put(self._probe_host)
        self._epoch: int | None = None
        self._alive = 0
        self._routes: np.ndarray | None = None

    def _route_probes(self) -> np.ndarray:
        # straight through the dispatcher — bypasses the router's monitored
        # path so probe traffic never pollutes the load accumulator
        from repro.kernels import ops

        return np.asarray(
            ops.route_bulk(self._probe_dev, self.router._fleet_dev,
                           self.router.spec)
        )

    def observe(
        self,
        prev: np.ndarray,
        now: np.ndarray,
        delta_events: int,
        n_before: int,
        n_after: int,
        *,
        epoch: int | None = None,
    ) -> float:
        """Score one membership window: moved fraction vs the bound.

        Factored out of ``check`` so a pathological remap can be scored
        directly (the chaos suite seeds one to prove the alarm fires).
        """
        moved = float(np.mean(prev != now)) if len(prev) else 0.0
        bound = disruption_bound(
            delta_events, n_before, n_after, self.config.disruption_slack
        )
        self.metrics.gauge("load_moved_fraction").set(moved)
        self.metrics.gauge("load_moved_bound").set(bound)
        if moved > bound:
            self.metrics.counter("disruption_alarms_total").inc()
            deliver(
                DisruptionBoundAlarm(
                    moved,
                    bound,
                    delta_events=delta_events,
                    n_before=n_before,
                    n_after=n_after,
                    epoch=epoch,
                ),
                self.on_alarm,
            )
        return moved

    def check(self) -> float | None:
        """Re-route the probes if ``routing_epoch`` advanced since the last
        look; returns the moved fraction (None = no epoch advance).  Called
        on every drain — event-cadence work, never per batch."""
        epoch = self.router.routing_epoch
        alive = self.router.alive
        if self._epoch is None:
            if alive == 0:
                return None  # nothing routable yet; baseline on next check
            self._epoch, self._alive = epoch, alive
            self._routes = self._route_probes()
            return None
        if epoch == self._epoch or alive == 0:
            return None
        now = self._route_probes()
        moved = self.observe(
            self._routes,
            now,
            epoch - self._epoch,
            self._alive,
            alive,
            epoch=epoch,
        )
        self._epoch, self._alive, self._routes = epoch, alive, now
        return moved


class LoadMonitor:
    """Per-shard load telemetry over a ``BatchRouter``'s routed batches.

    Attaching flips the router's fused dispatch to the instrumented pass
    (``ops.route_load_bulk``): every batch advances a device-resident
    accumulator in the same dispatch that routes it — exactly for batches
    up to ``config.exact_cutoff`` keys, by deterministic stride sample
    (weight ``2**config.sample_shift``, same key units) above it, so
    ``totals`` reads as per-shard key counts either way (exact counts
    when every batch fit under the cutoff, unbiased estimates otherwise).
    ``drain()`` runs on the configured batch cadence (or on demand): one
    host transfer, registry updates, balance-envelope evaluation and a
    disruption-bound check — see the module docstring for the full
    protocol.
    """

    def __init__(
        self,
        router,
        metrics: MetricsRegistry | None = None,
        config: LoadConfig | None = None,
        on_alarm=None,
    ):
        self.router = router
        self.config = config or LoadConfig()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.on_alarm = on_alarm
        self.tracker = DisruptionTracker(
            router, self.config, self.metrics, on_alarm=on_alarm
        )
        #: pinned once; drains re-point the accumulator here (zero uploads)
        self._zeros_dev = jax.device_put(
            np.zeros((router.capacity,), np.uint32)
        )
        self._counts_dev = self._zeros_dev
        self._window_batches = 0
        self._window_keys = 0
        #: host-side cumulative per-shard totals across drains
        self.totals = np.zeros((router.capacity,), np.uint64)
        self.total_keys = 0
        self.drains = 0
        router.attach_load_monitor(self)

    # -- router-facing surface ----------------------------------------------
    @property
    def counts_dev(self) -> jax.Array:
        """The live device accumulator (the instrumented dispatch operand)."""
        return self._counts_dev

    def effective_shift(self, n_keys: int) -> int:
        """Count-sampling shift for a batch of ``n_keys``: 0 (exact) at or
        below ``config.exact_cutoff``, ``config.sample_shift`` above it."""
        return 0 if n_keys <= self.config.exact_cutoff else \
            self.config.sample_shift

    def note_dispatch(self, new_counts: jax.Array, n_keys: int) -> None:
        """Called by the router after each instrumented dispatch with the
        advanced accumulator; drains when the window cadence is reached."""
        self._counts_dev = new_counts
        self._window_batches += 1
        self._window_keys += int(n_keys)
        if self._window_batches >= self.config.drain_every:
            self.drain()

    def detach(self) -> None:
        self.router.detach_load_monitor()

    # -- drain protocol ------------------------------------------------------
    def _alive_slots(self) -> list[int]:
        removed = self.router.domain.removed
        return [
            s for s in range(self.router.domain.total_count)
            if s not in removed
        ]

    def drain(self) -> np.ndarray:
        """Pull the window's per-shard counts to host; evaluate envelopes.

        Returns the window counts (capacity-length).  The device
        accumulator is reset by re-pointing at the pinned zeros array —
        no upload.
        """
        window = np.asarray(self._counts_dev)
        self._counts_dev = self._zeros_dev
        n_batches, self._window_batches = self._window_batches, 0
        self._window_keys = 0
        self.totals += window.astype(np.uint64)
        self.total_keys = int(self.totals.sum())
        self.drains += 1

        m = self.metrics
        m.counter("load_drains_total").inc()
        m.counter("load_keys_total").inc(int(window.sum()))
        alive = self._alive_slots()
        for s in alive:
            m.gauge("load_shard_keys", shard=str(s)).set(int(self.totals[s]))
        ratio = self.peak_over_mean(alive)
        if ratio is not None:
            m.gauge("load_peak_over_mean").set(ratio)
            self._check_balance(ratio, alive)
        self.tracker.check()
        return window

    def peak_over_mean(self, alive: list[int] | None = None) -> float | None:
        """Peak/mean cumulative load over the currently-alive shards
        (None when nothing routed yet or fewer than two shards live)."""
        if alive is None:
            alive = self._alive_slots()
        if len(alive) < 2:
            return None
        loads = self.totals[alive].astype(np.float64)
        total = loads.sum()
        if total == 0:
            return None
        return float(loads.max() / (total / len(alive)))

    def _check_balance(self, ratio: float, alive: list[int]) -> None:
        alive_keys = int(self.totals[alive].sum())
        if alive_keys < self.config.min_alarm_keys:
            return
        expected = expected_peak_over_mean(alive_keys, len(alive))
        threshold = self.config.balance_mult * expected
        if ratio > threshold:
            self.metrics.counter("balance_alarms_total").inc()
            deliver(
                BalanceDriftAlarm(
                    ratio,
                    expected,
                    threshold,
                    n_alive=len(alive),
                    total_keys=alive_keys,
                    epoch=self.router.routing_epoch,
                ),
                self.on_alarm,
            )

    def reset(self) -> None:
        """Zero every accumulator (device window + host totals)."""
        self._counts_dev = self._zeros_dev
        self._window_batches = self._window_keys = 0
        self.totals = np.zeros_like(self.totals)
        self.total_keys = 0
