"""Config registry: the 10 assigned architectures + the paper benchmark config."""
from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    apply_overrides,
    shape_applicable,
)

# -- dense LM family --------------------------------------------------------

DEEPSEEK_CODER_33B = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100000.0,
    norm="rmsnorm",
    mlp="swiglu",
)

STARCODER2_7B = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    window=4096,  # sliding-window attention (arXiv:2402.19173)
    norm="layernorm",
    norm_bias=True,
    mlp="gelu",
    mlp_bias=True,
    qkv_bias=True,
)

QWEN2_5_14B = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    norm="rmsnorm",
    mlp="swiglu",
)

STABLELM_3B = ArchConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    rope_fraction=0.25,
    norm="layernorm",
    mlp="swiglu",
)

# -- MoE family --------------------------------------------------------------

DEEPSEEK_V3_671B = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,  # dense layers
    vocab_size=129280,
    attention="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        shared_experts=1,
        router="sigmoid",
    ),
    moe_layer_start=3,  # first 3 layers dense
    mtp_depth=1,
    fsdp=True,
)

QWEN3_MOE_235B = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=12288,  # (unused: all layers MoE)
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536, router="topk"),
    moe_layer_start=0,
    fsdp=True,
)

# -- hybrid / SSM ------------------------------------------------------------

RECURRENTGEMMA_9B = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    window=2048,  # local attention layers
    pattern=("rec", "rec", "attn"),
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
    mlp="geglu",
    norm="rmsnorm",
)

MAMBA2_1_3B = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    pattern=("ssd",),
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1, conv_width=4, chunk=256),
    tie_embeddings=True,
)

# -- modality backbones (frontends stubbed; see DESIGN.md §5) -----------------

MUSICGEN_MEDIUM = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    input_mode="embeds",  # EnCodec frame embeddings provided by the stub
    pos_emb="sinusoidal",
    norm="layernorm",
    norm_bias=True,
    mlp="gelu",
)

QWEN2_VL_7B = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    input_mode="embeds_mrope",  # patch/text embeddings provided by the stub
    pos_emb="mrope",
    mrope_sections=(16, 24, 24),
    qkv_bias=True,
    rope_theta=1000000.0,
    norm="rmsnorm",
    mlp="swiglu",
)

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        DEEPSEEK_CODER_33B,
        STARCODER2_7B,
        QWEN2_5_14B,
        STABLELM_3B,
        DEEPSEEK_V3_671B,
        QWEN3_MOE_235B,
        RECURRENTGEMMA_9B,
        MAMBA2_1_3B,
        MUSICGEN_MEDIUM,
        QWEN2_VL_7B,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(name: str) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (per assignment rules)."""
    import dataclasses

    cfg = get_config(name)
    kw: dict = dict(
        num_layers=max(2, len(cfg.pattern)) if len(cfg.pattern) > 1 else 2,
        d_model=64,
        vocab_size=256,
        dtype="float32",
        param_dtype="float32",
        remat="none",
    )
    if cfg.attention != "none":
        kw.update(num_heads=4, num_kv_heads=max(1, min(cfg.num_kv_heads, 2)), head_dim=16)
        if cfg.num_kv_heads == cfg.num_heads:
            kw.update(num_kv_heads=4)  # keep the MHA family trait
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.moe is not None:
        # capacity_factor 8 -> no token dropping, so cached decode is exactly
        # consistent with the full forward in the tiny smoke regime
        kw.update(
            moe=dataclasses.replace(
                cfg.moe, num_experts=8, top_k=2, d_ff_expert=32, capacity_factor=8.0
            )
        )
        kw.update(moe_layer_start=min(cfg.moe_layer_start, 1), num_layers=3)
    if cfg.mla is not None:
        kw.update(
            mla=MLAConfig(
                q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16, qk_rope_head_dim=8,
                v_head_dim=16,
            )
        )
    if cfg.ssm is not None:
        kw.update(ssm=dataclasses.replace(cfg.ssm, d_state=16, head_dim=8, chunk=8))
    if cfg.rglru is not None:
        kw.update(rglru=dataclasses.replace(cfg.rglru, lru_width=64), num_layers=len(cfg.pattern) + 2)
    if cfg.window is not None:
        kw.update(window=16)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(4, 2, 2))
    kw.update(fsdp=False, mtp_depth=cfg.mtp_depth)
    return dataclasses.replace(cfg, **kw)


# The paper's own benchmark "config": cluster sizes for the hashing suite.
PAPER_BENCH = {
    "cluster_sizes": [10, 100, 1000, 10_000, 100_000],
    "keys_per_node": 1000,
    "omega": 64,
}
