"""Architecture / run configuration system.

``ArchConfig`` fully describes one of the assigned architectures; shape
presets describe the (seq_len, global_batch, kind) grid.  Configs are plain
frozen dataclasses; CLI overrides are ``key=value`` strings parsed by
``apply_overrides``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_experts: int = 0
    router: str = "topk"  # topk | sigmoid | hash  (hash = BinomialHash routing)
    aux_loss_weight: float = 0.01
    capacity_factor: float = 1.25
    router_hash_omega: int = 16  # lookup iteration bound of the hash router
    # hash router only: which BULK_ENGINES lookup routes tokens (binomial is
    # the paper engine; jump selects the JumpHash device flavour)
    router_hash_engine: str = "binomial"
    # hash router only: route via the traced-n lookup (lookup_dyn),
    # so standalone/eager routing passes (placement studies, routing sweeps)
    # share one compiled router trace across expert counts. NOTE: inside a
    # jitted model step num_experts is still a static config field, so the
    # step itself retraces on resize regardless of this flag.
    router_dynamic_n: bool = False


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0  # 0 -> d_model
    conv_width: int = 4
    c: float = 8.0  # RG-LRU exponent scale


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention flavour
    attention: str = "gqa"  # gqa | mla | none
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None  # sliding-window size (None = full causal)
    pos_emb: str = "rope"  # rope | mrope | sinusoidal
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    mrope_sections: tuple[int, ...] = ()  # thirds of head_dim/2 for M-RoPE

    # norm / mlp flavour
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_bias: bool = False
    mlp: str = "swiglu"  # swiglu | gelu | geglu
    mlp_bias: bool = False

    # block schedule: pattern repeated to cover num_layers
    # entries: attn | rec | ssd ; moe_layer_start marks dense->moe switch
    pattern: tuple[str, ...] = ("attn",)
    moe: Optional[MoEConfig] = None
    moe_layer_start: int = 0
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # io
    input_mode: str = "tokens"  # tokens | embeds | embeds_mrope
    tie_embeddings: bool = False
    mtp_depth: int = 0  # DeepSeek-V3 multi-token prediction depth

    # numerics / distribution
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: str = "full"  # none | full | dots
    fsdp: bool = False  # ZeRO-3 weight sharding along the data axis
    scan_layers: bool = True

    # sub-quadratic? (drives long_500k applicability)
    @property
    def subquadratic(self) -> bool:
        return self.window is not None or self.family in ("ssm", "hybrid")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so (vocab, d) params shard evenly
        on the 16-way model axis (standard MaxText-style vocab padding —
        padded classes are ordinary, never-targeted logits)."""
        if self.vocab_size % 256 == 0 or self.vocab_size < 4096:
            return self.vocab_size
        return (self.vocab_size + 255) // 256 * 256

    def layer_kinds(self) -> list[str]:
        """Expanded per-layer block kinds, honouring pattern + moe start."""
        kinds = []
        for i in range(self.num_layers):
            k = self.pattern[i % len(self.pattern)]
            if k == "attn" and self.moe is not None and i >= self.moe_layer_start:
                k = "attn_moe"
            kinds.append(k)
        return kinds


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason) — encodes the long_500k sub-quadratic rule."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            f"{cfg.name} is pure full attention; a 524288-token decode KV cache "
            "is the defining cost and the arch has no sub-quadratic mode "
            "(see DESIGN.md §5)"
        )
    return True, ""


def apply_overrides(cfg: ArchConfig, overrides: list[str]) -> ArchConfig:
    """Apply ``key=value`` CLI overrides (ints/floats/bools auto-coerced)."""
    kv = {}
    fields = {f.name: f for f in dataclasses.fields(ArchConfig)}
    for ov in overrides:
        k, _, v = ov.partition("=")
        if k not in fields:
            raise KeyError(f"unknown config field '{k}'")
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            kv[k] = v.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            kv[k] = int(v)
        elif isinstance(cur, float):
            kv[k] = float(v)
        else:
            kv[k] = v
    return replace(cfg, **kv)
