"""Serving-tier request router built on BinomialHash + Memento failures.

Sessions (chat threads / users) are routed to replicas by consistent hashing
so that (a) load is balanced (paper Eq. 3 bound), (b) a session sticks to its
replica across requests — KV-cache / prefix-cache affinity — and (c) scaling
the replica fleet up/down or losing a replica moves only the minimal set of
sessions (whose prefixes must be re-prefetched; everyone else's cache stays
hot).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import bits
from repro.placement.elastic import FailureDomain


@dataclass
class RoutingStats:
    lookups: int = 0
    moved_sessions: int = 0
    events: list = field(default_factory=list)


class SessionRouter:
    def __init__(self, n_replicas: int, engine: str = "binomial"):
        self.domain = FailureDomain(n_replicas, engine)
        self.stats = RoutingStats()
        self._last: dict[int, int] = {}  # session -> replica (observability only)

    @staticmethod
    def session_key(session_id: str | int) -> int:
        if isinstance(session_id, str):
            h = 0xCBF29CE484222325
            for b in session_id.encode():
                h = ((h ^ b) * 0x100000001B3) & bits.MASK64
            return h
        return bits.mix64(session_id)

    def route(self, session_id: str | int) -> int:
        key = self.session_key(session_id)
        replica = self.domain.locate(key)
        self.stats.lookups += 1
        prev = self._last.get(key)
        if prev is not None and prev != replica:
            self.stats.moved_sessions += 1
        self._last[key] = replica
        return replica

    # -- fleet events -----------------------------------------------------------
    def scale_up(self) -> int:
        r = self.domain.scale_up()
        self.stats.events.append(("scale_up", r))
        return r

    def scale_down(self) -> int:
        r = self.domain.scale_down()
        self.stats.events.append(("scale_down", r))
        return r

    def fail(self, replica: int) -> None:
        self.domain.fail(replica)
        self.stats.events.append(("fail", replica))

    def recover(self, replica: int) -> None:
        self.domain.recover(replica)
        self.stats.events.append(("recover", replica))

    @property
    def alive(self) -> int:
        return self.domain.alive_count
