"""Serving-tier request router built on BinomialHash + Memento failures.

Sessions (chat threads / users) are routed to replicas by consistent hashing
so that (a) load is balanced (paper Eq. 3 bound), (b) a session sticks to its
replica across requests — KV-cache / prefix-cache affinity — and (c) scaling
the replica fleet up/down or losing a replica moves only the minimal set of
sessions (whose prefixes must be re-prefetched; everyone else's cache stays
hot).

Two tiers share this architecture:

* ``SessionRouter`` (this module) — the scalar control plane: one Python
  lookup per call through ``FailureDomain.locate``.  With
  ``engine="binomial32", chain_bits=32, resolve="table"`` it is the
  bit-exact oracle for the batched datapath (``resolve="chain"`` keeps the
  paper-faithful rejection-chain flavour for library use).
* ``BatchRouter`` (``repro.serving.batch_router``) — the device datapath:
  whole request batches flow through the fused lookup+divert kernel
  (cluster size as a scalar-prefetch operand, removed-slot mask and
  replacement table as fixed-capacity device arrays — DESIGN.md §3, §7).
  Fleet events mutate only small traced operands, so scale/fail streams
  never retrace or recompile, and the bounded table divert keeps storm-time
  batch cost equal to steady-time cost.

``ServingTier`` routes with the batched tier and falls back to the scalar
path for single lookups; both agree key-for-key by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import bits
from repro.placement.elastic import FailureDomain


@dataclass
class RoutingStats:
    lookups: int = 0
    moved_sessions: int = 0
    events: list = field(default_factory=list)


class SessionRouter:
    def __init__(
        self,
        n_replicas: int,
        engine: str = "binomial",
        chain_bits: int = 64,
        omega: int | None = None,
        max_chain: int = 4096,
        resolve: str = "chain",
    ):
        self.domain = FailureDomain(
            n_replicas,
            engine,
            chain_bits=chain_bits,
            omega=omega,
            max_chain=max_chain,
            resolve=resolve,
        )
        self.stats = RoutingStats()
        self._last: dict[int, int] = {}  # session -> replica (observability only)

    @staticmethod
    def session_key(session_id: str | int) -> int:
        if isinstance(session_id, str):
            h = 0xCBF29CE484222325
            for b in session_id.encode():
                h = ((h ^ b) * 0x100000001B3) & bits.MASK64
            return h
        return bits.mix64(session_id)

    def route(self, session_id: str | int) -> int:
        key = self.session_key(session_id)
        replica = self.domain.locate(key)
        self.stats.lookups += 1
        self.note_routes((key,), (replica,))
        return replica

    #: cap on the observability map: beyond this many distinct sessions, NEW
    #: sessions are no longer movement-tracked (routing itself is stateless
    #: and unaffected) — bounds resident memory over long serving lifetimes
    LAST_MAX = 1 << 20

    def note_routes(self, keys, replicas) -> None:
        """Bulk observability update: record key -> replica, count movers.

        Used by the batched datapath (``BatchRouter.route_batch``) so the
        ``moved_sessions`` metric keeps working when routing bypasses the
        scalar ``route``.
        """
        last = self._last
        for key, replica in zip(keys, replicas):
            replica = int(replica)
            prev = last.get(key)
            if prev is None:
                if len(last) < self.LAST_MAX:
                    last[key] = replica
                continue
            if prev != replica:
                self.stats.moved_sessions += 1
                last[key] = replica

    # -- fleet events -----------------------------------------------------------
    def scale_up(self) -> int:
        r = self.domain.scale_up()
        self.stats.events.append(("scale_up", r))
        return r

    def scale_down(self) -> int:
        r = self.domain.scale_down()
        self.stats.events.append(("scale_down", r))
        return r

    def fail(self, replica: int) -> None:
        self.domain.fail(replica)
        self.stats.events.append(("fail", replica))

    def recover(self, replica: int) -> None:
        self.domain.recover(replica)
        self.stats.events.append(("recover", replica))

    @property
    def alive(self) -> int:
        return self.domain.alive_count
