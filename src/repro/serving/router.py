"""Serving-tier request router built on BinomialHash + Memento failures.

Sessions (chat threads / users) are routed to replicas by consistent hashing
so that (a) load is balanced (paper Eq. 3 bound), (b) a session sticks to its
replica across requests — KV-cache / prefix-cache affinity — and (c) scaling
the replica fleet up/down or losing a replica moves only the minimal set of
sessions (whose prefixes must be re-prefetched; everyone else's cache stays
hot).

Two tiers share this architecture:

* ``SessionRouter`` (this module) — the scalar control plane: one Python
  lookup per call through ``FailureDomain.locate``.  With
  ``engine="binomial32", chain_bits=32, resolve="table"`` it is the
  bit-exact oracle for the batched datapath (``resolve="chain"`` keeps the
  paper-faithful rejection-chain flavour for library use).
* ``BatchRouter`` (``repro.serving.batch_router``) — the device datapath:
  whole request batches flow through the fused lookup+divert kernel
  (cluster size as a scalar-prefetch operand, removed-slot mask and
  replacement table as fixed-capacity device arrays — DESIGN.md §3, §7).
  Fleet events mutate only small traced operands, so scale/fail streams
  never retrace or recompile, and the bounded table divert keeps storm-time
  batch cost equal to steady-time cost.

Session-id ingest is batched too (DESIGN.md §9): ``hash_session_ids``
vectorises ``session_key`` over whole request batches (padded byte-matrix
FNV-1a for strings, ``np_mix64`` for ints — bit-exact with the scalar
loop), and movement observability flows through the bulk open-addressing
``SessionStore`` instead of a per-key dict walk.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import bits
from repro.placement.elastic import FailureDomain
from repro.serving.lifecycle.errors import FleetUnavailableError
from repro.serving.session_store import SessionStore


def encode_session_ids(session_ids) -> tuple[np.ndarray, np.ndarray]:
    """String session ids -> padded ``(N, L)`` uint8 byte matrix + lengths.

    Two constructions, picked per batch:

    * **ASCII fast path** — join the whole batch and UTF-8 encode ONCE (two
      C calls); if the byte count equals the char count the batch is pure
      ASCII, so per-id char lengths are byte lengths and the flat buffer
      slices straight into rows: a free ``reshape`` when every id has the
      same length (the common shape), one masked scatter otherwise.
    * **general path** — UTF-8 encode each id (the one remaining per-item
      Python step), then let numpy's fixed-width bytes dtype pad the rows
      into a zero-filled matrix.

    Rows are byte prefixes + zero padding either way, so ``bits.np_fnv1a64``
    can hash the whole batch in L masked column passes.  Raises TypeError
    for non-str elements (the callers' mixed-batch fallback signal).
    """
    n = len(session_ids)
    lengths = np.fromiter(map(len, session_ids), dtype=np.int64, count=n)
    if n == 0:
        return np.zeros((0, 0), dtype=np.uint8), lengths
    joined = "".join(session_ids)
    raw = joined.encode()
    if len(raw) == len(joined):  # pure ASCII: char lengths ARE byte lengths
        flat = np.frombuffer(raw, dtype=np.uint8)
        max_len = int(lengths.max())
        if max_len == 0:
            return np.zeros((n, 0), dtype=np.uint8), lengths
        if (lengths == max_len).all():
            return flat.reshape(n, max_len), lengths
        mat = np.zeros((n, max_len), dtype=np.uint8)
        mat[np.arange(max_len) < lengths[:, None]] = flat
        return mat, lengths
    # non-ASCII: UTF-8 byte lengths differ from char counts — encode per id
    encoded = list(map(str.encode, session_ids))
    lengths = np.fromiter(map(len, encoded), dtype=np.int64, count=n)
    max_len = int(lengths.max())
    mat = (
        np.array(encoded, dtype=f"S{max_len}").view(np.uint8).reshape(n, max_len)
    )
    return mat, lengths


def _hash_str_batch(session_ids) -> np.ndarray:
    mat, lengths = encode_session_ids(session_ids)
    return bits.np_fnv1a64(mat, lengths)


def _hash_int_batch(session_ids) -> np.ndarray:
    # mask to the u64 key space exactly like the scalar oracle (mix64 wraps);
    # raises TypeError for str elements (the mixed-batch fallback signal)
    ints = np.fromiter(
        (i & bits.MASK64 for i in session_ids), dtype=np.uint64, count=len(session_ids)
    )
    return bits.np_mix64(ints)


def hash_session_ids(session_ids) -> np.ndarray:
    """Vectorised ``SessionRouter.session_key`` over a whole batch.

    Accepts an int ndarray (``np_mix64`` directly, zero per-item Python), or
    a sequence of str / int session ids (mixed freely); returns the uint64
    session keys, bit-exact with the scalar ``session_key`` per element.

    Type dispatch costs nothing extra on homogeneous batches: the hash path
    matching the first element is attempted outright, and its own length /
    mask pass doubles as the type check (a TypeError from a mismatched
    element falls back to the partition-and-reinterleave path).
    """
    if isinstance(session_ids, np.ndarray):
        if session_ids.dtype.kind in "iu":
            return bits.np_mix64(session_ids.astype(np.uint64, copy=False))
        session_ids = session_ids.tolist()
    elif not isinstance(session_ids, (list, tuple)):
        # accept any iterable (generators, sets, ...) like the scalar
        # per-item loop this replaced — the batch paths need len + indexing
        session_ids = list(session_ids)
    n = len(session_ids)
    if n == 0:
        return np.empty(0, dtype=np.uint64)
    try:
        if isinstance(session_ids[0], str):
            return _hash_str_batch(session_ids)
        return _hash_int_batch(session_ids)
    except TypeError:
        pass
    # mixed batch: partition by type, hash each side, re-interleave
    is_str = np.fromiter(
        (isinstance(s, str) for s in session_ids), dtype=bool, count=n
    )
    out = np.empty(n, dtype=np.uint64)
    s_idx = np.flatnonzero(is_str)
    i_idx = np.flatnonzero(~is_str)
    if s_idx.size:
        out[s_idx] = _hash_str_batch([session_ids[i] for i in s_idx])
    if i_idx.size:
        out[i_idx] = _hash_int_batch([session_ids[i] for i in i_idx])
    return out


@dataclass
class RoutingStats:
    lookups: int = 0
    moved_sessions: int = 0
    events: list = field(default_factory=list)


class SessionRouter:
    def __init__(
        self,
        n_replicas: int,
        engine: str = "binomial",
        chain_bits: int = 64,
        omega: int | None = None,
        max_chain: int = 4096,
        resolve: str = "chain",
        allow_empty: bool = False,
    ):
        self.domain = FailureDomain(
            n_replicas,
            engine,
            chain_bits=chain_bits,
            omega=omega,
            max_chain=max_chain,
            resolve=resolve,
            allow_empty=allow_empty,
        )
        self.stats = RoutingStats()
        #: session key -> last replica (observability only): bulk
        #: open-addressing store, vectorised probe/insert (DESIGN.md §9)
        self._last = SessionStore(max_entries=self.LAST_MAX)

    @staticmethod
    def session_key(session_id: str | int) -> int:
        if isinstance(session_id, str):
            h = bits.FNV64_OFFSET
            for b in session_id.encode():
                h = ((h ^ b) * bits.FNV64_PRIME) & bits.MASK64
            return h
        return bits.mix64(session_id)

    def route(self, session_id: str | int) -> int:
        if self.domain.alive_count == 0:
            raise FleetUnavailableError()
        key = self.session_key(session_id)
        replica = self.domain.locate(key)
        self.stats.lookups += 1
        self.note_routes((key,), (replica,))
        return replica

    #: cap on the observability store: beyond this many distinct sessions,
    #: NEW sessions are no longer movement-tracked (routing itself is
    #: stateless and unaffected) — bounds resident memory over long serving
    #: lifetimes
    LAST_MAX = 1 << 20

    def note_routes(self, keys, replicas) -> None:
        """Bulk observability update: record key -> replica, count movers.

        Used by the batched datapath (``BatchRouter.route_batch``) so the
        ``moved_sessions`` metric keeps working when routing bypasses the
        scalar ``route``.  One vectorised ``SessionStore.record`` call — no
        per-key Python, so at ingest batch sizes this is noise next to the
        routing dispatch itself; single-key calls (the scalar ``route``
        path) take the plain-int probe instead of paying the vectorised
        machinery's fixed cost.
        """
        if len(keys) == 1:
            self.stats.moved_sessions += self._last.record_one(
                int(keys[0]), int(replicas[0])
            )
            return
        self.stats.moved_sessions += self._last.record(
            np.asarray(keys, dtype=np.uint64),
            np.asarray(replicas),
        )

    # -- fleet events -----------------------------------------------------------
    def scale_up(self) -> int:
        r = self.domain.scale_up()
        self.stats.events.append(("scale_up", r))
        return r

    def scale_down(self) -> int:
        r = self.domain.scale_down()
        self.stats.events.append(("scale_down", r))
        return r

    def fail(self, replica: int) -> None:
        self.domain.fail(replica)
        self.stats.events.append(("fail", replica))

    def recover(self, replica: int) -> None:
        self.domain.recover(replica)
        self.stats.events.append(("recover", replica))

    @property
    def alive(self) -> int:
        return self.domain.alive_count
