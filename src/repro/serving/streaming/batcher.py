"""Micro-batching ingest loop: accumulate → one fused dispatch → overlap.

The streaming tier's core (DESIGN.md §14).  Requests accumulate in an open
batch until ``max_batch`` is reached or ``max_wait_us`` elapses, then the
whole batch routes in ONE fused device dispatch through the
lifecycle-wrapped router — the same single-dispatch datapath as the batch
tier, now fed by a continuous stream.  The pipeline is one deep
(double-buffered): while batch *k* computes on device, batch *k+1* fills
and its ``jax.device_put`` overlaps the in-flight compute (JAX async
dispatch); the handle is only materialised when the next batch closes.

**Deadline discipline.**  Admission (``AdmissionController``) sheds
requests that cannot possibly make their SLO; at batch close the second
gate runs: a request is served only if

    dispatch_start + service_bound_us <= deadline_us + max_wait_us

— i.e. its *predicted* overshoot is at most one batch window.  Everything
else is shed typed (``SHED_LATE``).  Under any service model that honours
the declared ``service_bound_us``, an admitted-and-served request
therefore misses its deadline by AT MOST one batch window — the invariant
the chaos ``overload``/``latency_spike`` storylines assert seed after
seed.  The bound is a *declaration* (an SLO capacity statement), not a
measurement: observed service time is EWMA-tracked into the registry's
``stream_service_ewma_us`` gauge for observability but never silently
substituted into the guarantee.

Telemetry (DESIGN.md §15): served/dispatch counters, batch-size and
per-tenant request-latency histograms all land in the shared
``MetricsRegistry``, and with a ``SpanTrace`` attached the batcher
records the request-path spans (``admit`` at submit, ``batch_close`` +
``dispatch`` at close, one ``request`` span per served request at
collect) on the same µs timeline the batcher itself runs on.

Time is pluggable (``clock.now_us()``): virtual for chaos/bench
determinism, wall for production.  In virtual mode the service model is
injected too; in wall mode the materialisation block is measured.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.serving.lifecycle.errors import SHED_LATE

from .admission import AdmissionConfig, AdmissionController
from .clock import WallClockUs


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Knobs for the streaming front end (all times in µs)."""

    #: close the open batch at this many requests
    max_batch: int = 64
    #: ... or this long after its first request arrived
    max_wait_us: int = 1_000
    #: declared per-dispatch service bound (SLO capacity statement)
    service_bound_us: int = 2_000
    #: hedge a suspect-primary read after this long without a response
    hedge_after_us: int = 300
    #: per-tenant token-bucket rate (requests/s); None = unlimited
    tenant_rate_per_s: float | None = None
    #: per-tenant burst ceiling
    tenant_burst: float = 32.0

    def __post_init__(self):
        if self.max_batch < 1 or self.max_wait_us < 0:
            raise ValueError(
                f"need max_batch >= 1 and max_wait_us >= 0, got "
                f"{self.max_batch} / {self.max_wait_us}"
            )
        if self.service_bound_us <= 0 or self.hedge_after_us < 0:
            raise ValueError(
                f"need service_bound_us > 0 and hedge_after_us >= 0, got "
                f"{self.service_bound_us} / {self.hedge_after_us}"
            )

    def admission(self) -> AdmissionConfig:
        return AdmissionConfig(
            service_bound_us=self.service_bound_us,
            max_wait_us=self.max_wait_us,
            tenant_rate_per_s=self.tenant_rate_per_s,
            tenant_burst=self.tenant_burst,
        )


@dataclasses.dataclass
class StreamRequest:
    """One streamed routing request: a key, the tenant it bills to, and the
    absolute µs deadline its SLO allows."""

    key: int
    deadline_us: int
    tenant: str = "default"
    #: stamped by the batcher at submit
    arrival_us: int = -1


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """A served request: where it routed and when it completed."""

    request: StreamRequest
    replica: int
    t_dispatch_us: int
    t_complete_us: int
    epoch: int
    mode: str

    @property
    def latency_us(self) -> int:
        return self.t_complete_us - self.request.arrival_us

    @property
    def deadline_miss_us(self) -> int:
        """How far past its deadline this request completed (0 = in SLO)."""
        return max(0, self.t_complete_us - self.request.deadline_us)


class LifecycleDispatch:
    """Default dispatch: tick the lifecycle (detector poll + one bounded
    repair batch), ``device_put`` the key batch, ONE fused route.  The
    returned handle is lazy — JAX async dispatch keeps the device busy
    while the next batch fills; ``result()`` materialises."""

    def __init__(self, mgr, on_events=None):
        self.mgr = mgr
        #: optional callback handed the detector events each tick surfaces
        #: (chaos/observability hooks)
        self.on_events = on_events

    def __call__(self, keys_u32: np.ndarray) -> "_RouteHandle":
        import jax
        import jax.numpy as jnp

        events = self.mgr.tick()
        if events and self.on_events is not None:
            self.on_events(events)
        dev = jax.device_put(jnp.asarray(keys_u32, dtype=jnp.uint32))
        return _RouteHandle(self.mgr.route_keys(dev))


class _RouteHandle:
    def __init__(self, batch):
        self._batch = batch

    def result(self) -> tuple[np.ndarray, int, str]:
        reps = np.asarray(self._batch.replicas, dtype=np.int64)
        return reps, self._batch.epoch, self._batch.mode


@dataclasses.dataclass
class _Inflight:
    requests: list
    handle: object
    t_dispatch_us: int
    #: predicted completion (drives pipeline back-pressure + admission ETA)
    eta_us: int


class MicroBatcher:
    """Accumulate → close → dispatch → overlap, with two-stage shedding.

    ``dispatch_fn(keys_u32) -> handle`` routes one closed batch (handle
    materialises to ``(replicas, epoch, mode)``); ``service_model(n)``
    returns simulated per-dispatch service µs (None = measure the
    materialisation block in wall time).
    """

    def __init__(
        self,
        dispatch_fn: Callable[[np.ndarray], object],
        config: StreamConfig | None = None,
        clock=None,
        admission: AdmissionController | None = None,
        service_model: Callable[[int], int] | None = None,
        metrics=None,
        tracer=None,
    ):
        self.config = config or StreamConfig()
        self.clock = clock or WallClockUs()
        self.dispatch_fn = dispatch_fn
        if metrics is None:
            if admission is not None:
                metrics = admission.metrics  # share the controller's ledger
            else:
                from repro.observability.metrics import MetricsRegistry

                metrics = MetricsRegistry(clock=self.clock)
        self.metrics = metrics
        self.tracer = tracer
        self.admission = admission or AdmissionController(
            self.config.admission(), metrics=metrics
        )
        self.service_model = service_model
        self._open: list[StreamRequest] = []
        self._open_since_us: int | None = None
        self._inflight: _Inflight | None = None
        self._last_done_us = 0
        self._completed: list[StreamResult] = []
        #: EWMA of observed service µs (observability only — the guarantee
        #: reasons against the declared bound, never this); mirrored to the
        #: ``stream_service_ewma_us`` gauge on every collect
        self.service_ewma_us: float = float(self.config.service_bound_us)
        self._served = metrics.counter("stream_served_total")
        self._dispatched = metrics.counter("stream_dispatches_total")
        self._batch_sizes = metrics.histogram(
            "stream_batch_size",
            bounds=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024),
        )

    #: registry-backed counters, exposed under the historical names
    @property
    def served(self) -> int:
        return self._served.value

    @property
    def dispatches(self) -> int:
        return self._dispatched.value

    # -- pipeline state -------------------------------------------------------
    @property
    def open_depth(self) -> int:
        return len(self._open)

    @property
    def inflight_depth(self) -> int:
        return len(self._inflight.requests) if self._inflight else 0

    def dispatch_eta_us(self, now_us: int) -> int:
        """Earliest possible dispatch start for a request arriving now —
        the one-deep pipeline is busy until the in-flight batch's ETA."""
        eta = self._inflight.eta_us if self._inflight else now_us
        return max(now_us, eta)

    # -- ingest ---------------------------------------------------------------
    def submit(self, request: StreamRequest) -> None:
        """Admit (or raise ``AdmissionRejectedError``) and enqueue."""
        now = self.clock.now_us()
        request.arrival_us = now
        self.admission.admit(
            request.tenant, request.deadline_us, now, self.dispatch_eta_us(now)
        )
        if self.tracer is not None:
            self.tracer.record(
                "admit", now, now, tenant=request.tenant,
                deadline_us=request.deadline_us,
            )
        if not self._open:
            self._open_since_us = now
        self._open.append(request)
        if len(self._open) >= self.config.max_batch:
            self._close(now)

    def pump(self) -> list[StreamResult]:
        """Advance time-driven transitions: close the open batch if its
        window expired, materialise a due in-flight batch, and hand back
        everything completed since the last call."""
        now = self.clock.now_us()
        if self._inflight is not None and now >= self._inflight.eta_us:
            self._collect()
        # close on window expiry only when the pipeline slot is free: while
        # the device is busy the open batch keeps filling (adaptive sizing —
        # dispatching a sliver mid-backlog would waste the dispatch slot and
        # collapse throughput below capacity)
        if (
            self._open
            and self._inflight is None
            and self._open_since_us is not None
            and now - self._open_since_us >= self.config.max_wait_us
        ):
            self._close(now)
        out = self._completed
        self._completed = []
        return out

    def drain(self) -> list[StreamResult]:
        """Flush everything: close any open batch, materialise in-flight."""
        now = self.clock.now_us()
        if self._open:
            self._close(now)
        if self._inflight is not None:
            self._collect()
        out = self._completed
        self._completed = []
        return out

    # -- close + dispatch -----------------------------------------------------
    def _close(self, now_us: int) -> None:
        if self._inflight is not None:
            self._collect()  # one-deep pipeline: the slot must free first
        batch, self._open, self._open_since_us = self._open, [], None
        start = max(now_us, self._last_done_us)
        cfg = self.config
        keep: list[StreamRequest] = []
        for req in batch:
            # second gate: serve only if the PREDICTED overshoot is within
            # one batch window — everything else is shed typed, not served
            # late (this is what bounds the deadline-miss invariant)
            if start + cfg.service_bound_us <= req.deadline_us + cfg.max_wait_us:
                keep.append(req)
            else:
                self.admission.record_late_shed(req.tenant, SHED_LATE)
        if not keep:
            return
        keys = np.asarray([r.key for r in keep], dtype=np.uint32)
        handle = self.dispatch_fn(keys)
        self._dispatched.inc()
        self._batch_sizes.observe(len(keep))
        bound = (
            self.service_model(len(keep))
            if self.service_model is not None
            else cfg.service_bound_us
        )
        if self.tracer is not None:
            self.tracer.record(
                "batch_close", start, start, size=len(keep),
                shed=len(batch) - len(keep),
            )
            self.tracer.record(
                "dispatch", start, start + int(bound), size=len(keep)
            )
        self._inflight = _Inflight(keep, handle, start, start + int(bound))

    def _collect(self) -> None:
        inf, self._inflight = self._inflight, None
        t0 = time.perf_counter_ns()
        replicas, epoch, mode = inf.handle.result()
        measured_us = max(1, (time.perf_counter_ns() - t0) // 1_000)
        if self.service_model is not None:
            # the model was sampled ONCE at dispatch (stateful models — e.g.
            # spike windows — must see exactly one draw per dispatch)
            service_us = inf.eta_us - inf.t_dispatch_us
            done = inf.t_dispatch_us + int(service_us)
        else:
            # wall mode: completion is simply "now, after the block"
            service_us = int(measured_us)
            done = max(self.clock.now_us(), inf.t_dispatch_us + 1)
        self._last_done_us = done
        self.service_ewma_us += 0.1 * (float(service_us) - self.service_ewma_us)
        self.metrics.gauge("stream_service_ewma_us").set(self.service_ewma_us)
        for req, rep in zip(inf.requests, replicas):
            self._completed.append(
                StreamResult(
                    request=req,
                    replica=int(rep),
                    t_dispatch_us=inf.t_dispatch_us,
                    t_complete_us=done,
                    epoch=epoch,
                    mode=mode,
                )
            )
            self.metrics.histogram(
                "stream_request_latency_us", tenant=req.tenant
            ).observe(max(0, done - req.arrival_us))
            if self.tracer is not None:
                self.tracer.record(
                    "request", req.arrival_us, done, tenant=req.tenant,
                    replica=int(rep), epoch=epoch,
                )
        self._served.inc(len(inf.requests))
