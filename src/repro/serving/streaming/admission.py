"""Deadline-aware admission control with per-tenant token buckets.

The streaming tier's first line of defense (DESIGN.md §14): a request that
cannot make its SLO given the current pipeline state is shed AT THE DOOR
with a typed ``AdmissionRejectedError`` — it never occupies a batch slot,
never poisons tail latency, and the caller gets a machine-readable reason
(``SHED_*``) instead of a timeout.  Shedding is the controller *working*,
so every rejection lands in the observability registry as a
``stream_shed_total{tenant, reason}`` counter series (admits as
``stream_admitted_total{tenant}``); the ``admitted`` / ``shed_by_reason``
/ ``shed_by_tenant`` properties are aggregate views over those series,
kept for callers that predate the registry.

Admission checks, in order:

1. **past deadline** — ``deadline_us <= now``: dead on arrival;
2. **rate limit** — the tenant's token bucket is empty (zipf-skewed
   multi-tenant load means one hot tenant must not starve the rest);
3. **feasibility** — even if the open batch closed *right now* behind the
   in-flight batch, ``dispatch_eta + service_bound_us`` already overshoots
   ``deadline + max_wait_us`` (the one-batch-window grace the close-time
   check enforces): the request cannot be served in time, shed it early.

The controller never serves anything itself — the batch-close late check
in ``MicroBatcher`` is the second (and final) gate.
"""
from __future__ import annotations

import dataclasses

from repro.serving.lifecycle.errors import (
    SHED_INFEASIBLE,
    SHED_PAST_DEADLINE,
    SHED_RATE_LIMITED,
    AdmissionRejectedError,
)

from .clock import US_PER_S


class TokenBucket:
    """Classic token bucket in µs time: ``rate_per_s`` sustained,
    ``burst`` ceiling, lazily refilled on each ``try_take``."""

    def __init__(self, rate_per_s: float, burst: float, now_us: int = 0):
        if rate_per_s <= 0 or burst <= 0:
            raise ValueError(
                f"need rate_per_s > 0 and burst > 0, got "
                f"{rate_per_s} / {burst}"
            )
        self.rate_per_s = float(rate_per_s)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._last_us = int(now_us)

    def _refill(self, now_us: int) -> None:
        dt = max(0, now_us - self._last_us)
        self._tokens = min(
            self.burst, self._tokens + dt * self.rate_per_s / US_PER_S
        )
        self._last_us = now_us

    def try_take(self, now_us: int, n: float = 1.0) -> bool:
        self._refill(now_us)
        if self._tokens >= n:
            self._tokens -= n
            return True
        return False

    @property
    def tokens(self) -> float:
        return self._tokens


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    #: declared service bound per dispatch, µs — the SLO capacity statement
    #: admission feasibility and the close-time check both reason against
    service_bound_us: int = 2_000
    #: batch-window grace (mirrors ``StreamConfig.max_wait_us``): an admitted
    #: request may complete at most this far past its deadline
    max_wait_us: int = 1_000
    #: per-tenant sustained admission rate (requests/s); None disables
    #: rate limiting entirely
    tenant_rate_per_s: float | None = None
    #: per-tenant burst ceiling (defaults to one batch worth at rate)
    tenant_burst: float = 32.0

    def __post_init__(self):
        if self.service_bound_us <= 0 or self.max_wait_us < 0:
            raise ValueError(
                f"need service_bound_us > 0 and max_wait_us >= 0, got "
                f"{self.service_bound_us} / {self.max_wait_us}"
            )


class AdmissionController:
    """Stateful admission gate: per-tenant buckets + registry-backed shed
    accounting (``metrics=None`` builds a private ``MetricsRegistry``; the
    front end passes its shared one)."""

    def __init__(
        self, config: AdmissionConfig | None = None, metrics=None
    ):
        self.config = config or AdmissionConfig()
        self._buckets: dict[str, TokenBucket] = {}
        if metrics is None:
            from repro.observability.metrics import MetricsRegistry

            metrics = MetricsRegistry()
        self.metrics = metrics

    # -- aggregate views over the registry series ----------------------------
    @property
    def admitted(self) -> int:
        return self.metrics.total("stream_admitted_total")

    @property
    def shed_by_reason(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for m in self.metrics.family("stream_shed_total").values():
            reason = m.labels["reason"]
            out[reason] = out.get(reason, 0) + m.value
        return out

    @property
    def shed_by_tenant(self) -> dict[tuple[str, str], int]:
        return {
            (m.labels["tenant"], m.labels["reason"]): m.value
            for m in self.metrics.family("stream_shed_total").values()
        }

    def _bucket(self, tenant: str, now_us: int) -> TokenBucket | None:
        if self.config.tenant_rate_per_s is None:
            return None
        b = self._buckets.get(tenant)
        if b is None:
            b = TokenBucket(
                self.config.tenant_rate_per_s, self.config.tenant_burst, now_us
            )
            self._buckets[tenant] = b
        return b

    def _shed(
        self, reason: str, tenant: str, deadline_us: int, now_us: int
    ) -> AdmissionRejectedError:
        self.metrics.counter(
            "stream_shed_total", tenant=tenant, reason=reason
        ).inc()
        return AdmissionRejectedError(
            reason, tenant=tenant, deadline_us=deadline_us, now_us=now_us
        )

    def admit(
        self, tenant: str, deadline_us: int, now_us: int, dispatch_eta_us: int
    ) -> None:
        """Admit or raise.  ``dispatch_eta_us`` is the batcher's earliest
        possible dispatch start for a request arriving now (accounts for the
        in-flight batch occupying the one-deep pipeline)."""
        cfg = self.config
        if deadline_us <= now_us:
            raise self._shed(SHED_PAST_DEADLINE, tenant, deadline_us, now_us)
        bucket = self._bucket(tenant, now_us)
        if bucket is not None and not bucket.try_take(now_us):
            raise self._shed(SHED_RATE_LIMITED, tenant, deadline_us, now_us)
        best_done = max(dispatch_eta_us, now_us) + cfg.service_bound_us
        if best_done > deadline_us + cfg.max_wait_us:
            raise self._shed(SHED_INFEASIBLE, tenant, deadline_us, now_us)
        self.metrics.counter("stream_admitted_total", tenant=tenant).inc()

    def record_late_shed(self, tenant: str, reason: str) -> None:
        """Account a batch-close shed (the second gate lives in the
        batcher, the ledger lives here)."""
        self.metrics.counter(
            "stream_shed_total", tenant=tenant, reason=reason
        ).inc()

    @property
    def shed_total(self) -> int:
        return self.metrics.total("stream_shed_total")
