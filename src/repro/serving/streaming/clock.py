"""Microsecond time sources for the streaming tier.

The batcher, admission controller, hedger and breakers all reason in
integer microseconds (SLO deadlines are µs-scale; float seconds lose
precision exactly where tail latency lives).  The clock is pluggable:
``WallClockUs`` for production, ``VirtualClockUs`` for tests/chaos/bench —
fully deterministic, advanced explicitly by the harness.

``VirtualClockUs.seconds_view()`` adapts the same time source to the
``FailureDetector``'s float-seconds ``now()`` protocol, so one virtual
timeline drives the whole stack (batcher deadlines AND detector
suspect/fail windows) with no drift between layers.
"""
from __future__ import annotations

import time

US_PER_S = 1_000_000


class WallClockUs:
    """Production clock: ``time.monotonic_ns`` truncated to µs."""

    def now_us(self) -> int:
        return time.monotonic_ns() // 1_000


class VirtualClockUs:
    """Deterministic µs clock — advances only when told to."""

    def __init__(self, start_us: int = 0):
        self._t = int(start_us)

    def now_us(self) -> int:
        return self._t

    def advance_us(self, dt_us: int) -> int:
        if dt_us < 0:
            raise ValueError(f"cannot advance time backwards (dt_us={dt_us})")
        self._t += int(dt_us)
        return self._t

    def seconds_view(self) -> "_SecondsView":
        """A float-seconds ``now()`` facade over this clock, for components
        speaking the ``FailureDetector`` clock protocol."""
        return _SecondsView(self)


class _SecondsView:
    def __init__(self, base: VirtualClockUs):
        self._base = base

    def now(self) -> float:
        return self._base.now_us() / US_PER_S
