"""Streaming serving front end: micro-batching with latency SLOs.

The subsystem that turns the batch datapath into a *service* (DESIGN.md
§14): an ingest loop accumulating requests up to ``max_wait_us`` /
``max_batch`` and routing them in ONE fused dispatch (double-buffered
against the next batch's fill), deadline-aware admission control with
per-tenant token buckets and typed shedding, hedged degraded reads over
the placement tier, and per-shard circuit breakers driven by the failure
detector's hysteresis.
"""
from repro.serving.streaming.admission import (
    AdmissionConfig,
    AdmissionController,
    TokenBucket,
)
from repro.serving.streaming.batcher import (
    LifecycleDispatch,
    MicroBatcher,
    StreamConfig,
    StreamRequest,
    StreamResult,
)
from repro.serving.streaming.clock import (
    US_PER_S,
    VirtualClockUs,
    WallClockUs,
)
from repro.serving.streaming.frontend import StreamingFrontEnd
from repro.serving.streaming.hedge import (
    BreakerBoard,
    BreakerConfig,
    HedgedRead,
    HedgedReader,
)

__all__ = [
    "AdmissionConfig",
    "AdmissionController",
    "TokenBucket",
    "LifecycleDispatch",
    "MicroBatcher",
    "StreamConfig",
    "StreamRequest",
    "StreamResult",
    "US_PER_S",
    "VirtualClockUs",
    "WallClockUs",
    "StreamingFrontEnd",
    "BreakerBoard",
    "BreakerConfig",
    "HedgedRead",
    "HedgedReader",
]
