"""Hedged degraded reads + per-shard circuit breakers (DESIGN.md §14).

**Hedging.**  A read goes to the key's primary holder (first distinct
alive holder from ``StorePlacement.read``).  If that primary is *suspect*
in the ``FailureDetector`` — silent past ``suspect_after`` but not yet
formally failed — or its breaker is open, a hedge fires at
``hedge_after_us``: the SAME read against the next distinct alive holder,
first response wins.  The candidate set is ALWAYS drawn from the key's
reachable holders, so a hedged read can never return a shard that does not
actually hold the key (the chaos harness asserts exactly this).

**Circuit breakers.**  The detector's hysteresis means a flapping shard
oscillates alive↔suspect without ever emitting a formal ``fail`` — correct
for membership (the replacement table is not thrashed) but miserable for
tail latency if reads keep electing it primary.  The ``BreakerBoard``
watches detector state transitions: ``trip_after`` alive→suspect flips
within ``window_us`` opens the shard's breaker for ``cooldown_us``,
removing it from primary/hedge candidacy *before* the detector declares
anything.  After cooldown the breaker half-opens (candidate again); a
clean interval closes it fully.  A shard the detector formally removes
drops out of the holder sets anyway — the breaker's job is the gray zone
the detector deliberately rides out.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.serving.lifecycle.detector import REMOVED, SUSPECT


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    #: alive→suspect transitions within ``window_us`` that trip the breaker
    trip_after: int = 3
    #: sliding window the transitions are counted over
    window_us: int = 30_000_000
    #: how long a tripped breaker stays open (then half-opens)
    cooldown_us: int = 10_000_000

    def __post_init__(self):
        if self.trip_after < 1 or self.window_us <= 0 or self.cooldown_us <= 0:
            raise ValueError(
                f"need trip_after >= 1 and positive windows, got "
                f"{self.trip_after} / {self.window_us} / {self.cooldown_us}"
            )


class BreakerBoard:
    """Per-shard circuit breakers fed by detector state transitions.

    Trips land in the registry as ``stream_breaker_trips_total{shard}``;
    ``trips`` is the aggregate view over that series."""

    def __init__(
        self,
        detector,
        clock,
        config: BreakerConfig | None = None,
        metrics=None,
    ):
        self.detector = detector
        self.clock = clock
        self.config = config or BreakerConfig()
        self._last_state: dict[int, str] = {}
        self._suspect_at: dict[int, deque] = {}
        self._open_until: dict[int, int] = {}
        if metrics is None:
            from repro.observability.metrics import MetricsRegistry

            metrics = MetricsRegistry(clock=clock)
        self.metrics = metrics

    @property
    def trips(self) -> int:
        return self.metrics.total("stream_breaker_trips_total")

    def observe(self) -> None:
        """Snapshot detector states; record alive→suspect flips and trip
        breakers that crossed the threshold.  Call once per pump/dispatch —
        the same cadence the detector itself is polled on."""
        now = self.clock.now_us()
        cfg = self.config
        for slot in self.detector.slots:
            state = self.detector.state_of(slot)
            prev = self._last_state.get(slot)
            if state == SUSPECT and prev != SUSPECT:
                dq = self._suspect_at.setdefault(slot, deque())
                dq.append(now)
                while dq and now - dq[0] > cfg.window_us:
                    dq.popleft()
                if len(dq) >= cfg.trip_after and not self.is_open(slot):
                    self._open_until[slot] = now + cfg.cooldown_us
                    self.metrics.counter(
                        "stream_breaker_trips_total", shard=str(slot)
                    ).inc()
            elif state == REMOVED:
                # the detector formally failed it: membership takes over,
                # the breaker's flap history is moot
                self._suspect_at.pop(slot, None)
                self._open_until.pop(slot, None)
            self._last_state[slot] = state

    def is_open(self, slot: int) -> bool:
        until = self._open_until.get(int(slot))
        if until is None:
            return False
        if self.clock.now_us() >= until:
            # cooldown over: half-open — candidate again; a clean window
            # (no further trips) leaves it closed
            del self._open_until[int(slot)]
            return False
        return True

    @property
    def open_slots(self) -> tuple[int, ...]:
        return tuple(sorted(s for s in self._open_until if self.is_open(s)))


@dataclasses.dataclass(frozen=True)
class HedgedRead:
    """Outcome of one (possibly hedged) read."""

    key_index: int
    shard: int
    mode: str
    hedged: bool
    latency_us: int
    #: the distinct alive holders the read chose among
    holders: tuple


class HedgedReader:
    """First-response-wins reads over a key's holder set.

    ``probe(shard) -> latency_us`` is the pluggable transport (simulated in
    chaos/bench; a real RPC in production).  With a suspect-or-broken
    primary the hedge fires at ``hedge_after_us`` against the next distinct
    alive holder; the winner is whichever response lands first.
    """

    def __init__(
        self,
        store,
        detector,
        breakers: BreakerBoard,
        hedge_after_us: int,
        probe=None,
        metrics=None,
        tracer=None,
        clock=None,
    ):
        self.store = store
        self.detector = detector
        self.breakers = breakers
        self.hedge_after_us = int(hedge_after_us)
        self.probe = probe if probe is not None else (lambda shard: 100)
        self.metrics = metrics if metrics is not None else breakers.metrics
        self.tracer = tracer
        self.clock = clock if clock is not None else breakers.clock
        self._reads = self.metrics.counter("stream_reads_total")
        self._hedge_launched = self.metrics.counter(
            "stream_hedge_launched_total"
        )
        self._hedge_won = self.metrics.counter("stream_hedge_won_total")

    #: registry-backed counters, exposed under the historical names
    @property
    def reads(self) -> int:
        return self._reads.value

    @property
    def hedge_launched(self) -> int:
        return self._hedge_launched.value

    @property
    def hedge_won(self) -> int:
        return self._hedge_won.value

    def _is_suspect(self, shard: int) -> bool:
        try:
            return self.detector.state_of(shard) == SUSPECT
        except KeyError:
            return False  # retired slot: not tracked, membership handles it

    def read(self, key_index: int) -> HedgedRead:
        """One read: primary (breaker-closed holders first), hedged to the
        next distinct alive holder when the primary looks unhealthy."""
        holders, mode = self.store.read(key_index)
        holders = [int(h) for h in np.asarray(holders).tolist()]
        closed = [h for h in holders if not self.breakers.is_open(h)]
        candidates = closed if closed else holders  # never an empty ballot
        primary = candidates[0]
        p_lat = int(self.probe(primary))
        winner, latency, hedged = primary, p_lat, False
        unhealthy = self._is_suspect(primary) or self.breakers.is_open(primary)
        if unhealthy and len(candidates) > 1 and p_lat > self.hedge_after_us:
            # the primary is slow AND unhealthy: fire the hedge
            alt = candidates[1]
            a_lat = self.hedge_after_us + int(self.probe(alt))
            hedged = True
            self._hedge_launched.inc()
            if a_lat < p_lat:
                winner, latency = alt, a_lat
                self._hedge_won.inc()
        self._reads.inc()
        self.metrics.histogram("stream_read_latency_us").observe(latency)
        if self.tracer is not None:
            now = self.clock.now_us()
            self.tracer.record(
                "read", now, now + latency, shard=winner, hedged=hedged
            )
        return HedgedRead(
            key_index=key_index,
            shard=winner,
            mode=mode,
            hedged=hedged,
            latency_us=latency,
            holders=tuple(holders),
        )
