"""The assembled streaming front end: admission → micro-batch → fused
dispatch, plus hedged reads against the placement tier.

``StreamingFrontEnd`` is the one object a gateway talks to (DESIGN.md
§14).  It owns a ``MicroBatcher`` whose default dispatch is the
lifecycle-wrapped router (every dispatch ticks the failure detector and
emits one bounded placement-repair batch — the serve path IS the repair
cadence), a ``BreakerBoard`` over the manager's detector, and — when a
``StorePlacement`` is attached — a ``HedgedReader`` for degraded reads.

Everything is deterministic under an injected ``VirtualClockUs``: the
chaos storylines and the serving bench drive the exact same code with a
scripted timeline, and production swaps in ``WallClockUs`` with no other
change.

Telemetry: the front end owns ONE ``MetricsRegistry`` (on its clock) and
one ``SpanTrace`` shared by every component it assembles — pass
``metrics=`` / ``tracer=`` to aggregate several front ends into a common
ledger.  ``stats()`` is an aggregate snapshot over the registry;
``repro.observability.export`` renders the full thing.
"""
from __future__ import annotations

from .admission import AdmissionController
from .batcher import (
    LifecycleDispatch,
    MicroBatcher,
    StreamConfig,
    StreamRequest,
    StreamResult,
)
from .clock import WallClockUs
from .hedge import BreakerBoard, BreakerConfig, HedgedReader


class StreamingFrontEnd:
    """Compose admission control, micro-batching, breakers and hedging
    over a ``LifecycleManager`` (and optionally a ``StorePlacement``)."""

    def __init__(
        self,
        manager,
        store=None,
        config: StreamConfig | None = None,
        clock=None,
        breaker_config: BreakerConfig | None = None,
        dispatch_fn=None,
        service_model=None,
        probe=None,
        metrics=None,
        tracer=None,
    ):
        from repro.observability.metrics import MetricsRegistry
        from repro.observability.trace import SpanTrace

        self.manager = manager
        self.store = store
        self.config = config or StreamConfig()
        self.clock = clock or WallClockUs()
        self.metrics = (
            metrics if metrics is not None else MetricsRegistry(clock=self.clock)
        )
        self.tracer = tracer if tracer is not None else SpanTrace()
        if getattr(manager, "tracer", None) is None:
            manager.tracer = self.tracer
        self.admission = AdmissionController(
            self.config.admission(), metrics=self.metrics
        )
        self.batcher = MicroBatcher(
            dispatch_fn if dispatch_fn is not None else LifecycleDispatch(manager),
            config=self.config,
            clock=self.clock,
            admission=self.admission,
            service_model=service_model,
            metrics=self.metrics,
            tracer=self.tracer,
        )
        self.breakers = BreakerBoard(
            manager.detector, self.clock, breaker_config, metrics=self.metrics
        )
        self.reader = (
            HedgedReader(
                store,
                manager.detector,
                self.breakers,
                self.config.hedge_after_us,
                probe=probe,
                metrics=self.metrics,
                tracer=self.tracer,
                clock=self.clock,
            )
            if store is not None
            else None
        )

    # -- write path (routing) -------------------------------------------------
    def submit(self, request: StreamRequest) -> None:
        """Admit + enqueue (raises ``AdmissionRejectedError`` on shed)."""
        self.batcher.submit(request)

    def pump(self) -> list[StreamResult]:
        """One event-loop turn: observe breakers, close/collect batches."""
        self.breakers.observe()
        return self.batcher.pump()

    def drain(self) -> list[StreamResult]:
        """Flush the pipeline (open batch + in-flight)."""
        self.breakers.observe()
        return self.batcher.drain()

    # -- read path (placement) ------------------------------------------------
    def read(self, key_index: int):
        """Hedged read of one registered key (requires a store)."""
        if self.reader is None:
            raise RuntimeError(
                "no StorePlacement attached: construct StreamingFrontEnd "
                "with store=... to read"
            )
        self.breakers.observe()
        return self.reader.read(key_index)

    # -- observability --------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate snapshot over the shared registry (historical shape);
        ``repro.observability.export.snapshot`` renders every series."""
        b, a = self.batcher, self.admission
        out = {
            "admitted": a.admitted,
            "served": b.served,
            "dispatches": b.dispatches,
            "shed_total": a.shed_total,
            "shed_by_reason": dict(a.shed_by_reason),
            "service_ewma_us": b.service_ewma_us,
            "breaker_trips": self.breakers.trips,
            "breaker_open": list(self.breakers.open_slots),
        }
        if self.reader is not None:
            out.update(
                reads=self.reader.reads,
                hedge_launched=self.reader.hedge_launched,
                hedge_won=self.reader.hedge_won,
            )
        return out
