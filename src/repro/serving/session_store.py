"""Bulk open-addressing session-movement store — loop-free observability.

``SessionRouter`` tracks which sessions changed replica (the
``moved_sessions`` metric: every move is a lost KV/prefix-cache) in a
key -> last-replica map.  The original implementation walked a Python dict
one key at a time, which at batched-ingest rates costs more than the entire
device routing dispatch.  ``SessionStore`` replaces it with a fixed-layout
open-addressing hash table held in two numpy arrays and driven entirely by
vectorised probe/insert rounds (DESIGN.md §9):

* **layout** — ``_keys`` (uint64) and ``_vals`` (int32) of power-of-two
  length; ``_vals == EMPTY`` (-1, never a valid replica id) marks a free
  slot, so key content in free slots is irrelevant and no tombstones exist
  (the store never deletes).
* **probe sequence** — linear: slot_j = (h + j) mod slots, where
  ``h = (key ^ key >> 32) mod slots``.  Session keys are splitmix64 / FNV-1a
  outputs, i.e. already avalanched, so the fold is enough mixing.
* **bulk find** — one numpy round per probe distance over the still-active
  subset: gather slots, resolve rows that hit their key (present) or an
  empty slot (absent — valid because there are no deletions).
* **bulk insert** — per round, every pending row scatters its key at its
  probe slot if free; last-write-wins collisions are resolved by re-reading
  the slot (the winner sees its own key, losers advance to the next probe
  distance).  Load factor is kept <= 1/2 by doubling + rehash, so both
  loops terminate in O(1) expected rounds.
* **capacity semantics** — ``max_entries`` mirrors the dict version's
  ``LAST_MAX`` cap: beyond it, NEW sessions silently stop being tracked
  (routing is stateless and unaffected); within a batch the insert budget
  is spent in first-occurrence order, exactly like the sequential loop.

``record`` preserves the per-key dict-loop semantics bit-for-bit, counting
each *distinct* moved key once (duplicate keys inside one batch carry the
same replica — routing is deterministic — so the sequential loop also
counts them once).
"""
from __future__ import annotations

import numpy as np

#: free-slot marker in ``_vals`` — replica ids are always >= 0
EMPTY = np.int32(-1)


class SessionStore:
    def __init__(self, max_entries: int = 1 << 20, initial_slots: int = 1 << 10):
        if initial_slots & (initial_slots - 1) or initial_slots < 2:
            raise ValueError(f"initial_slots must be a power of two >= 2, got {initial_slots}")
        self.max_entries = max_entries
        self._keys = np.zeros(initial_slots, dtype=np.uint64)
        self._vals = np.full(initial_slots, EMPTY, dtype=np.int32)
        self.count = 0

    def __len__(self) -> int:
        return self.count

    @staticmethod
    def _home(keys: np.ndarray, mask: int) -> np.ndarray:
        """First probe slot per key: fold the u64 onto the slot space."""
        return ((keys ^ (keys >> np.uint64(32))) & np.uint64(mask)).astype(np.int64)

    def _find(self, keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Bulk lookup: -> (found bool[N], slot int64[N]; slot valid iff found).

        One vectorised gather+compare round per probe distance over the rows
        still unresolved; with load <= 1/2 the expected round count is O(1).
        """
        n = keys.size
        mask = self._keys.size - 1
        home = self._home(keys, mask)
        # round 0 runs on the full arrays with no index indirection — at
        # load <= 1/2 it resolves the large majority of rows, so the
        # fancy-indexed rounds below only ever see a small remainder
        occupied = self._vals[home] != EMPTY  # one gather, reused below
        hit = occupied & (self._keys[home] == keys)
        found = hit
        slot = np.where(hit, home, -1)
        active = np.flatnonzero(occupied & ~hit)  # ~occupied ends the chain
        for j in range(1, self._keys.size):
            if active.size == 0:
                break
            s = (home[active] + j) & mask
            occupied = self._vals[s] != EMPTY
            hit = occupied & (self._keys[s] == keys[active])
            resolved = active[hit]
            found[resolved] = True
            slot[resolved] = s[hit]
            active = active[occupied & ~hit]
        return found, slot

    def _insert(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Bulk insert of DISTINCT keys known absent from the store."""
        if keys.size == 0:
            return
        if (self.count + keys.size) * 2 > self._keys.size:
            self._grow(self.count + keys.size)
        mask = self._keys.size - 1
        home = self._home(keys, mask)
        active = np.arange(keys.size)
        for j in range(self._keys.size):
            s = (home[active] + j) & mask
            free = self._vals[s] == EMPTY
            cand, sc = active[free], s[free]
            # claim: scatter keys (numpy last-write-wins on duplicate slots),
            # then re-read — the row whose key survived owns the slot
            self._keys[sc] = keys[cand]
            won = self._keys[sc] == keys[cand]
            self._vals[sc[won]] = vals[cand[won]]
            settled = np.zeros(active.size, dtype=bool)
            settled[np.flatnonzero(free)[won]] = True
            active = active[~settled]
            if active.size == 0:
                break
        self.count += keys.size

    def _grow(self, need: int) -> None:
        """Double the slot space until load <= 1/2, rehashing every entry."""
        slots = self._keys.size
        while need * 2 > slots:
            slots *= 2
        live = self._vals != EMPTY
        old_keys, old_vals = self._keys[live], self._vals[live]
        self._keys = np.zeros(slots, dtype=np.uint64)
        self._vals = np.full(slots, EMPTY, dtype=np.int32)
        self.count = 0
        self._insert(old_keys, old_vals)

    def record(self, keys: np.ndarray, replicas: np.ndarray) -> int:
        """Bulk key -> replica update; returns how many tracked keys MOVED.

        Semantics of the sequential dict loop, vectorised: tracked keys whose
        replica changed are counted (once per distinct key) and updated; new
        keys are admitted in first-occurrence order until ``max_entries``;
        keys beyond the cap are ignored.
        """
        keys = np.asarray(keys, dtype=np.uint64).reshape(-1)
        replicas = np.asarray(replicas).astype(np.int32, copy=False).reshape(-1)
        if keys.size == 0:
            return 0
        # probe the RAW batch (duplicates included) and only dedup the rows
        # that need it: in steady state — everything tracked, nothing moved —
        # this is one probe pass and two compares, no O(N log N) sort
        found, slot = self._find(keys)
        moved = found & (self._vals[slot] != replicas)
        n_moved = 0
        if moved.any():
            # duplicate keys carry equal replicas (routing is deterministic),
            # so the scatter is idempotent and each distinct key counts once
            self._vals[slot[moved]] = replicas[moved]
            n_moved = int(np.unique(keys[moved]).size)
        fresh = np.flatnonzero(~found)
        if fresh.size and self.count < self.max_entries:
            # distinct new keys in first-occurrence order (the cap budget is
            # spent in batch order, like the sequential loop)
            uniq, first = np.unique(keys[fresh], return_index=True)
            order = np.argsort(first)[: self.max_entries - self.count]
            self._insert(uniq[order], replicas[fresh[first[order]]])
        return n_moved

    def record_one(self, key: int, replica: int) -> int:
        """Scalar ``record``: one key, plain-int probe loop, no array temps.

        The per-request control-plane path (``SessionRouter.route``) calls
        this instead of paying the vectorised machinery's fixed cost for a
        size-1 batch.  Semantics identical to ``record([key], [replica])``.
        """
        mask = self._keys.size - 1
        key = int(key)
        home = (key ^ (key >> 32)) & mask
        keys, vals = self._keys, self._vals
        for j in range(keys.size):
            s = (home + j) & mask
            if vals[s] == EMPTY:
                if self.count >= self.max_entries:
                    return 0  # past the cap: new keys go untracked
                if (self.count + 1) * 2 > keys.size:
                    self._grow(self.count + 1)
                    return self.record_one(key, replica)  # re-probe, rehashed
                keys[s] = key
                vals[s] = replica
                self.count += 1
                return 0
            if keys[s] == key:
                if vals[s] != replica:
                    vals[s] = replica
                    return 1
                return 0
        return 0  # unreachable at load <= 1/2

    def lookup(self, keys: np.ndarray) -> np.ndarray:
        """Bulk read: int32 last-known replica per key, EMPTY (-1) if untracked."""
        keys = np.asarray(keys, dtype=np.uint64).reshape(-1)
        out = np.full(keys.size, EMPTY, dtype=np.int32)
        if keys.size:
            found, slot = self._find(keys)
            out[found] = self._vals[slot[found]]
        return out
