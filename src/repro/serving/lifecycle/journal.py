"""Epoch-stamped membership journal: append-only fleet-event log + replay.

The replacement-table control plane (``FailureDomain`` over
``MementoWrapper``/``ReplacementTable``) is a deterministic state machine:
its state is a pure function of the initial fleet size and the ordered
fail/recover/scale event stream.  This module makes that explicit:

* ``MembershipJournal`` — the append-only log.  Every fleet event gets a
  strictly increasing **epoch** (1-based; epoch 0 is the genesis fleet).
  The journal serialises to JSON lines, so "crash" means: keep the text,
  lose every live object.
* ``replay(journal, factory)`` — rebuild the domain by re-applying the
  event stream from genesis.  Bit-exact: the rebuilt
  ``ReplacementTable.slots/pos/n_alive``, the removed set and the packed
  device operands (``FleetState.pack``) all equal the live ones, for
  arbitrary event streams (property-tested).
* ``JournalSnapshot`` / ``restore(snapshot, factory)`` — O(n) state capture
  so recovery does not have to replay from genesis: restore the snapshot,
  then replay only ``journal.events(since=snapshot.epoch)``.  Crash at ANY
  event index i: ``restore(snap_i) + replay(tail_i)`` == full replay ==
  live state (the crash-equivalence property in ``tests/test_lifecycle.py``).

Scale-up events record the slot id the control plane assigned so replay can
*verify* determinism instead of assuming it; scale-down records the retired
id the same way.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Iterable

#: the four membership transitions the control plane knows
EVENT_KINDS = ("fail", "recover", "scale_up", "scale_down")


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """One journaled fleet event.

    ``slot`` is the failed/recovered replica for fail/recover, the assigned
    id for scale_up and the retired id for scale_down (recorded, and checked
    on replay — LIFO determinism is an invariant, not an assumption).
    """

    epoch: int
    kind: str
    slot: int

    def to_json(self) -> str:
        return json.dumps(
            {"epoch": self.epoch, "kind": self.kind, "slot": self.slot},
            sort_keys=True,
        )

    @classmethod
    def from_json(cls, line: str) -> "MembershipEvent":
        d = json.loads(line)
        return cls(epoch=int(d["epoch"]), kind=str(d["kind"]), slot=int(d["slot"]))


@dataclasses.dataclass(frozen=True)
class JournalSnapshot:
    """Deep capture of the control-plane state at one epoch.

    Everything ``restore`` needs to rebuild a ``FailureDomain`` without
    replaying from genesis: the slot-space size, the replacement-table
    permutation + inverse + alive count, and the removed set.
    """

    epoch: int
    n_total: int
    n_alive: int
    slots: tuple[int, ...]
    pos: tuple[int, ...]
    removed: tuple[int, ...]

    @classmethod
    def capture(cls, epoch: int, domain) -> "JournalSnapshot":
        rt = domain.replacement_table
        return cls(
            epoch=epoch,
            n_total=domain.total_count,
            n_alive=rt.n_alive,
            slots=tuple(rt.slots),
            pos=tuple(rt.pos),
            removed=tuple(sorted(domain.removed)),
        )

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, line: str) -> "JournalSnapshot":
        d = json.loads(line)
        return cls(
            epoch=int(d["epoch"]),
            n_total=int(d["n_total"]),
            n_alive=int(d["n_alive"]),
            slots=tuple(int(s) for s in d["slots"]),
            pos=tuple(int(p) for p in d["pos"]),
            removed=tuple(int(r) for r in d["removed"]),
        )


class MembershipJournal:
    """Append-only epoch-stamped log of membership events."""

    def __init__(self, n_initial: int):
        if n_initial < 1:
            raise ValueError(f"n_initial must be >= 1, got {n_initial}")
        self.n_initial = n_initial
        self._events: list[MembershipEvent] = []

    @property
    def epoch(self) -> int:
        """Current epoch = number of recorded events (genesis is epoch 0)."""
        return len(self._events)

    def record(self, kind: str, slot: int) -> MembershipEvent:
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {kind!r}; expected one of {EVENT_KINDS}")
        ev = MembershipEvent(epoch=self.epoch + 1, kind=kind, slot=int(slot))
        self._events.append(ev)
        return ev

    def events(self, since: int = 0) -> tuple[MembershipEvent, ...]:
        """Events with ``epoch > since``, in order."""
        if since < 0:
            raise ValueError(f"since must be >= 0, got {since}")
        return tuple(self._events[since:])

    # -- persistence ---------------------------------------------------------
    def to_jsonl(self) -> str:
        """Header line (genesis size) + one JSON line per event."""
        head = json.dumps({"n_initial": self.n_initial}, sort_keys=True)
        return "\n".join([head] + [e.to_json() for e in self._events])

    @classmethod
    def from_jsonl(cls, text: str) -> "MembershipJournal":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty journal text")
        head = json.loads(lines[0])
        journal = cls(int(head["n_initial"]))
        for i, line in enumerate(lines[1:], start=1):
            ev = MembershipEvent.from_json(line)
            if ev.epoch != i:
                raise ValueError(
                    f"journal corrupt: event #{i} carries epoch {ev.epoch}"
                )
            journal._events.append(ev)
        return journal


def apply_event(domain, ev: MembershipEvent) -> None:
    """Apply one journaled event to a domain, checking determinism."""
    if ev.kind == "fail":
        domain.fail(ev.slot)
    elif ev.kind == "recover":
        domain.recover(ev.slot)
    elif ev.kind == "scale_up":
        got = domain.scale_up()
        if got != ev.slot:
            raise ValueError(
                f"replay divergence at epoch {ev.epoch}: scale_up assigned "
                f"slot {got}, journal recorded {ev.slot}"
            )
    elif ev.kind == "scale_down":
        got = domain.scale_down()
        if got != ev.slot:
            raise ValueError(
                f"replay divergence at epoch {ev.epoch}: scale_down retired "
                f"slot {got}, journal recorded {ev.slot}"
            )
    else:  # pragma: no cover - record() validates kinds
        raise ValueError(f"unknown event kind {ev.kind!r}")


def replay(
    journal: MembershipJournal,
    domain_factory: Callable[[int], object],
    upto: int | None = None,
):
    """Rebuild a domain from genesis by re-applying events ``1..upto``.

    ``domain_factory(n)`` must build the domain exactly as the live control
    plane was built (same engine, omega, resolve flavour) — the
    ``LifecycleManager`` supplies its router's own factory.
    """
    domain = domain_factory(journal.n_initial)
    for ev in journal.events():
        if upto is not None and ev.epoch > upto:
            break
        apply_event(domain, ev)
    return domain


def restore(
    snapshot: JournalSnapshot,
    domain_factory: Callable[[int], object],
    events: Iterable[MembershipEvent] = (),
):
    """Rebuild a domain from a snapshot, then replay the event tail.

    The snapshot's permutation/inverse/alive-count and removed set are
    installed verbatim (they ARE the state — no re-derivation), so
    ``restore(snap_i, tail_i)`` is bit-exact with a genesis replay however
    the stream is split.
    """
    domain = domain_factory(snapshot.n_total)
    eng = domain._eng
    if eng.table is None:
        raise ValueError("snapshot restore requires a resolve='table' domain")
    eng.removed = set(snapshot.removed)
    eng.table.slots = list(snapshot.slots)
    eng.table.pos = list(snapshot.pos)
    eng.table.n_alive = snapshot.n_alive
    for ev in events:
        apply_event(domain, ev)
    return domain
