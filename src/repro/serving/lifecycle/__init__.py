"""Fleet lifecycle hardening: journal, failure detection, degradation.

The robustness layer around the constant-time routing kernel (DESIGN.md
§12): epoch-journaled membership with bit-exact crash replay, heartbeat
failure detection with hysteresis/quarantine, event-storm coalescing and
typed degraded/unavailable routing modes.
"""
from repro.serving.lifecycle.detector import (
    ALIVE,
    QUARANTINED,
    REMOVED,
    SUSPECT,
    FailureDetector,
    HeartbeatConfig,
    ManualClock,
    MonotonicClock,
)
from repro.serving.lifecycle.errors import (
    SHED_INFEASIBLE,
    SHED_LATE,
    SHED_PAST_DEADLINE,
    SHED_RATE_LIMITED,
    AdmissionRejectedError,
    ClockWentBackwardsError,
    FleetDegradedError,
    FleetUnavailableError,
    LifecycleError,
    PlacementDegradedError,
    PlacementExhaustedError,
)
from repro.serving.lifecycle.journal import (
    EVENT_KINDS,
    JournalSnapshot,
    MembershipEvent,
    MembershipJournal,
    apply_event,
    replay,
    restore,
)
from repro.serving.lifecycle.manager import (
    MODE_DEGRADED,
    MODE_NORMAL,
    MODE_UNAVAILABLE,
    LifecycleConfig,
    LifecycleManager,
    PlacementRepairer,
    RepairTask,
    RoutedBatch,
)

__all__ = [
    "ALIVE",
    "SUSPECT",
    "REMOVED",
    "QUARANTINED",
    "FailureDetector",
    "HeartbeatConfig",
    "ManualClock",
    "MonotonicClock",
    "LifecycleError",
    "AdmissionRejectedError",
    "ClockWentBackwardsError",
    "SHED_PAST_DEADLINE",
    "SHED_INFEASIBLE",
    "SHED_RATE_LIMITED",
    "SHED_LATE",
    "FleetUnavailableError",
    "FleetDegradedError",
    "PlacementDegradedError",
    "PlacementExhaustedError",
    "EVENT_KINDS",
    "MembershipEvent",
    "MembershipJournal",
    "JournalSnapshot",
    "apply_event",
    "replay",
    "restore",
    "LifecycleConfig",
    "LifecycleManager",
    "PlacementRepairer",
    "RepairTask",
    "RoutedBatch",
    "MODE_NORMAL",
    "MODE_DEGRADED",
    "MODE_UNAVAILABLE",
]
