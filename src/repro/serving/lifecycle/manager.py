"""LifecycleManager: journal + detector + degradation around a BatchRouter.

The robustness layer of the serving tier (DESIGN.md §12).  Composition, not
inheritance: the manager *wraps* a ``BatchRouter`` (anything with the fleet
-event + route surface works) and adds

* **journaling** — every membership event that flows through the manager is
  epoch-stamped into a ``MembershipJournal``; ``snapshot()`` +
  ``verify_replay()`` prove the live control plane and the device operands
  are reproducible from the log (crash recovery = restore + tail replay);
* **failure detection** — replica heartbeats feed a deadline
  ``FailureDetector``; ``tick()`` turns deadline expiries into coalesced
  fail/recover events before the next dispatch;
* **coalescing** — a storm of N events becomes ONE device-state upload
  (``BatchRouter.coalesced_events``), with the final routing bit-exact
  against per-event application (the device operands are a pure function of
  the final control-plane state);
* **degradation** — typed route-time answers: ``FleetUnavailableError`` at
  ``n_alive == 0`` always; below ``min_alive_floor`` either a
  ``FleetDegradedError`` (``strict_floor=True``) or a routed batch marked
  ``mode="degraded"``;
* **epochs** — every routed batch carries the routing epoch it was computed
  under, so callers can detect placements staled by later events.

Everything here is host-side control plane: the device hot path is the same
single fused dispatch ``BatchRouter`` always ran (the constant-time
certifier pins this — ``repro.analysis`` certifies the lifecycle-wrapped
route entry too).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

from repro.serving.lifecycle.detector import (
    FailureDetector,
    HeartbeatConfig,
    MonotonicClock,
)
from repro.serving.lifecycle.errors import (
    MODE_DEGRADED,
    MODE_NORMAL,
    MODE_UNAVAILABLE,
    FleetDegradedError,
    FleetUnavailableError,
)
from repro.serving.lifecycle.journal import (
    JournalSnapshot,
    MembershipJournal,
    replay,
    restore,
)


@dataclasses.dataclass(frozen=True)
class LifecycleConfig:
    #: below this many alive replicas the fleet counts as degraded
    min_alive_floor: int = 1
    #: True: routing below the floor raises FleetDegradedError; False (the
    #: default): routing proceeds, the result is marked mode="degraded"
    strict_floor: bool = False
    heartbeat: HeartbeatConfig = dataclasses.field(default_factory=HeartbeatConfig)

    def __post_init__(self):
        if self.min_alive_floor < 1:
            raise ValueError(
                f"min_alive_floor must be >= 1, got {self.min_alive_floor}"
            )


class RoutedBatch(NamedTuple):
    """A routed batch + the epoch/mode it was computed under."""

    replicas: object  # jax.Array / np.ndarray of int32 replica ids
    epoch: int
    mode: str


class LifecycleManager:
    def __init__(
        self,
        router,
        config: LifecycleConfig | None = None,
        clock=None,
        tracer=None,
    ):
        self.router = router
        self.config = config or LifecycleConfig()
        self.clock = clock or MonotonicClock()
        #: optional SpanTrace — each tick() records a ``lifecycle_tick``
        #: span (the streaming front end attaches its shared trace here)
        self.tracer = tracer
        #: attached PlacementRepairer (None = no placement tier); every
        #: journaled membership mutation re-syncs it
        self._placement: "PlacementRepairer | None" = None
        self.journal = MembershipJournal(router.domain.total_count)
        self.detector = FailureDetector(
            (s for s in range(router.domain.total_count)
             if s not in router.domain.removed),
            self.config.heartbeat,
            self.clock,
        )
        # journal epochs continue from the router's own event counter so the
        # per-batch epoch is consistent whether events arrive via the
        # manager or (pre-attach) via the router directly
        if router.routing_epoch != 0:
            raise ValueError(
                "attach the LifecycleManager before mutating the fleet: the "
                f"router has already seen {router.routing_epoch} event(s) "
                "the journal cannot replay"
            )

    # -- health --------------------------------------------------------------
    @property
    def n_alive(self) -> int:
        return self.router.domain.alive_count

    @property
    def mode(self) -> str:
        n = self.n_alive
        if n == 0:
            return MODE_UNAVAILABLE
        if n < self.config.min_alive_floor:
            return MODE_DEGRADED
        return MODE_NORMAL

    @property
    def epoch(self) -> int:
        return self.journal.epoch

    # -- heartbeat plane -----------------------------------------------------
    def heartbeat(self, slot: int) -> None:
        self.detector.heartbeat(slot)

    def tick(self) -> list:
        """Poll the detector; apply any expiries as ONE coalesced update.

        Call once per dispatch (the serving tier does) — a whole failure
        storm between two batches lands as a single device-state upload.
        With a placement tier attached, each tick also emits ONE bounded
        repair batch (the repairer's budget), so re-replication bandwidth
        is metered by the dispatch cadence.
        """
        events = self.apply(self.detector.poll())
        if self._placement is not None:
            self._placement.tick()
        if self.tracer is not None:
            now_us = int(self.clock.now() * 1_000_000)
            self.tracer.record(
                "lifecycle_tick", now_us, now_us,
                events=len(events), epoch=self.epoch,
            )
        return events

    # -- membership events (all journaled) -----------------------------------
    def apply(self, transitions) -> list:
        """Apply ``("fail"|"recover", slot)`` pairs under one coalesced
        device update; journal each.  Returns the recorded events."""
        recorded = []
        if not transitions:
            return recorded
        with self.router.coalesced_events():
            for kind, slot in transitions:
                if kind == "fail":
                    self.router.fail(slot)
                elif kind == "recover":
                    self.router.recover(slot)
                else:
                    raise ValueError(f"unknown transition kind {kind!r}")
                recorded.append(self.journal.record(kind, slot))
        self._forget_retired()
        self._sync_placement()
        return recorded

    def _sync_placement(self) -> None:
        """Membership changed: re-enumerate the placement repair backlog."""
        if self._placement is not None:
            self._placement.sync()

    def _forget_retired(self) -> None:
        """Drop detector tracks for slots the control plane retired (failing
        the top slot is a LIFO retirement that may GC tombstones too)."""
        total = self.router.domain.total_count
        for slot in self.detector.slots:
            if slot >= total:
                self.detector.forget(slot)

    def fail(self, slot: int) -> None:
        """Operator-initiated failure (journaled; detector aligned)."""
        self.router.fail(slot)
        self.journal.record("fail", slot)
        if slot in self.router.domain.removed:
            self.detector.mark_removed(slot)
        self._forget_retired()
        self._sync_placement()

    def recover(self, slot: int) -> None:
        """Operator-initiated recovery (journaled; detector re-admits)."""
        self.router.recover(slot)
        self.journal.record("recover", slot)
        self.detector.register(slot)
        self._sync_placement()

    def scale_up(self) -> int:
        new = self.router.scale_up()
        self.journal.record("scale_up", new)
        self.detector.register(new)
        self._sync_placement()
        return new

    def scale_down(self) -> int:
        gone = self.router.scale_down()
        self.journal.record("scale_down", gone)
        # the retirement may have garbage-collected tombstones off the end
        for slot in self.detector.slots:
            if slot >= self.router.domain.total_count:
                self.detector.forget(slot)
        self._sync_placement()
        return gone

    # -- routing (degradation-guarded, epoch-stamped) ------------------------
    def _guard(self) -> str:
        mode = self.mode
        if mode == MODE_UNAVAILABLE:
            raise FleetUnavailableError(epoch=self.epoch)
        if mode == MODE_DEGRADED and self.config.strict_floor:
            raise FleetDegradedError(
                self.n_alive, self.config.min_alive_floor, epoch=self.epoch
            )
        return mode

    def route_keys(self, keys) -> RoutedBatch:
        mode = self._guard()
        return RoutedBatch(self.router.route_keys(keys), self.epoch, mode)

    def route_keys_np(self, keys) -> RoutedBatch:
        mode = self._guard()
        return RoutedBatch(self.router.route_keys_np(keys), self.epoch, mode)

    def route_batch(self, session_ids) -> RoutedBatch:
        mode = self._guard()
        return RoutedBatch(self.router.route_batch(session_ids), self.epoch, mode)

    # -- crash recovery ------------------------------------------------------
    def _domain_factory(self, n: int):
        """Build a domain EXACTLY like the router's control plane builds
        its oracle — same engine flavour, omega and resolution."""
        from repro.placement.elastic import FailureDomain

        return FailureDomain(
            n,
            engine=self.router._bulk.scalar_engine,
            chain_bits=32,
            omega=self.router.spec.omega,
            max_chain=self.router.max_chain,
            resolve="table",
            allow_empty=True,
        )

    def snapshot(self) -> JournalSnapshot:
        return JournalSnapshot.capture(self.epoch, self.router.domain)

    def rebuild_domain(self, snapshot: JournalSnapshot | None = None):
        """Rebuild the control plane from the log (and optional snapshot)."""
        if snapshot is None:
            return replay(self.journal, self._domain_factory)
        return restore(
            snapshot, self._domain_factory, self.journal.events(since=snapshot.epoch)
        )

    def verify_replay(self, snapshot: JournalSnapshot | None = None) -> None:
        """Assert replay(journal) == live state, bit-exactly — the scalar
        control plane AND the packed device operands.  Raises on mismatch."""
        import numpy as np

        from repro.core.bulk import FleetState

        rebuilt = self.rebuild_domain(snapshot)
        live = self.router.domain
        if rebuilt.total_count != live.total_count:
            raise AssertionError(
                f"replay n_total {rebuilt.total_count} != live {live.total_count}"
            )
        if rebuilt.removed != live.removed:
            raise AssertionError(
                f"replay removed {sorted(rebuilt.removed)} != live "
                f"{sorted(live.removed)}"
            )
        rt_new, rt_live = rebuilt.replacement_table, live.replacement_table
        if (
            rt_new.slots != rt_live.slots
            or rt_new.pos != rt_live.pos
            or rt_new.n_alive != rt_live.n_alive
        ):
            raise AssertionError("replayed ReplacementTable differs from live")
        packed = FleetState.pack(rebuilt, self.router.spec.capacity)
        host = self.router._fleet_host
        for leaf in ("packed", "table", "state"):
            if not np.array_equal(getattr(packed, leaf), getattr(host, leaf)):
                raise AssertionError(
                    f"replayed device operand {leaf!r} differs from live"
                )


# ---------------------------------------------------------------------------
# placement repair scheduling (DESIGN.md §13)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RepairTask:
    """One executed repair copy: ``key``'s replica column ``column`` was
    re-materialised on ``dst`` from the reachable copy on ``src``.
    ``epoch`` is the journal epoch the under-replication was first
    observed at (the oldest-first scheduling key)."""

    key_index: int
    key: int
    column: int
    dst: int
    src: int
    epoch: int


class PlacementRepairer:
    """Bounded-bandwidth repair scheduler: drives a ``StorePlacement``'s
    holders back to the target placement after membership events.

    Attaches to a ``LifecycleManager`` (same fleet as the store's router):
    every journaled membership mutation triggers ``sync()`` — one device
    pass re-enumerating the under-replicated ``(key, column)`` pairs, each
    stamped with the journal epoch it was FIRST observed at — and each
    ``tick()`` emits at most ``budget_per_tick`` repair copies, oldest
    epoch first.  Crash recovery needs no repair journal of its own: the
    target placement is a pure function of the membership journal's fleet
    state, so replaying the journal reproduces it bit-exactly
    (``verify_placement_replay``); the backlog is then re-enumerated from
    the surviving holders.
    """

    def __init__(self, store, manager: LifecycleManager,
                 budget_per_tick: int = 64):
        if store.router is not manager.router:
            raise ValueError(
                "store and manager must wrap the SAME router: the repairer "
                "schedules against the fleet the journal records"
            )
        if budget_per_tick < 1:
            raise ValueError(
                f"budget_per_tick must be >= 1, got {budget_per_tick}"
            )
        self.store = store
        self.manager = manager
        self.budget_per_tick = budget_per_tick
        #: (key_index, column) -> (dst shard, first-observed epoch)
        self._pending: dict[tuple[int, int], tuple[int, int]] = {}
        #: repair copies executed / keys found with no reachable source
        self.completed = 0
        self.lost = 0
        #: per-tick emitted batch sizes — the bounded-bandwidth audit trail
        self.batches: list[int] = []
        manager._placement = self
        self.sync()

    @property
    def backlog(self) -> int:
        return len(self._pending)

    # -- enumeration ---------------------------------------------------------
    def sync(self) -> int:
        """Re-enumerate under-replication against the CURRENT fleet (one
        device pass via ``StorePlacement.sync_targets``).  Tasks still
        needed keep their first-observed epoch — oldest-first ordering
        survives re-syncs; tasks obsoleted by the new target are dropped.
        Returns the backlog size.  With ``n_alive == 0`` nothing is
        schedulable; the backlog is left as-is until capacity returns."""
        if self.manager.n_alive == 0:
            return len(self._pending)
        epoch = self.manager.epoch
        fresh: dict[tuple[int, int], tuple[int, int]] = {}
        for ki, col, dst in self.store.sync_targets():
            prev = self._pending.get((ki, col))
            if prev is not None and prev[0] == dst:
                fresh[(ki, col)] = prev
            else:
                fresh[(ki, col)] = (dst, epoch)
        self._pending = fresh
        return len(fresh)

    # -- bounded execution ---------------------------------------------------
    def tick(self, budget: int | None = None) -> list[RepairTask]:
        """Emit ONE repair batch: at most ``budget`` copies (default the
        configured per-tick budget), oldest first-observed epoch first.
        Keys whose every copy is unreachable are counted in ``lost`` and
        re-enumerated at the next membership sync."""
        if not self._pending:
            return []
        budget = self.budget_per_tick if budget is None else budget
        order = sorted(self._pending.items(), key=lambda kv: (kv[1][1], kv[0]))
        done: list[RepairTask] = []
        for (ki, col), (dst, epoch) in order[:budget]:
            del self._pending[(ki, col)]
            src = self.store.repair_source(ki)
            if src < 0:
                self.lost += 1
                continue
            self.store.complete_repair(ki, col, dst)
            done.append(RepairTask(
                key_index=ki, key=int(self.store.keys[ki]), column=col,
                dst=dst, src=src, epoch=epoch,
            ))
        self.completed += len(done)
        if done:
            self.batches.append(len(done))
        return done

    def quiesce(self, max_ticks: int = 100_000) -> int:
        """Drain the backlog in budgeted batches; returns copies executed."""
        total = 0
        for _ in range(max_ticks):
            if not self._pending:
                break
            total += len(self.tick())
        return total

    # -- crash recovery ------------------------------------------------------
    def verify_placement_replay(self, snapshot=None) -> None:
        """Assert placement(replayed journal) == live placement bit-exactly:
        the manager's device-operand replay parity, then the full R-way
        placement of every registered key recomputed from the rebuilt fleet
        state.  Raises ``AssertionError`` on mismatch."""
        import numpy as np

        from repro.core.bulk import FleetState
        from repro.kernels import ops

        self.manager.verify_replay(snapshot)
        if self.store.keys.size == 0 or self.manager.n_alive == 0:
            return
        rebuilt = self.manager.rebuild_domain(snapshot)
        fleet = FleetState.pack(rebuilt, self.manager.router.spec.capacity)
        replayed, _ = ops.route_replicas_bulk(
            self.store.keys, fleet.device_put(), self.store.spec
        )
        live, _ = self.store.place_keys(self.store.keys)
        if not np.array_equal(np.asarray(replayed), np.asarray(live)):
            raise AssertionError(
                "replayed placement differs from live placement"
            )
