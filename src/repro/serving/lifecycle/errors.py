"""Typed lifecycle errors — the degradation layer's contract with callers.

``BatchRouter.route_*`` / ``SessionRouter.route`` / ``ServingTier.serve``
raise these instead of tripping over an internal ``ValueError`` deep in the
scalar oracle: an all-failed fleet is a *defined* state with a *typed*
answer, not undefined behavior (DESIGN.md §12).
"""
from __future__ import annotations

#: fleet/placement health modes, ordered by health — shared by the lifecycle
#: manager's ``RoutedBatch`` and the placement tier's ``PlacedBatch``
MODE_NORMAL = "normal"
MODE_DEGRADED = "degraded"
MODE_UNAVAILABLE = "unavailable"


class LifecycleError(RuntimeError):
    """Base class for fleet-lifecycle errors."""


class FleetUnavailableError(LifecycleError):
    """Every replica is failed: there is no alive slot to route to.

    Raised by the route entry points *before* any device dispatch (the
    device kernels never see ``n_alive == 0``) and by the degradation layer
    when a caller routes through an unavailable fleet.  Recover or scale up
    to clear it.
    """

    def __init__(self, message: str | None = None, *, epoch: int | None = None):
        if message is None:
            message = "fleet unavailable: no alive replicas to route to"
            if epoch is not None:
                message += f" (epoch {epoch})"
        super().__init__(message)
        #: routing epoch at which the fleet was observed unavailable (None
        #: when the raising layer does not track epochs)
        self.epoch = epoch


class FleetDegradedError(LifecycleError):
    """``n_alive`` fell below the configured floor and the lifecycle policy
    is strict: routing is refused until capacity recovers.

    Only raised when ``LifecycleConfig.strict_floor`` is set; the default
    policy keeps routing (mode ``"degraded"``) and lets the caller decide.
    """

    def __init__(self, n_alive: int, floor: int, *, epoch: int | None = None):
        super().__init__(
            f"fleet degraded: {n_alive} alive replica(s) below the "
            f"min_alive floor of {floor}"
        )
        self.n_alive = n_alive
        self.floor = floor
        self.epoch = epoch


class PlacementDegradedError(LifecycleError):
    """Fewer alive shards than the replication factor: full R-way
    replication is impossible and the placement policy is strict.

    Mirrors ``FleetDegradedError`` one tier up: the default placement
    policy keeps placing (mode ``"degraded"``, every key on all ``n_alive``
    distinct shards) and lets the caller decide; ``strict=True`` turns the
    shortfall into this typed refusal instead.
    """

    def __init__(self, n_alive: int, r: int, *, epoch: int | None = None):
        super().__init__(
            f"placement degraded: {n_alive} alive shard(s) cannot hold "
            f"{r} distinct replicas"
        )
        self.n_alive = n_alive
        self.r = r
        self.epoch = epoch


class ClockWentBackwardsError(LifecycleError):
    """The failure detector's clock returned a timestamp earlier than one it
    already handed out.

    Deadline detection is only sound over a monotone time source: a regressed
    ``now`` silently shrinks every silence window and can un-expire suspect
    timers.  Rather than corrupt the state machine, the detector refuses the
    reading — fix the clock (or the test's ``ManualClock`` choreography).
    """

    def __init__(self, now: float, last: float):
        super().__init__(
            f"clock went backwards: now={now} < last observed {last}; "
            "failure-detector deadlines require a monotone clock"
        )
        self.now = now
        self.last = last


#: admission-rejection reason codes (``AdmissionRejectedError.reason``)
SHED_PAST_DEADLINE = "past_deadline"
SHED_INFEASIBLE = "deadline_infeasible"
SHED_RATE_LIMITED = "rate_limited"
SHED_LATE = "late_at_batch_close"


class AdmissionRejectedError(LifecycleError):
    """A streaming request was shed at admission (or batch close) instead of
    being served past its deadline.

    Typed so callers can distinguish load shedding from infrastructure
    failure: a shed request is the *admission controller working*, carrying
    the machine-readable ``reason`` (one of the ``SHED_*`` codes) and the
    tenant it was charged to.
    """

    def __init__(
        self,
        reason: str,
        *,
        tenant: str | None = None,
        deadline_us: int | None = None,
        now_us: int | None = None,
    ):
        msg = f"request shed: {reason}"
        if tenant is not None:
            msg += f" (tenant {tenant!r})"
        if deadline_us is not None and now_us is not None:
            msg += f" [deadline_us={deadline_us}, now_us={now_us}]"
        super().__init__(msg)
        self.reason = reason
        self.tenant = tenant
        self.deadline_us = deadline_us
        self.now_us = now_us


class PlacementExhaustedError(LifecycleError):
    """The bounded re-salt chain ran out of probes before finding a distinct
    alive shard for some key, even though enough alive shards exist.

    Only reachable with an explicit ``PlacementSpec.max_resalt`` below the
    distinctness-guaranteeing default — the default bound of ``r`` probes
    per column makes exhaustion impossible whenever ``n_alive`` exceeds the
    column index.  Typed so a too-tight bound is a loud error, never a
    silent duplicate replica.
    """

    def __init__(
        self, n_keys: int, max_resalt: int, *, epoch: int | None = None
    ):
        super().__init__(
            f"placement exhausted: {n_keys} key(s) found no distinct alive "
            f"shard within {max_resalt} re-salt probe(s); raise max_resalt "
            "(None guarantees distinctness) or accept degraded placement"
        )
        self.n_keys = n_keys
        self.max_resalt = max_resalt
        self.epoch = epoch
