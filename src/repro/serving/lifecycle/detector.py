"""Heartbeat-driven failure detection with hysteresis + quarantine.

Deadline-based (not phi-accrual: the thresholds are explicit, the state
machine is exactly testable with a manual clock, and the serving tier's
heartbeats arrive on a fixed cadence anyway).  Per replica (DESIGN.md §12):

    alive --silence > suspect_after--> suspect
    suspect --beat--> alive                      (no event: hysteresis)
    suspect --silence > fail_after--> removed    (emits ONE "fail")
    removed --beat--> quarantined                (no event yet)
    quarantined --gap > suspect_after--> removed (flap: window resets)
    quarantined --stable readmit window--> alive (emits ONE "recover")

A flapping replica therefore costs the replacement table ONE fail swap and
ONE recover swap per genuine outage, however many times it blips during
quarantine — the table and the device fleet-state upload are never thrashed
per flap.  Each re-entry into ``removed`` within ``flap_window`` of the
last readmission doubles the required stable window (capped), so habitual
flappers wait longer each round.

The clock is pluggable: ``ManualClock`` for tests/chaos (deterministic
replays), ``MonotonicClock`` for production.  All transitions that *emit
events* happen in ``poll()`` — ``heartbeat()`` only updates per-replica
bookkeeping — so the caller controls exactly when membership changes are
observed (the lifecycle manager polls once per dispatch, coalescing a whole
storm into one device update).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable

from .errors import ClockWentBackwardsError

# -- replica lifecycle states (DESIGN.md §12 state machine) -----------------
ALIVE = "alive"
SUSPECT = "suspect"
REMOVED = "removed"
QUARANTINED = "quarantined"


class MonotonicClock:
    """Production clock: ``time.monotonic``."""

    def now(self) -> float:
        return time.monotonic()


class ManualClock:
    """Deterministic test/chaos clock — advances only when told to."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance time backwards (dt={dt})")
        self._t += dt
        return self._t


@dataclasses.dataclass(frozen=True)
class HeartbeatConfig:
    """Deadline thresholds, all in clock seconds."""

    #: expected beat cadence (documentation + quarantine-gap tolerance)
    heartbeat_interval: float = 1.0
    #: silence before an alive replica turns suspect (no event emitted)
    suspect_after: float = 3.0
    #: silence before a suspect replica is declared failed (emits "fail")
    fail_after: float = 6.0
    #: continuous-beat window a quarantined replica must survive before
    #: re-admission (emits "recover")
    readmit_after: float = 5.0
    #: re-failure within this of the last readmission counts as a flap
    flap_window: float = 60.0
    #: per-flap multiplier on the required readmit window
    flap_backoff: float = 2.0
    #: hard cap on the (backed-off) readmit window
    max_readmit_after: float = 120.0

    def __post_init__(self):
        if not (0 < self.heartbeat_interval <= self.suspect_after):
            raise ValueError(
                f"need 0 < heartbeat_interval <= suspect_after, got "
                f"{self.heartbeat_interval} / {self.suspect_after}"
            )
        if self.fail_after < self.suspect_after:
            raise ValueError(
                f"fail_after ({self.fail_after}) must be >= suspect_after "
                f"({self.suspect_after})"
            )
        if self.readmit_after <= 0 or self.flap_backoff < 1:
            raise ValueError("readmit_after must be > 0 and flap_backoff >= 1")


@dataclasses.dataclass
class _Track:
    state: str = ALIVE
    last_beat: float = 0.0
    quarantine_start: float = 0.0
    last_readmitted: float = -float("inf")
    flaps: int = 0


class FailureDetector:
    """Deadline failure detector over a set of replica slots."""

    def __init__(
        self,
        slots: Iterable[int],
        config: HeartbeatConfig | None = None,
        clock=None,
    ):
        self.config = config or HeartbeatConfig()
        self.clock = clock or MonotonicClock()
        self._last_now = self.clock.now()
        self._tracks: dict[int, _Track] = {
            int(s): _Track(last_beat=self._last_now) for s in slots
        }

    def _now(self) -> float:
        """Read the clock, refusing any regression (deadline math is only
        sound over monotone time — a backwards step would silently shrink
        every silence window)."""
        now = self.clock.now()
        if now < self._last_now:
            raise ClockWentBackwardsError(now=now, last=self._last_now)
        self._last_now = now
        return now

    # -- membership of the *detector* (scale events) ------------------------
    def register(self, slot: int) -> None:
        """A new replica joined (scale-up): tracked alive from now."""
        self._tracks[int(slot)] = _Track(last_beat=self._now())

    def forget(self, slot: int) -> None:
        """A replica left the slot space (scale-down)."""
        self._tracks.pop(int(slot), None)

    def state_of(self, slot: int) -> str:
        return self._tracks[int(slot)].state

    @property
    def slots(self) -> tuple[int, ...]:
        return tuple(sorted(self._tracks))

    def mark_removed(self, slot: int) -> None:
        """Operator-initiated failure: align the detector with a manual
        ``fail`` event so heartbeats must re-earn admission."""
        tr = self._tracks[int(slot)]
        tr.state = REMOVED

    def _required_readmit(self, tr: _Track) -> float:
        window = self.config.readmit_after * (self.config.flap_backoff ** tr.flaps)
        return min(window, self.config.max_readmit_after)

    # -- inputs --------------------------------------------------------------
    def heartbeat(self, slot: int) -> None:
        """One beat from ``slot``.  Never emits events (see ``poll``)."""
        tr = self._tracks[int(slot)]
        now = self._now()
        if tr.state == SUSPECT:
            # hysteresis: a suspect that beats again was never declared
            # failed, so nothing downstream ever heard about it
            tr.state = ALIVE
        elif tr.state == REMOVED:
            tr.state = QUARANTINED
            tr.quarantine_start = now
        elif tr.state == QUARANTINED and (
            now - tr.last_beat > self.config.suspect_after
        ):
            # beats resumed after a gap: the stability window restarts
            tr.quarantine_start = now
        tr.last_beat = now

    # -- transitions ---------------------------------------------------------
    def poll(self) -> list[tuple[str, int]]:
        """Advance deadline-driven transitions; return emitted events.

        Returns ``("fail", slot)`` / ``("recover", slot)`` pairs in slot
        order — the lifecycle manager applies them to the router under one
        coalesced device update.
        """
        now = self._now()
        out: list[tuple[str, int]] = []
        for slot in sorted(self._tracks):
            tr = self._tracks[slot]
            silence = now - tr.last_beat
            if tr.state == ALIVE and silence > self.config.suspect_after:
                tr.state = SUSPECT
            if tr.state == SUSPECT and silence > self.config.fail_after:
                tr.state = REMOVED
                if now - tr.last_readmitted <= self.config.flap_window:
                    tr.flaps += 1  # re-failed soon after readmission
                else:
                    tr.flaps = 0
                out.append(("fail", slot))
            elif tr.state == QUARANTINED:
                if silence > self.config.suspect_after:
                    # went quiet again during quarantine: back to removed,
                    # NO event (downstream still considers it failed)
                    tr.state = REMOVED
                elif now - tr.quarantine_start >= self._required_readmit(tr):
                    tr.state = ALIVE
                    tr.last_readmitted = now
                    out.append(("recover", slot))
        return out
