"""Minimal batched serving engine (CPU-scale) + replicated serving tier.

Each ``Replica`` owns model params and serves aligned batches: prefill the
batch of prompts, then decode step-by-step (greedy).  The ``ServingTier``
composes replicas with the BinomialHash ``BatchRouter``: the whole request
batch is routed in ONE device dispatch (the fused lookup + replacement-table
divert kernel over device-resident fleet state, DESIGN.md §3/§7; handed a
``mesh``, one sharded dispatch across local devices, §8), grouped by routed
replica, each replica serves its group, and fleet events
(fail/recover/scale) only disturb the sessions the paper's guarantees say
they may — and never recompile or re-upload the routing datapath.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.serving.batch_router import BatchRouter


class Replica:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 64):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(lambda p, b: M.prefill(p, b, cfg, max_len))
        self._decode = jax.jit(lambda p, c, b: M.decode_step(p, c, b, cfg))
        self.steps_served = 0

    def generate(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        """prompts (B, S0) int32 -> generated (B, n_new) greedy tokens."""
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        cache, logits = self._prefill(self.params, batch)
        outs = []
        for _ in range(n_new):
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            outs.append(np.asarray(nxt))
            cache, logits = self._decode(self.params, cache, {"tokens": nxt})
            self.steps_served += 1
        return np.concatenate(outs, axis=1)


@dataclass
class Request:
    session_id: str
    prompt: np.ndarray  # (S0,)
    n_new: int = 8


class ServingTier:
    def __init__(
        self,
        cfg: ArchConfig,
        params,
        n_replicas: int,
        max_len: int = 64,
        mesh=None,
        shard_axis: str | None = None,
        engine: str | None = None,
        router_spec=None,
    ):
        self.cfg = cfg
        self.max_len = max_len
        # a mesh shards the routing datapath across local devices (keys
        # split over ``shard_axis``, fleet state replicated — DESIGN.md §8);
        # ``engine`` picks the bulk routing engine (any BULK_ENGINES entry).
        # A full ``RouterSpec`` carries both fields itself, so combining it
        # with either keyword is a conflict, not a merge (same rule as
        # BatchRouter).
        if router_spec is not None:
            clash = [
                k for k, v in (("engine", engine), ("shard_axis", shard_axis))
                if v is not None
            ]
            if clash:
                raise ValueError(
                    f"pass either router_spec or {clash}, not both — the "
                    "spec already carries those fields"
                )
            self.router = BatchRouter(n_replicas, router_spec, mesh=mesh)
        else:
            self.router = BatchRouter(
                n_replicas,
                engine="binomial" if engine is None else engine,
                mesh=mesh,
                shard_axis="data" if shard_axis is None else shard_axis,
            )
        self.replicas = [Replica(cfg, params, max_len) for _ in range(n_replicas)]
        #: optional lifecycle robustness layer (``attach_lifecycle``)
        self.lifecycle = None

    def attach_lifecycle(self, config=None, clock=None):
        """Wrap the router in a ``LifecycleManager`` (DESIGN.md §12).

        Heartbeats then flow through ``tier.heartbeat(slot)``, and every
        ``serve`` first ticks the failure detector — expirations land as
        ONE coalesced device-state update before the batch is routed.
        """
        from repro.serving.lifecycle import LifecycleManager

        self.lifecycle = LifecycleManager(self.router, config=config, clock=clock)
        return self.lifecycle

    def heartbeat(self, replica: int) -> None:
        if self.lifecycle is None:
            raise RuntimeError("call attach_lifecycle() before heartbeat()")
        self.lifecycle.heartbeat(replica)

    def serve(self, requests: list[Request]) -> dict[str, np.ndarray]:
        """Route the whole batch in one device pass, group, serve aligned.

        Ingest is batched end to end (DESIGN.md §9): session ids are hashed
        vectorised, routed in one fused dispatch, and movement-tracked in
        bulk — no per-request Python on the routing path.  With a lifecycle
        attached, detector expirations are applied (coalesced) before
        routing; an all-failed fleet raises ``FleetUnavailableError``.
        """
        if self.lifecycle is not None:
            self.lifecycle.tick()
        if not requests:
            return {}  # zero-row batches have nothing to route or serve
        replicas = self.router.route_batch([r.session_id for r in requests])
        groups: dict[int, list[Request]] = {}
        for r, rep_id in zip(requests, replicas):
            groups.setdefault(int(rep_id), []).append(r)
        results: dict[str, np.ndarray] = {}
        for rep_id, group in groups.items():
            rep = self.replicas[rep_id]
            s0 = max(len(g.prompt) for g in group)
            n_new = max(g.n_new for g in group)
            prompts = np.stack(
                [np.pad(g.prompt, (s0 - len(g.prompt), 0), constant_values=0) for g in group]
            )
            gen = rep.generate(prompts, n_new)
            for g, row in zip(group, gen):
                results[g.session_id] = row[: g.n_new]
        return results

    # fleet events delegate through the lifecycle manager when one is
    # attached (journaled, detector-aligned, placement-synced — a tier-level
    # fail that bypassed the manager would never enter the journal or seed
    # the repairer's backlog) and fall back to the raw router otherwise.
    # Replicas list stays (dead ones idle) — except failing the LAST slot,
    # which the control plane treats as a true LIFO retirement that shrinks
    # the slot space.
    def fail(self, replica: int):
        if self.lifecycle is not None:
            self.lifecycle.fail(replica)
        else:
            self.router.fail(replica)
        del self.replicas[self.router.domain.total_count:]

    def recover(self, replica: int):
        if self.lifecycle is not None:
            self.lifecycle.recover(replica)
        else:
            self.router.recover(replica)

    def scale_up(self, params) -> int:
        """Append a replica serving ``params``; only movers re-prefill."""
        if len(self.replicas) != self.router.domain.total_count:
            raise RuntimeError(
                f"replica list ({len(self.replicas)}) out of lockstep with "
                f"router slot space ({self.router.domain.total_count}) — "
                "was the router mutated directly instead of via the tier?"
            )
        if self.lifecycle is not None:
            new = self.lifecycle.scale_up()
        else:
            new = self.router.scale_up()
        self.replicas.append(Replica(self.cfg, params, self.max_len))
        return new

    def scale_down(self) -> int:
        """Retire the last replica (LIFO, per the paper's operating model)."""
        if self.lifecycle is not None:
            gone = self.lifecycle.scale_down()
        else:
            gone = self.router.scale_down()
        # the router may garbage-collect failed tombstones off the end too
        del self.replicas[self.router.domain.total_count:]
        return gone
