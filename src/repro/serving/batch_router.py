"""Batched, recompile-free session routing — the serving-tier datapath.

``SessionRouter`` routes one session at a time through scalar Python
(``FailureDomain.locate``); fine for a control plane, hopeless for a serving
tier taking millions of lookups per second.  ``BatchRouter`` embeds a u32
``SessionRouter`` (binomial32 base engine + u32 Memento chain) as its
control plane — scalar lookups, stats and fleet-event bookkeeping all live
there — and routes whole key batches on device in ONE dispatch (DESIGN.md §3):

    keys[N] --binomial_route_bulk--> replicas[N]     (fused lookup + remap)

The fused kernel takes the fleet state as *traced*, *device-resident*
operands — ``[n_total, first_alive]`` as a scalar-prefetch/SMEM 2-vector,
the removed-slot set as a fixed-shape packed bit-table in VMEM — so an
arbitrary stream of scale-up / scale-down / fail / recover events re-uses
one compiled executable per batch shape: zero retraces, which is exactly the
paper's constant-time guarantee carried through to the compiled datapath.
Fleet events update the device copies incrementally (a one-word bit flip +
``jax.device_put`` of a few hundred bytes, event-time only); ``route_keys``
itself performs zero host->device state uploads and zero host round-trips —
it accepts and returns ``jax.Array`` (``route_keys_np`` / ``route_batch``
are the numpy convenience wrappers).

The pre-fusion two-stage pipeline (``binomial_bulk_lookup_dyn`` then
``memento_remap`` — two dispatches, ``buckets[N]`` materialised in HBM
between them) is kept behind ``fused=False`` as the benchmark baseline.

Bit-exactness (enforced by tests): for every key, the device path returns
exactly what the embedded scalar router's ``domain.locate`` returns — the
scalar router is the oracle for the batched one.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import bits
from repro.core.memento_jax import mask_words, memento_remap, pack_removed_mask
from repro.kernels.ops import binomial_bulk_lookup_dyn, binomial_route_bulk
from repro.serving.router import SessionRouter


class BatchRouter:
    """Route request batches through the fused single-dispatch kernel."""

    def __init__(
        self,
        n_replicas: int,
        capacity: int | None = None,
        omega: int = 16,
        max_chain: int = 4096,
        use_pallas: bool | None = None,
        interpret: bool = False,
        block_rows: int = 512,
        fused: bool = True,
    ):
        if capacity is None:
            capacity = max(64, bits.next_pow2(2 * n_replicas))
        if n_replicas > capacity:
            raise ValueError(f"n_replicas ({n_replicas}) exceeds capacity ({capacity})")
        # control-plane truth: u32 engine + u32 chain (the device word size);
        # omega/max_chain mirror the device operands so scalar == batch holds
        # for non-default values too
        self.scalar = SessionRouter(
            n_replicas, engine="binomial32", chain_bits=32, omega=omega, max_chain=max_chain
        )
        self.capacity = capacity
        self.n_words = mask_words(capacity)
        self.omega = omega
        self.max_chain = max_chain
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.block_rows = block_rows
        self.fused = fused
        # canonical host mirror of the removed set (packed bit-words),
        # mutated incrementally on fleet events
        self._packed_host = pack_removed_mask((), capacity)
        # device-resident fleet state: pinned once here, then refreshed only
        # on fleet events — never rebuilt or re-uploaded per batch.  Only the
        # operands the selected datapath reads are maintained: packed words +
        # state 2-vector (fused), bool mask + split scalars (two-pass).
        self._packed_dev: jax.Array | None = None
        self._mask_dev: jax.Array | None = None
        self._state_dev: jax.Array | None = None
        self._n_dev: jax.Array | None = None
        self._fa_dev: jax.Array | None = None
        self._resync_device_state()

    @property
    def domain(self):
        return self.scalar.domain

    @property
    def stats(self):
        return self.scalar.stats

    # -- device-side fleet state -------------------------------------------
    def _resync_device_state(self) -> None:
        """Rebuild the device operands from control-plane truth.

        Used at construction and after scale-down (which may garbage-collect
        removed-slot tombstones off the end of the slot space); fail/recover
        take the incremental single-bit path instead.
        """
        self._packed_host = pack_removed_mask(self.domain.removed, self.capacity)
        self._put_mask()
        self._put_scalars()

    def _put_mask(self) -> None:
        """Re-pin the removed-slot table for the selected datapath."""
        if self.fused:
            self._packed_dev = jax.device_put(self._packed_host)
        else:
            mask = np.zeros((self.capacity,), dtype=bool)
            removed = self.domain.removed
            if removed:
                mask[list(removed)] = True
            self._mask_dev = jax.device_put(mask)

    def _put_scalars(self) -> None:
        """Re-pin [n_total, first_alive] on device (a 8-byte upload)."""
        n, fa = self.domain.total_count, self.domain.first_alive()
        if self.fused:
            self._state_dev = jax.device_put(np.array([n, fa], dtype=np.uint32))
        else:
            self._n_dev = jax.device_put(np.uint32(n))
            self._fa_dev = jax.device_put(np.uint32(fa))

    def _set_removed_bit(self, replica: int, removed: bool) -> None:
        """Incremental fleet-event update: flip one mask bit, re-pin."""
        word, bit = replica >> 5, np.uint32(1) << np.uint32(replica & 31)
        if removed:
            self._packed_host[0, word] |= bit
        else:
            self._packed_host[0, word] &= ~bit
        self._put_mask()
        self._put_scalars()  # first_alive may have changed

    # -- routing ------------------------------------------------------------
    session_key = staticmethod(SessionRouter.session_key)

    def _coerce_keys(self, keys) -> jax.Array | np.ndarray:
        """Any int keys -> u32, truncating exactly like the scalar oracle.

        Already-u32 arrays (jax or contiguous numpy) pass straight through —
        no ``uint64 -> uint32`` double conversion, and for ``jax.Array`` no
        host round-trip at all (wider jax ints are truncated in-trace by the
        fused jit, which is the same mod-2^32 semantics).
        """
        if isinstance(keys, jax.Array):
            return keys
        if isinstance(keys, np.ndarray) and keys.dtype == np.uint32:
            # no-op for contiguous input, one widen-free copy for views
            return np.ascontiguousarray(keys)
        return np.ascontiguousarray(keys, dtype=np.uint64).astype(np.uint32)

    def route_keys(self, keys) -> jax.Array:
        """Pre-hashed keys (any int array) -> int32 replica ids, on device.

        The hot path: ONE device dispatch (fused lookup + remap kernel), no
        host round-trip — input ``jax.Array``s stay on device and the result
        is returned as a ``jax.Array`` without synchronising.  Keys are
        truncated to u32, identical to what the scalar u32 oracle
        (``binomial_lookup32`` / the u32 Memento chain) does with wide keys.
        Skips per-session movement bookkeeping; use ``route_batch`` for
        session-level observability, ``route_keys_np`` for a numpy result.
        """
        keys_u32 = self._coerce_keys(keys)
        if self.fused:
            out = binomial_route_bulk(
                keys_u32,
                self._packed_dev,
                self._state_dev,
                n_words=self.n_words,
                omega=self.omega,
                max_chain=self.max_chain,
                use_pallas=self.use_pallas,
                interpret=self.interpret,
                block_rows=self.block_rows,
            )
        else:
            # pre-fusion two-pass pipeline (benchmark baseline): buckets[N]
            # round-trips through HBM between two dispatches
            buckets = binomial_bulk_lookup_dyn(
                keys_u32,
                self._n_dev,
                omega=self.omega,
                use_pallas=self.use_pallas,
                interpret=self.interpret,
                block_rows=self.block_rows,
            )
            out = memento_remap(
                keys_u32,
                buckets,
                self._mask_dev,
                self._n_dev,
                self._fa_dev,
                max_chain=self.max_chain,
            )
        self.stats.lookups += int(np.size(keys_u32))
        return out

    def route_keys_np(self, keys) -> np.ndarray:
        """Numpy-in/numpy-out convenience wrapper around ``route_keys``."""
        return np.asarray(self.route_keys(keys))

    def route_batch(self, session_ids) -> np.ndarray:
        """Session ids (str/int) -> int32 replica ids, one device round-trip.

        Session-id hashing and movement bookkeeping are O(N) host Python —
        fine at request-batch sizes.  For the raw throughput path (millions
        of pre-hashed keys) call ``route_keys`` directly; that is what
        ``benchmarks/bench_router.py`` measures.
        """
        keys = [self.session_key(s) for s in session_ids]
        out = self.route_keys_np(np.array(keys, dtype=np.uint64))
        self.scalar.note_routes(keys, out)
        return out

    def route(self, session_id) -> int:
        """Scalar lookup through the control plane (bit-exact with the batch)."""
        return self.scalar.route(session_id)

    # -- fleet events --------------------------------------------------------
    # Each event mutates the scalar control plane, then refreshes the device
    # state: fail/recover flip one bit incrementally; scale-up touches only
    # the scalar 2-vector; scale-down resyncs (tombstone GC can clear bits).
    def scale_up(self) -> int:
        if self.domain.total_count >= self.capacity:
            raise ValueError(
                f"fleet at device-table capacity ({self.capacity}); "
                "construct BatchRouter with a larger capacity"
            )
        r = self.scalar.scale_up()
        self._put_scalars()
        return r

    def scale_down(self) -> int:
        r = self.scalar.scale_down()
        self._resync_device_state()
        return r

    def fail(self, replica: int) -> None:
        self.scalar.fail(replica)
        if replica in self.domain.removed:
            self._set_removed_bit(replica, True)
        else:
            # failing the LAST slot is a true LIFO removal in the control
            # plane (slot space shrinks, tombstones may GC) — resync wholesale
            self._resync_device_state()

    def recover(self, replica: int) -> None:
        self.scalar.recover(replica)
        self._set_removed_bit(replica, False)

    @property
    def alive(self) -> int:
        return self.scalar.alive
