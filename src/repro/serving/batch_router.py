"""Batched, recompile-free, storm-proof session routing — the serving-tier
datapath, generic over the pluggable bulk engines (DESIGN.md §10).

``SessionRouter`` routes one session at a time through scalar Python
(``FailureDomain.locate``); fine for a control plane, hopeless for a serving
tier taking millions of lookups per second.  ``BatchRouter`` embeds a u32
``SessionRouter`` as its control plane — scalar lookups, stats and
fleet-event bookkeeping all live there — and routes whole key batches on
device in ONE dispatch (DESIGN.md §3, §7):

    keys[N] --route_bulk--> replicas[N]   (fused lookup + divert)

Which consistent-hash algorithm runs inside that dispatch is the
``RouterSpec.engine`` (``BatchRouter(engine="binomial")`` is the default;
``engine="jump"`` selects the JumpHash device datapath): each
``BULK_ENGINES`` entry pairs the device kernels with the scalar oracle
flavour the embedded control plane runs, so device == scalar bit-exactness
holds per engine (tests enforce).  The engine's fused kernel takes the
fleet state as *traced*, *device-resident* operands — one ``FleetState``
pytree: ``[n_total, n_alive]`` as a scalar-prefetch/SMEM 2-vector, the
removed-slot set as a fixed-shape packed bit-table, and the MementoHash-
style replacement table (``(1, capacity)`` i32 — the ``slots`` permutation;
``pos`` stays host-side) in VMEM — so an arbitrary stream of scale-up /
scale-down / fail / recover events re-uses one compiled executable per
batch shape: zero retraces.  Removed buckets resolve through AT MOST TWO
bounded table gathers instead of a data-dependent rejection walk, so an
event storm costs the same per batch as a healthy fleet — the paper's
constant-time guarantee carried through the compiled datapath *including*
its failure path.  Fleet events update the device copies incrementally (a
one-word bit flip + permutation swap on the host ``FleetState`` mirror,
then a few-KiB ``jax.device_put``, event-time only); ``route_keys`` itself
performs zero host->device state uploads and zero host round-trips — it
accepts and returns ``jax.Array`` (``route_keys_np`` / ``route_batch`` are
the numpy convenience wrappers).

Multi-device hosts hand ``BatchRouter`` a mesh: key batches are then split
across the mesh axis under one jitted ``shard_map`` (fleet state
replicated, per-device fused dispatch, no collectives — DESIGN.md §8) for
near-linear keys/s scaling.  ``block_rows=None`` engages the measure-once
persistent autotuner on Pallas backends (``repro.kernels.autotune``).

The pre-fusion two-stage pipeline (``lookup_bulk_dyn`` then
``memento_remap_table`` — two dispatches, ``buckets[N]`` materialised in
HBM between them) is kept behind ``fused=False`` as the benchmark baseline.

Configuration rides in one frozen ``RouterSpec`` (``BatchRouter(16,
spec)``); the individual keyword arguments remain as sugar that builds the
spec (``BatchRouter(16, engine="jump", capacity=128)``) — passing both is
an error, not a merge.

Bit-exactness (enforced by tests): for every key, the device path returns
exactly what the embedded scalar router's ``domain.locate`` returns — the
scalar router is the oracle for the batched one.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bits
from repro.core.bulk import FleetState, RouterSpec
from repro.core.memento_jax import memento_remap_table
from repro.core.registry import make_bulk
from repro.kernels import autotune
from repro.kernels import ops
from repro.kernels.fused import LANES
from repro.serving.lifecycle.errors import FleetUnavailableError
from repro.serving.router import SessionRouter, hash_session_ids

#: "this keyword was not passed" sentinel — None is meaningful for several
#: spec fields (use_pallas auto, block_rows autotune), so absence needs its
#: own marker to detect spec-vs-kwargs conflicts
_UNSET = object()


def _check_block_rows(block_rows) -> None:
    """The serving tier insists on whole sublane tiles; the raw kernel entry
    points accept any divisor (tests tile tiny batches)."""
    if block_rows is not None and (block_rows <= 0 or block_rows % 8):
        raise ValueError(
            f"block_rows must be a positive multiple of 8 (the i32 sublane "
            f"tile), got {block_rows}; pass None to autotune"
        )


class BatchRouter:
    """Route request batches through the fused single-dispatch kernel of a
    pluggable bulk engine."""

    def __init__(
        self,
        n_replicas: int,
        spec: RouterSpec | None = None,
        *,
        mesh=None,
        fused: bool = True,
        max_chain: int = 4096,
        engine=_UNSET,
        capacity=_UNSET,
        omega=_UNSET,
        use_pallas=_UNSET,
        interpret=_UNSET,
        block_rows=_UNSET,
        shard_axis=_UNSET,
        donate_keys=_UNSET,
    ):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        kwargs = {
            name: value
            for name, value in (
                ("engine", engine),
                ("capacity", capacity),
                ("omega", omega),
                ("use_pallas", use_pallas),
                ("interpret", interpret),
                ("block_rows", block_rows),
                ("shard_axis", shard_axis),
                ("donate_keys", donate_keys),
            )
            if value is not _UNSET
        }
        if spec is not None:
            if not isinstance(spec, RouterSpec):
                raise TypeError(
                    f"the second positional argument is the RouterSpec (got "
                    f"{type(spec).__name__}); pre-spec positional callers "
                    "should pass capacity and friends as keywords: "
                    "BatchRouter(n, capacity=..., omega=...)"
                )
            if kwargs:
                raise ValueError(
                    f"pass either a RouterSpec or individual spec fields, not "
                    f"both (got spec and {sorted(kwargs)})"
                )
        else:
            if kwargs.get("capacity", _UNSET) is None:
                kwargs.pop("capacity")  # explicit capacity=None = default
            kwargs.setdefault(
                "capacity", max(64, bits.next_pow2(2 * n_replicas))
            )
            # before RouterSpec(**kwargs): the spec's own weaker check
            # (>= 1) would otherwise claim e.g. block_rows=0 first, with
            # the wrong error message for this constructor's contract
            _check_block_rows(kwargs.get("block_rows"))
            spec = RouterSpec(**kwargs)  # validates capacity/omega
        _check_block_rows(spec.block_rows)  # spec-mode path
        if n_replicas > spec.capacity:
            raise ValueError(
                f"n_replicas ({n_replicas}) exceeds capacity ({spec.capacity})"
            )
        if max_chain < 0:
            raise ValueError(
                f"max_chain must be >= 0, got {max_chain}; note the table "
                "resolution has a hard two-redirect bound, so max_chain only "
                "labels the (unused) chain budget — any value >= 0 routes "
                "identically"
            )
        if mesh is not None and not fused:
            raise ValueError(
                "the two-pass baseline (fused=False) is single-host only; "
                "the mesh-sharded datapath always runs the fused kernel"
            )
        if spec.donate_keys and mesh is None:
            raise ValueError(
                "donate_keys applies to the mesh-sharded datapath only; "
                "pass a mesh or drop donate_keys"
            )
        self.spec = spec
        self._bulk = make_bulk(spec.engine)  # fails loudly on unknown engines
        # control-plane truth: the engine's u32 scalar oracle + u32 table
        # resolution (the device semantics); omega mirrors the device
        # operand so scalar == batch holds for non-default values too.
        # max_chain is INERT under table resolution (hard two-redirect
        # bound) — accepted and validated for API stability with the
        # chain-mode library flavour, forwarded only so the control plane
        # would stay bit-exact if flipped to chain mode.
        # allow_empty: an all-failed fleet is a queryable state the route
        # entry points answer with FleetUnavailableError — the failure event
        # itself is never refused (DESIGN.md §12)
        self.scalar = SessionRouter(
            n_replicas,
            engine=self._bulk.scalar_engine,
            chain_bits=32,
            omega=spec.omega,
            max_chain=max_chain,
            resolve="table",
            allow_empty=True,
        )
        self.max_chain = max_chain
        self.fused = fused
        self.mesh = mesh
        self._n_shards = 1 if mesh is None else int(mesh.shape[spec.shard_axis])
        #: per-batch-rows resolved block size (autotuner results memoised)
        self._tuned_rows: dict[int, int] = {}
        #: per-block_rows dispatch specs (replace + re-validate once, not
        #: per batch — route_keys does zero host work beyond the dispatch)
        self._dispatch_specs: dict[int, RouterSpec] = {}
        #: per-(rows, block_rows) jitted sharded executables (mesh mode)
        self._sharded_route: dict[int, object] = {}
        # canonical host mirror of the device fleet state, mutated
        # incrementally on fleet events; the device twin is pinned once
        # here, then refreshed only on fleet events — never rebuilt or
        # re-uploaded per batch.  The two-pass baseline additionally keeps
        # the n scalar its first dispatch reads.
        self._fleet_host = FleetState.pack(self.domain, spec.capacity)
        self._fleet_dev: FleetState | None = None
        self._n_dev: jax.Array | None = None
        #: attached observability LoadMonitor (None = uninstrumented): when
        #: set, the fused dispatch runs the instrumented route so the
        #: per-shard bincount rides in the SAME device pass as the routing
        self._load_monitor = None
        #: routing epoch: one tick per fleet event — callers (and the
        #: lifecycle layer) use it to detect placements staled by later
        #: events; the journal's epochs match it one-to-one
        self._epoch = 0
        # event-storm coalescing state (see ``coalesced_events``)
        self._coalescing = False
        self._state_dirty = False
        self._put_state()

    # -- spec facade (the pre-spec attribute names, kept as properties) -----
    @property
    def engine(self) -> str:
        return self.spec.engine

    @property
    def capacity(self) -> int:
        return self.spec.capacity

    @property
    def n_words(self) -> int:
        return self.spec.n_words

    @property
    def omega(self) -> int:
        return self.spec.omega

    @property
    def use_pallas(self):
        return self.spec.use_pallas

    @property
    def interpret(self) -> bool:
        return self.spec.interpret

    @property
    def block_rows(self):
        return self.spec.block_rows

    @property
    def shard_axis(self) -> str:
        return self.spec.shard_axis

    @property
    def donate_keys(self) -> bool:
        return self.spec.donate_keys

    @property
    def domain(self):
        return self.scalar.domain

    @property
    def stats(self):
        return self.scalar.stats

    # the device FleetState leaves, as the historical attribute names
    @property
    def _packed_dev(self):
        return None if self._fleet_dev is None else self._fleet_dev.packed

    @property
    def _table_dev(self):
        return None if self._fleet_dev is None else self._fleet_dev.table

    @property
    def _state_dev(self):
        return None if self._fleet_dev is None else self._fleet_dev.state

    # -- device-side fleet state -------------------------------------------
    def _device_put(self, host_tree):
        """Pin host state on device — replicated across the mesh if sharded."""
        if self.mesh is None:
            return jax.device_put(host_tree)
        from jax.sharding import NamedSharding, PartitionSpec as P

        return jax.device_put(host_tree, NamedSharding(self.mesh, P()))

    def _resync_device_state(self) -> None:
        """Rebuild the device operands from control-plane truth.

        Used after scale-down (which may garbage-collect removed-slot
        tombstones off the end of the slot space); fail/recover take the
        incremental single-bit + permutation-swap path instead.
        """
        self._fleet_host.resync(self.domain)  # includes the table/state pack
        self._upload_state()

    def _put_state(self) -> None:
        """Re-pack the ``FleetState`` mirror's table + state (the host
        ``ReplacementTable`` is updated O(1) per event by the control
        plane) and re-pin the device twin."""
        self._fleet_host.update(self.domain)
        self._upload_state()

    def _upload_state(self) -> None:
        """Re-pin every device operand of the fleet state — event-time only,
        never per batch, and ONE ``device_put`` for the lot (a few KiB; the
        per-call fixed cost dominates at these sizes, so batching the
        transfers keeps fleet events well under a millisecond)."""
        if self._coalescing:
            # inside coalesced_events: defer — the whole event burst lands
            # as ONE wholesale resync + upload on exit
            self._state_dirty = True
            return
        if self.fused:
            self._fleet_dev = self._device_put(self._fleet_host)
        else:
            self._fleet_dev, self._n_dev = self._device_put(
                (self._fleet_host, np.uint32(self.domain.total_count))
            )

    def _set_removed_bit(self, replica: int, removed: bool) -> None:
        """Incremental fleet-event update: flip one mask bit, re-pin."""
        self._fleet_host.set_removed(replica, removed)
        self._put_state()  # the permutation swapped O(1) entries

    # -- event-storm coalescing ---------------------------------------------
    @contextlib.contextmanager
    def coalesced_events(self):
        """Defer device-state refresh across a burst of fleet events.

        Inside the context every fail/recover/scale event still mutates the
        host control plane immediately (the scalar oracle, the journal
        epochs and ``routing_epoch`` all stay exact per event); only the
        device-twin refresh is deferred.  On exit the final state lands in
        ONE wholesale resync + upload — bit-exact with per-event
        application, because the device operands are a pure function of the
        final control-plane state.  Re-entrant: the outermost context owns
        the flush.  ``route_keys``/``route_ids`` flush defensively, so a
        dispatch can never read a stale device twin.
        """
        if self._coalescing:
            yield
            return
        self._coalescing = True
        try:
            yield
        finally:
            self._coalescing = False
            if self._state_dirty:
                self._flush_events()

    def _flush_events(self) -> None:
        """Land every deferred event in one resync + one device upload."""
        self._state_dirty = False
        self._fleet_host.resync(self.domain)
        self._upload_state()

    # -- block-size resolution ----------------------------------------------
    def _resolve_block_rows(self, rows: int) -> int:
        """Static tiling for a batch of ``rows`` x128 keys.

        Explicit ``block_rows`` wins; the jnp fallback and interpret mode
        (a test harness, not a perf target) take the default; otherwise the
        measure-once autotuner picks per (backend, rows, capacity) and
        persists the verdict (DESIGN.md §7).
        """
        if self.spec.block_rows is not None:
            return self.spec.block_rows
        if not self.spec.pallas_selected() or self.spec.interpret:
            return autotune.DEFAULT_BLOCK_ROWS
        if rows not in self._tuned_rows:
            probe = np.zeros((rows * LANES,), dtype=np.uint32)

            def measure(candidate: int) -> None:
                # probe batches are timing scaffolding, not traffic: keep
                # them out of any attached load accumulator
                monitor, self._load_monitor = self._load_monitor, None
                try:
                    jax.block_until_ready(self._dispatch(probe, candidate))
                finally:
                    self._load_monitor = monitor

            flavour = "fused" if self.fused else "two_pass"
            if self.spec.engine != "binomial":
                flavour = f"{self.spec.engine}_{flavour}"
            self._tuned_rows[rows] = autotune.tuned_block_rows(
                jax.default_backend(),
                rows,
                self.spec.capacity,
                measure,
                variant=flavour,
            )
        return self._tuned_rows[rows]

    def _dispatch_spec(self, block_rows: int) -> RouterSpec:
        """The spec with the per-batch tiling resolved to a concrete int
        (memoised — block_rows takes a handful of values per router)."""
        if block_rows == self.spec.block_rows:
            return self.spec
        spec = self._dispatch_specs.get(block_rows)
        if spec is None:
            spec = dataclasses.replace(self.spec, block_rows=block_rows)
            self._dispatch_specs[block_rows] = spec
        return spec

    # -- routing ------------------------------------------------------------
    session_key = staticmethod(SessionRouter.session_key)

    def _check_routable(self) -> None:
        """Route-entry guard: typed error on an all-failed fleet, and land
        any coalesced events the dispatch would otherwise miss."""
        if self.scalar.alive == 0:
            raise FleetUnavailableError(epoch=self._epoch)
        if self._state_dirty and not self._coalescing:
            self._flush_events()

    def _coerce_keys(self, keys) -> jax.Array | np.ndarray:
        """Any int keys -> u32, truncating exactly like the scalar oracle.

        Already-u32 arrays (jax or contiguous numpy) pass straight through —
        no ``uint64 -> uint32`` double conversion, and for ``jax.Array`` no
        host round-trip at all (wider jax ints are truncated in-trace by the
        fused jit, which is the same mod-2^32 semantics).
        """
        if isinstance(keys, jax.Array):
            return keys
        if isinstance(keys, np.ndarray) and keys.dtype == np.uint32:
            # no-op for contiguous input, one widen-free copy for views
            return np.ascontiguousarray(keys)
        return np.ascontiguousarray(keys, dtype=np.uint64).astype(np.uint32)

    # -- load-monitor attachment (observability tier, DESIGN.md §15) --------
    def attach_load_monitor(self, monitor) -> None:
        """Instrument the fused dispatch with the monitor's device-side
        load accumulator (``ops.route_load_bulk``).  Replica ids stay
        bit-exact with the uninstrumented path; the accumulate is folded
        into the same single dispatch.  Single-host fused datapath only —
        the mesh-sharded and two-pass paths are not instrumented."""
        if self.mesh is not None:
            raise ValueError(
                "load monitoring is single-host only; the mesh-sharded "
                "datapath is not instrumented"
            )
        if not self.fused:
            raise ValueError(
                "load monitoring requires the fused datapath "
                "(fused=False is the two-pass benchmark baseline)"
            )
        self._load_monitor = monitor

    def detach_load_monitor(self) -> None:
        self._load_monitor = None

    def _dispatch(self, keys_u32, block_rows: int) -> jax.Array:
        """Single-host dispatch of one batch at a given tiling."""
        spec = self._dispatch_spec(block_rows)
        if self.fused:
            monitor = self._load_monitor
            if monitor is not None:
                # the instrumented route: same dispatch count, the per-shard
                # bincount rides along (always the fused jnp pass — like the
                # placement pass it has no Pallas twin; bit-exact with the
                # kernel, as tests enforce)
                n_keys = int(np.size(keys_u32))
                out, counts = ops.route_load_bulk(
                    keys_u32, self._fleet_dev, monitor.counts_dev, spec,
                    sample_shift=monitor.effective_shift(n_keys),
                )
                monitor.note_dispatch(counts, n_keys)
                return out
            return ops.route_bulk(keys_u32, self._fleet_dev, spec)
        # pre-fusion two-pass pipeline (benchmark baseline): buckets[N]
        # round-trips through HBM between two dispatches
        buckets = ops.lookup_bulk_dyn(keys_u32, self._n_dev, spec)
        return memento_remap_table(
            keys_u32,
            buckets,
            self._fleet_dev.packed,
            self._fleet_dev.table,
            self._fleet_dev.state,
            n_words=self.spec.n_words,
        )

    def _route_sharded(self, keys_u32, block_rows: int) -> jax.Array:
        """Mesh-sharded dispatch: keys split over the mesh axis, fleet state
        replicated, ONE jitted shard_map executable (DESIGN.md §8)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        shape = keys_u32.shape
        flat = keys_u32.reshape(-1)
        total = flat.shape[0]
        pad = (-total) % self._n_shards
        owned = not isinstance(keys_u32, jax.Array)  # we upload -> we may donate
        if pad:
            flat = (np.pad if isinstance(flat, np.ndarray) else jnp.pad)(flat, (0, pad))
            owned = True
        if isinstance(flat, np.ndarray):
            # upload already sharded along the mesh axis — the executable
            # never has to re-lay it out, and the buffer is ours to donate
            flat = jax.device_put(
                flat, NamedSharding(self.mesh, P(self.spec.shard_axis))
            )
        route = self._sharded_route.get(block_rows)
        if route is None:
            route = ops.make_sharded_route(self.mesh, self._dispatch_spec(block_rows))
            self._sharded_route[block_rows] = route
        if self.spec.donate_keys and not owned:
            # donation consumes the buffer; never consume one the caller owns
            flat = jnp.asarray(flat).copy()
        out = route(flat, self._fleet_dev)
        if pad:
            out = out[:total]
        return out.reshape(shape)

    def route_keys(self, keys) -> jax.Array:
        """Pre-hashed keys (any int array) -> int32 replica ids, on device.

        The hot path: ONE device dispatch (the engine's fused lookup +
        table-divert kernel; one jitted shard_map over the mesh when
        sharded), no host round-trip — input ``jax.Array``s stay on device
        and the result is returned as a ``jax.Array`` without
        synchronising.  Keys are truncated to u32, identical to what the
        engine's scalar u32 oracle does with wide keys.  Skips per-session
        movement bookkeeping; use ``route_batch`` for session-level
        observability, ``route_keys_np`` for numpy.
        """
        self._check_routable()
        keys_u32 = self._coerce_keys(keys)
        size = int(np.size(keys_u32))
        if size == 0:
            # zero-row batches have nothing to dispatch (and the kernel grid
            # cannot be empty) — answer with an empty result of the right type
            return jnp.zeros(np.shape(keys_u32), dtype=jnp.int32)
        rows = -(-size // LANES)
        # tune for what one device actually sees: the per-shard row count
        block_rows = self._resolve_block_rows(-(-rows // self._n_shards))
        if self.mesh is not None:
            out = self._route_sharded(keys_u32, block_rows)
        else:
            out = self._dispatch(keys_u32, block_rows)
        self.stats.lookups += size
        return out

    def route_keys_np(self, keys) -> np.ndarray:
        """Numpy-in/numpy-out convenience wrapper around ``route_keys``."""
        return np.asarray(self.route_keys(keys))

    def route_ids(self, session_ids) -> jax.Array:
        """Raw u64 int session ids -> int32 replica ids, ONE fused dispatch.

        The device ingest path (DESIGN.md §9): ids are split into u32 halves
        on the host (two cheap vectorised views) and the splitmix64 session
        hash, the engine's lookup and the table divert all run inside
        the SAME kernel — the ``keys[N]`` array the pre-hash path builds
        never exists.  Bit-exact with ``route_keys(hash_session_ids(ids))``.
        Single-host only (mesh users pre-hash and call ``route_keys``);
        skips movement bookkeeping like ``route_keys``.
        """
        if self.mesh is not None:
            raise ValueError(
                "route_ids is single-host only; under a mesh pre-hash with "
                "hash_session_ids and call route_keys"
            )
        self._check_routable()
        ids = np.ascontiguousarray(session_ids, dtype=np.uint64)
        if ids.size == 0:
            return jnp.zeros(ids.shape, dtype=jnp.int32)
        lo, hi = bits.np_split64(ids)
        rows = -(-int(ids.size) // LANES)
        block_rows = self._resolve_block_rows(rows)
        out = ops.route_ingest_bulk(
            lo, hi, self._fleet_dev, self._dispatch_spec(block_rows)
        )
        self.stats.lookups += int(ids.size)
        return out

    def route_batch(self, session_ids) -> np.ndarray:
        """Session ids (str/int) -> int32 replica ids, one device round-trip.

        The whole request path is batched (DESIGN.md §9): ids are hashed by
        the vectorised ``hash_session_ids`` (padded byte-matrix FNV-1a for
        strings, ``np_mix64`` for ints — bit-exact with the scalar
        ``session_key``), routed in one fused device dispatch, and movement
        bookkeeping lands in the bulk open-addressing ``SessionStore`` — no
        per-session Python anywhere, so ingest keeps up with the device
        rate instead of capping it.  For pre-hashed keys call ``route_keys``
        directly; for raw u64 int ids ``route_ids`` additionally fuses the
        hash into the routing kernel itself.
        """
        keys = hash_session_ids(session_ids)
        if keys.size == 0:
            return np.empty(keys.shape, dtype=np.int32)
        out = self.route_keys_np(keys)
        self.scalar.note_routes(keys, out)
        return out

    def route(self, session_id) -> int:
        """Scalar lookup through the control plane (bit-exact with the batch)."""
        return self.scalar.route(session_id)

    # -- fleet events --------------------------------------------------------
    # Each event mutates the scalar control plane (removed set + O(1)
    # replacement-table swaps), then refreshes the device state: fail/recover
    # flip one bit + re-pin the few-KiB table; scale-up re-pins table +
    # scalars; scale-down resyncs (tombstone GC can clear bits).
    def scale_up(self) -> int:
        if self.domain.total_count >= self.spec.capacity:
            raise ValueError(
                f"fleet at device-table capacity ({self.spec.capacity}); "
                "construct BatchRouter with a larger capacity"
            )
        r = self.scalar.scale_up()
        self._epoch += 1
        self._put_state()
        return r

    def scale_down(self) -> int:
        r = self.scalar.scale_down()
        self._epoch += 1
        self._resync_device_state()
        return r

    def fail(self, replica: int) -> None:
        self.scalar.fail(replica)
        self._epoch += 1
        if replica in self.domain.removed:
            self._set_removed_bit(replica, True)
        else:
            # failing the LAST slot is a true LIFO removal in the control
            # plane (slot space shrinks, tombstones may GC) — resync wholesale
            self._resync_device_state()

    def recover(self, replica: int) -> None:
        self.scalar.recover(replica)
        self._epoch += 1
        self._set_removed_bit(replica, False)

    @property
    def alive(self) -> int:
        return self.scalar.alive

    @property
    def routing_epoch(self) -> int:
        """Fleet-event counter: the epoch the next dispatch routes under."""
        return self._epoch
