"""Batched, recompile-free session routing — the serving-tier datapath.

``SessionRouter`` routes one session at a time through scalar Python
(``FailureDomain.locate``); fine for a control plane, hopeless for a serving
tier taking millions of lookups per second.  ``BatchRouter`` embeds a u32
``SessionRouter`` (binomial32 base engine + u32 Memento chain) as its
control plane — scalar lookups, stats and fleet-event bookkeeping all live
there — and routes whole key batches on device:

    keys[N] --binomial_bulk_lookup_dyn--> buckets[N] --memento_remap--> replicas[N]

Both device stages take the fleet state as *traced* operands — the cluster
size ``n_total`` as a scalar-prefetch/SMEM scalar, the removed-slot table as
a fixed-``capacity`` bool array — so an arbitrary stream of scale-up /
scale-down / fail / recover events re-uses one compiled executable per batch
shape: zero retraces, which is exactly the paper's constant-time guarantee
carried through to the compiled datapath.

Bit-exactness (enforced by tests): for every key, the device path returns
exactly what the embedded scalar router's ``domain.locate`` returns — the
scalar router is the oracle for the batched one.
"""
from __future__ import annotations

import numpy as np

from repro.core import bits
from repro.core.memento_jax import memento_remap
from repro.kernels.ops import binomial_bulk_lookup_dyn
from repro.serving.router import SessionRouter


class BatchRouter:
    """Route request batches through the dynamic-n kernel + device remap."""

    def __init__(
        self,
        n_replicas: int,
        capacity: int | None = None,
        omega: int = 16,
        max_chain: int = 4096,
        use_pallas: bool | None = None,
        interpret: bool = False,
        block_rows: int = 512,
    ):
        if capacity is None:
            capacity = max(64, bits.next_pow2(2 * n_replicas))
        if n_replicas > capacity:
            raise ValueError(f"n_replicas ({n_replicas}) exceeds capacity ({capacity})")
        # control-plane truth: u32 engine + u32 chain (the device word size);
        # omega/max_chain mirror the device operands so scalar == batch holds
        # for non-default values too
        self.scalar = SessionRouter(
            n_replicas, engine="binomial32", chain_bits=32, omega=omega, max_chain=max_chain
        )
        self.capacity = capacity
        self.omega = omega
        self.max_chain = max_chain
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.block_rows = block_rows
        self._mask: np.ndarray | None = None  # cached (capacity,) removed table

    @property
    def domain(self):
        return self.scalar.domain

    @property
    def stats(self):
        return self.scalar.stats

    # -- device-side fleet state -------------------------------------------
    def _device_state(self):
        if self._mask is None:
            mask = np.zeros((self.capacity,), dtype=bool)
            removed = list(self.domain.removed)
            if removed:
                mask[removed] = True
            self._mask = mask
        return (
            self._mask,
            np.uint32(self.domain.total_count),
            np.uint32(self.domain.first_alive()),
        )

    def _invalidate(self):
        self._mask = None

    # -- routing ------------------------------------------------------------
    session_key = staticmethod(SessionRouter.session_key)

    def route_keys(self, keys) -> np.ndarray:
        """Pre-hashed keys (any int array) -> int32 replica ids, on device.

        Keys are truncated to u32 — identical to what the scalar u32 oracle
        (``binomial_lookup32`` / the u32 Memento chain) does with wide keys.
        The raw-key hot path skips per-session movement bookkeeping; use
        ``route_batch`` for session-level observability.
        """
        keys_u32 = np.ascontiguousarray(keys, dtype=np.uint64).astype(np.uint32)
        mask, n_total, first_alive = self._device_state()
        buckets = binomial_bulk_lookup_dyn(
            keys_u32,
            n_total,
            omega=self.omega,
            use_pallas=self.use_pallas,
            interpret=self.interpret,
            block_rows=self.block_rows,
        )
        out = memento_remap(
            keys_u32, buckets, mask, n_total, first_alive, max_chain=self.max_chain
        )
        self.stats.lookups += int(keys_u32.size)
        return np.asarray(out)

    def route_batch(self, session_ids) -> np.ndarray:
        """Session ids (str/int) -> int32 replica ids, one device round-trip.

        Session-id hashing and movement bookkeeping are O(N) host Python —
        fine at request-batch sizes.  For the raw throughput path (millions
        of pre-hashed keys) call ``route_keys`` directly; that is what
        ``benchmarks/bench_router.py`` measures.
        """
        keys = [self.session_key(s) for s in session_ids]
        out = self.route_keys(np.array(keys, dtype=np.uint64))
        self.scalar.note_routes(keys, out)
        return out

    def route(self, session_id) -> int:
        """Scalar lookup through the control plane (bit-exact with the batch)."""
        return self.scalar.route(session_id)

    # -- fleet events --------------------------------------------------------
    def scale_up(self) -> int:
        if self.domain.total_count >= self.capacity:
            raise ValueError(
                f"fleet at device-table capacity ({self.capacity}); "
                "construct BatchRouter with a larger capacity"
            )
        self._invalidate()
        return self.scalar.scale_up()

    def scale_down(self) -> int:
        self._invalidate()
        return self.scalar.scale_down()

    def fail(self, replica: int) -> None:
        self._invalidate()
        self.scalar.fail(replica)

    def recover(self, replica: int) -> None:
        self._invalidate()
        self.scalar.recover(replica)

    @property
    def alive(self) -> int:
        return self.scalar.alive
