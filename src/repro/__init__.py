"""repro — BinomialHash consistent hashing as the placement/routing substrate
of a multi-pod JAX training/inference framework. See README.md / DESIGN.md."""
