"""repro — BinomialHash consistent hashing as the placement/routing substrate
of a multi-pod JAX training/inference framework. See DESIGN.md for the
architecture notes and ``examples/`` for runnable entry points.

The curated public surface (``__all__``):

* ``BatchRouter`` / ``ServingTier`` — the batched serving datapath and the
  replicated tier built on it;
* ``RouterSpec`` / ``FleetState`` / ``BulkEngine`` — the engine-agnostic
  bulk-routing protocol (DESIGN.md §10);
* ``route_bulk`` / ``route_ingest_bulk`` / ``lookup_bulk_dyn`` /
  ``make_sharded_route`` — the jit'd bulk routing entry points;
* ``make`` / ``make_bulk`` + the ``ENGINES`` / ``BULK_ENGINES`` registries —
  the scalar comparison suite and the pluggable device engines;
* ``SessionRouter`` / ``hash_session_ids`` — the scalar control plane and
  the vectorised session-id ingest;
* ``StorePlacement`` / ``PlacementSpec`` / ``PlacementRepairer`` +
  ``route_replicas_bulk`` / ``placement_diff_bulk`` — the R-way replicated
  placement tier (DESIGN.md §13);
* ``MetricsRegistry`` / ``LoadMonitor`` / ``SpanTrace`` +
  ``route_load_bulk`` and the ``BalanceDriftAlarm`` /
  ``DisruptionBoundAlarm`` types — the observability tier (DESIGN.md §15).

Attributes resolve lazily (PEP 562): ``import repro`` stays light, and the
serving stack (models, configs) only loads when actually touched.
"""
from __future__ import annotations

import importlib

#: export name -> defining module (resolved on first attribute access);
#: ``__all__`` derives from this, so the two can never drift
_EXPORTS = {
    "BatchRouter": "repro.serving.batch_router",
    "ServingTier": "repro.serving.engine",
    "SessionRouter": "repro.serving.router",
    "hash_session_ids": "repro.serving.router",
    "RouterSpec": "repro.core.bulk",
    "FleetState": "repro.core.bulk",
    "BulkEngine": "repro.core.bulk",
    "ENGINES": "repro.core.registry",
    "BULK_ENGINES": "repro.core.registry",
    "make": "repro.core.registry",
    "make_bulk": "repro.core.registry",
    "route_bulk": "repro.kernels.ops",
    "route_ingest_bulk": "repro.kernels.ops",
    "lookup_bulk_dyn": "repro.kernels.ops",
    "make_sharded_route": "repro.kernels.ops",
    "PlacementSpec": "repro.core.bulk",
    "StorePlacement": "repro.placement.store",
    "PlacementRepairer": "repro.serving.lifecycle",
    "route_replicas_bulk": "repro.kernels.ops",
    "placement_diff_bulk": "repro.kernels.ops",
    "MetricsRegistry": "repro.observability",
    "LoadMonitor": "repro.observability",
    "LoadConfig": "repro.observability",
    "SpanTrace": "repro.observability",
    "BalanceDriftAlarm": "repro.observability",
    "DisruptionBoundAlarm": "repro.observability",
    "route_load_bulk": "repro.kernels.ops",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module 'repro' has no attribute '{name}'")
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value  # cache: subsequent accesses skip the import
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
