"""Measure-once ``block_rows`` autotuner for the bulk routing kernels.

``block_rows`` is the VMEM tiling knob of the Pallas datapath (rows per
grid step, x128 lanes).  The right value depends on backend generation,
batch size and fleet capacity; a hardcoded 512 leaves double-buffering
headroom on the table (PR 2) but is not optimal everywhere.  This module
replaces the constant with a tiny persistent autotuner (DESIGN.md §7):

* the FIRST time a (backend, rows, capacity) combination is routed, each
  candidate block size is timed once on the live datapath (compile excluded
  via a warmup call) and the winner is persisted to a JSON cache file;
* every later construction — including future processes — reads the cache
  and never measures again, so serving startup stays measurement-free.

The cache lives at ``~/.cache/repro-binomialhash/block_rows.json`` (override
with ``REPRO_AUTOTUNE_CACHE``; useful for tests and hermetic CI).  Callers
that pass an explicit ``block_rows`` bypass the autotuner entirely, and the
pure-jnp CPU/GPU fallback ignores the knob, so tuning only ever runs where
it matters: on a real Pallas backend.
"""
from __future__ import annotations

import json
import os
import time

#: fallback when the autotuner is bypassed (explicit value, interpret mode,
#: or the jnp fallback path, which has no block tiling at all) — the ONE
#: definition; ``RouterSpec.resolved_block_rows`` resolves through it too
from repro.core.bulk import DEFAULT_BLOCK_ROWS  # noqa: F401,E402

#: candidate VMEM tilings: 64 KiB .. 1 MiB per in/out block at 4B x 128 lanes
CANDIDATES = (128, 256, 512, 1024, 2048)


def cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro-binomialhash", "block_rows.json"
    )


def _load(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _store(path: str, cache: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(cache, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)  # atomic: concurrent routers never see half a file


#: bump to invalidate every persisted verdict when the kernels change shape
CACHE_SCHEMA = "v1"


def tuned_block_rows(
    backend: str,
    rows: int,
    capacity: int,
    measure,
    candidates: tuple[int, ...] = CANDIDATES,
    path: str | None = None,
    repeats: int = 3,
    variant: str = "fused",
) -> int:
    """Best ``block_rows`` for (backend, variant, rows, capacity) — measured
    once.

    ``measure(block_rows) -> None`` runs the live datapath once with that
    tiling (the caller closes over its real operands); it is invoked
    ``repeats + 1`` times per candidate on a cache miss (first call warms
    up/compiles, the rest are timed, best-of wins) and never on a hit.
    ``variant`` names the datapath being measured (e.g. ``fused`` vs
    ``two_pass``) so verdicts are never reused across kernels with
    different cost profiles; ``CACHE_SCHEMA`` in the key invalidates stale
    verdicts when the kernels themselves change shape.
    """
    path = path or cache_path()
    key = f"{CACHE_SCHEMA}/{backend}/{variant}/rows={rows}/capacity={capacity}"
    cache = _load(path)
    hit = cache.get(key)
    if hit:
        return int(hit["block_rows"])
    timed: dict[int, float] = {}
    for c in candidates:
        if c > max(rows, candidates[0]):
            continue  # bigger blocks than the batch just pad dead lanes
        measure(c)  # compile + warm
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            measure(c)
            best = min(best, time.perf_counter() - t0)
        timed[c] = best
    winner = min(timed, key=timed.get)
    # re-load and merge just before storing: measuring takes long enough
    # that a concurrent process may have written other keys meanwhile, and
    # os.replace only prevents torn files, not lost updates
    cache = _load(path)
    cache[key] = {
        "block_rows": winner,
        "us": {str(c): round(t * 1e6, 2) for c, t in sorted(timed.items())},
    }
    _store(path, cache)
    return winner
