"""Pure-jnp oracle for the bulk BinomialHash lookup kernel.

This is the reference the Pallas kernel is tested against (and itself
bit-exact against the scalar u32 implementation in repro.core.binomial).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binomial_jax import _unrolled_body


def binomial_bulk_lookup_ref(keys: jax.Array, n: int, omega: int = 16) -> jax.Array:
    """keys (any shape, any int dtype) -> int32 buckets in [0, n)."""
    keys_u32 = keys.astype(jnp.uint32)
    if n <= 1:
        return jnp.zeros(keys.shape, dtype=jnp.int32)
    l = (n - 1).bit_length()
    E = np.uint32(1 << l)
    M = np.uint32(1 << (l - 1))
    return _unrolled_body(keys_u32, E, M, np.uint32(n), omega).astype(jnp.int32)
