"""Pure-jnp oracles for the bulk BinomialHash lookup / fused routing kernels.

These are the references the Pallas kernels are tested against (and
themselves bit-exact against the scalar u32 implementations in
``repro.core.binomial`` / ``repro.core.memento``).  Unjitted on purpose —
tests call them eagerly; the production jit'd flavours live in
``repro.core.binomial_jax`` and ``repro.core.memento_jax``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binomial_jax import _unrolled_body, mix64_lo32
from repro.core.memento_jax import _route_table_impl


def binomial_bulk_lookup_ref(keys: jax.Array, n: int, omega: int = 16) -> jax.Array:
    """keys (any shape, any int dtype) -> int32 buckets in [0, n)."""
    keys_u32 = keys.astype(jnp.uint32)
    if n <= 1:
        return jnp.zeros(keys.shape, dtype=jnp.int32)
    l = (n - 1).bit_length()
    E = np.uint32(1 << l)
    M = np.uint32(1 << (l - 1))
    return _unrolled_body(keys_u32, E, M, np.uint32(n), omega).astype(jnp.int32)


def binomial_route_ref(
    keys: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    omega: int = 16,
    n_words: int | None = None,
) -> jax.Array:
    """Fused lookup + table divert oracle (same math as the fused kernel).

    keys         any int shape; packed_mask (1, W) u32 bit-words;
    table        (1, C) i32 slots permutation; state (2,) u32 [n_total, n_alive];
    n_words      static mask word count (defaults to the full padded width —
                 slower cascade, fine for an eager test oracle).
    """
    packed_mask = jnp.asarray(packed_mask, jnp.uint32)
    return _route_table_impl(
        jnp.asarray(keys),
        packed_mask,
        jnp.asarray(table, jnp.int32),
        jnp.asarray(state, jnp.uint32),
        omega,
        int(packed_mask.shape[1]) if n_words is None else n_words,
    )


def binomial_ingest_route_ref(
    ids_lo: jax.Array,
    ids_hi: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    omega: int = 16,
    n_words: int | None = None,
) -> jax.Array:
    """Fused u64-id ingest + lookup + divert oracle (same math as the ingest
    kernel): the id halves are mixed with the limb-wise splitmix64 and the
    resulting u32 key routed exactly like ``binomial_route_ref``."""
    keys = mix64_lo32(jnp.asarray(ids_lo), jnp.asarray(ids_hi))
    return binomial_route_ref(
        keys, packed_mask, table, state, omega=omega, n_words=n_words
    )
