"""Pallas TPU kernels for the jump bulk engine (DESIGN.md §10).

The JumpHash device datapath, instantiated from the generic fused machinery
(``repro.kernels.fused``): the ω-unrolled, f32-step, u32-limb jump chain
(``repro.core.jump_jax.jump_unrolled_body``) replaces the binomial lookup
body; the scalar-prefetch fleet state, the whole-block mask/table VMEM
operands and the replacement-table divert are shared with the binomial
kernels verbatim, so every retrace-free / storm-proof guarantee carries
over by construction.

Bit-exactness chain (tests enforce each link): Pallas kernel == jnp mirror
(``jump_memento_route``) == scalar ``jump32`` oracle.
"""
from __future__ import annotations

from repro.core.jump_jax import jump_unrolled_body
from repro.kernels.fused import make_fused_kernels

_KERNELS = make_fused_kernels(jump_unrolled_body, "jump")

#: fused lookup + divert, (rows, 128) layout — the jump twin of
#: ``binomial_hash.binomial_route_fused_2d``
jump_route_fused_2d = _KERNELS.route_2d
#: any-shape fused routing entry point (pad/reshape wrapper)
jump_route_pallas_fused = _KERNELS.route_pallas
#: fused u64-id ingest twins
jump_ingest_fused_2d = _KERNELS.ingest_2d
jump_ingest_pallas_fused = _KERNELS.ingest_pallas
#: plain dynamic-n bulk lookup (the two-pass baseline's first dispatch)
jump_bulk_lookup_dyn_2d = _KERNELS.lookup_dyn_2d
jump_bulk_lookup_pallas_dyn = _KERNELS.lookup_dyn_pallas
