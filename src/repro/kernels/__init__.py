"""Pallas TPU kernels for the paper's compute hot spot: bulk consistent-hash
lookup (binomial_hash.py) with jit'd dispatcher (ops.py) and pure-jnp oracle
(ref.py). Validated in interpret mode on CPU; TPU is the target.

``binomial_bulk_lookup`` bakes n into the trace (fastest steady state);
``binomial_bulk_lookup_dyn`` takes n as a traced scalar-prefetch operand so
elastic resize / failure events never recompile (the serving datapath)."""
from repro.kernels.ops import binomial_bulk_lookup, binomial_bulk_lookup_dyn  # noqa: F401
