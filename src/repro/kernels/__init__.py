"""Pallas TPU kernels for the paper's compute hot spot: bulk consistent-hash
lookup (binomial_hash.py) with jit'd dispatcher (ops.py) and pure-jnp oracle
(ref.py). Validated in interpret mode on CPU; TPU is the target."""
from repro.kernels.ops import binomial_bulk_lookup  # noqa: F401
