"""Pallas TPU kernels for the compute hot spot: bulk consistent-hash
routing.  Engine-specific kernels live in binomial_hash.py / jump_hash.py
(the latter instantiated from the generic machinery in fused.py); ops.py is
the spec dispatcher every caller goes through; ref.py holds the pure-jnp
test oracles.  Validated in interpret mode on CPU; TPU is the target.

``route_bulk(keys, fleet, spec)`` is the fused single-dispatch serving hot
path for any registered ``BULK_ENGINES`` engine (DESIGN.md §10);
``binomial_bulk_lookup`` bakes n into the trace (fastest steady state);
``binomial_bulk_lookup_dyn`` takes n as a traced scalar-prefetch operand so
elastic resize / failure events never recompile (the serving datapath)."""
from repro.kernels.ops import (  # noqa: F401
    binomial_bulk_lookup,
    binomial_bulk_lookup_dyn,
    lookup_bulk_dyn,
    make_sharded_route,
    route_bulk,
    route_ingest_bulk,
)
