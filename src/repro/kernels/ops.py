"""Generic jit'd entry points for bulk consistent-hash routing.

The dispatcher over the engine protocol (DESIGN.md §10): every function
takes a ``RouterSpec`` (which engine, capacity, ω, kernel selection,
tiling) plus the traced operands, resolves the engine's bundle from
``repro.core.registry.BULK_ENGINES`` *per call* (so tests can swap entries
in to intercept dispatches), and picks the Pallas kernel on TPU backends /
interpret mode or the pure-jnp mirror elsewhere — model and serving code
calls one function everywhere.

Spec-era entry points:

* ``route_bulk(keys, fleet, spec)``                — fused lookup + divert;
* ``route_load_bulk(keys, fleet, counts, spec)``   — fused route + per-shard
  load accumulate (the observability tier's instrumented dispatch);
* ``route_ingest_bulk(lo, hi, fleet, spec)``       — fused u64-id ingest;
* ``lookup_bulk_dyn(keys, n, spec)``               — plain traced-n lookup;
* ``make_sharded_route(mesh, spec)``               — the mesh-sharded route.

The pre-spec binomial-only signatures (``binomial_route_bulk``,
``binomial_route_ingest_bulk``, kwargs-style ``make_sharded_route``) remain
as thin deprecation shims: warn once, build the equivalent spec, forward —
bit-identical results (tests enforce).  The plain static-n
``binomial_bulk_lookup`` / ``binomial_bulk_lookup_dyn`` helpers predate the
fleet-state datapath and stay as-is.
"""
from __future__ import annotations

import warnings

import jax

from repro.core.binomial_jax import binomial_lookup_dyn
from repro.core.bulk import FleetState, RouterSpec
from repro.core.memento_jax import binomial_ingest_route, binomial_memento_route
from repro.kernels.binomial_hash import (
    binomial_bulk_lookup_pallas,
    binomial_bulk_lookup_pallas_dyn,
    binomial_ingest_pallas_fused,
    binomial_route_pallas_fused,
)
from repro.kernels.ref import binomial_bulk_lookup_ref

#: deprecation shims that already warned this process (warn once, not per
#: batch; tests reset this to assert the warning fires)
_warned: set[str] = set()


def _warn_once(name: str, hint: str) -> None:
    if name in _warned:
        return
    _warned.add(name)
    warnings.warn(
        f"{name} is deprecated; {hint}", DeprecationWarning, stacklevel=3
    )


def _engine(spec: RouterSpec):
    """Resolve the spec's engine bundle — live, so monkeypatched/updated
    ``BULK_ENGINES`` entries take effect immediately."""
    from repro.core.registry import make_bulk  # late: registry imports kernels

    return make_bulk(spec.engine)


def route_bulk(keys: jax.Array, fleet: FleetState, spec: RouterSpec) -> jax.Array:
    """Fused routing: keys + fleet state -> int32 replica ids, ONE dispatch.

    The single-dispatch serving hot path, engine-generic: the spec's engine
    runs its base lookup AND the replacement-table failure divert under one
    compiled executable (fused Pallas kernel on TPU / interpret mode, fused
    jnp jit elsewhere) — no intermediate ``buckets[N]`` HBM round-trip,
    every fleet-state operand is traced so scale/fail/recover streams never
    retrace, and the divert is two bounded hash rounds + ONE table gather
    per lane so an event storm never shows up on the batch critical path
    (DESIGN.md §7, §10).

    keys   any int shape (u32 key space)
    fleet  ``FleetState`` — packed (1, W) u32 mask words, (1, C) i32 slots
           permutation, (2,) u32 ``[n_total, n_alive]``
    spec   ``RouterSpec`` — engine, capacity (fixing W/C), ω, kernel choice
    """
    eng = _engine(spec)
    if (spec.pallas_selected() or spec.interpret) and eng.route_pallas is not None:
        return eng.route_pallas(
            keys,
            fleet.packed,
            fleet.table,
            fleet.state,
            spec.n_words,
            spec.n_slots,
            omega=spec.omega,
            block_rows=spec.resolved_block_rows(),
            interpret=spec.interpret,
        )
    return eng.route(
        keys, fleet.packed, fleet.table, fleet.state,
        omega=spec.omega, n_words=spec.n_words,
    )


def route_load_bulk(
    keys: jax.Array, fleet: FleetState, counts: jax.Array, spec: RouterSpec,
    *, sample_shift: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Instrumented fused routing: route + per-shard load accumulate in ONE
    dispatch — ``(replicas (N,) i32, new_counts (capacity,) u32)``.

    The observability tier's device pass (DESIGN.md §15): the spec'd
    engine's fused jnp route plus a bincount of the replica vector into a
    device-resident accumulator, all under one jitted executable.  With
    ``sample_shift > 0`` the bincount covers the ``[::2**shift]`` stride
    sample at weight ``2**shift`` — key-unit estimates for bulk batches
    where exact counting would break the overhead budget (the
    ``LoadMonitor`` picks the shift per batch via its exact cutoff).
    Replica ids are bit-exact with ``route_bulk`` at every shift — the
    instrumentation never changes routing — and the accumulator stays on
    device (the monitor drains it on its own cadence).  Like the
    placement pass, pure-jnp on every backend (the accumulate is one
    comparison-sum or scatter — no Pallas twin); certified as
    ``observability/load_pass``.

    keys    any int shape (u32 key space)
    fleet   ``FleetState``;  counts  (capacity,) u32 running accumulator
    spec    ``RouterSpec`` — engine, capacity, ω
    """
    from repro.observability.load import _route_with_load_jit  # late:
    # observability imports this module

    eng = _engine(spec)
    return _route_with_load_jit(
        keys, fleet.packed, fleet.table, fleet.state, counts,
        omega=spec.omega, n_words=spec.n_words, route=eng.route,
        sample_shift=sample_shift,
    )


def route_ingest_bulk(
    ids_lo: jax.Array, ids_hi: jax.Array, fleet: FleetState, spec: RouterSpec
) -> jax.Array:
    """Fused ingest routing: raw u64 session ids (as u32 halves) + fleet
    state -> int32 replica ids, ONE dispatch (DESIGN.md §9, §10).

    The limb-wise splitmix64 session-key mix, the engine's base lookup AND
    the replacement-table divert all run under one compiled executable —
    the ``keys[N]`` array the pre-hash path materialises never exists.
    Engines without an in-kernel ingest mix raise; route pre-hashed keys
    through ``route_bulk`` instead.
    """
    eng = _engine(spec)
    if eng.ingest is None:
        raise ValueError(
            f"bulk engine '{spec.engine}' has no fused ingest path; pre-hash "
            "the ids (hash_session_ids) and call route_bulk"
        )
    if (spec.pallas_selected() or spec.interpret) and eng.ingest_pallas is not None:
        return eng.ingest_pallas(
            ids_lo,
            ids_hi,
            fleet.packed,
            fleet.table,
            fleet.state,
            spec.n_words,
            spec.n_slots,
            omega=spec.omega,
            block_rows=spec.resolved_block_rows(),
            interpret=spec.interpret,
        )
    return eng.ingest(
        ids_lo, ids_hi, fleet.packed, fleet.table, fleet.state,
        omega=spec.omega, n_words=spec.n_words,
    )


def route_replicas_bulk(keys: jax.Array, fleet: FleetState, pspec) -> tuple:
    """R-way replicated placement: keys + fleet state -> ``(replicas (N, r)
    i32 distinct alive shards, exhausted (N,) bool)``, ONE dispatch.

    The placement tier's device pass (DESIGN.md §13): all ``r`` salted key
    families route through the spec'd engine's fused jnp datapath as one
    broadcast batch, then the bounded re-salt resolution breaks inter-family
    collisions in-trace.  Engine resolved per call like every dispatcher
    here; the pass is pure-jnp on every backend (the resolution is
    elementwise + gathers — XLA fuses it; no Pallas twin).

    keys   any int shape (u32 key space); fleet  ``FleetState``;
    pspec  ``PlacementSpec`` — replication r, probe bound, the RouterSpec
    """
    from repro.placement.store import _route_replicas_jit  # late: placement
    # imports this module

    spec = pspec.router
    eng = _engine(spec)
    return _route_replicas_jit(
        keys, fleet.packed, fleet.table, fleet.state,
        r=pspec.r, omega=spec.omega, n_words=spec.n_words,
        max_resalt=pspec.resolved_max_resalt, route=eng.route,
    )


def placement_diff_bulk(
    keys: jax.Array, fleet_old: FleetState, fleet_new: FleetState, pspec
) -> tuple:
    """Bulk migration diff: both placements + the transfer mask in ONE
    dispatch — ``(old (N, r), new (N, r), moved (N, r) bool, exhausted)``
    with ``moved[i, j] = new[i, j] not in old[i, :]`` (membership, not
    positional inequality: a column swap is free, only a shard with no
    prior copy needs bytes).  Operand contract as ``route_replicas_bulk``.
    """
    from repro.placement.store import _placement_diff_jit

    spec = pspec.router
    eng = _engine(spec)
    return _placement_diff_jit(
        keys,
        fleet_old.packed, fleet_old.table, fleet_old.state,
        fleet_new.packed, fleet_new.table, fleet_new.state,
        r=pspec.r, omega=spec.omega, n_words=spec.n_words,
        max_resalt=pspec.resolved_max_resalt, route=eng.route,
    )


def lookup_bulk_dyn(keys: jax.Array, n, spec: RouterSpec) -> jax.Array:
    """Plain dynamic-n bulk lookup for the spec's engine: n is traced, so
    elastic resize never retraces.  The two-pass baseline's first dispatch
    (the divert then runs as a second dispatch over ``buckets[N]``)."""
    eng = _engine(spec)
    if eng.lookup_dyn is None:
        raise ValueError(f"bulk engine '{spec.engine}' has no dynamic-n lookup")
    if (spec.pallas_selected() or spec.interpret) and eng.lookup_dyn_pallas is not None:
        return eng.lookup_dyn_pallas(
            keys, n, omega=spec.omega,
            block_rows=spec.resolved_block_rows(), interpret=spec.interpret,
        )
    return eng.lookup_dyn(keys, n, omega=spec.omega)


# ---------------------------------------------------------------------------
# static-n helpers (predate the fleet-state datapath; binomial-specific)
# ---------------------------------------------------------------------------


def binomial_bulk_lookup(
    keys: jax.Array,
    n: int,
    omega: int = 16,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """keys (any int shape) -> int32 buckets in [0, n).

    use_pallas=None selects the kernel automatically (TPU backend only).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return binomial_bulk_lookup_pallas(
            keys, n, omega=omega, block_rows=block_rows, interpret=interpret
        )
    return binomial_bulk_lookup_ref(keys, n, omega=omega)


def binomial_bulk_lookup_dyn(
    keys: jax.Array,
    n,
    omega: int = 16,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """Dynamic-n bulk lookup: n is traced, so resize events never retrace.

    Dispatches to the scalar-prefetch Pallas kernel on TPU (or in interpret
    mode) and to the pure-jnp ``binomial_lookup_dyn`` elsewhere; both keep a
    single compiled executable across arbitrary n.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return binomial_bulk_lookup_pallas_dyn(
            keys, n, omega=omega, block_rows=block_rows, interpret=interpret
        )
    return binomial_lookup_dyn(keys, n, omega=omega)


# ---------------------------------------------------------------------------
# mesh-sharded datapath
# ---------------------------------------------------------------------------


def make_sharded_route(mesh, spec: RouterSpec | None = None, **legacy_kwargs):
    """Build the mesh-sharded bulk routing callable (DESIGN.md §8).

    Returns ``route(keys, fleet) -> replica ids`` where 1-D ``keys`` are
    split along the mesh's ``spec.shard_axis`` (length must be a multiple
    of the axis size — the caller pads) and the ``FleetState`` operands are
    replicated on every device.  Each device runs the fused single-dispatch
    datapath on its shard — zero cross-device collectives, zero per-batch
    host round-trips — so multi-device hosts scale routed keys/s with the
    device count.  The whole thing is ONE jitted executable (``shard_map``
    under ``jit``); all fleet state stays traced, so scale/fail/recover
    event streams never retrace.

    ``spec.donate_keys=True`` donates the key buffer to the executable (the
    caller must not reuse it) — the serving tier enables this for key
    batches it uploads itself, making the sharded hot path allocation-free
    on the input side.

    The pre-spec kwargs signature ``make_sharded_route(mesh, axis_name,
    n_words=..., n_slots=..., ...)`` is a deprecation shim returning the
    old 4-operand ``route(keys, packed_mask, table, state)`` callable.
    """
    if spec is None and not legacy_kwargs:
        raise TypeError(
            "make_sharded_route requires a RouterSpec: "
            "make_sharded_route(mesh, RouterSpec(...))"
        )
    if spec is None or not isinstance(spec, RouterSpec):
        # pre-spec call shapes: axis_name positional (bound to ``spec``),
        # axis_name keyword (in ``legacy_kwargs``), or omitted entirely
        axis_name = spec if spec is not None else legacy_kwargs.pop("axis_name", None)
        return _make_sharded_route_legacy(mesh, axis_name, **legacy_kwargs)
    if legacy_kwargs:
        raise TypeError(
            f"make_sharded_route(mesh, spec) takes no extra kwargs, got "
            f"{sorted(legacy_kwargs)}; fold them into the RouterSpec"
        )
    return _make_sharded_route_impl(mesh, spec)


def _make_sharded_route_impl(mesh, spec: RouterSpec):
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import shard_map_compat

    def inner(keys, fleet):
        return route_bulk(keys, fleet, spec)

    fleet_specs = FleetState(P(), P(), P(), capacity=spec.capacity)
    sharded = shard_map_compat(
        inner,
        mesh,
        in_specs=(P(spec.shard_axis), fleet_specs),
        out_specs=P(spec.shard_axis),
    )
    return jax.jit(sharded, donate_argnums=(0,) if spec.donate_keys else ())


def _make_sharded_route_legacy(
    mesh,
    axis_name: str | None = None,
    *,
    n_words: int,
    n_slots: int,
    omega: int = 16,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
    donate_keys: bool = False,
):
    """Pre-spec shim: kwargs -> RouterSpec, old 4-operand callable out."""
    _warn_once(
        "make_sharded_route(mesh, axis_name, n_words=..., ...)",
        "pass a RouterSpec: make_sharded_route(mesh, spec) — the returned "
        "route then takes (keys, FleetState)",
    )
    spec = _legacy_spec(
        n_words, n_slots, omega, use_pallas, interpret, block_rows,
        shard_axis="data" if axis_name is None else axis_name,
        donate_keys=donate_keys,
    )
    route = _make_sharded_route_impl(mesh, spec)

    def legacy_route(keys, packed_mask, table, state):
        return route(keys, _legacy_fleet(packed_mask, table, state, spec))

    return legacy_route


# ---------------------------------------------------------------------------
# pre-spec fused entry points — thin deprecation shims over the spec path
# ---------------------------------------------------------------------------


def _legacy_spec(
    n_words: int, n_slots: int, omega, use_pallas, interpret, block_rows,
    **extra,
) -> RouterSpec:
    """Pre-spec kwargs -> the equivalent ``RouterSpec``.

    ``capacity`` is the next power of two >= ``n_slots`` — pre-spec callers
    could pass any slot bound (the jnp path ignored it, the Pallas gather
    cascade just scanned it), and rounding up is result-identical: the
    extra mask words are zero padding, the extra cascade entries are never
    selected (every index < n_total <= n_slots).  ``n_words`` must match
    what the caller's ``n_slots`` implies — the contract every pre-spec
    call site followed.
    """
    from repro.core.bits import next_pow2

    spec = RouterSpec(
        engine="binomial", capacity=next_pow2(max(1, n_slots)), omega=omega,
        use_pallas=use_pallas, interpret=interpret, block_rows=block_rows,
        **extra,
    )
    from repro.core.memento_jax import mask_words

    if n_words != mask_words(n_slots):
        raise ValueError(
            f"n_words ({n_words}) disagrees with n_slots {n_slots} "
            f"(expected {mask_words(n_slots)})"
        )
    return spec


def _legacy_fleet(packed_mask, table, state, spec: RouterSpec) -> FleetState:
    """Legacy operands -> ``FleetState``, zero-padded out to the rounded-up
    capacity's extents when the caller packed for a non-pow2 ``n_slots``
    (the padding is never read: every gathered index < n_total <= the
    caller's real slot payload, and zero mask words mean never-removed)."""
    import jax.numpy as jnp

    if table.shape[1] < spec.n_slots:
        table = jnp.pad(
            jnp.asarray(table), ((0, 0), (0, spec.n_slots - table.shape[1]))
        )
    if packed_mask.shape[1] < spec.n_words:
        packed_mask = jnp.pad(
            jnp.asarray(packed_mask),
            ((0, 0), (0, spec.n_words - packed_mask.shape[1])),
        )
    return FleetState(packed_mask, table, state)


def binomial_route_bulk(
    keys: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    *,
    n_words: int,
    n_slots: int,
    omega: int = 16,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """Deprecated pre-spec signature of the fused binomial route.

    Forwards to ``route_bulk(keys, FleetState(...), RouterSpec(...))`` —
    bit-identical results (tests enforce).  ``n_words`` is implied by
    ``n_slots`` and only validated here.
    """
    _warn_once(
        "binomial_route_bulk",
        "use route_bulk(keys, FleetState(packed, table, state), "
        "RouterSpec(engine='binomial', capacity=n_slots, ...))",
    )
    spec = _legacy_spec(n_words, n_slots, omega, use_pallas, interpret, block_rows)
    return route_bulk(keys, _legacy_fleet(packed_mask, table, state, spec), spec)


def binomial_route_ingest_bulk(
    ids_lo: jax.Array,
    ids_hi: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    *,
    n_words: int,
    n_slots: int,
    omega: int = 16,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """Deprecated pre-spec signature of the fused binomial u64-id ingest.

    Forwards to ``route_ingest_bulk`` — bit-identical results (tests
    enforce); operand contract as ``binomial_route_bulk``.
    """
    _warn_once(
        "binomial_route_ingest_bulk",
        "use route_ingest_bulk(ids_lo, ids_hi, FleetState(packed, table, "
        "state), RouterSpec(engine='binomial', capacity=n_slots, ...))",
    )
    spec = _legacy_spec(n_words, n_slots, omega, use_pallas, interpret, block_rows)
    return route_ingest_bulk(
        ids_lo, ids_hi, _legacy_fleet(packed_mask, table, state, spec), spec
    )
