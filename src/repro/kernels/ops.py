"""Jit'd public entry point for bulk consistent-hash lookup.

Dispatches to the Pallas TPU kernel on TPU backends and to the pure-jnp
reference elsewhere (CPU dry-run / tests), so model code can call one
function everywhere.  ``interpret=True`` forces the Pallas path in
interpreter mode (used by kernel tests on CPU).
"""
from __future__ import annotations

import jax

from repro.core.binomial_jax import binomial_lookup_dyn
from repro.core.memento_jax import binomial_memento_route
from repro.kernels.binomial_hash import (
    binomial_bulk_lookup_pallas,
    binomial_bulk_lookup_pallas_dyn,
    binomial_route_pallas_fused,
)
from repro.kernels.ref import binomial_bulk_lookup_ref


def binomial_bulk_lookup(
    keys: jax.Array,
    n: int,
    omega: int = 16,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """keys (any int shape) -> int32 buckets in [0, n).

    use_pallas=None selects the kernel automatically (TPU backend only).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return binomial_bulk_lookup_pallas(
            keys, n, omega=omega, block_rows=block_rows, interpret=interpret
        )
    return binomial_bulk_lookup_ref(keys, n, omega=omega)


def binomial_bulk_lookup_dyn(
    keys: jax.Array,
    n,
    omega: int = 16,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """Dynamic-n bulk lookup: n is traced, so resize events never retrace.

    Dispatches to the scalar-prefetch Pallas kernel on TPU (or in interpret
    mode) and to the pure-jnp ``binomial_lookup_dyn`` elsewhere; both keep a
    single compiled executable across arbitrary n.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return binomial_bulk_lookup_pallas_dyn(
            keys, n, omega=omega, block_rows=block_rows, interpret=interpret
        )
    return binomial_lookup_dyn(keys, n, omega=omega)


def binomial_route_bulk(
    keys: jax.Array,
    packed_mask: jax.Array,
    state: jax.Array,
    *,
    n_words: int,
    omega: int = 16,
    max_chain: int = 4096,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """Fused routing: keys + fleet state -> int32 replica ids, ONE dispatch.

    The single-dispatch serving hot path: BinomialHash lookup and the bounded
    Memento rejection chain run under one compiled executable (fused Pallas
    kernel on TPU / interpret mode, fused jnp jit elsewhere) — no
    intermediate ``buckets[N]`` HBM round-trip, and every fleet-state operand
    is traced so scale/fail/recover streams never retrace.

    packed_mask  (1, W) u32 removed-slot bit-words (``pack_removed_mask``)
    state        (2,) u32 ``[n_total, first_alive]``
    n_words      static payload word count (= ceil(capacity/32))
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return binomial_route_pallas_fused(
            keys,
            packed_mask,
            state,
            n_words,
            omega=omega,
            max_chain=max_chain,
            block_rows=block_rows,
            interpret=interpret,
        )
    return binomial_memento_route(
        keys, packed_mask, state, omega=omega, max_chain=max_chain
    )
