"""Jit'd public entry point for bulk consistent-hash lookup.

Dispatches to the Pallas TPU kernel on TPU backends and to the pure-jnp
reference elsewhere (CPU dry-run / tests), so model code can call one
function everywhere.  ``interpret=True`` forces the Pallas path in
interpreter mode (used by kernel tests on CPU).
"""
from __future__ import annotations

import jax

from repro.core.binomial_jax import binomial_lookup_dyn
from repro.core.memento_jax import binomial_ingest_route, binomial_memento_route
from repro.kernels.binomial_hash import (
    binomial_bulk_lookup_pallas,
    binomial_bulk_lookup_pallas_dyn,
    binomial_ingest_pallas_fused,
    binomial_route_pallas_fused,
)
from repro.kernels.ref import binomial_bulk_lookup_ref


def binomial_bulk_lookup(
    keys: jax.Array,
    n: int,
    omega: int = 16,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """keys (any int shape) -> int32 buckets in [0, n).

    use_pallas=None selects the kernel automatically (TPU backend only).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return binomial_bulk_lookup_pallas(
            keys, n, omega=omega, block_rows=block_rows, interpret=interpret
        )
    return binomial_bulk_lookup_ref(keys, n, omega=omega)


def binomial_bulk_lookup_dyn(
    keys: jax.Array,
    n,
    omega: int = 16,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """Dynamic-n bulk lookup: n is traced, so resize events never retrace.

    Dispatches to the scalar-prefetch Pallas kernel on TPU (or in interpret
    mode) and to the pure-jnp ``binomial_lookup_dyn`` elsewhere; both keep a
    single compiled executable across arbitrary n.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return binomial_bulk_lookup_pallas_dyn(
            keys, n, omega=omega, block_rows=block_rows, interpret=interpret
        )
    return binomial_lookup_dyn(keys, n, omega=omega)


def binomial_route_bulk(
    keys: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    *,
    n_words: int,
    n_slots: int,
    omega: int = 16,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """Fused routing: keys + fleet state -> int32 replica ids, ONE dispatch.

    The single-dispatch serving hot path: BinomialHash lookup and the
    replacement-table failure divert run under one compiled executable
    (fused Pallas kernel on TPU / interpret mode, fused jnp jit elsewhere) —
    no intermediate ``buckets[N]`` HBM round-trip, every fleet-state operand
    is traced so scale/fail/recover streams never retrace, and the divert is
    two bounded hash rounds + ONE table gather per lane so an event storm
    never shows up on the batch critical path (DESIGN.md §7).

    packed_mask  (1, W) u32 removed-slot bit-words (``pack_removed_mask``)
    table        (1, C) i32 slots permutation (``pack_table``)
    state        (2,) u32 ``[n_total, n_alive]``
    n_words      static mask word count (= ceil(capacity/32))
    n_slots      static table slot count (= capacity)
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return binomial_route_pallas_fused(
            keys,
            packed_mask,
            table,
            state,
            n_words,
            n_slots,
            omega=omega,
            block_rows=block_rows,
            interpret=interpret,
        )
    return binomial_memento_route(
        keys, packed_mask, table, state, omega=omega, n_words=n_words
    )


def binomial_route_ingest_bulk(
    ids_lo: jax.Array,
    ids_hi: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    *,
    n_words: int,
    n_slots: int,
    omega: int = 16,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """Fused ingest routing: raw u64 session ids (as u32 halves) + fleet
    state -> int32 replica ids, ONE dispatch.

    The end-to-end request hot path (DESIGN.md §9): the limb-wise splitmix64
    session-key mix, the BinomialHash lookup AND the replacement-table divert
    all run under one compiled executable (fused ingest Pallas kernel on TPU /
    interpret mode, fused jnp jit elsewhere) — the ``keys[N]`` array that the
    pre-hash path materialises on the host never exists anywhere.  Bit-exact
    with hashing ids via ``bits.np_mix64`` (truncated u32) and routing
    through ``binomial_route_bulk``.

    ids_lo / ids_hi  low/high u32 halves of the u64 ids (``bits.np_split64``)
    — remaining operands exactly as ``binomial_route_bulk``.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return binomial_ingest_pallas_fused(
            ids_lo,
            ids_hi,
            packed_mask,
            table,
            state,
            n_words,
            n_slots,
            omega=omega,
            block_rows=block_rows,
            interpret=interpret,
        )
    return binomial_ingest_route(
        ids_lo, ids_hi, packed_mask, table, state, omega=omega, n_words=n_words
    )


def make_sharded_route(
    mesh,
    axis_name: str = "data",
    *,
    n_words: int,
    n_slots: int,
    omega: int = 16,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
    donate_keys: bool = False,
):
    """Build the mesh-sharded bulk routing callable (DESIGN.md §8).

    Returns ``route(keys, packed_mask, table, state) -> replica ids`` where
     1-D ``keys`` are split along ``mesh``'s ``axis_name`` (length must be a
    multiple of the axis size — the caller pads) and the three fleet-state
    operands are replicated on every device.  Each device runs the fused
    single-dispatch datapath on its shard — zero cross-device collectives,
    zero per-batch host round-trips — so multi-device hosts scale routed
    keys/s with the device count.  The whole thing is ONE jitted executable
    (``shard_map`` under ``jit``); all fleet state stays traced, so
    scale/fail/recover event streams never retrace.

    ``donate_keys=True`` donates the key buffer to the executable (the
    caller must not reuse it) — the serving tier enables this for key
    batches it uploads itself, making the sharded hot path allocation-free
    on the input side.
    """
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import shard_map_compat

    def inner(keys, packed_mask, table, state):
        return binomial_route_bulk(
            keys,
            packed_mask,
            table,
            state,
            n_words=n_words,
            n_slots=n_slots,
            omega=omega,
            use_pallas=use_pallas,
            interpret=interpret,
            block_rows=block_rows,
        )

    sharded = shard_map_compat(
        inner,
        mesh,
        in_specs=(P(axis_name), P(), P(), P()),
        out_specs=P(axis_name),
    )
    return jax.jit(sharded, donate_argnums=(0,) if donate_keys else ())
