"""Jit'd public entry point for bulk consistent-hash lookup.

Dispatches to the Pallas TPU kernel on TPU backends and to the pure-jnp
reference elsewhere (CPU dry-run / tests), so model code can call one
function everywhere.  ``interpret=True`` forces the Pallas path in
interpreter mode (used by kernel tests on CPU).
"""
from __future__ import annotations

import jax

from repro.core.binomial_jax import binomial_lookup_dyn
from repro.kernels.binomial_hash import (
    binomial_bulk_lookup_pallas,
    binomial_bulk_lookup_pallas_dyn,
)
from repro.kernels.ref import binomial_bulk_lookup_ref


def binomial_bulk_lookup(
    keys: jax.Array,
    n: int,
    omega: int = 16,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """keys (any int shape) -> int32 buckets in [0, n).

    use_pallas=None selects the kernel automatically (TPU backend only).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return binomial_bulk_lookup_pallas(
            keys, n, omega=omega, block_rows=block_rows, interpret=interpret
        )
    return binomial_bulk_lookup_ref(keys, n, omega=omega)


def binomial_bulk_lookup_dyn(
    keys: jax.Array,
    n,
    omega: int = 16,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_rows: int = 512,
) -> jax.Array:
    """Dynamic-n bulk lookup: n is traced, so resize events never retrace.

    Dispatches to the scalar-prefetch Pallas kernel on TPU (or in interpret
    mode) and to the pure-jnp ``binomial_lookup_dyn`` elsewhere; both keep a
    single compiled executable across arbitrary n.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return binomial_bulk_lookup_pallas_dyn(
            keys, n, omega=omega, block_rows=block_rows, interpret=interpret
        )
    return binomial_lookup_dyn(keys, n, omega=omega)
