"""Generic fused-routing Pallas kernels — any ``BulkEngine`` lookup body +
the replacement-table divert under ONE ``pallas_call``.

This module is the machinery EVERY ``BULK_ENGINES`` entry gets its device
kernels from (DESIGN.md §10) — the binomial paper engine included
(``repro.kernels.binomial_hash`` instantiates it alongside its static-n
extras): hand ``make_fused_kernels`` an unrolled jnp lookup body
``lookup(keys_u32, n_u32, omega) -> u32 buckets`` (usable inside a kernel:
u32/f32 elementwise ops only, n <= 1 handled) and it returns the full
kernel set —

* ``route_2d`` / ``route_pallas``   — fused lookup + divert, pre-hashed keys;
* ``ingest_2d`` / ``ingest_pallas`` — the u64-id ingest twins (limb-wise
  splitmix64 mixed in-register, then the same body);
* ``lookup_dyn_2d`` / ``lookup_dyn_pallas`` — the plain dynamic-n bulk
  lookup (the two-pass baseline's first dispatch).

All flavours keep the fleet state traced (scalar-prefetch ``[n_total,
n_alive]``, whole-block VMEM mask + table), so fleet events never retrace;
the divert body is the one ``_fused_route_body`` below with the lookup
swapped, so every engine presents the SAME kernel shape — which is also
what lets the constant-time certifier (``repro.analysis``) check one
uniform structure per engine instead of per-engine plumbing.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.binomial_jax import (
    GOLDEN32,
    hash_pair,
    mix32,
    mix64_lo32,
    mulhi32,
)
from repro.core.memento_jax import _binomial_lookup_body

LANES = 128  # TPU minor-dim tile


def _fused_route_body(
    keys, state_ref, mask_ref, table_ref, *, omega: int, n_words: int,
    n_slots: int, lookup=_binomial_lookup_body,
):
    """Shared fused lookup+divert body: u32 keys -> u32 replica ids.

    Factored out so the plain fused kernel (pre-hashed keys) and the ingest
    kernel (u64 ids mixed in-kernel) run the exact same routing math — and
    generic over the base engine: ``lookup(keys_u32, n_u32, omega)`` is the
    only engine-specific piece (``make_fused_kernels`` instantiates every
    ``BULK_ENGINES`` entry's kernels from this same body).
    """
    n = state_ref[0].astype(jnp.uint32)
    n_alive = state_ref[1].astype(jnp.uint32)
    b = lookup(keys, n, omega)

    def removed(bv):
        # select-cascade membership test over the packed bit-words: W scalar
        # broadcasts + selects, no vector gather needed.  Cheaper than the
        # n_slots-wide table cascade — this is why the kernel keeps the mask
        # operand: the steady-state skip test touches W words, not C slots.
        w = bv >> np.uint32(5)
        word = jnp.zeros_like(bv)
        for s in range(n_words):
            word = jnp.where(w == np.uint32(s), mask_ref[0, s], word)
        return ((word >> (bv & np.uint32(31))) & np.uint32(1)) != 0

    def gather(idx):
        # select-cascade "gather" from the slots permutation: C scalar
        # broadcasts + selects per read (idx is always < n_total <= C).
        out = jnp.zeros_like(idx)
        for s in range(n_slots):
            out = jnp.where(
                idx == np.uint32(s), table_ref[0, s].astype(jnp.uint32), out
            )
        return out

    hit = removed(b)

    def divert(bb):
        # ReplacementTable.resolve, lane-wise: two bounded redirects, the
        # Lemire mulhi32 reduction in place of a modulo (the VPU has no
        # integer divide, and mulhi32 is ~11 mul/shift/add ops), then ONE
        # table read.
        h = hash_pair(keys, bb)
        q = mulhi32(h, n)
        deep = q >= n_alive  # a removed position: one more redirect settles it
        # second hash chains off the first (h is avalanched; one fmix32)
        q = jnp.where(deep, mulhi32(mix32(h ^ (q * GOLDEN32)), n_alive), q)
        return jnp.where(hit, gather(q), bb)

    return jax.lax.cond(jnp.any(hit), divert, lambda bb: bb, b)


class FusedKernels(NamedTuple):
    """The per-engine Pallas kernel set ``make_fused_kernels`` returns."""

    route_2d: Callable
    route_pallas: Callable
    ingest_2d: Callable
    ingest_pallas: Callable
    lookup_dyn_2d: Callable
    lookup_dyn_pallas: Callable


def _check_2d(rows: int, lanes: int, block_rows: int) -> None:
    if lanes != LANES:
        raise ValueError(f"minor dim must be {LANES}, got {lanes}")
    if rows % block_rows != 0:
        raise ValueError(
            f"rows ({rows}) must be a multiple of block_rows ({block_rows})"
        )


def _check_state_extents(packed_mask, table, n_words: int, n_slots: int) -> None:
    if not 1 <= n_words <= packed_mask.shape[1]:
        raise ValueError(f"n_words ({n_words}) must be in [1, {packed_mask.shape[1]}]")
    if not 1 <= n_slots <= table.shape[1]:
        raise ValueError(f"n_slots ({n_slots}) must be in [1, {table.shape[1]}]")


def _pad_flat(flat: jax.Array, block_rows: int) -> tuple[jax.Array, int]:
    total = flat.shape[0]
    tile = block_rows * LANES
    padded = (total + tile - 1) // tile * tile
    if padded != total:
        flat = jnp.pad(flat, (0, padded - total))
    return flat, total


def make_fused_kernels(lookup, name: str) -> FusedKernels:
    """Build the device kernel set for one engine's lookup body.

    ``lookup(keys_u32, n_u32, omega) -> u32`` must be traceable inside a
    Pallas TPU kernel body (elementwise u32/f32 ops, no gathers) and map
    n <= 1 to bucket 0 itself.  ``name`` brands the jitted wrappers for
    debuggability.
    """

    def _kernel_route(
        state_ref, mask_ref, table_ref, keys_ref, out_ref, *, omega, n_words, n_slots
    ):
        keys = keys_ref[...].astype(jnp.uint32)
        b = _fused_route_body(
            keys, state_ref, mask_ref, table_ref, omega=omega,
            n_words=n_words, n_slots=n_slots, lookup=lookup,
        )
        out_ref[...] = b.astype(jnp.int32)

    def _kernel_ingest(
        state_ref, mask_ref, table_ref, lo_ref, hi_ref, out_ref, *, omega,
        n_words, n_slots,
    ):
        keys = mix64_lo32(lo_ref[...], hi_ref[...])
        b = _fused_route_body(
            keys, state_ref, mask_ref, table_ref, omega=omega,
            n_words=n_words, n_slots=n_slots, lookup=lookup,
        )
        out_ref[...] = b.astype(jnp.int32)

    def _kernel_lookup_dyn(n_ref, keys_ref, out_ref, *, omega):
        keys = keys_ref[...].astype(jnp.uint32)
        out_ref[...] = lookup(keys, n_ref[0].astype(jnp.uint32), omega).astype(
            jnp.int32
        )

    def _route_grid_spec(block_rows, mask_shape, table_shape, n_blocks):
        return pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks,),
            in_specs=[
                # whole-block mask/table: same small blocks every grid step
                pl.BlockSpec(mask_shape, lambda i, s: (0, 0)),
                pl.BlockSpec(table_shape, lambda i, s: (0, 0)),
                pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
            ],
            out_specs=pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
        )

    @functools.partial(
        jax.jit,
        static_argnames=("n_words", "n_slots", "omega", "block_rows", "interpret"),
    )
    def route_2d(
        keys, packed_mask, table, state, n_words, n_slots,
        omega=16, block_rows=512, interpret=False,
    ):
        """(rows, 128) u32 keys + fleet state -> (rows, 128) i32 replica ids."""
        rows, lanes = keys.shape
        _check_2d(rows, lanes, block_rows)
        _check_state_extents(packed_mask, table, n_words, n_slots)
        grid_spec = _route_grid_spec(
            block_rows, packed_mask.shape, table.shape, rows // block_rows
        )
        return pl.pallas_call(
            functools.partial(
                _kernel_route, omega=omega, n_words=n_words, n_slots=n_slots
            ),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            interpret=interpret,
        )(
            jnp.asarray(state, jnp.uint32).reshape(2),
            packed_mask.astype(jnp.uint32),
            table.astype(jnp.int32),
            keys.astype(jnp.uint32),
        )

    def route_pallas(
        keys, packed_mask, table, state, n_words, n_slots,
        omega=16, block_rows=512, interpret=False,
    ):
        """Any-shape int keys + fleet state -> i32 replica ids, fused kernel."""
        flat, total = _pad_flat(keys.reshape(-1).astype(jnp.uint32), block_rows)
        out = route_2d(
            flat.reshape(-1, LANES), packed_mask, table, state, n_words,
            n_slots, omega=omega, block_rows=block_rows, interpret=interpret,
        )
        return out.reshape(-1)[:total].reshape(keys.shape)

    @functools.partial(
        jax.jit,
        static_argnames=("n_words", "n_slots", "omega", "block_rows", "interpret"),
    )
    def ingest_2d(
        ids_lo, ids_hi, packed_mask, table, state, n_words, n_slots,
        omega=16, block_rows=512, interpret=False,
    ):
        """(rows, 128) u32 id halves + fleet state -> (rows, 128) i32 ids."""
        rows, lanes = ids_lo.shape
        if ids_hi.shape != ids_lo.shape:
            raise ValueError(
                f"id halves must agree in shape, got {ids_lo.shape} vs {ids_hi.shape}"
            )
        _check_2d(rows, lanes, block_rows)
        _check_state_extents(packed_mask, table, n_words, n_slots)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(rows // block_rows,),
            in_specs=[
                pl.BlockSpec(packed_mask.shape, lambda i, s: (0, 0)),
                pl.BlockSpec(table.shape, lambda i, s: (0, 0)),
                pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
                pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
            ],
            out_specs=pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
        )
        return pl.pallas_call(
            functools.partial(
                _kernel_ingest, omega=omega, n_words=n_words, n_slots=n_slots
            ),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            interpret=interpret,
        )(
            jnp.asarray(state, jnp.uint32).reshape(2),
            packed_mask.astype(jnp.uint32),
            table.astype(jnp.int32),
            ids_lo.astype(jnp.uint32),
            ids_hi.astype(jnp.uint32),
        )

    def ingest_pallas(
        ids_lo, ids_hi, packed_mask, table, state, n_words, n_slots,
        omega=16, block_rows=512, interpret=False,
    ):
        """Any-shape u32 id halves + fleet state -> i32 ids, fused ingest."""
        lo, total = _pad_flat(ids_lo.reshape(-1).astype(jnp.uint32), block_rows)
        hi, _ = _pad_flat(ids_hi.reshape(-1).astype(jnp.uint32), block_rows)
        out = ingest_2d(
            lo.reshape(-1, LANES), hi.reshape(-1, LANES), packed_mask, table,
            state, n_words, n_slots, omega=omega, block_rows=block_rows,
            interpret=interpret,
        )
        return out.reshape(-1)[:total].reshape(ids_lo.shape)

    @functools.partial(jax.jit, static_argnames=("omega", "block_rows", "interpret"))
    def lookup_dyn_2d(keys, n, omega=16, block_rows=512, interpret=False):
        """(rows, 128) u32 keys + traced n -> (rows, 128) i32 buckets."""
        rows, lanes = keys.shape
        _check_2d(rows, lanes, block_rows)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(rows // block_rows,),
            in_specs=[pl.BlockSpec((block_rows, LANES), lambda i, n_ref: (i, 0))],
            out_specs=pl.BlockSpec((block_rows, LANES), lambda i, n_ref: (i, 0)),
        )
        return pl.pallas_call(
            functools.partial(_kernel_lookup_dyn, omega=omega),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            interpret=interpret,
        )(jnp.asarray(n, jnp.uint32).reshape(1), keys.astype(jnp.uint32))

    def lookup_dyn_pallas(keys, n, omega=16, block_rows=512, interpret=False):
        """Any-shape int keys + traced n -> i32 buckets (recompile-free)."""
        flat, total = _pad_flat(keys.reshape(-1).astype(jnp.uint32), block_rows)
        out = lookup_dyn_2d(
            flat.reshape(-1, LANES), n, omega=omega, block_rows=block_rows,
            interpret=interpret,
        )
        return out.reshape(-1)[:total].reshape(keys.shape)

    for fn, suffix in (
        (route_2d, "route_fused_2d"),
        (route_pallas, "route_pallas_fused"),
        (ingest_2d, "ingest_fused_2d"),
        (ingest_pallas, "ingest_pallas_fused"),
        (lookup_dyn_2d, "bulk_lookup_dyn_2d"),
        (lookup_dyn_pallas, "bulk_lookup_pallas_dyn"),
    ):
        try:
            fn.__name__ = f"{name}_{suffix}"
        except AttributeError:  # jitted wrappers may refuse the rebrand
            pass
    return FusedKernels(
        route_2d, route_pallas, ingest_2d, ingest_pallas,
        lookup_dyn_2d, lookup_dyn_pallas,
    )
