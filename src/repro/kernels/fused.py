"""Generic fused-routing Pallas kernels — any ``BulkEngine`` lookup body +
the replacement-table divert under ONE ``pallas_call``.

``repro.kernels.binomial_hash`` holds the paper engine's hand-tuned kernels;
this module is the machinery every *other* ``BULK_ENGINES`` entry gets its
device kernels from (DESIGN.md §10): hand ``make_fused_kernels`` an unrolled
jnp lookup body ``lookup(keys_u32, n_u32, omega) -> u32 buckets`` (usable
inside a kernel: u32/f32 elementwise ops only, n <= 1 handled) and it
returns the full kernel set with the exact operand contract of the binomial
flavours —

* ``route_2d`` / ``route_pallas``   — fused lookup + divert, pre-hashed keys;
* ``ingest_2d`` / ``ingest_pallas`` — the u64-id ingest twins (limb-wise
  splitmix64 mixed in-register, then the same body);
* ``lookup_dyn_2d`` / ``lookup_dyn_pallas`` — the plain dynamic-n bulk
  lookup (the two-pass baseline's first dispatch).

All flavours keep the fleet state traced (scalar-prefetch ``[n_total,
n_alive]``, whole-block VMEM mask + table), so fleet events never retrace —
the same guarantees the binomial kernels make, inherited by construction
because the divert body is literally ``binomial_hash._fused_route_body``
with the lookup swapped.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.binomial_jax import mix64_lo32
from repro.kernels.binomial_hash import LANES, _fused_route_body


class FusedKernels(NamedTuple):
    """The per-engine Pallas kernel set ``make_fused_kernels`` returns."""

    route_2d: Callable
    route_pallas: Callable
    ingest_2d: Callable
    ingest_pallas: Callable
    lookup_dyn_2d: Callable
    lookup_dyn_pallas: Callable


def _check_2d(rows: int, lanes: int, block_rows: int) -> None:
    if lanes != LANES:
        raise ValueError(f"minor dim must be {LANES}, got {lanes}")
    if rows % block_rows != 0:
        raise ValueError(
            f"rows ({rows}) must be a multiple of block_rows ({block_rows})"
        )


def _check_state_extents(packed_mask, table, n_words: int, n_slots: int) -> None:
    if not 1 <= n_words <= packed_mask.shape[1]:
        raise ValueError(f"n_words ({n_words}) must be in [1, {packed_mask.shape[1]}]")
    if not 1 <= n_slots <= table.shape[1]:
        raise ValueError(f"n_slots ({n_slots}) must be in [1, {table.shape[1]}]")


def _pad_flat(flat: jax.Array, block_rows: int) -> tuple[jax.Array, int]:
    total = flat.shape[0]
    tile = block_rows * LANES
    padded = (total + tile - 1) // tile * tile
    if padded != total:
        flat = jnp.pad(flat, (0, padded - total))
    return flat, total


def make_fused_kernels(lookup, name: str) -> FusedKernels:
    """Build the device kernel set for one engine's lookup body.

    ``lookup(keys_u32, n_u32, omega) -> u32`` must be traceable inside a
    Pallas TPU kernel body (elementwise u32/f32 ops, no gathers) and map
    n <= 1 to bucket 0 itself.  ``name`` brands the jitted wrappers for
    debuggability.
    """

    def _kernel_route(
        state_ref, mask_ref, table_ref, keys_ref, out_ref, *, omega, n_words, n_slots
    ):
        keys = keys_ref[...].astype(jnp.uint32)
        b = _fused_route_body(
            keys, state_ref, mask_ref, table_ref, omega=omega,
            n_words=n_words, n_slots=n_slots, lookup=lookup,
        )
        out_ref[...] = b.astype(jnp.int32)

    def _kernel_ingest(
        state_ref, mask_ref, table_ref, lo_ref, hi_ref, out_ref, *, omega,
        n_words, n_slots,
    ):
        keys = mix64_lo32(lo_ref[...], hi_ref[...])
        b = _fused_route_body(
            keys, state_ref, mask_ref, table_ref, omega=omega,
            n_words=n_words, n_slots=n_slots, lookup=lookup,
        )
        out_ref[...] = b.astype(jnp.int32)

    def _kernel_lookup_dyn(n_ref, keys_ref, out_ref, *, omega):
        keys = keys_ref[...].astype(jnp.uint32)
        out_ref[...] = lookup(keys, n_ref[0].astype(jnp.uint32), omega).astype(
            jnp.int32
        )

    def _route_grid_spec(block_rows, mask_shape, table_shape, n_blocks):
        return pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks,),
            in_specs=[
                # whole-block mask/table: same small blocks every grid step
                pl.BlockSpec(mask_shape, lambda i, s: (0, 0)),
                pl.BlockSpec(table_shape, lambda i, s: (0, 0)),
                pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
            ],
            out_specs=pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
        )

    @functools.partial(
        jax.jit,
        static_argnames=("n_words", "n_slots", "omega", "block_rows", "interpret"),
    )
    def route_2d(
        keys, packed_mask, table, state, n_words, n_slots,
        omega=16, block_rows=512, interpret=False,
    ):
        """(rows, 128) u32 keys + fleet state -> (rows, 128) i32 replica ids."""
        rows, lanes = keys.shape
        _check_2d(rows, lanes, block_rows)
        _check_state_extents(packed_mask, table, n_words, n_slots)
        grid_spec = _route_grid_spec(
            block_rows, packed_mask.shape, table.shape, rows // block_rows
        )
        return pl.pallas_call(
            functools.partial(
                _kernel_route, omega=omega, n_words=n_words, n_slots=n_slots
            ),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            interpret=interpret,
        )(
            jnp.asarray(state, jnp.uint32).reshape(2),
            packed_mask.astype(jnp.uint32),
            table.astype(jnp.int32),
            keys.astype(jnp.uint32),
        )

    def route_pallas(
        keys, packed_mask, table, state, n_words, n_slots,
        omega=16, block_rows=512, interpret=False,
    ):
        """Any-shape int keys + fleet state -> i32 replica ids, fused kernel."""
        flat, total = _pad_flat(keys.reshape(-1).astype(jnp.uint32), block_rows)
        out = route_2d(
            flat.reshape(-1, LANES), packed_mask, table, state, n_words,
            n_slots, omega=omega, block_rows=block_rows, interpret=interpret,
        )
        return out.reshape(-1)[:total].reshape(keys.shape)

    @functools.partial(
        jax.jit,
        static_argnames=("n_words", "n_slots", "omega", "block_rows", "interpret"),
    )
    def ingest_2d(
        ids_lo, ids_hi, packed_mask, table, state, n_words, n_slots,
        omega=16, block_rows=512, interpret=False,
    ):
        """(rows, 128) u32 id halves + fleet state -> (rows, 128) i32 ids."""
        rows, lanes = ids_lo.shape
        if ids_hi.shape != ids_lo.shape:
            raise ValueError(
                f"id halves must agree in shape, got {ids_lo.shape} vs {ids_hi.shape}"
            )
        _check_2d(rows, lanes, block_rows)
        _check_state_extents(packed_mask, table, n_words, n_slots)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(rows // block_rows,),
            in_specs=[
                pl.BlockSpec(packed_mask.shape, lambda i, s: (0, 0)),
                pl.BlockSpec(table.shape, lambda i, s: (0, 0)),
                pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
                pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
            ],
            out_specs=pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
        )
        return pl.pallas_call(
            functools.partial(
                _kernel_ingest, omega=omega, n_words=n_words, n_slots=n_slots
            ),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            interpret=interpret,
        )(
            jnp.asarray(state, jnp.uint32).reshape(2),
            packed_mask.astype(jnp.uint32),
            table.astype(jnp.int32),
            ids_lo.astype(jnp.uint32),
            ids_hi.astype(jnp.uint32),
        )

    def ingest_pallas(
        ids_lo, ids_hi, packed_mask, table, state, n_words, n_slots,
        omega=16, block_rows=512, interpret=False,
    ):
        """Any-shape u32 id halves + fleet state -> i32 ids, fused ingest."""
        lo, total = _pad_flat(ids_lo.reshape(-1).astype(jnp.uint32), block_rows)
        hi, _ = _pad_flat(ids_hi.reshape(-1).astype(jnp.uint32), block_rows)
        out = ingest_2d(
            lo.reshape(-1, LANES), hi.reshape(-1, LANES), packed_mask, table,
            state, n_words, n_slots, omega=omega, block_rows=block_rows,
            interpret=interpret,
        )
        return out.reshape(-1)[:total].reshape(ids_lo.shape)

    @functools.partial(jax.jit, static_argnames=("omega", "block_rows", "interpret"))
    def lookup_dyn_2d(keys, n, omega=16, block_rows=512, interpret=False):
        """(rows, 128) u32 keys + traced n -> (rows, 128) i32 buckets."""
        rows, lanes = keys.shape
        _check_2d(rows, lanes, block_rows)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(rows // block_rows,),
            in_specs=[pl.BlockSpec((block_rows, LANES), lambda i, n_ref: (i, 0))],
            out_specs=pl.BlockSpec((block_rows, LANES), lambda i, n_ref: (i, 0)),
        )
        return pl.pallas_call(
            functools.partial(_kernel_lookup_dyn, omega=omega),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            interpret=interpret,
        )(jnp.asarray(n, jnp.uint32).reshape(1), keys.astype(jnp.uint32))

    def lookup_dyn_pallas(keys, n, omega=16, block_rows=512, interpret=False):
        """Any-shape int keys + traced n -> i32 buckets (recompile-free)."""
        flat, total = _pad_flat(keys.reshape(-1).astype(jnp.uint32), block_rows)
        out = lookup_dyn_2d(
            flat.reshape(-1, LANES), n, omega=omega, block_rows=block_rows,
            interpret=interpret,
        )
        return out.reshape(-1)[:total].reshape(keys.shape)

    for fn, suffix in (
        (route_2d, "route_fused_2d"),
        (route_pallas, "route_pallas_fused"),
        (ingest_2d, "ingest_fused_2d"),
        (ingest_pallas, "ingest_pallas_fused"),
        (lookup_dyn_2d, "bulk_lookup_dyn_2d"),
        (lookup_dyn_pallas, "bulk_lookup_pallas_dyn"),
    ):
        try:
            fn.__name__ = f"{name}_{suffix}"
        except AttributeError:  # jitted wrappers may refuse the rebrand
            pass
    return FusedKernels(
        route_2d, route_pallas, ingest_2d, ingest_pallas,
        lookup_dyn_2d, lookup_dyn_pallas,
    )
