"""Pallas TPU kernels: bulk BinomialHash lookup (keys[N] -> buckets[N]).

TPU adaptation of the paper's scalar hot loop (DESIGN.md §3):
* u32 integer arithmetic only (murmur3 fmix32 mixers) — the VPU has no
  integer divide and no 64-bit datapath; the paper's modulo-free power-of-two
  mask design maps 1:1 onto AND/shift/mul ops;
* the early-exit rejection loop becomes an ω-unrolled masked blend — on an
  8×128 lane grid divergent exits buy nothing;
* keys are laid out (rows, 128) so each block is a native VREG tile; the
  block row count is the VMEM tiling knob (default 512 rows = 256 KiB per
  in/out block, comfortably inside the ~16 MiB VMEM budget with double
  buffering).

Only the **static-n** flavour (``binomial_bulk_lookup_2d`` /
``binomial_bulk_lookup_pallas`` — ``n`` baked into the trace, masks
constant-fold, any cluster resize retraces) is hand-written here.  The
serving datapath kernels are instantiated from the generic factory
(``repro.kernels.fused.make_fused_kernels``) with the binomial lookup body
— the SAME machinery every other ``BULK_ENGINES`` entry uses, so the
constant-time certifier (``repro.analysis``) checks one uniform kernel
shape per engine:

* **dynamic-n** (``binomial_bulk_lookup_dyn_2d`` /
  ``binomial_bulk_lookup_pallas_dyn``) — ``n`` rides in as a scalar-prefetch
  operand (SMEM before the grid body runs); ``E``/``M`` are derived
  in-kernel with the shift-or cascade, so elastic scale-up/down and replica
  failures NEVER retrace;
* **fused** (``binomial_route_fused_2d`` / ``binomial_route_pallas_fused``)
  — the dynamic-n lookup *and* the replacement-table failure divert in one
  kernel (DESIGN.md §3, §7): ``[n_total, n_alive]`` scalar-prefetch SMEM,
  packed removed-slot mask + (1, C) slots permutation as whole-block VMEM
  operands, replica ids written in a single pass — no intermediate
  ``buckets[N]`` HBM round-trip, ONE device dispatch per batch, storm-time
  cost equal to steady-time cost;
* **fused ingest** (``binomial_ingest_fused_2d`` /
  ``binomial_ingest_pallas_fused``) — the fused kernel with the session-key
  hash pulled inside too: raw u64 session ids ride in as (lo, hi) u32
  halves, the limb-wise splitmix64 (``binomial_jax.mix64_lo32``) derives
  the u32 routing key in-register — id -> replica in ONE dispatch with no
  ``keys[N]`` array anywhere (DESIGN.md §9; ``BatchRouter.route_ids``).

The kernel bodies reuse the exact jnp math from ``repro.core.binomial_jax``,
so kernel == ref == scalar-u32-oracle is enforced transitively by tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.binomial_jax import _unrolled_body
from repro.core.memento_jax import _binomial_lookup_body
from repro.kernels.fused import (  # noqa: F401  (re-exported for back-compat)
    LANES,
    _fused_route_body,
    make_fused_kernels,
)


def _kernel(keys_ref, out_ref, *, n: int, omega: int):
    keys = keys_ref[...]
    l = (n - 1).bit_length()  # ct: host-ok — n is a static Python int
    E = np.uint32(1 << l)  # ct: host-ok
    M = np.uint32(1 << (l - 1))  # ct: host-ok
    out = _unrolled_body(keys.astype(jnp.uint32), E, M, np.uint32(n), omega)
    out_ref[...] = out.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n", "omega", "block_rows", "interpret")
)
def binomial_bulk_lookup_2d(
    keys: jax.Array,
    n: int,
    omega: int = 16,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(rows, 128) uint32 keys -> (rows, 128) int32 buckets. rows % block_rows == 0."""
    rows, lanes = keys.shape
    if lanes != LANES:
        raise ValueError(f"minor dim must be {LANES}, got {lanes}")
    if rows % block_rows != 0:
        raise ValueError(f"rows ({rows}) must be a multiple of block_rows ({block_rows})")
    if n <= 1:
        return jnp.zeros(keys.shape, dtype=jnp.int32)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, n=n, omega=omega),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(keys.astype(jnp.uint32))


def binomial_bulk_lookup_pallas(
    keys: jax.Array,
    n: int,
    omega: int = 16,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Any-shape int keys -> int32 buckets, padding/reshaping to kernel layout."""
    flat = keys.reshape(-1).astype(jnp.uint32)
    total = flat.shape[0]
    tile = block_rows * LANES
    padded = (total + tile - 1) // tile * tile
    if padded != total:
        flat = jnp.pad(flat, (0, padded - total))
    out = binomial_bulk_lookup_2d(
        flat.reshape(-1, LANES), n, omega=omega, block_rows=block_rows, interpret=interpret
    )
    return out.reshape(-1)[:total].reshape(keys.shape)


# ---------------------------------------------------------------------------
# serving-datapath kernels: ONE factory call replaces the hand-written
# dynamic-n / fused / fused-ingest pallas_call plumbing (operand contracts,
# jit static_argnames and numerics are identical by construction — the
# factory body IS the former hand-written body, parameterised on the
# lookup; tests pin kernel == jnp mirror == scalar oracle bit-for-bit).
# ---------------------------------------------------------------------------

_KERNELS = make_fused_kernels(_binomial_lookup_body, "binomial")

#: fused lookup + replacement-table divert, (rows, 128) layout (DESIGN §3, §7)
binomial_route_fused_2d = _KERNELS.route_2d
#: any-shape fused routing entry point (pad/reshape wrapper)
binomial_route_pallas_fused = _KERNELS.route_pallas
#: fused u64-id ingest twins — splitmix64 limb mix + lookup + divert (DESIGN §9)
binomial_ingest_fused_2d = _KERNELS.ingest_2d
binomial_ingest_pallas_fused = _KERNELS.ingest_pallas
#: plain dynamic-n bulk lookup (the two-pass baseline's first dispatch)
binomial_bulk_lookup_dyn_2d = _KERNELS.lookup_dyn_2d
binomial_bulk_lookup_pallas_dyn = _KERNELS.lookup_dyn_pallas
