"""Pallas TPU kernel: bulk BinomialHash lookup (keys[N] -> buckets[N]).

TPU adaptation of the paper's scalar hot loop (DESIGN.md §3):
* u32 integer arithmetic only (murmur3 fmix32 mixers) — the VPU has no
  integer divide and no 64-bit datapath; the paper's modulo-free power-of-two
  mask design maps 1:1 onto AND/shift/mul ops;
* the early-exit rejection loop becomes an ω-unrolled masked blend — on an
  8×128 lane grid divergent exits buy nothing;
* keys are laid out (rows, 128) so each block is a native VREG tile; the
  block row count is the VMEM tiling knob (default 512 rows = 256 KiB per
  in/out block, comfortably inside the ~16 MiB VMEM budget with double
  buffering).

The kernel body reuses the exact jnp math from ``repro.core.binomial_jax``,
so kernel == ref == scalar-u32-oracle is enforced transitively by tests.

Two flavours of the same kernel body:

* **static-n** (``binomial_bulk_lookup_2d`` / ``binomial_bulk_lookup_pallas``)
  — ``n`` is a Python int baked into the trace; masks constant-fold, but any
  change to the cluster size retraces and recompiles;
* **dynamic-n** (``binomial_bulk_lookup_dyn_2d`` /
  ``binomial_bulk_lookup_pallas_dyn``) — ``n`` rides in as a scalar-prefetch
  operand (``pltpu.PrefetchScalarGridSpec``, landing in SMEM before the grid
  body runs); ``E``/``M`` are derived in-kernel with the shift-or cascade, so
  elastic scale-up/down and replica failures NEVER retrace.

Plus the serving hot path built on the dynamic flavour:

* **fused** (``binomial_route_fused_2d`` / ``binomial_route_pallas_fused``) —
  the dynamic-n lookup *and* the replacement-table failure divert in one
  kernel (DESIGN.md §3, §7).  ``[n_total, n_alive]`` is the scalar-prefetch
  SMEM operand, the packed removed-slot mask and the (1, C) slots
  permutation are whole-block VMEM operands, and final replica ids are written in
  a single pass: no intermediate ``buckets[N]`` HBM round-trip, ONE device
  dispatch per batch, and a storm-time cost equal to the steady-time cost
  (at most two bounded table gathers per lane, never a rejection walk).
  ``repro.serving.batch_router.BatchRouter`` routes whole request batches
  through this kernel with device-resident fleet state — zero recompiles and
  zero per-batch host->device state uploads across arbitrary scale/fail
  event streams.

* **fused ingest** (``binomial_ingest_fused_2d`` /
  ``binomial_ingest_pallas_fused``) — the fused kernel with the session-key
  hash pulled inside too: raw u64 session ids ride in as (lo, hi) u32
  halves, the limb-wise splitmix64 (``binomial_jax.mix64_lo32``) derives
  the u32 routing key in-register, and the identical lookup+divert body
  finishes the job — id -> replica in ONE dispatch with no ``keys[N]``
  array anywhere (DESIGN.md §9; ``BatchRouter.route_ids``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.binomial_jax import (
    GOLDEN32,
    _unrolled_body,
    hash_pair,
    mix32,
    mix64_lo32,
    mulhi32,
    next_pow2_u32,
)
from repro.core.memento_jax import _binomial_lookup_body

LANES = 128  # TPU minor-dim tile


def _kernel(keys_ref, out_ref, *, n: int, omega: int):
    keys = keys_ref[...]
    l = (n - 1).bit_length()
    E = np.uint32(1 << l)
    M = np.uint32(1 << (l - 1))
    out = _unrolled_body(keys.astype(jnp.uint32), E, M, np.uint32(n), omega)
    out_ref[...] = out.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n", "omega", "block_rows", "interpret")
)
def binomial_bulk_lookup_2d(
    keys: jax.Array,
    n: int,
    omega: int = 16,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(rows, 128) uint32 keys -> (rows, 128) int32 buckets. rows % block_rows == 0."""
    rows, lanes = keys.shape
    if lanes != LANES:
        raise ValueError(f"minor dim must be {LANES}, got {lanes}")
    if rows % block_rows != 0:
        raise ValueError(f"rows ({rows}) must be a multiple of block_rows ({block_rows})")
    if n <= 1:
        return jnp.zeros(keys.shape, dtype=jnp.int32)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, n=n, omega=omega),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(keys.astype(jnp.uint32))


def binomial_bulk_lookup_pallas(
    keys: jax.Array,
    n: int,
    omega: int = 16,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Any-shape int keys -> int32 buckets, padding/reshaping to kernel layout."""
    flat = keys.reshape(-1).astype(jnp.uint32)
    total = flat.shape[0]
    tile = block_rows * LANES
    padded = (total + tile - 1) // tile * tile
    if padded != total:
        flat = jnp.pad(flat, (0, padded - total))
    out = binomial_bulk_lookup_2d(
        flat.reshape(-1, LANES), n, omega=omega, block_rows=block_rows, interpret=interpret
    )
    return out.reshape(-1)[:total].reshape(keys.shape)


# ---------------------------------------------------------------------------
# dynamic-n flavour: n is a scalar-prefetch operand, never baked into the
# trace — elastic resize / failure events reuse one compiled executable.
# ---------------------------------------------------------------------------


def _kernel_dyn(n_ref, keys_ref, out_ref, *, omega: int):
    # E/M derived from the prefetched SMEM scalar with the same shift-or
    # cascade as binomial_lookup_dyn (shared helper keeps kernel == ref).
    n = n_ref[0].astype(jnp.uint32)
    E = next_pow2_u32(n)
    M = E >> 1
    keys = keys_ref[...]
    out = _unrolled_body(keys.astype(jnp.uint32), E, M, n, omega)
    out = jnp.where(n <= np.uint32(1), np.uint32(0), out)
    out_ref[...] = out.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("omega", "block_rows", "interpret")
)
def binomial_bulk_lookup_dyn_2d(
    keys: jax.Array,
    n: jax.Array,
    omega: int = 16,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(rows, 128) uint32 keys + traced scalar n -> (rows, 128) int32 buckets.

    ``n`` may be a Python int, a 0-d array or a (1,)-array; it is traced, so
    calling again with a different cluster size hits the same executable.
    """
    rows, lanes = keys.shape
    if lanes != LANES:
        raise ValueError(f"minor dim must be {LANES}, got {lanes}")
    if rows % block_rows != 0:
        raise ValueError(f"rows ({rows}) must be a multiple of block_rows ({block_rows})")
    grid = (rows // block_rows,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i, n_ref: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i, n_ref: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_dyn, omega=omega),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(n, jnp.uint32).reshape(1), keys.astype(jnp.uint32))


def binomial_bulk_lookup_pallas_dyn(
    keys: jax.Array,
    n: jax.Array,
    omega: int = 16,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Any-shape int keys + traced n -> int32 buckets (recompile-free resize)."""
    flat = keys.reshape(-1).astype(jnp.uint32)
    total = flat.shape[0]
    tile = block_rows * LANES
    padded = (total + tile - 1) // tile * tile
    if padded != total:
        flat = jnp.pad(flat, (0, padded - total))
    out = binomial_bulk_lookup_dyn_2d(
        flat.reshape(-1, LANES), n, omega=omega, block_rows=block_rows, interpret=interpret
    )
    return out.reshape(-1)[:total].reshape(keys.shape)


# ---------------------------------------------------------------------------
# fused flavour: BinomialHash lookup + replacement-table divert in ONE kernel.
# The serving hot path — no intermediate buckets[N] HBM round-trip, one
# dispatch per batch.  Fleet state rides as traced operands:
#   * [n_total, n_alive]  — scalar-prefetch (SMEM before the grid runs);
#   * packed removed mask — (1, W) u32 bit-words, whole-block VMEM operand
#     re-used by every grid step (W = capacity/32 words, lane-padded);
#   * replacement table   — (1, C) i32 slots permutation, whole-block VMEM
#     operand (DESIGN.md §7), rebuilt incrementally at fleet-event time.
# Removed buckets resolve via two bounded hash rounds and EXACTLY ONE table
# read (the MementoHash-style divert) instead of a data-dependent rejection
# walk, so storm-time block cost equals steady-time cost.  The VPU has no
# vector gather, so the table read is a select cascade over the C static
# entries (and membership over the W mask words); the divert's range
# reductions use the Lemire mulhi32 mul+shift (the VPU has no integer
# divide either).  With no removed slots a single `jnp.any` skips the whole
# divert, so the healthy-fleet cost is the base lookup alone.
# ---------------------------------------------------------------------------


def _fused_route_body(
    keys, state_ref, mask_ref, table_ref, *, omega: int, n_words: int,
    n_slots: int, lookup=_binomial_lookup_body,
):
    """Shared fused lookup+divert body: u32 keys -> u32 replica ids.

    Factored out so the plain fused kernel (pre-hashed keys) and the ingest
    kernel (u64 ids mixed in-kernel) run the exact same routing math — and
    generic over the base engine: ``lookup(keys_u32, n_u32, omega)`` is the
    only engine-specific piece (``repro.kernels.fused`` instantiates the
    other ``BULK_ENGINES`` entries' kernels from this same body).
    """
    n = state_ref[0].astype(jnp.uint32)
    n_alive = state_ref[1].astype(jnp.uint32)
    b = lookup(keys, n, omega)

    def removed(bv):
        # select-cascade membership test over the packed bit-words: W scalar
        # broadcasts + selects, no vector gather needed.  Cheaper than the
        # n_slots-wide table cascade — this is why the kernel keeps the mask
        # operand: the steady-state skip test touches W words, not C slots.
        w = bv >> np.uint32(5)
        word = jnp.zeros_like(bv)
        for s in range(n_words):
            word = jnp.where(w == np.uint32(s), mask_ref[0, s], word)
        return ((word >> (bv & np.uint32(31))) & np.uint32(1)) != 0

    def gather(idx):
        # select-cascade "gather" from the slots permutation: C scalar
        # broadcasts + selects per read (idx is always < n_total <= C).
        out = jnp.zeros_like(idx)
        for s in range(n_slots):
            out = jnp.where(
                idx == np.uint32(s), table_ref[0, s].astype(jnp.uint32), out
            )
        return out

    hit = removed(b)

    def divert(bb):
        # ReplacementTable.resolve, lane-wise: two bounded redirects, the
        # Lemire mulhi32 reduction in place of a modulo (the VPU has no
        # integer divide, and mulhi32 is ~11 mul/shift/add ops), then ONE
        # table read.
        h = hash_pair(mix32(keys + GOLDEN32), bb)  # hash_iter(key, 1) folded
        q = mulhi32(h, n)
        deep = q >= n_alive  # a removed position: one more redirect settles it
        # second hash chains off the first (h is well mixed; one pair-mix)
        q = jnp.where(deep, mulhi32(hash_pair(h, q), n_alive), q)
        return jnp.where(hit, gather(q), bb)

    return jax.lax.cond(jnp.any(hit), divert, lambda bb: bb, b)


def _kernel_fused(
    state_ref, mask_ref, table_ref, keys_ref, out_ref, *, omega: int,
    n_words: int, n_slots: int,
):
    keys = keys_ref[...].astype(jnp.uint32)
    b = _fused_route_body(
        keys, state_ref, mask_ref, table_ref, omega=omega, n_words=n_words,
        n_slots=n_slots,
    )
    out_ref[...] = b.astype(jnp.int32)


def _kernel_ingest(
    state_ref, mask_ref, table_ref, lo_ref, hi_ref, out_ref, *, omega: int,
    n_words: int, n_slots: int,
):
    # u64 ids -> u32 routing keys via the limb-wise splitmix64 (the VPU has
    # no 64-bit datapath), then the identical fused lookup+divert body: the
    # whole request->replica map in ONE kernel, no key array in HBM.
    keys = mix64_lo32(lo_ref[...], hi_ref[...])
    b = _fused_route_body(
        keys, state_ref, mask_ref, table_ref, omega=omega, n_words=n_words,
        n_slots=n_slots,
    )
    out_ref[...] = b.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n_words", "n_slots", "omega", "block_rows", "interpret"),
)
def binomial_route_fused_2d(
    keys: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    n_words: int,
    n_slots: int,
    omega: int = 16,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(rows, 128) u32 keys + fleet state -> (rows, 128) int32 replica ids.

    One ``pallas_call`` — base lookup *and* failure divert.  ``state`` is
    the (2,) u32 ``[n_total, n_alive]`` scalar-prefetch operand;
    ``packed_mask`` is the (1, W) u32 removed-slot bit-table
    (``repro.core.memento_jax.pack_removed_mask``); ``table`` is the (1, C)
    i32 slots permutation (``repro.core.memento_jax.pack_table``).
    ``n_words`` / ``n_slots`` are the static payload extents (capacity/32
    mask words, capacity table slots) bounding the select cascades.
    Everything dynamic is traced, so fleet events never retrace.
    """
    rows, lanes = keys.shape
    if lanes != LANES:
        raise ValueError(f"minor dim must be {LANES}, got {lanes}")
    if rows % block_rows != 0:
        raise ValueError(f"rows ({rows}) must be a multiple of block_rows ({block_rows})")
    if not 1 <= n_words <= packed_mask.shape[1]:
        raise ValueError(
            f"n_words ({n_words}) must be in [1, {packed_mask.shape[1]}]"
        )
    if not 1 <= n_slots <= table.shape[1]:
        raise ValueError(
            f"n_slots ({n_slots}) must be in [1, {table.shape[1]}]"
        )
    grid = (rows // block_rows,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # whole-block mask/table: same small blocks for every grid step
            pl.BlockSpec(packed_mask.shape, lambda i, s: (0, 0)),
            pl.BlockSpec(table.shape, lambda i, s: (0, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(
            _kernel_fused, omega=omega, n_words=n_words, n_slots=n_slots
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(
        jnp.asarray(state, jnp.uint32).reshape(2),
        packed_mask.astype(jnp.uint32),
        table.astype(jnp.int32),
        keys.astype(jnp.uint32),
    )


def binomial_route_pallas_fused(
    keys: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    n_words: int,
    n_slots: int,
    omega: int = 16,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Any-shape int keys + fleet state -> int32 replica ids, fused kernel."""
    flat = keys.reshape(-1).astype(jnp.uint32)
    total = flat.shape[0]
    tile = block_rows * LANES
    padded = (total + tile - 1) // tile * tile
    if padded != total:
        flat = jnp.pad(flat, (0, padded - total))
    out = binomial_route_fused_2d(
        flat.reshape(-1, LANES),
        packed_mask,
        table,
        state,
        n_words,
        n_slots,
        omega=omega,
        block_rows=block_rows,
        interpret=interpret,
    )
    return out.reshape(-1)[:total].reshape(keys.shape)


# ---------------------------------------------------------------------------
# fused ingest flavour: raw u64 session ids -> replica ids in ONE kernel.
# The ids arrive as (lo, hi) u32 halves (the VPU has no 64-bit datapath);
# the limb-wise splitmix64 (`mix64_lo32`, ~30 VPU ops) derives the u32
# routing key in-register and feeds the SAME fused lookup+divert body — no
# intermediate keys[N] array ever exists, on-chip or in HBM (DESIGN.md §9).
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("n_words", "n_slots", "omega", "block_rows", "interpret"),
)
def binomial_ingest_fused_2d(
    ids_lo: jax.Array,
    ids_hi: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    n_words: int,
    n_slots: int,
    omega: int = 16,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(rows, 128) u32 id halves + fleet state -> (rows, 128) i32 replica ids.

    The ingest twin of ``binomial_route_fused_2d``: two key blocks in (the
    u64 id split into u32 limbs), one replica block out, hash + lookup +
    divert under one ``pallas_call``.  Same operand contract otherwise.
    """
    rows, lanes = ids_lo.shape
    if ids_hi.shape != ids_lo.shape:
        raise ValueError(
            f"id halves must agree in shape, got {ids_lo.shape} vs {ids_hi.shape}"
        )
    if lanes != LANES:
        raise ValueError(f"minor dim must be {LANES}, got {lanes}")
    if rows % block_rows != 0:
        raise ValueError(f"rows ({rows}) must be a multiple of block_rows ({block_rows})")
    if not 1 <= n_words <= packed_mask.shape[1]:
        raise ValueError(
            f"n_words ({n_words}) must be in [1, {packed_mask.shape[1]}]"
        )
    if not 1 <= n_slots <= table.shape[1]:
        raise ValueError(
            f"n_slots ({n_slots}) must be in [1, {table.shape[1]}]"
        )
    grid = (rows // block_rows,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec(packed_mask.shape, lambda i, s: (0, 0)),
            pl.BlockSpec(table.shape, lambda i, s: (0, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i, s: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(
            _kernel_ingest, omega=omega, n_words=n_words, n_slots=n_slots
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(
        jnp.asarray(state, jnp.uint32).reshape(2),
        packed_mask.astype(jnp.uint32),
        table.astype(jnp.int32),
        ids_lo.astype(jnp.uint32),
        ids_hi.astype(jnp.uint32),
    )


def binomial_ingest_pallas_fused(
    ids_lo: jax.Array,
    ids_hi: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    n_words: int,
    n_slots: int,
    omega: int = 16,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Any-shape u32 id halves + fleet state -> i32 replica ids, fused ingest."""
    lo = ids_lo.reshape(-1).astype(jnp.uint32)
    hi = ids_hi.reshape(-1).astype(jnp.uint32)
    total = lo.shape[0]
    tile = block_rows * LANES
    padded = (total + tile - 1) // tile * tile
    if padded != total:
        lo = jnp.pad(lo, (0, padded - total))
        hi = jnp.pad(hi, (0, padded - total))
    out = binomial_ingest_fused_2d(
        lo.reshape(-1, LANES),
        hi.reshape(-1, LANES),
        packed_mask,
        table,
        state,
        n_words,
        n_slots,
        omega=omega,
        block_rows=block_rows,
        interpret=interpret,
    )
    return out.reshape(-1)[:total].reshape(ids_lo.shape)
