"""Roofline terms for a compiled (SPMD-partitioned, per-device) module.

compute term    = HLO_FLOPs / peak_FLOP/s
memory term     = HLO_bytes / HBM_bw
collective term = collective_bytes / link_bw

Primary source: the post-SPMD-partitioning HLO dump parsed trip-aware by
``hlo_parse`` (XLA's cost_analysis() counts every while body once and the
CPU backend promotes bf16->f32, both of which corrupt the terms — see
hlo_parse docstring).  cost_analysis() numbers are kept as ``raw_*`` for
reference.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.roofline import hw
from repro.roofline.hlo_parse import analyze_hlo_text


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    dcn_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    collectives: dict
    raw_flops: float
    raw_bytes: float

    def row(self) -> dict:
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


def analyze(
    compiled,
    n_chips: int,
    model_flops_total: float,
    hlo_text: str | None = None,
    pod_group_size: int = 1,
) -> Roofline:
    """model_flops_total: 6·N·D (train) or 2·N·D (fwd-only), WHOLE program."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))
    if hlo_text is None:
        hlo_text = compiled.as_text()
    cost = analyze_hlo_text(hlo_text, pod_group_size)
    compute_s = cost.flops / hw.PEAK_FLOPS_BF16
    memory_s = cost.bytes / hw.HBM_BW
    collective_s = cost.coll_bytes / hw.ICI_LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops_total / max(cost.flops * n_chips, 1.0)
    return Roofline(
        flops=cost.flops,
        hbm_bytes=cost.bytes,
        coll_bytes=cost.coll_bytes,
        dcn_bytes=cost.dcn_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops_total,
        useful_ratio=useful,
        collectives={k: tuple(v) for k, v in cost.coll_by_kind.items()},
        raw_flops=raw_flops,
        raw_bytes=raw_bytes,
    )


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out
