"""Trip-count-aware cost analysis over post-SPMD-partitioning HLO text.

Why this stage of the pipeline (dumped via --xla_dump_hlo_pass_re):
* it is PER-DEVICE (collectives materialised) — the roofline unit we need;
* dtypes are still true (the CPU backend later promotes bf16->f32, which
  would inflate every byte count 2x and add promotion converts that do not
  exist on TPU);
* XLA's own cost_analysis() visits each ``while`` body once, so scanned
  models (layers / attention chunks / CE chunks) are under-counted by the
  trip count — here we multiply through the loop nest ourselves (trip counts
  recovered from the loop-condition ``compare(_, constant)``).

Cost model per op (documented in EXPERIMENTS.md §Roofline):
* flops — ``dot`` ops only: 2 * prod(result dims) * contracted size.
  Elementwise flops are negligible for these models.
* bytes — tensor-granularity approximation of fused traffic:
    - dot/reduce/reduce-window/sort/gather/scatter/concatenate/transpose/
      pad/convolution: 2 x result bytes (one write + one read downstream);
    - dynamic-slice: 2 x slice bytes; dynamic-update-slice: 2 x update bytes
      (in-place);
    - collectives: operand + result bytes;
    - elementwise ops are assumed fused (skipped) INSIDE loop bodies, but
      counted (2 x result) in the entry computation, where the optimizer
      update / loss tail run at tensor granularity.
* collective bytes — operand bytes by kind; groups whose size matches the
  pod axis are classified DCN on multi-pod meshes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.roofline import hw

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_MATERIALIZE = {
    "dot", "reduce", "reduce-window", "sort", "gather", "scatter",
    "concatenate", "transpose", "pad", "convolution", "select-and-scatter",
    "copy", "iota-large",
}
_SHAPE_ONLY = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "iota",
    "while", "call", "conditional", "custom-call", "broadcast",
}

_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|[a-z][a-z0-9]*\[[0-9,]*\]\S*)\s+([a-z][a-z0-9\-]*)\((.*)$"
)
_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_PARAM_SIG = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\]))")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND = re.compile(r"%([\w.\-]+)")
_CONST_VAL = re.compile(r"constant\((\d+)\)")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)")


def _nbytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * hw.BYTES_PER_DTYPE.get(dtype, 4)
    return total


def _dims(shape_str: str) -> list[int]:
    m = _SHAPE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class Op:
    name: str
    kind: str
    shape: str
    rest: str


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    params: dict = field(default_factory=dict)
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)


def parse_module(text: str) -> tuple[dict[str, "Computation"], str | None]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and ("{" in line) and ("->" in line):
            is_entry = line.startswith("ENTRY")
            m = _COMP_HEAD.match(line[5:].strip() if is_entry else line)
            if m:
                cur = Computation(m.group(1), is_entry)
                for pname, pshape in _PARAM_SIG.findall(m.group(2)):
                    cur.params[pname] = pshape
                    cur.symbols[pname] = pshape
                comps[cur.name] = cur
                if is_entry:
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF.match(line)
        if m:
            name, shape, kind, rest = m.groups()
            cur.ops.append(Op(name, kind, shape, rest))
            cur.symbols[name] = shape
    return comps, entry


def _split_operands_attrs(rest: str) -> tuple[str, str]:
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1 :]
    return rest, ""


def _dot_flops(op: Op, comp: Computation) -> float:
    operands, attrs = _split_operands_attrs(op.rest)
    names = _OPERAND.findall(operands)
    if not names:
        return 0.0
    lhs_dims = _dims(comp.symbols.get(names[0], ""))
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", attrs)
    contracted = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            if int(d) < len(lhs_dims):
                contracted *= lhs_dims[int(d)]
    out = 1
    for d in _dims(op.shape):
        out *= d
    return 2.0 * out * contracted


def _trip_count_strict(cond: Computation | None, attrs: str) -> int | None:
    """Static trip count of a lowered ``while``, or ``None`` when it cannot
    be recovered — i.e. the loop bound is not provably data-independent.

    Two recovery routes, in order: the compiler's own
    ``known_trip_count`` backend config (XLA annotates every loop it proves
    counted; a data-dependent loop never carries it), then the canonical
    counted-loop shape ``compare(induction, constant(N)) direction=LT``
    with an *integer-typed* constant — induction variables are s32/u32, so
    a float compare is a data threshold, not a trip bound.  Anything else
    returns ``None`` — the HLO gate treats that as a data-dependent loop
    on the hot path.
    """
    m = _TRIP_CFG.search(attrs)
    if m:
        return int(m.group(1))
    if cond is None:
        return None
    consts = {}
    for op in cond.ops:
        if op.kind == "constant" and re.match(r"[su]\d+\[", op.shape):
            vm = _CONST_VAL.search("constant(" + op.rest)
            if vm:
                consts[op.name] = int(vm.group(1))
    for op in cond.ops:
        if op.kind == "compare" and "direction=LT" in op.rest:
            for n in _OPERAND.findall(_split_operands_attrs(op.rest)[0]):
                if n in consts:
                    return consts[n]
    return None


def _trip_count(cond: Computation, attrs: str) -> int:
    trips = _trip_count_strict(cond, attrs)
    return 1 if trips is None else trips


def while_trip_counts(comps: dict) -> list[tuple[str, str, int | None]]:
    """Every ``while`` op in the module as ``(computation, op name, trips)``
    with ``trips=None`` when the static trip count is unrecoverable.  The
    constant-time HLO gate (``repro.analysis.hlo_gate``) asserts this list
    contains no ``None`` for the compiled fused route."""
    out: list[tuple[str, str, int | None]] = []
    for comp in comps.values():
        for op in comp.ops:
            if op.kind != "while":
                continue
            _operands, attrs = _split_operands_attrs(op.rest)
            cm = re.search(r"condition=%([\w.\-]+)", attrs)
            cond = comps.get(cm.group(1)) if cm else None
            out.append((comp.name, op.name, _trip_count_strict(cond, attrs)))
    return out


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    dcn_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.dcn_bytes += other.dcn_bytes * mult
        for k, (c, b) in other.coll_by_kind.items():
            e = self.coll_by_kind.setdefault(k, [0, 0])
            e[0] += c * mult
            e[1] += b * mult


def _comp_cost(comp: Computation, comps: dict, memo: dict, pod_group_size: int) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    cost = Cost()
    memo[comp.name] = cost
    for op in comp.ops:
        operands, attrs = _split_operands_attrs(op.rest)
        kind = op.kind
        if kind == "dot":
            cost.flops += _dot_flops(op, comp)
            cost.bytes += 2 * _nbytes(op.shape) + sum(
                _nbytes(comp.symbols.get(n, "")) for n in _OPERAND.findall(operands)
            )
        elif kind == "while":
            body = cond = None
            bm = re.search(r"body=%([\w.\-]+)", attrs)
            cm = re.search(r"condition=%([\w.\-]+)", attrs)
            if bm:
                body = comps.get(bm.group(1))
            if cm:
                cond = comps.get(cm.group(1))
            trips = _trip_count(cond, attrs) if cond else 1
            if body:
                cost.add(_comp_cost(body, comps, memo, pod_group_size), trips)
        elif kind in ("call",):
            cm = re.search(r"to_apply=%([\w.\-]+)", attrs)
            if cm and cm.group(1) in comps:
                cost.add(_comp_cost(comps[cm.group(1)], comps, memo, pod_group_size), 1.0)
        elif any(kind.startswith(c) for c in _COLL):
            if kind.endswith("-done"):
                continue
            ob = sum(_nbytes(comp.symbols.get(n, "")) for n in _OPERAND.findall(operands))
            base = kind.replace("-start", "")
            cost.coll_bytes += ob
            gm = _GROUPS.search(attrs)
            if gm and pod_group_size > 1 and int(gm.group(2)) == pod_group_size:
                cost.dcn_bytes += ob
            e = cost.coll_by_kind.setdefault(base, [0, 0])
            e[0] += 1
            e[1] += ob
            cost.bytes += ob + _nbytes(op.shape)
        elif kind == "dynamic-slice":
            cost.bytes += 2 * _nbytes(op.shape)
        elif kind == "dynamic-update-slice":
            names = _OPERAND.findall(operands)
            upd = _nbytes(comp.symbols.get(names[1], "")) if len(names) > 1 else _nbytes(op.shape)
            cost.bytes += 2 * upd
        elif kind == "fusion":
            cm = re.search(r"calls=%([\w.\-]+)", attrs)
            if cm and cm.group(1) in comps:
                cost.add(_comp_cost(comps[cm.group(1)], comps, memo, pod_group_size), 1.0)
        elif kind in _MATERIALIZE:
            cost.bytes += 2 * _nbytes(op.shape)
        elif kind in _SHAPE_ONLY:
            continue
        else:
            # elementwise: assumed fused inside loop bodies; counted in entry
            # (optimizer update / loss tail run at tensor granularity there)
            if comp.is_entry:
                cost.bytes += 2 * _nbytes(op.shape)
    return cost


def analyze_hlo_text(text: str, pod_group_size: int = 1) -> Cost:
    comps, entry = parse_module(text)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c].ops))
    total = Cost()
    total.add(_comp_cost(comps[entry], comps, {}, pod_group_size))
    return total
