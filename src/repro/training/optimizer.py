"""Optimizers (pure-pytree, no external deps): AdamW and Adafactor.

ZeRO-1 style optimizer-state sharding: state pspecs are derived from the
param pspecs by assigning the first unsharded dim of each tensor to the
``data`` axis (GSPMD then emits the reduce-scatter / all-gather pattern of a
sharded optimizer automatically).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import current_mesh

# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def warmup_cosine(base_lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)

    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AdamW:
    lr_fn: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    master_weights: bool = True

    def init(self, params):
        st = {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }
        if self.master_weights:
            # copy=True: when params are already fp32, astype would ALIAS the
            # param buffer and donation would see the same buffer twice
            st["master"] = jax.tree.map(
                lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
            )
        return st

    def update(self, grads, state, params, step):
        lr = self.lr_fn(step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        c1 = 1.0 - self.b1**t
        c2 = 1.0 - self.b2**t

        def upd(g, m, v, ref, pdtype):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            step_ = lr * (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            new_ref = ref - step_ - lr * self.weight_decay * ref
            return new_ref.astype(pdtype), m, v, new_ref

        refs = state["master"] if self.master_weights else jax.tree.map(
            lambda p: p.astype(jnp.float32), params
        )
        out = jax.tree.map(
            lambda g, m, v, r, p: upd(g, m, v, r, p.dtype),
            grads, state["m"], state["v"], refs, params,
        )
        first = lambda o: o[0]
        is_t = lambda x: isinstance(x, tuple)
        new_p = jax.tree.map(first, out, is_leaf=is_t)
        new_state = {
            "m": jax.tree.map(lambda o: o[1], out, is_leaf=is_t),
            "v": jax.tree.map(lambda o: o[2], out, is_leaf=is_t),
        }
        if self.master_weights:
            new_state["master"] = jax.tree.map(lambda o: o[3], out, is_leaf=is_t)
        return new_p, new_state


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; memory ~ O(rows + cols))
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Adafactor:
    lr_fn: Callable
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def _factored(self, shape) -> bool:
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def init(self, params):
        # state is a flat list aligned with tree_flatten(params) order
        def leaf(p):
            if self._factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": [leaf(p) for p in jax.tree.leaves(params)]}

    def update(self, grads, state, params, step):
        lr = self.lr_fn(step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** (-self.decay)

        def upd(g, st, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if "vr" in st:
                vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :] / jnp.maximum(
                        jnp.mean(vr, axis=-1, keepdims=True)[..., None], self.eps
                    )
                )
                u = g / jnp.maximum(denom, self.eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(v)
                new_st = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            pf = p.astype(jnp.float32)
            new_p = pf - lr * u - lr * self.weight_decay * pf
            return new_p.astype(p.dtype), new_st

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree.leaves(grads)
        out = [upd(g, st, p) for g, st, p in zip(flat_g, state["f"], flat_p)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        return new_p, {"f": [o[1] for o in out]}


def make_optimizer(name: str, lr: float = 3e-4, warmup: int = 100, total: int = 10000, **kw):
    sched = warmup_cosine(lr, warmup, total)
    if name == "adamw":
        return AdamW(sched, **kw)
    if name == "adafactor":
        return Adafactor(sched, **kw)
    raise KeyError(name)


# ---------------------------------------------------------------------------
# ZeRO-1 state sharding
# ---------------------------------------------------------------------------


def zero1_pspecs(params, param_pspecs, opt_state):
    """PartitionSpec tree for ``opt_state``: params' specs with the first
    unsharded, large-enough dim additionally moved onto the data axis
    (ZeRO-1 — GSPMD emits the reduce-scatter/all-gather pattern)."""
    mesh = current_mesh()
    data = mesh.shape.get("data", 1) if mesh is not None else 1

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_spec = jax.tree.leaves(param_pspecs, is_leaf=lambda x: isinstance(x, P))

    def widen(spec: P, leaf) -> P:
        used = {a for e in spec if e is not None for a in (e if isinstance(e, tuple) else (e,))}
        new = list(spec) + [None] * (leaf.ndim - len(spec))
        if "data" not in used:
            for i, (dim, s) in enumerate(zip(leaf.shape, new)):
                if s is None and dim >= data and dim % data == 0:
                    new[i] = "data"
                    break
        return P(*new)

    wide = [widen(s, l) for s, l in zip(flat_spec, flat_p)]
    mirror = jax.tree_util.tree_unflatten(treedef, wide)

    out = {}
    for k, v in opt_state.items():
        if k in ("m", "v", "master"):
            out[k] = mirror
        elif k == "f":  # Adafactor flat list
            fl = []
            for spec, leaf in zip(wide, flat_p):
                ax = list(spec) + [None] * (leaf.ndim - len(spec))
                if leaf.ndim >= 2 and leaf.shape[-1] > 1 and leaf.shape[-2] > 1:
                    fl.append({"vr": P(*ax[:-1]), "vc": P(*(ax[:-2] + ax[-1:]))})
                else:
                    fl.append({"v": P(*ax)})
            out[k] = fl
        else:
            out[k] = jax.tree.map(lambda _: P(), v)
    return out
