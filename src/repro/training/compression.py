"""Gradient compression hooks for the cross-pod (DCN) data-parallel axis.

On a real 2-pod mesh the pod-axis gradient all-reduce crosses DCN, which is
an order of magnitude slower than ICI — compressing those reduces is a
standard distributed-optimization trick.  In this framework the hooks are
applied to the gradient pytree inside ``train_step``:

* ``int8``  — per-tensor symmetric int8 quantise -> dequantise with error
  feedback (residual carried in fp32 between steps);
* ``topk``  — keep the top fraction of entries by magnitude, error feedback
  for the rest;
* ``none``  — identity.

The quantise/dequantise round-trip inside the jitted step is the honest
CPU-testable simulation of "reduce the quantised tensor"; on a real mesh the
same hook brackets a ``shard_map``-wrapped ``psum`` over the ``pod`` axis
(wired in launch/train.py when pods > 1).  Quality impact is what matters
for convergence and is fully captured; tests assert the error-feedback
property (compression error does not accumulate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_int8(g, err):
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), gf - deq


def compress_topk(g, err, frac: float = 0.05):
    gf = g.astype(jnp.float32) + err
    flat = gf.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(gf) >= thresh, gf, 0.0)
    return kept.astype(g.dtype), gf - kept


def apply_compression(grads, err_state, kind: str, **kw):
    """-> (compressed grads, new error state)."""
    if kind == "none":
        return grads, err_state
    fn = {"int8": compress_int8, "topk": compress_topk}[kind]
    out = jax.tree.map(lambda g, e: fn(g, e, **kw), grads, err_state)
    is_t = lambda x: isinstance(x, tuple)
    return (
        jax.tree.map(lambda o: o[0], out, is_leaf=is_t),
        jax.tree.map(lambda o: o[1], out, is_leaf=is_t),
    )
