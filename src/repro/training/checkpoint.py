"""Checkpoint manager with consistent-hash shard placement.

Layout on disk:

    <dir>/step_<N>/
        manifest.json      {step, n_nodes, engine, entries: path -> node}
        node_<k>.npz       all leaves placed on storage node k

Placement: leaf-path -> storage node via BinomialHash (u64).  When the
storage fleet is resized, ``plan_resize`` returns exactly the minimal set of
leaves that must move (paper's monotonicity / minimal-disruption guarantees),
which the manager then executes incrementally instead of rewriting the world.

Saves are atomic (tmp dir + rename); ``latest_step`` + ``restore`` implement
crash-consistent resume.  Async saves snapshot to host memory first so the
training loop can continue.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from dataclasses import dataclass

import jax
import numpy as np

from repro.core.binomial import binomial_lookup64
from repro.core import bits


def _leaf_key(path) -> str:
    return jax.tree_util.keystr(path)


def _fnv1a(s: str) -> int:
    """Deterministic 64-bit string hash (python hash() is process-salted)."""
    h = 0xCBF29CE484222325
    for b in s.encode():
        h = ((h ^ b) * 0x100000001B3) & bits.MASK64
    return h


def _place(leaf_key: str, n_nodes: int) -> int:
    return binomial_lookup64(bits.mix64(_fnv1a(leaf_key)), n_nodes)


@dataclass
class CheckpointManager:
    directory: str
    n_nodes: int = 4  # simulated storage nodes

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state) -> str:
        flat, _ = jax.tree_util.tree_flatten_with_path(state)
        host = [(_leaf_key(p), np.asarray(jax.device_get(l))) for p, l in flat]
        return self._write(step, host)

    def save_async(self, step: int, state) -> threading.Thread:
        """Snapshot to host, then write on a background thread."""
        flat, _ = jax.tree_util.tree_flatten_with_path(state)
        host = [(_leaf_key(p), np.asarray(jax.device_get(l))) for p, l in flat]
        t = threading.Thread(target=self._write, args=(step, host), daemon=True)
        t.start()
        return t

    def _write(self, step: int, host_leaves) -> str:
        final = os.path.join(self.directory, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        by_node: dict[int, dict[str, np.ndarray]] = {}
        entries = {}
        for key, arr in host_leaves:
            node = _place(key, self.n_nodes)
            by_node.setdefault(node, {})[key] = arr
            entries[key] = node
        for node, leaves in by_node.items():
            np.savez(os.path.join(tmp, f"node_{node}.npz"), **leaves)
        manifest = {
            "step": step,
            "n_nodes": self.n_nodes,
            "engine": "binomial",
            "entries": entries,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> int | None:
        if not os.path.isdir(self.directory):
            return None
        steps = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d, "manifest.json")):
                    steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, step: int, like):
        """Restore into the structure of ``like`` (a pytree template)."""
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data: dict[str, np.ndarray] = {}
        for node in set(manifest["entries"].values()):
            with np.load(os.path.join(d, f"node_{node}.npz")) as z:
                for k in z.files:
                    data[k] = z[k]
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, tmpl in flat:
            arr = data[_leaf_key(path)]
            leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # -- elastic storage ------------------------------------------------------
    def plan_resize(self, state_like, new_n_nodes: int):
        """Minimal movement plan for a storage-fleet resize."""
        flat, _ = jax.tree_util.tree_flatten_with_path(state_like)
        moves = []
        for path, _ in flat:
            key = _leaf_key(path)
            src = _place(key, self.n_nodes)
            dst = _place(key, new_n_nodes)
            if src != dst:
                moves.append((key, src, dst))
        return moves
