"""Train step: loss + grad + optimizer update, with microbatched gradient
accumulation, global-norm clipping and optional gradient compression."""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.training import compression as C


@dataclass(frozen=True)
class TrainHparams:
    grad_accum: int = 1
    clip_norm: float = 1.0
    compression: str = "none"  # none | int8 | topk


def _clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def make_train_state(params, optimizer, hp: TrainHparams):
    state = {"params": params, "opt": optimizer.init(params), "step": jnp.zeros((), jnp.int32)}
    if hp.compression != "none":
        state["comp_err"] = C.init_error_state(params)
    return state


def make_train_step(cfg: ArchConfig, optimizer, hp: TrainHparams = TrainHparams()):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(M.loss_fn, has_aux=True)(params, batch, cfg)

    def train_step(state, batch):
        params = state["params"]
        if hp.grad_accum > 1:
            # split the global batch into microbatches along dim 0
            def micro(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = grads_of(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + loss), None

            def split(x):
                if x.ndim >= 2 and x.shape[0] == 3:  # mrope positions (3,B,S)
                    b = x.shape[1]
                    return jnp.moveaxis(
                        x.reshape(3, hp.grad_accum, b // hp.grad_accum, *x.shape[2:]), 1, 0
                    )
                return x.reshape(hp.grad_accum, x.shape[0] // hp.grad_accum, *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / hp.grad_accum, grads)
            loss = loss / hp.grad_accum
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = grads_of(params, batch)

        if hp.compression != "none":
            grads, new_err = C.apply_compression(grads, state["comp_err"], hp.compression)
        grads, gn = _clip_by_global_norm(grads, hp.clip_norm)
        new_params, new_opt = optimizer.update(grads, state["opt"], params, state["step"])
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        if hp.compression != "none":
            new_state["comp_err"] = new_err
        metrics = dict(metrics)
        metrics["grad_norm"] = gn
        return new_state, metrics

    return train_step


def make_serve_steps(cfg: ArchConfig, max_len: int):
    """Returns (prefill_fn, decode_fn) with the model closed over cfg."""

    def prefill_fn(params, batch):
        return M.prefill(params, batch, cfg, max_len)

    def decode_fn(params, cache, batch):
        return M.decode_step(params, cache, batch, cfg)

    return prefill_fn, decode_fn
