"""Memento-style wrapper: arbitrary (non-LIFO) node removal on top of any
LIFO consistent-hash engine.

The BinomialHash paper (§1, §7) notes that all constant-time LIFO algorithms
"can be extended to handle arbitrary node removals and random failures by
leveraging the procedure described in MementoHash".  This module implements
that composition as a *rejection-chaining reconstruction*:

* the base engine addresses the full slot space ``[0, n_total)``;
* a removed/failed slot ``b`` is recorded in an O(#removed) set;
* lookups that land on a removed slot are re-hashed (seeded by the slot id,
  so the chain is deterministic per key) until they hit an alive slot.

Properties (verified by tests):
* balance      — keys of removed slots scatter uniformly over alive slots;
* minimal disruption — removing slot b moves only keys chained through b;
* recovery monotonicity — when b comes back, exactly the keys that chained
  away from b return to it, nobody else moves.

Memory is O(#removed); expected lookup cost is O(n_total / n_alive) extra
hashes, i.e. O(1) while failures are a bounded fraction of the fleet.

The rejection chain comes in two word sizes:

* ``chain_bits=64`` (default) — splitmix64 chain, paper-faithful host flavour;
* ``chain_bits=32`` — murmur3 fmix32 chain on ``key & MASK32``; the bit-exact
  scalar oracle for the vectorised device remap in ``repro.core.memento_jax``
  (TPUs have no 64-bit integer datapath).  Pair it with a u32 base engine
  (``binomial32``) so the whole lookup+remap path shares one word size.

Failure *resolution* also comes in two flavours (``resolve=``):

* ``"chain"`` (default) — the rejection walk above: expected O(1) per key
  but *data-dependent*; a batched device implementation pays
  O(log batch / log(1/f)) full-batch rounds at removed-fraction f.
* ``"table"`` — MementoHash-style replacement table (DESIGN.md §7): a
  permutation of the slot space with an alive prefix (``ReplacementTable``),
  updated O(1) per fleet event, resolving any removed slot in AT MOST TWO
  u32-hash table redirects.  Storm-time lookup cost is a hard constant, so
  the batched device path stays flat under failures.  This is the semantics
  of the serving datapath (``repro.serving.batch_router.BatchRouter``) and
  its scalar oracle.
"""
from __future__ import annotations

from repro.core import bits


class ReplacementTable:
    """Permutation of the slot space ``[0, n_total)`` with an alive prefix.

    Invariants (maintained O(1) per event by swap):
    * ``slots`` is a permutation of ``[0, n_total)``; ``pos`` is its inverse;
    * ``slots[0:n_alive]`` are exactly the alive slots;
    * ``slots[n_alive:]`` are exactly the removed slots.

    Lookup for a key whose base bucket ``b`` is removed (``resolve``):

    1. ``q = mulhi32(hash_pair(key, b), n_total)`` — the Lemire
       reduction maps the u32 hash uniformly onto the position space
       (mul+shift only: no integer divide, which the TPU VPU lacks and
       which costs ~10x these ops with a vector divisor on XLA:CPU).  If
       ``q < n_alive`` the redirect lands alive and we are done
       (probability ``n_alive / n_total``).
    2. otherwise ONE more redirect, ``q = mulhi32(mix32(h ^ q*GOLDEN32),
       n_alive)`` — uniform over the alive prefix, alive by construction.
       It chains off the first hash ``h`` and is seeded by the *position*
       q, so no extra mixing of the key is spent on the deep round: one
       fmix32 over the already-avalanched ``h`` suffices.

    One ``slots`` gather, two u32 hashes, zero data-dependent iteration:
    the device kernels implement the identical math on an uploaded copy of
    ``slots`` (see ``repro.core.memento_jax``), so storm-time cost matches
    steady-time cost.  Redirect 1's range is ``n_total`` — a *scalar*
    frozen across fail/recover events (only scale events change it) — so a
    failure or recovery re-aims only the redirected keys whose picked
    position was one of the (at most two) positions the event swapped,
    plus the second-order deep rounds: approximately minimal disruption,
    like the rejection chain, without its data-dependent walk and without
    a per-lane ``pos`` gather on the hot path.
    """

    def __init__(self, n: int):
        self.slots = list(range(n))
        self.pos = list(range(n))
        self.n_alive = n

    @property
    def n_total(self) -> int:
        return len(self.slots)

    def _swap(self, i: int, j: int) -> None:
        si, sj = self.slots[i], self.slots[j]
        self.slots[i], self.slots[j] = sj, si
        self.pos[si], self.pos[sj] = j, i

    def fail(self, b: int) -> None:
        """Alive slot b fails: swap it to the alive/removed boundary."""
        if self.pos[b] >= self.n_alive:
            raise ValueError(f"slot {b} is not alive")
        self._swap(self.pos[b], self.n_alive - 1)
        self.n_alive -= 1

    def recover(self, b: int) -> None:
        """Removed slot b recovers: swap it back into the alive prefix."""
        if self.pos[b] < self.n_alive:
            raise ValueError(f"slot {b} is not removed")
        self._swap(self.pos[b], self.n_alive)
        self.n_alive += 1

    def append(self) -> int:
        """LIFO scale-up: new slot id ``n_total`` joins the alive prefix."""
        t = len(self.slots)
        self.slots.append(t)
        self.pos.append(t)
        self._swap(t, self.n_alive)
        self.n_alive += 1
        return t

    def pop_last(self) -> int:
        """LIFO scale-down: slot id ``n_total - 1`` (alive or a tombstone)
        leaves the slot space entirely."""
        t = len(self.slots) - 1
        if self.pos[t] < self.n_alive:  # alive: retire via the boundary
            self._swap(self.pos[t], self.n_alive - 1)
            self.n_alive -= 1
        self._swap(self.pos[t], t)  # park at the last position, then drop
        self.slots.pop()
        self.pos.pop()
        return t

    def resolve(self, key: int, b: int) -> int:
        """Divert ``key`` off removed slot ``b`` — at most two redirects.

        ``key`` is masked to u32; the hashes are the same murmur3 fmix32
        pair/iter mixers as the device kernels (bit-exact by construction).
        """
        key &= bits.MASK32
        h = bits.hash_pair32(key, b)
        q = bits.mulhi32(h, self.n_total)
        if q >= self.n_alive:
            # chain the second hash off the first — h is already avalanched,
            # so one fmix32 over h xor the golden-scaled position suffices
            q = bits.mulhi32(
                bits.mix32((h ^ ((q * bits.GOLDEN32) & bits.MASK32)) & bits.MASK32),
                self.n_alive,
            )
        return self.slots[q]


class MementoWrapper:
    name = "memento"
    exact = False  # reconstruction of the published description

    def __init__(
        self,
        base_factory,
        n: int,
        max_chain: int = 4096,
        chain_bits: int = 64,
        resolve: str = "chain",
        allow_empty: bool = False,
    ):
        """``base_factory(n) -> engine`` builds the underlying LIFO engine.

        ``resolve="chain"`` walks the rejection chain (paper-faithful);
        ``resolve="table"`` resolves removed slots through the
        ``ReplacementTable`` in at most two redirects (the serving-datapath
        semantics; ``max_chain`` is then irrelevant to lookups).

        ``allow_empty=True`` lets the LAST alive bucket fail too (the slot
        space never shrinks below one slot — the removal is tombstoned, so
        recovery works): an all-failed fleet is then a queryable *state*
        (``size == 0``; lookups raise) instead of a forbidden transition.
        The serving tier uses this to answer routes on an all-failed fleet
        with a typed ``FleetUnavailableError`` rather than refusing the
        failure event itself, which no real outage asks permission for.
        """
        if chain_bits not in (32, 64):
            raise ValueError(f"chain_bits must be 32 or 64, got {chain_bits}")
        if resolve not in ("chain", "table"):
            raise ValueError(f"resolve must be 'chain' or 'table', got {resolve!r}")
        self._base_factory = base_factory
        self.base = base_factory(n)
        self.removed: set[int] = set()
        self.max_chain = max_chain
        self.chain_bits = chain_bits
        self.resolve = resolve
        self.allow_empty = allow_empty
        self.table = ReplacementTable(n) if resolve == "table" else None

    # -- size/state ---------------------------------------------------------
    @property
    def n_total(self) -> int:
        return self.base.size

    @property
    def size(self) -> int:
        return self.base.size - len(self.removed)

    def alive(self) -> list[int]:
        return [b for b in range(self.n_total) if b not in self.removed]

    # -- membership ---------------------------------------------------------
    def add_bucket(self) -> int:
        """LIFO append of a brand-new slot (scale-up)."""
        out = self.base.add_bucket()
        if self.table is not None:
            self.table.append()
        return out

    def remove_bucket(self, b: int | None = None) -> int:
        """Remove an arbitrary bucket (failure) or the last one (LIFO)."""
        if self.size <= 1:
            if not self.allow_empty:
                raise ValueError("cannot remove the last alive bucket")
            if self.size == 0:
                raise ValueError("no alive buckets left to remove")
            # the last alive bucket fails: tombstone it (even when it is the
            # last slot id — a LIFO shrink here would empty the slot space,
            # and the fixed-capacity device operands need n_total >= 1)
            last = self.n_total - 1 if b is None else b
            if last in self.removed or not (0 <= last < self.n_total):
                raise ValueError(f"bucket {last} is not alive")
            self.removed.add(last)
            if self.table is not None:
                self.table.fail(last)
            return last
        if b is None or b == self.n_total - 1:
            # true LIFO removal — shrink the base engine; also garbage-collect
            # any tombstones that fall off the end.
            out = self.base.remove_bucket()
            self.removed.discard(out)
            if self.table is not None:
                self.table.pop_last()
            while self.n_total - 1 in self.removed and self.n_total > 1:
                self.removed.discard(self.n_total - 1)
                self.base.remove_bucket()
                if self.table is not None:
                    self.table.pop_last()
            return out
        if b in self.removed or not (0 <= b < self.n_total):
            raise ValueError(f"bucket {b} is not alive")
        self.removed.add(b)
        if self.table is not None:
            self.table.fail(b)
        return b

    def restore_bucket(self, b: int) -> None:
        """A failed node recovered."""
        if b not in self.removed:
            raise ValueError(f"bucket {b} is not removed")
        self.removed.discard(b)
        if self.table is not None:
            self.table.recover(b)

    # -- lookup -------------------------------------------------------------
    def _chain_step(self, key: int, b: int, i: int, total: int) -> int:
        """Deterministic chain seeded by (key, failed slot, attempt)."""
        if self.chain_bits == 64:
            return bits.hash_pair64(bits.hash_iter64(key, i + 1), b) % total
        return bits.hash_pair32(bits.hash_iter32(key & bits.MASK32, i + 1), b) % total

    def first_alive(self) -> int:
        """Lowest alive slot id (the max_chain-overflow fallback target)."""
        for b in range(self.n_total):
            if b not in self.removed:
                return b
        raise ValueError("no alive buckets")

    def get_bucket(self, key: int) -> int:
        if not self.size:
            # every bucket is a tombstone (allow_empty fleets only): there
            # is no alive target — the serving layer turns this into a
            # typed FleetUnavailableError before any lookup gets here
            raise ValueError("no alive buckets")
        b = self.base.get_bucket(key)
        if b not in self.removed:
            return b
        if self.table is not None:
            return self.table.resolve(key, b)
        total = self.n_total
        for i in range(self.max_chain):
            b = self._chain_step(key, b, i, total)
            if b not in self.removed:
                return b
        # unreachable for any sane failure fraction; fall back to first alive
        return self.first_alive()
