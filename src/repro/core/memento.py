"""Memento-style wrapper: arbitrary (non-LIFO) node removal on top of any
LIFO consistent-hash engine.

The BinomialHash paper (§1, §7) notes that all constant-time LIFO algorithms
"can be extended to handle arbitrary node removals and random failures by
leveraging the procedure described in MementoHash".  This module implements
that composition as a *rejection-chaining reconstruction*:

* the base engine addresses the full slot space ``[0, n_total)``;
* a removed/failed slot ``b`` is recorded in an O(#removed) set;
* lookups that land on a removed slot are re-hashed (seeded by the slot id,
  so the chain is deterministic per key) until they hit an alive slot.

Properties (verified by tests):
* balance      — keys of removed slots scatter uniformly over alive slots;
* minimal disruption — removing slot b moves only keys chained through b;
* recovery monotonicity — when b comes back, exactly the keys that chained
  away from b return to it, nobody else moves.

Memory is O(#removed); expected lookup cost is O(n_total / n_alive) extra
hashes, i.e. O(1) while failures are a bounded fraction of the fleet.

The rejection chain comes in two word sizes:

* ``chain_bits=64`` (default) — splitmix64 chain, paper-faithful host flavour;
* ``chain_bits=32`` — murmur3 fmix32 chain on ``key & MASK32``; the bit-exact
  scalar oracle for the vectorised device remap in ``repro.core.memento_jax``
  (TPUs have no 64-bit integer datapath).  Pair it with a u32 base engine
  (``binomial32``) so the whole lookup+remap path shares one word size.
"""
from __future__ import annotations

from repro.core import bits


class MementoWrapper:
    name = "memento"
    exact = False  # reconstruction of the published description

    def __init__(self, base_factory, n: int, max_chain: int = 4096, chain_bits: int = 64):
        """``base_factory(n) -> engine`` builds the underlying LIFO engine."""
        if chain_bits not in (32, 64):
            raise ValueError(f"chain_bits must be 32 or 64, got {chain_bits}")
        self._base_factory = base_factory
        self.base = base_factory(n)
        self.removed: set[int] = set()
        self.max_chain = max_chain
        self.chain_bits = chain_bits

    # -- size/state ---------------------------------------------------------
    @property
    def n_total(self) -> int:
        return self.base.size

    @property
    def size(self) -> int:
        return self.base.size - len(self.removed)

    def alive(self) -> list[int]:
        return [b for b in range(self.n_total) if b not in self.removed]

    # -- membership ---------------------------------------------------------
    def add_bucket(self) -> int:
        """LIFO append of a brand-new slot (scale-up)."""
        return self.base.add_bucket()

    def remove_bucket(self, b: int | None = None) -> int:
        """Remove an arbitrary bucket (failure) or the last one (LIFO)."""
        if self.size <= 1:
            raise ValueError("cannot remove the last alive bucket")
        if b is None or b == self.n_total - 1:
            # true LIFO removal — shrink the base engine; also garbage-collect
            # any tombstones that fall off the end.
            out = self.base.remove_bucket()
            self.removed.discard(out)
            while self.n_total - 1 in self.removed and self.n_total > 1:
                self.removed.discard(self.n_total - 1)
                self.base.remove_bucket()
            return out
        if b in self.removed or not (0 <= b < self.n_total):
            raise ValueError(f"bucket {b} is not alive")
        self.removed.add(b)
        return b

    def restore_bucket(self, b: int) -> None:
        """A failed node recovered."""
        if b not in self.removed:
            raise ValueError(f"bucket {b} is not removed")
        self.removed.discard(b)

    # -- lookup -------------------------------------------------------------
    def _chain_step(self, key: int, b: int, i: int, total: int) -> int:
        """Deterministic chain seeded by (key, failed slot, attempt)."""
        if self.chain_bits == 64:
            return bits.hash_pair64(bits.hash_iter64(key, i + 1), b) % total
        return bits.hash_pair32(bits.hash_iter32(key & bits.MASK32, i + 1), b) % total

    def first_alive(self) -> int:
        """Lowest alive slot id (the max_chain-overflow fallback target)."""
        for b in range(self.n_total):
            if b not in self.removed:
                return b
        raise ValueError("no alive buckets")

    def get_bucket(self, key: int) -> int:
        b = self.base.get_bucket(key)
        if b not in self.removed:
            return b
        total = self.n_total
        for i in range(self.max_chain):
            b = self._chain_step(key, b, i, total)
            if b not in self.removed:
                return b
        # unreachable for any sane failure fraction; fall back to first alive
        return self.first_alive()
