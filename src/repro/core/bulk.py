"""Engine-agnostic bulk-routing API: ``RouterSpec``, ``FleetState``,
``BulkEngine`` (DESIGN.md §10).

The device datapath (fused lookup + replacement-table divert, one dispatch
per batch) is algorithm-agnostic: any consistent-hash engine whose lookup
loop is bounded and vectorizable can ride the same machinery.  This module
defines the three pieces the datapath is parameterised over:

* ``RouterSpec`` — the frozen configuration bundle that used to travel as
  six copy-pasted kwargs through every entry point (capacity, ω, kernel
  selection, tiling, shard axis, donation).  Hashable, so specs can key
  caches; validated at construction, so bad configs fail loudly instead of
  deep inside a trace.
* ``FleetState`` — the device-operand pytree of the fleet (packed
  removed-slot bit-words, replacement-table slots permutation, the
  ``[n_total, n_alive]`` 2-vector) with the pack / incremental-update hooks
  the serving tier drives at fleet-event time.  Registered as a jax pytree,
  so a whole ``FleetState`` passes through ``jit`` / ``shard_map`` /
  ``device_put`` as one value.
* ``BulkEngine`` — the per-engine bundle: the name of the bit-exact scalar
  oracle (an ``ENGINES`` entry — the control-plane truth the device path is
  tested against), the pure-jnp fused ``route``/``ingest`` mirrors, the
  optional Pallas kernels, and the plain bulk-lookup flavours the two-pass
  baseline and the MoE hash router consume.  Engines register in
  ``repro.core.registry.BULK_ENGINES``.

``repro.kernels.ops`` dispatches over a spec + fleet state; porting a new
engine means writing one unrolled jnp lookup body and registering the
bundle (see DESIGN.md §10 for the recipe).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.core.memento_jax import mask_words, pack_removed_mask, pack_table

#: default block tiling of the fused kernels (rows of 128 lanes per grid
#: step) — the one definition; ``repro.kernels.autotune`` re-exports it
DEFAULT_BLOCK_ROWS = 512

#: engines that step through f32 arithmetic (jump) need b+1 exact in a
#: float32 mantissa, so the slot space is bounded well below u32
MAX_CAPACITY = 1 << 24


@dataclasses.dataclass(frozen=True)
class RouterSpec:
    """Frozen configuration of one bulk-routing datapath.

    engine       BULK_ENGINES name selecting the device datapath (and its
                 scalar control-plane oracle)
    capacity     power-of-two bound on the fleet slot space — sizes the
                 packed mask words and replacement-table lanes (which tile
                 evenly only at pow2), fixed across arbitrary event streams
    omega        lookup iteration bound (binomial's ω; the jump engine's
                 unroll depth) — shared by oracle and kernel so scalar ==
                 batch holds at non-default values too
    use_pallas   None = auto (Pallas on TPU backends only); True/False force
    interpret    run the Pallas kernel in interpreter mode (CPU test rig)
    block_rows   kernel tiling in rows of 128 lanes; None = default /
                 autotune (``BatchRouter`` engages the measure-once tuner)
    shard_axis   mesh axis the sharded datapath splits key batches over
    donate_keys  donate uploaded key buffers to the sharded executable
    """

    engine: str = "binomial"
    capacity: int = 64
    omega: int = 16
    use_pallas: bool | None = None
    interpret: bool = False
    block_rows: int | None = None
    shard_axis: str = "data"
    donate_keys: bool = False

    def __post_init__(self):
        if self.capacity < 1 or self.capacity & (self.capacity - 1):
            raise ValueError(
                f"capacity must be a power of two (got {self.capacity}); the "
                "packed mask words and table lanes tile evenly only at pow2 "
                "capacities"
            )
        if self.capacity > MAX_CAPACITY:
            raise ValueError(
                f"capacity {self.capacity} exceeds {MAX_CAPACITY}; f32-stepping "
                "engines (jump) need slot ids exact in a float32 mantissa"
            )
        if self.omega < 1:
            raise ValueError(f"omega must be >= 1, got {self.omega}")
        if self.block_rows is not None and self.block_rows < 1:
            raise ValueError(
                f"block_rows must be >= 1, got {self.block_rows}; pass None "
                "for the default / autotune"
            )

    # -- derived static extents (the fused kernels' select-cascade bounds) --
    @property
    def n_words(self) -> int:
        """Static packed-mask word count: ceil(capacity / 32)."""
        return mask_words(self.capacity)

    @property
    def n_slots(self) -> int:
        """Static replacement-table slot count (= capacity)."""
        return self.capacity

    def resolved_block_rows(self) -> int:
        """Concrete tiling for the raw kernel entry points (None -> default;
        ``BatchRouter`` resolves None through the autotuner instead)."""
        return DEFAULT_BLOCK_ROWS if self.block_rows is None else self.block_rows

    def pallas_selected(self) -> bool:
        """Whether this spec dispatches to the Pallas kernel (auto = TPU)."""
        if self.use_pallas is None:
            return jax.default_backend() == "tpu"
        return self.use_pallas


@dataclasses.dataclass
class FleetState:
    """The traced device operands of one fleet — a registered jax pytree.

    packed    (1, W) uint32 removed-slot bit-words (bit b = slot b removed)
    table     (1, C) int32 replacement-table ``slots`` permutation
    state     (2,)   uint32 ``[n_total, n_alive]``
    capacity  the slot-space bound the arrays were packed for (pytree aux
              data, not a leaf; 0 = derive from the padded table width)

    Shapes are fixed by the spec's ``capacity`` across arbitrary fleet-event
    streams — that is what keeps the compiled datapath retrace-free.  The
    host-side instance (numpy arrays, built by ``pack``) is the mutable
    mirror the event hooks update; ``device_put`` pins a device twin in ONE
    transfer, re-done at event time only, never per batch.
    """

    packed: Any
    table: Any
    state: Any
    capacity: int = 0

    def __post_init__(self):
        if not self.capacity:
            # manual construction (e.g. the deprecation shims): the padded
            # table width bounds the slot space, which is all packing needs.
            # Leaves without a (1, C) shape (PartitionSpec trees, tracing
            # placeholders) keep capacity 0 — they never pack.
            shape = getattr(self.table, "shape", None)
            if shape is not None and len(shape) == 2:
                self.capacity = int(shape[1])

    @classmethod
    def pack(cls, domain, capacity: int) -> "FleetState":
        """Host-side pack of a ``FailureDomain`` (table resolution) truth."""
        return cls(
            packed=pack_removed_mask(domain.removed, capacity),
            table=pack_table(domain.replacement_table, capacity),
            state=np.array(
                [domain.total_count, domain.alive_count], dtype=np.uint32
            ),
            capacity=capacity,
        )

    # -- incremental event-time hooks (host mirror only) --------------------
    def set_removed(self, replica: int, removed: bool) -> None:
        """Flip one mask bit — the fail/recover incremental update."""
        word, bit = replica >> 5, np.uint32(1) << np.uint32(replica & 31)
        if removed:
            self.packed[0, word] |= bit
        else:
            self.packed[0, word] &= ~bit

    def update(self, domain) -> None:
        """Re-pack table + state from the domain (the permutation swapped
        O(1) entries; the counters may have moved).  Mask bits are flipped
        separately by ``set_removed`` — scale-down GC goes through
        ``resync`` instead."""
        self.table = pack_table(domain.replacement_table, self.capacity)
        self.state = np.array(
            [domain.total_count, domain.alive_count], dtype=np.uint32
        )

    def resync(self, domain) -> None:
        """Wholesale rebuild (scale-down may garbage-collect tombstones off
        the end of the slot space, clearing mask bits non-incrementally)."""
        self.packed = pack_removed_mask(domain.removed, self.capacity)
        self.update(domain)

    def device_put(self, sharding=None) -> "FleetState":
        """Pin a device twin — ONE ``jax.device_put`` for the whole pytree."""
        if sharding is None:
            return jax.device_put(self)
        return jax.device_put(self, sharding)


# capacity is deliberately NOT treedef metadata: it only parameterises the
# host-side pack/update hooks, and two FleetStates over the same arrays must
# be the same pytree structure (shard_map prefix-matches in_specs by treedef)
jax.tree_util.register_pytree_node(
    FleetState,
    lambda f: ((f.packed, f.table, f.state), None),
    lambda _, children: FleetState(*children),
)


@dataclasses.dataclass(frozen=True)
class PlacementSpec:
    """Frozen configuration of one R-way replicated placement tier.

    router      the ``RouterSpec`` of the underlying bulk engine — every
                replica column routes through the same fused datapath
    r           replication factor: each key is placed on ``r`` distinct
                alive shards (degrading to ``n_alive`` distinct copies when
                the fleet is smaller than ``r``)
    max_resalt  bound on the deterministic collision-resolution probes per
                replica column; ``None`` (the default) resolves to ``r``,
                which guarantees distinctness whenever ``n_alive > column``
                (column ``j`` probes ``j+1 <= r`` alive-prefix positions, at
                most ``j`` of which are taken).  Smaller explicit bounds are
                allowed for experiments — exhaustion then surfaces as a
                typed ``PlacementExhaustedError``, never a silent duplicate.

    Hashable (it keys jit caches); validated at construction.
    """

    router: RouterSpec = dataclasses.field(default_factory=RouterSpec)
    r: int = 3
    max_resalt: int | None = None

    def __post_init__(self):
        if self.r < 1:
            raise ValueError(f"replication factor r must be >= 1, got {self.r}")
        if self.r > self.router.capacity:
            raise ValueError(
                f"replication factor r ({self.r}) exceeds the fleet capacity "
                f"({self.router.capacity}); r distinct shards cannot exist"
            )
        if self.max_resalt is not None and self.max_resalt < 0:
            raise ValueError(
                f"max_resalt must be >= 0, got {self.max_resalt}; pass None "
                "for the distinctness-guaranteeing default"
            )

    @property
    def resolved_max_resalt(self) -> int:
        """Concrete probe bound: column ``j`` needs ``j+1`` probes in the
        worst case (``j`` earlier replicas occupy ``j`` alive-prefix
        positions), so ``r`` probes make distinctness deterministic for
        every column whenever ``n_alive > j``."""
        return self.r if self.max_resalt is None else self.max_resalt


@dataclasses.dataclass(frozen=True)
class BulkEngine:
    """One pluggable device routing engine (DESIGN.md §10).

    scalar_engine     ``ENGINES`` name of the bit-exact scalar oracle (a u32
                      flavour — the device word size); the serving control
                      plane embeds it via ``SessionRouter`` and tests pin
                      device == scalar key-for-key
    route             pure-jnp fused lookup+divert mirror:
                      ``(keys, packed, table, state, omega=, *, n_words=)``
    ingest            fused u64-id ingest mirror (u32 halves); None if the
                      engine has no in-kernel session-key mix
    route_pallas /    the Pallas kernel twins (same operand contract as the
    ingest_pallas     binomial flavours); None falls back to the jnp mirror
                      even when Pallas is selected
    lookup_dyn        traced-n bulk lookup ``(keys, n, omega=)`` — the
                      two-pass baseline's first dispatch and the eager MoE
                      hash router
    lookup_dyn_pallas scalar-prefetch Pallas twin of ``lookup_dyn``
    lookup_vec        static-n bulk lookup (constant-folded masks; the
                      jitted-model MoE router)
    """

    name: str
    scalar_engine: str
    route: Callable
    ingest: Callable | None = None
    route_pallas: Callable | None = None
    ingest_pallas: Callable | None = None
    lookup_dyn: Callable | None = None
    lookup_dyn_pallas: Callable | None = None
    lookup_vec: Callable | None = None
