"""Vectorised Memento-style failure resolution — the device half of the
serving datapath.

Two device-side resolutions of keys that land on removed slots, mirroring
the two host flavours in ``repro.core.memento``:

* **table** (``resolve="table"`` — the serving datapath, DESIGN.md §7):
  the ``ReplacementTable`` slots permutation rides on device as a
  ``(1, C)`` i32 array, uploaded at fleet-event time.  A removed bucket is
  resolved by at most two u32 hash rounds and EXACTLY ONE table gather —
  no data-dependent loop, so storm-time batch cost equals steady-time cost.
  ``binomial_memento_route`` fuses base lookup + table divert under one jit
  (the pure-jnp mirror of the fused Pallas kernel);
  ``memento_remap_table`` is the second dispatch of the two-pass baseline.

* **chain** (``resolve="chain"`` — paper-faithful library flavour):
  ``memento_remap`` applies the deterministic rejection chain to a whole
  batch of buckets via a ``lax.while_loop`` over the batch.  Each round is
  one gather + one mix over all lanes; the loop exits when every lane has
  settled, so the expected cost is O(n_total / n_alive) rounds — but the
  number of rounds is data-dependent (max over the batch), which is exactly
  the storm-time cliff the table flavour removes.  Bit-exact against
  ``MementoWrapper(chain_bits=32)`` (tests enforce).

Both keep every fleet-state operand fixed-shape and traced (``capacity`` is
a static upper bound on the fleet size), so the compiled executables are
invariant across arbitrary scale/fail/recover event streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.markers import constant_time_waiver
from repro.core.binomial_jax import (
    GOLDEN32,
    _unrolled_body,
    hash_iter,
    hash_pair,
    mix32,
    mix64_lo32,
    mulhi32,
    next_pow2_u32,
)

#: lanes the packed removed-mask is padded to — one native TPU VREG row, so
#: the fused kernel can take it as a whole-block VMEM operand without layout
#: surprises (capacity/32 words of real payload, zero-padded to a multiple).
MASK_LANES = 128


def mask_words(capacity: int) -> int:
    """Number of u32 bit-words holding a ``capacity``-slot removed mask."""
    return max(1, -(-capacity // 32))


def pack_removed_mask(removed, capacity: int, lanes: int = MASK_LANES) -> np.ndarray:
    """Removed-slot ids -> ``(1, W)`` uint32 bit-words (bit b = slot b removed).

    ``W`` is ``mask_words(capacity)`` rounded up to a multiple of ``lanes``;
    the padding words are zero (never-removed).  This is the host-side mirror
    of the fused kernel's VMEM mask operand: O(capacity/32) words, shape
    fixed across arbitrary fleet-event streams.
    """
    words = -(-mask_words(capacity) // lanes) * lanes
    packed = np.zeros((1, words), dtype=np.uint32)
    for b in removed:
        if not 0 <= b < capacity:
            raise ValueError(f"removed slot {b} outside capacity {capacity}")
        packed[0, b >> 5] |= np.uint32(1) << np.uint32(b & 31)
    return packed


def table_width(capacity: int, lanes: int = MASK_LANES) -> int:
    """Lane-padded width of the device replacement table for ``capacity``."""
    return -(-capacity // lanes) * lanes


def pack_table(table, capacity: int, lanes: int = MASK_LANES) -> np.ndarray:
    """``ReplacementTable`` -> ``(1, C)`` int32 device operand.

    The ``slots`` permutation (alive prefix first); ``pos`` stays host-side
    (it exists to make the event-time swaps O(1), the device lookup never
    reads it).  ``C`` is ``capacity`` rounded up to a multiple of ``lanes``
    so the operand is a whole native VMEM block; the padding entries are
    never gathered (every index is < n_total <= capacity).  Shape is fixed
    across arbitrary fleet-event streams — this is the host-side mirror the
    incremental event-time uploads re-pin.
    """
    n = table.n_total
    if n > capacity:
        raise ValueError(f"table spans {n} slots, exceeding capacity {capacity}")
    packed = np.zeros((1, table_width(capacity, lanes)), dtype=np.int32)
    packed[0, :n] = table.slots
    return packed


@functools.partial(jax.jit, static_argnames=("max_chain",))
@constant_time_waiver(
    "paper-faithful chain-mode baseline: the Memento rejection walk is a "
    "lax.while_loop by design, bounded by the static max_chain operand; "
    "serving datapaths use the while-free table-mode engines instead"
)
def memento_remap(
    keys: jax.Array,
    buckets: jax.Array,
    removed_mask: jax.Array,
    n_total: jax.Array,
    first_alive: jax.Array,
    max_chain: int = 4096,
) -> jax.Array:
    """Divert buckets that landed on removed slots onto alive ones.

    keys         any int shape S (uint32 key space)
    buckets      shape S, base-engine buckets in [0, n_total)
    removed_mask (capacity,) bool, capacity >= n_total (fixed across events)
    n_total      traced uint32 scalar — total slot space of the base engine
    first_alive  traced uint32 scalar — fallback after max_chain rejections
    """
    shape = buckets.shape
    keys_u32 = keys.reshape(-1).astype(jnp.uint32)
    b = buckets.reshape(-1).astype(jnp.uint32)
    total = jnp.asarray(n_total, jnp.uint32)
    active = removed_mask[b]

    def cond(state):
        i, b, active = state
        return (i < np.uint32(max_chain)) & jnp.any(active)

    def body(state):
        i, b, active = state
        nb = hash_pair(hash_iter(keys_u32, i + np.uint32(1)), b) % total
        b = jnp.where(active, nb, b)
        return i + np.uint32(1), b, active & removed_mask[b]

    _, b, active = jax.lax.while_loop(cond, body, (jnp.uint32(0), b, active))
    # lanes that exhausted the chain fall back to the first alive slot,
    # mirroring MementoWrapper.first_alive().
    b = jnp.where(active, jnp.asarray(first_alive, jnp.uint32), b)
    return b.astype(jnp.int32).reshape(shape)


# ---------------------------------------------------------------------------
# table-based resolution: storm-time cost == steady-time cost.
# ---------------------------------------------------------------------------


def _table_divert(
    keys_u32: jax.Array,
    b: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    n_words: int,
) -> jax.Array:
    """Divert buckets off removed slots — EXACTLY ONE gather, no loop.

    Mirrors ``ReplacementTable.resolve`` lane-wise (DESIGN.md §7):

    1. ``q = mulhi32(h, n_total)`` with ``h = hash_pair(key, b)`` —
       Lemire reduction to a position in the permutation; alive iff
       ``q < n_alive`` (probability n_alive / n_total);
    2. else ``q = mulhi32(mix32(h ^ q*GOLDEN32), n_alive)`` — a position in
       the alive prefix, alive by construction (chained off ``h`` and seeded
       by the *position*, so no gather is needed between the rounds; ``h``
       is already avalanched, so one fmix32 replaces a full pair-mix and
       keeps the storm divert ~20% cheaper per lane).

    Membership is a select cascade over the ``n_words`` packed mask words —
    pure elementwise ops that fuse into the hash pass, unlike a per-lane
    LUT gather.  The whole divert is therefore one fused elementwise pass +
    one final ``slots`` gather per lane — data-independent and one memory
    pass short of the two-gather variant, which is what keeps an event
    storm off the batch critical path on memory-bound hosts.
    """
    total = state[0].astype(jnp.uint32)
    n_alive = state[1].astype(jnp.uint32)
    slots = table[0].astype(jnp.uint32)
    words = packed_mask.reshape(-1)
    w = b >> np.uint32(5)
    word = jnp.zeros_like(b)
    for s in range(n_words):
        word = jnp.where(w == np.uint32(s), words[s], word)
    hit = ((word >> (b & np.uint32(31))) & np.uint32(1)) != 0
    h = hash_pair(keys_u32, b)
    q = mulhi32(h, total)
    deep = q >= n_alive  # a removed position: one more redirect settles it
    # second hash chains off the first (h is avalanched; one fmix32 over q)
    q = jnp.where(deep, mulhi32(mix32(h ^ (q * GOLDEN32)), n_alive), q)
    # q is in-bounds by construction (q < n_total <= C) — promise_in_bounds
    # skips XLA's clamp logic (~30% cheaper gathers on XLA:CPU at 1M lanes)
    return jnp.where(hit, slots.at[q].get(mode="promise_in_bounds"), b)


def _binomial_lookup_body(keys_u32: jax.Array, total: jax.Array, omega: int) -> jax.Array:
    """The BinomialHash base-lookup body of the fused route: u32 keys +
    traced n -> u32 buckets (n <= 1 collapses to bucket 0)."""
    E = next_pow2_u32(total)
    M = E >> 1
    b = _unrolled_body(keys_u32, E, M, total, omega)
    return jnp.where(total <= np.uint32(1), np.uint32(0), b)


def fused_route_impl(
    keys: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    omega: int,
    n_words: int,
    lookup=_binomial_lookup_body,
) -> jax.Array:
    """Traceable fused lookup + table-divert body, generic over the base
    engine: ``lookup(keys_u32, n_total, omega) -> u32 buckets`` is the only
    engine-specific piece (DESIGN.md §10); the replacement-table divert is
    engine-agnostic.  Shared by the jit'd jnp mirrors (CPU/GPU fallback of
    every ``BULK_ENGINES`` entry) and the unjitted test oracles in
    ``repro.kernels.ref``.

    keys         any int shape S (uint32 key space)
    packed_mask  (1, W) uint32 bit-words — bit b set iff slot b removed
    table        (1, C) int32 — the slots permutation (``pack_table``)
    state        (2,) uint32 — [n_total, n_alive]
    n_words      static mask word count (= ceil(capacity/32)), bounding the
                 membership select cascade
    """
    shape = keys.shape
    keys_u32 = keys.reshape(-1).astype(jnp.uint32)
    total = state[0].astype(jnp.uint32)
    n_alive = state[1].astype(jnp.uint32)
    b = lookup(keys_u32, total, omega)

    # Healthy-fleet fast path: one scalar compare skips the divert entirely,
    # so the steady-state fused cost degenerates to the base lookup alone.
    # The cond boundary also keeps the ω-unrolled producer of ``b`` at
    # exactly ONE consumer — XLA:CPU's fusion pass happily duplicates the
    # ~850-op producer into each additional elementwise consumer otherwise
    # (measured at 2x batch latency in the PR 2 chain implementation).
    b = jax.lax.cond(
        n_alive != total,
        lambda bb: _table_divert(keys_u32, bb, packed_mask, table, state, n_words),
        lambda bb: bb,
        b,
    )
    return b.astype(jnp.int32).reshape(shape)


#: backward-compatible name for the binomial-lookup flavour of the body
_route_table_impl = fused_route_impl


@functools.partial(jax.jit, static_argnames=("omega", "n_words"))
def binomial_memento_route(
    keys: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    omega: int = 16,
    *,
    n_words: int,
) -> jax.Array:
    """Fused BinomialHash lookup + replacement-table divert — one dispatch.

    The pure-jnp mirror of the fused Pallas kernel
    (``repro.kernels.binomial_hash.binomial_route_fused_2d``): the ω-unrolled
    base lookup feeds the one-gather table divert in-trace, so no
    intermediate ``buckets[N]`` array ever round-trips through HBM and a
    ``BatchRouter.route_keys`` call costs exactly one dispatch.  All fleet
    state is traced and fixed-shape (``packed_mask``, ``table``, the state
    2-vector), so scale/fail/recover streams never retrace.  Bit-exact
    against the scalar ``SessionRouter(binomial32, chain_bits=32,
    resolve="table")`` oracle (tests enforce).
    """
    return _route_table_impl(keys, packed_mask, table, state, omega, n_words)


@functools.partial(jax.jit, static_argnames=("omega", "n_words"))
def binomial_ingest_route(
    ids_lo: jax.Array,
    ids_hi: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    omega: int = 16,
    *,
    n_words: int,
) -> jax.Array:
    """Fused u64-id ingest + lookup + divert — ONE dispatch, no key array.

    The pure-jnp mirror of the fused ingest Pallas kernel
    (``repro.kernels.binomial_hash.binomial_ingest_fused_2d``): raw u64
    session ids arrive as (lo, hi) u32 halves, ``mix64_lo32`` derives the
    u32 routing key in-trace, and the key feeds the same ω-unrolled
    lookup + table divert as ``binomial_memento_route`` — all inside one
    jit, so XLA fuses the ~30-op splitmix64 limb mix into the lookup's
    elementwise pass and no intermediate ``keys[N]`` array is ever
    materialised in memory (DESIGN.md §9).  Bit-exact with hashing on the
    host (``bits.np_mix64`` then truncate) and routing the keys.
    """
    keys = mix64_lo32(ids_lo, ids_hi)
    return _route_table_impl(keys, packed_mask, table, state, omega, n_words)


@functools.partial(jax.jit, static_argnames=("n_words",))
def memento_remap_table(
    keys: jax.Array,
    buckets: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    *,
    n_words: int,
) -> jax.Array:
    """Second dispatch of the two-pass table baseline: divert pre-computed
    buckets off removed slots (``buckets[N]`` round-trips through HBM
    between the lookup dispatch and this one — the cost the fused kernel
    removes).

    keys    any int shape S; buckets shape S in [0, n_total)
    packed_mask (1, W) u32 bit-words; table (1, C) i32 slots permutation;
    state   (2,) u32 [n_total, n_alive]; n_words static mask word count
    """
    shape = buckets.shape
    keys_u32 = keys.reshape(-1).astype(jnp.uint32)
    b = buckets.reshape(-1).astype(jnp.uint32)
    total = state[0].astype(jnp.uint32)
    n_alive = state[1].astype(jnp.uint32)
    b = jax.lax.cond(
        n_alive != total,
        lambda bb: _table_divert(keys_u32, bb, packed_mask, table, state, n_words),
        lambda bb: bb,
        b,
    )
    return b.astype(jnp.int32).reshape(shape)
