"""Vectorised Memento-style failure remap — the device half of the serving
datapath.

``MementoWrapper`` (scalar, host) diverts keys landing on removed slots down
a deterministic rejection chain.  This module applies the identical chain to
a whole batch of buckets on device, after the bulk BinomialHash lookup:

    buckets = binomial_bulk_lookup_dyn(keys, n_total)       # Pallas kernel
    buckets = memento_remap(keys, buckets, mask, n_total, first_alive)

The replacement table is a single ``(capacity,)`` bool array (``mask[b]`` is
True iff slot ``b`` is removed) — O(capacity) device bytes, updated on fleet
events with one small host->device transfer.  ``capacity`` is a static upper
bound on the fleet size, so the array shape — and therefore the compiled
executable — is invariant across arbitrary scale/fail event streams;
``n_total`` rides in as a traced scalar exactly like the kernel's n.

Bit-exact against ``MementoWrapper(chain_bits=32)``: both sides step
``b <- hash_pair32(hash_iter32(key, i+1), b) % n_total`` until an alive slot
(tests enforce this).  The loop is a ``lax.while_loop`` over the *batch* —
each round is one gather + one mix over all lanes, and the loop exits as
soon as every lane has settled, so the expected cost is
O(n_total / n_alive) rounds, O(1) while failures are a bounded fraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binomial_jax import hash_iter, hash_pair


@functools.partial(jax.jit, static_argnames=("max_chain",))
def memento_remap(
    keys: jax.Array,
    buckets: jax.Array,
    removed_mask: jax.Array,
    n_total: jax.Array,
    first_alive: jax.Array,
    max_chain: int = 4096,
) -> jax.Array:
    """Divert buckets that landed on removed slots onto alive ones.

    keys         any int shape S (uint32 key space)
    buckets      shape S, base-engine buckets in [0, n_total)
    removed_mask (capacity,) bool, capacity >= n_total (fixed across events)
    n_total      traced uint32 scalar — total slot space of the base engine
    first_alive  traced uint32 scalar — fallback after max_chain rejections
    """
    shape = buckets.shape
    keys_u32 = keys.reshape(-1).astype(jnp.uint32)
    b = buckets.reshape(-1).astype(jnp.uint32)
    total = jnp.asarray(n_total, jnp.uint32)
    active = removed_mask[b]

    def cond(state):
        i, b, active = state
        return (i < np.uint32(max_chain)) & jnp.any(active)

    def body(state):
        i, b, active = state
        nb = hash_pair(hash_iter(keys_u32, i + np.uint32(1)), b) % total
        b = jnp.where(active, nb, b)
        return i + np.uint32(1), b, active & removed_mask[b]

    _, b, active = jax.lax.while_loop(cond, body, (jnp.uint32(0), b, active))
    # lanes that exhausted the chain fall back to the first alive slot,
    # mirroring MementoWrapper.first_alive().
    b = jnp.where(active, jnp.asarray(first_alive, jnp.uint32), b)
    return b.astype(jnp.int32).reshape(shape)
