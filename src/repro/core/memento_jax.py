"""Vectorised Memento-style failure remap — the device half of the serving
datapath.

``MementoWrapper`` (scalar, host) diverts keys landing on removed slots down
a deterministic rejection chain.  This module applies the identical chain to
a whole batch of buckets on device, after the bulk BinomialHash lookup:

    buckets = binomial_bulk_lookup_dyn(keys, n_total)       # Pallas kernel
    buckets = memento_remap(keys, buckets, mask, n_total, first_alive)

The replacement table is a single ``(capacity,)`` bool array (``mask[b]`` is
True iff slot ``b`` is removed) — O(capacity) device bytes, updated on fleet
events with one small host->device transfer.  ``capacity`` is a static upper
bound on the fleet size, so the array shape — and therefore the compiled
executable — is invariant across arbitrary scale/fail event streams;
``n_total`` rides in as a traced scalar exactly like the kernel's n.

Bit-exact against ``MementoWrapper(chain_bits=32)``: both sides step
``b <- hash_pair32(hash_iter32(key, i+1), b) % n_total`` until an alive slot
(tests enforce this).  The loop is a ``lax.while_loop`` over the *batch* —
each round is one gather + one mix over all lanes, and the loop exits as
soon as every lane has settled, so the expected cost is
O(n_total / n_alive) rounds, O(1) while failures are a bounded fraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binomial_jax import (
    _unrolled_body,
    hash_iter,
    hash_pair,
    next_pow2_u32,
)

#: lanes the packed removed-mask is padded to — one native TPU VREG row, so
#: the fused kernel can take it as a whole-block VMEM operand without layout
#: surprises (capacity/32 words of real payload, zero-padded to a multiple).
MASK_LANES = 128


def mask_words(capacity: int) -> int:
    """Number of u32 bit-words holding a ``capacity``-slot removed mask."""
    return max(1, -(-capacity // 32))


def pack_removed_mask(removed, capacity: int, lanes: int = MASK_LANES) -> np.ndarray:
    """Removed-slot ids -> ``(1, W)`` uint32 bit-words (bit b = slot b removed).

    ``W`` is ``mask_words(capacity)`` rounded up to a multiple of ``lanes``;
    the padding words are zero (never-removed).  This is the host-side mirror
    of the fused kernel's VMEM mask operand: O(capacity/32) words, shape
    fixed across arbitrary fleet-event streams.
    """
    words = -(-mask_words(capacity) // lanes) * lanes
    packed = np.zeros((1, words), dtype=np.uint32)
    for b in removed:
        if not 0 <= b < capacity:
            raise ValueError(f"removed slot {b} outside capacity {capacity}")
        packed[0, b >> 5] |= np.uint32(1) << np.uint32(b & 31)
    return packed


@functools.partial(jax.jit, static_argnames=("max_chain",))
def memento_remap(
    keys: jax.Array,
    buckets: jax.Array,
    removed_mask: jax.Array,
    n_total: jax.Array,
    first_alive: jax.Array,
    max_chain: int = 4096,
) -> jax.Array:
    """Divert buckets that landed on removed slots onto alive ones.

    keys         any int shape S (uint32 key space)
    buckets      shape S, base-engine buckets in [0, n_total)
    removed_mask (capacity,) bool, capacity >= n_total (fixed across events)
    n_total      traced uint32 scalar — total slot space of the base engine
    first_alive  traced uint32 scalar — fallback after max_chain rejections
    """
    shape = buckets.shape
    keys_u32 = keys.reshape(-1).astype(jnp.uint32)
    b = buckets.reshape(-1).astype(jnp.uint32)
    total = jnp.asarray(n_total, jnp.uint32)
    active = removed_mask[b]

    def cond(state):
        i, b, active = state
        return (i < np.uint32(max_chain)) & jnp.any(active)

    def body(state):
        i, b, active = state
        nb = hash_pair(hash_iter(keys_u32, i + np.uint32(1)), b) % total
        b = jnp.where(active, nb, b)
        return i + np.uint32(1), b, active & removed_mask[b]

    _, b, active = jax.lax.while_loop(cond, body, (jnp.uint32(0), b, active))
    # lanes that exhausted the chain fall back to the first alive slot,
    # mirroring MementoWrapper.first_alive().
    b = jnp.where(active, jnp.asarray(first_alive, jnp.uint32), b)
    return b.astype(jnp.int32).reshape(shape)


# ---------------------------------------------------------------------------
# fused lookup + remap: the whole routing decision under ONE jit dispatch.
# ---------------------------------------------------------------------------


def _route_fused_impl(
    keys: jax.Array,
    packed_mask: jax.Array,
    state: jax.Array,
    omega: int,
    max_chain: int,
) -> jax.Array:
    """Traceable body shared by ``binomial_memento_route`` (jit'd, CPU/GPU
    fallback) and ``kernels.ref.binomial_route_ref`` (unjitted oracle).

    keys         any int shape S (uint32 key space)
    packed_mask  (1, W) uint32 bit-words — bit b set iff slot b removed
    state        (2,) uint32 — [n_total, first_alive]
    """
    shape = keys.shape
    keys_u32 = keys.reshape(-1).astype(jnp.uint32)
    total = state[0].astype(jnp.uint32)
    first_alive = state[1].astype(jnp.uint32)
    E = next_pow2_u32(total)
    M = E >> 1
    b = _unrolled_body(keys_u32, E, M, total, omega)
    b = jnp.where(total <= np.uint32(1), np.uint32(0), b)

    # Expand the packed words into a (capacity,) bool LUT once per call —
    # membership then costs ONE gather per lane per round instead of
    # gather+shift+mask arithmetic.  (The Pallas kernel keeps the packed
    # select-cascade: no vector gather on the VPU.)
    words = packed_mask.reshape(-1)
    slot = jnp.arange(words.shape[0] * 32, dtype=jnp.uint32)
    removed_lut = ((words[slot >> 5] >> (slot & np.uint32(31))) & np.uint32(1)) != 0

    def removed(bv):
        return removed_lut[bv]

    # Loop shape is performance-critical on XLA:CPU, in three non-obvious
    # ways (measured on 1M-key batches; the Pallas kernel keeps the classic
    # test-first loop because its carry lives in registers/VMEM, not HBM):
    # * the ω-unrolled producer of ``b`` must have exactly ONE consumer — the
    #   carry init.  Testing membership outside the loop (``removed(b)``)
    #   hands the fusion pass a second elementwise consumer and it happily
    #   recomputes all ~850 ops of the producer into it (2x batch latency;
    #   optimization_barrier gets stripped).  So the membership test lives
    #   INSIDE the body, on the materialised carry, and ``active`` starts
    #   all-True — one extra (cheap) round on a healthy fleet.
    # * that extra round must not pay for hashing: the chain step is wrapped
    #   in ``lax.cond`` so a round with no active lanes skips it entirely.
    # * the chain recomputes hash_iter(keys, i+1) from the closed-over keys
    #   instead of carrying a hash accumulator — an extra while-loop carry is
    #   a whole keys-sized buffer XLA:CPU copies in and out even for zero
    #   rounds.
    def cond(state_):
        i, _, act = state_
        return (i < np.uint32(max_chain)) & jnp.any(act)

    def body(state_):
        i, bb, act = state_
        act = act & removed(bb)

        def step(bb):
            nb = hash_pair(hash_iter(keys_u32, i + np.uint32(1)), bb) % total
            return jnp.where(act, nb, bb)

        bb = jax.lax.cond(jnp.any(act), step, lambda bb: bb, bb)
        return i + np.uint32(1), bb, act

    def chain(b):
        _, b, active = jax.lax.while_loop(
            cond, body, (jnp.uint32(0), b, jnp.ones(b.shape, dtype=bool))
        )
        # ``active`` lags one membership test behind ``b`` (and is all-True
        # when max_chain == 0): re-test the final buckets for exhaustion.
        return jnp.where(active & removed(b), first_alive, b)

    # Healthy-fleet fast path: with zero removed slots — the steady state —
    # a scalar reduction over the TINY packed mask skips the whole chain, so
    # the fused cost degenerates to the base lookup alone.
    b = jax.lax.cond(jnp.any(words != 0), chain, lambda b: b, b)
    return b.astype(jnp.int32).reshape(shape)


@functools.partial(jax.jit, static_argnames=("omega", "max_chain"))
def binomial_memento_route(
    keys: jax.Array,
    packed_mask: jax.Array,
    state: jax.Array,
    omega: int = 16,
    max_chain: int = 4096,
) -> jax.Array:
    """Fused BinomialHash lookup + Memento remap — one device dispatch.

    The pure-jnp mirror of the fused Pallas kernel
    (``repro.kernels.binomial_hash.binomial_route_fused_2d``): the ω-unrolled
    base lookup feeds the rejection chain in-trace, so no intermediate
    ``buckets[N]`` array ever round-trips through HBM and a
    ``BatchRouter.route_keys`` call costs exactly one dispatch.  All fleet
    state is traced (``packed_mask`` fixed-shape, ``state`` a 2-vector), so
    scale/fail/recover streams never retrace.  Bit-exact against the scalar
    ``SessionRouter(binomial32, chain_bits=32)`` oracle (tests enforce).
    """
    return _route_fused_impl(keys, packed_mask, state, omega, max_chain)
