"""Integer mixing / bit-twiddling primitives for the consistent-hash suite.

Two parallel families:

* ``u64``  — host-side (pure Python) 64-bit arithmetic, paper-faithful
  (the paper's reference implementations are Java ``long``).  Mixers are
  splitmix64 finalizers (Steele et al.), a standard strong 64-bit mixer.
* ``u32``  — device-side (JAX/Pallas) 32-bit arithmetic, since TPUs have no
  native 64-bit integer datapath.  Mixers are murmur3 ``fmix32`` finalizers.

Both families provide:
  mix(x)            strong avalanche finalizer
  hash_iter(key, i) the i-th hash of the key (the paper's ``hash^i``)
  hash_pair(h, f)   the two-argument hash used by ``relocateWithinLevel``
"""
from __future__ import annotations

import numpy as np

MASK64 = (1 << 64) - 1
MASK32 = (1 << 32) - 1

GOLDEN64 = 0x9E3779B97F4A7C15
GOLDEN32 = 0x9E3779B9

#: FNV-1a 64-bit parameters — the session-id string hash of
#: ``repro.serving.router.SessionRouter.session_key`` (scalar) and
#: ``np_fnv1a64`` (vectorised) share these.
FNV64_OFFSET = 0xCBF29CE484222325
FNV64_PRIME = 0x100000001B3

# ---------------------------------------------------------------------------
# u64 host-side family (pure python ints)
# ---------------------------------------------------------------------------


def mix64(z: int) -> int:
    """splitmix64 finalizer — full-avalanche 64-bit mixer."""
    z &= MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return (z ^ (z >> 31)) & MASK64


def hash_iter64(key: int, i: int) -> int:
    """The paper's hash^i(key): an indexed family of independent hashes."""
    return mix64((key + i * GOLDEN64) & MASK64)


def hash_pair64(h: int, f: int) -> int:
    """Two-argument hash(h, f) used by relocateWithinLevel (Alg. 2 line 7)."""
    return mix64(h ^ mix64((f + GOLDEN64) & MASK64))


def highest_one_bit_index(b: int) -> int:
    """Index of the highest set bit (floor(log2 b)) for b >= 1."""
    return b.bit_length() - 1


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << (n - 1).bit_length() if n > 1 else 1


# ---------------------------------------------------------------------------
# u32 device-side family — numpy scalar flavour (oracle for the jnp/pallas
# implementations; wraps modulo 2**32 exactly like the device code).
# ---------------------------------------------------------------------------


def mix32(h: int) -> int:
    """murmur3 fmix32 finalizer."""
    h &= MASK32
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & MASK32
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & MASK32
    h ^= h >> 16
    return h


def hash_iter32(key: int, i: int) -> int:
    return mix32((key + i * GOLDEN32) & MASK32)


def hash_pair32(h: int, f: int) -> int:
    return mix32((h ^ mix32((f + GOLDEN32) & MASK32)) & MASK32)


def mulhi32(a: int, b: int) -> int:
    """High 32 bits of the u32xu32 product — the Lemire range reduction
    ``hash -> [0, b)`` used by ``ReplacementTable.resolve`` (scalar oracle of
    ``repro.core.binomial_jax.mulhi32``)."""
    return ((a & MASK32) * (b & MASK32)) >> 32


# ---------------------------------------------------------------------------
# u32 vectorised numpy flavour (bulk oracle; mirrors jnp code path exactly)
# ---------------------------------------------------------------------------


def np_mix32(h: np.ndarray) -> np.ndarray:
    h = h.astype(np.uint32)
    h ^= h >> np.uint32(16)
    h = h * np.uint32(0x85EBCA6B)
    h ^= h >> np.uint32(13)
    h = h * np.uint32(0xC2B2AE35)
    h ^= h >> np.uint32(16)
    return h


def np_hash_iter32(key: np.ndarray, i: int) -> np.ndarray:
    return np_mix32(key.astype(np.uint32) + np.uint32((i * GOLDEN32) & MASK32))


def np_hash_pair32(h: np.ndarray, f: np.ndarray | int) -> np.ndarray:
    fm = np_mix32(np.asarray(f, dtype=np.uint32) + np.uint32(GOLDEN32))
    return np_mix32(h.astype(np.uint32) ^ fm)


# ---------------------------------------------------------------------------
# u64 vectorised numpy flavour — the host half of the batched ingest path
# (DESIGN.md §9).  numpy uint64 arithmetic wraps mod 2**64 exactly like the
# masked pure-python family above; tests pin the two equal element-for-element.
# ---------------------------------------------------------------------------


def np_mix64(z: np.ndarray) -> np.ndarray:
    """Vectorised splitmix64 finalizer — bit-exact with ``mix64`` per lane."""
    z = np.asarray(z, dtype=np.uint64)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def np_fnv1a64(byte_mat: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    """Vectorised FNV-1a over a padded ``(N, L)`` uint8 byte matrix.

    Row i hashes its first ``lengths[i]`` bytes; the padding columns beyond a
    row's length leave its accumulator untouched, so ragged batches hash
    bit-exactly like the scalar per-byte loop (``SessionRouter.session_key``).
    One fused numpy pass per byte *column* — O(L) passes over N rows instead
    of O(N·L) interpreted byte steps.  The matrix is walked transposed
    (contiguous column reads) and the ``live`` blend is skipped for the
    columns every row still owns — for near-uniform id lengths (the common
    shape) the whole hash is pure xor/multiply passes.
    """
    byte_mat = np.asarray(byte_mat, dtype=np.uint8)
    lengths = np.asarray(lengths)
    cols = np.ascontiguousarray(byte_mat.T)
    n, L = byte_mat.shape
    min_len = int(lengths.min()) if n else 0
    h = np.full(n, np.uint64(FNV64_OFFSET), dtype=np.uint64)
    prime = np.uint64(FNV64_PRIME)
    for j in range(L):
        nh = (h ^ cols[j]) * prime
        h = nh if j < min_len else np.where(j < lengths, nh, h)
    return h


def np_split64(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """u64 array -> (low, high) u32 halves — the device-ingest operand split
    (the TPU datapath is u32-only; the fused ingest kernel re-assembles the
    pair in 32-bit limb arithmetic)."""
    x = np.asarray(x, dtype=np.uint64)
    return x.astype(np.uint32), (x >> np.uint64(32)).astype(np.uint32)


def np_highest_one_bit_index(b: np.ndarray) -> np.ndarray:
    """floor(log2 b) for b >= 1, vectorised, exact for all u32.

    Shift-or cascade to smear the top bit downwards, then popcount-1.
    """
    b = b.astype(np.uint32)
    b |= b >> np.uint32(1)
    b |= b >> np.uint32(2)
    b |= b >> np.uint32(4)
    b |= b >> np.uint32(8)
    b |= b >> np.uint32(16)
    # popcount via parallel bit summation
    v = b - ((b >> np.uint32(1)) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> np.uint32(2)) & np.uint32(0x33333333))
    v = (v + (v >> np.uint32(4))) & np.uint32(0x0F0F0F0F)
    v = (v * np.uint32(0x01010101)) >> np.uint32(24)
    return (v - np.uint32(1)).astype(np.uint32)
