"""Comparison suite of consistent-hashing algorithms.

Fidelity tiers (see DESIGN.md §6):

* EXACT — implemented from published pseudocode, bit-for-bit:
    JumpHash (Lamping & Veach 2014), Rendezvous/HRW (Thaler & Ravishankar),
    Karger ring, naive modulo.
* EXACT-EQUIVALENT for the paper's LIFO/no-failure operating model:
    AnchorHashLIFO (the LIFO specialisation of AnchorHash collapses to an
    iterative mod-shrink), DxHashLIFO (fixed-capacity rejection ring).
* RECONSTRUCTION — same algorithmic family, implemented from the published
    *description* (not claimed bit-identical to the authors' code):
    FlipHashRecon, PowerCHRecon (floating point, as the original),
    JumpBackHashRecon.

All engines expose the same facade as ``BinomialHash``:
``get_bucket(key) -> int``, ``add_bucket()``, ``remove_bucket()`` (LIFO),
``.size``, ``.name``, ``.exact``.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field

from repro.core import bits
from repro.core.bits import MASK64

# ---------------------------------------------------------------------------
# JumpHash — exact (Lamping & Veach 2014)
# ---------------------------------------------------------------------------


def jump_lookup(key: int, n: int) -> int:
    b, j = -1, 0
    k = key & MASK64
    while j < n:
        b = j
        k = (k * 2862933555777941757 + 1) & MASK64
        j = int(float(b + 1) * (float(1 << 31) / float((k >> 33) + 1)))
    return b


@dataclass
class JumpHash:
    n: int
    name = "jump"
    exact = True

    def get_bucket(self, key: int) -> int:
        return jump_lookup(key, self.n)

    def add_bucket(self) -> int:
        self.n += 1
        return self.n - 1

    def remove_bucket(self) -> int:
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        return self.n

    @property
    def size(self) -> int:
        return self.n


# ---------------------------------------------------------------------------
# Rendezvous / HRW — exact, O(n) per lookup (quality baseline, not constant time)
# ---------------------------------------------------------------------------


@dataclass
class RendezvousHash:
    n: int
    name = "rendezvous"
    exact = True

    def get_bucket(self, key: int) -> int:
        best_b, best_w = 0, -1
        for b in range(self.n):
            w = bits.mix64(key ^ bits.mix64(b))
            if w > best_w:
                best_b, best_w = b, w
        return best_b

    def add_bucket(self) -> int:
        self.n += 1
        return self.n - 1

    def remove_bucket(self) -> int:
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        return self.n

    @property
    def size(self) -> int:
        return self.n


# ---------------------------------------------------------------------------
# Karger ring — exact structure (sorted virtual nodes + bisect), O(log nv)
# ---------------------------------------------------------------------------


class RingHash:
    name = "ring"
    exact = True

    def __init__(self, n: int, vnodes: int = 100):
        self.n = 0
        self.vnodes = vnodes
        self._points: list[tuple[int, int]] = []  # (position, bucket)
        for _ in range(n):
            self.add_bucket()

    def _positions(self, b: int):
        return [bits.mix64((b << 20) ^ bits.mix64(v)) for v in range(self.vnodes)]

    def add_bucket(self) -> int:
        b = self.n
        for p in self._positions(b):
            bisect.insort(self._points, (p, b))
        self.n += 1
        return b

    def remove_bucket(self) -> int:
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        b = self.n - 1
        pts = set(self._positions(b))
        self._points = [(p, q) for (p, q) in self._points if not (q == b and p in pts)]
        self.n -= 1
        return b

    def get_bucket(self, key: int) -> int:
        h = bits.mix64(key)
        i = bisect.bisect_left(self._points, (h, -1))
        if i == len(self._points):
            i = 0
        return self._points[i][1]

    @property
    def size(self) -> int:
        return self.n


# ---------------------------------------------------------------------------
# Naive modulo — exact worst-case baseline (massive disruption on resize)
# ---------------------------------------------------------------------------


@dataclass
class ModuloHash:
    n: int
    name = "modulo"
    exact = True

    def get_bucket(self, key: int) -> int:
        return bits.mix64(key) % self.n

    def add_bucket(self) -> int:
        self.n += 1
        return self.n - 1

    def remove_bucket(self) -> int:
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        return self.n

    @property
    def size(self) -> int:
        return self.n


# ---------------------------------------------------------------------------
# AnchorHash — LIFO specialisation (Mendelson et al. 2020).
#
# With LIFO-only removals the anchor arrays collapse: A[b] = b for every
# removed bucket b >= n, K = identity.  GETBUCKET degenerates to the
# iterative mod-shrink below, which is exact-equivalent for this regime.
# ---------------------------------------------------------------------------


@dataclass
class AnchorHashLIFO:
    n: int
    capacity: int = 0  # anchor size `a`; defaults to 2 * initial n
    name = "anchor-lifo"
    exact = True  # exact-equivalent in the LIFO/no-failure regime

    def __post_init__(self):
        if self.capacity <= 0:
            self.capacity = max(2 * self.n, 16)
        if self.n > self.capacity:
            raise ValueError("n exceeds anchor capacity")

    def get_bucket(self, key: int) -> int:
        b = bits.mix64(key) % self.capacity
        while b >= self.n:  # removed bucket: rehash within its removal-time set
            b = bits.hash_pair64(key, b) % b
        return b

    def add_bucket(self) -> int:
        if self.n >= self.capacity:
            raise ValueError("anchor capacity exhausted")
        self.n += 1
        return self.n - 1

    def remove_bucket(self) -> int:
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        return self.n

    @property
    def size(self) -> int:
        return self.n


# ---------------------------------------------------------------------------
# DxHash — LIFO specialisation (Dong & Wang 2021): rejection over a
# fixed-capacity pseudo-random ring.
# ---------------------------------------------------------------------------


@dataclass
class DxHashLIFO:
    n: int
    capacity: int = 0  # ring size (power of two), fixed at construction
    max_iters: int = 4096
    name = "dx-lifo"
    exact = True  # exact-equivalent in the LIFO/no-failure regime

    def __post_init__(self):
        if self.capacity <= 0:
            self.capacity = bits.next_pow2(max(2 * self.n, 16))
        self.capacity = bits.next_pow2(self.capacity)

    def get_bucket(self, key: int) -> int:
        for i in range(self.max_iters):
            r = bits.hash_iter64(key, i) & (self.capacity - 1)
            if r < self.n:
                return r
        return bits.mix64(key) % self.n  # unreachable in practice

    def add_bucket(self) -> int:
        if self.n >= self.capacity:
            raise ValueError("ring capacity exhausted")
        self.n += 1
        return self.n - 1

    def remove_bucket(self) -> int:
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        return self.n

    @property
    def size(self) -> int:
        return self.n


# ---------------------------------------------------------------------------
# FlipHash — reconstruction (Masson & Lee 2024).  Same enclosing-tree
# rejection family as BinomialHash; integer arithmetic.
# ---------------------------------------------------------------------------


@dataclass
class FlipHashRecon:
    n: int
    omega: int = 64
    name = "fliphash-recon"
    exact = False

    def get_bucket(self, key: int) -> int:
        n = self.n
        if n <= 1:
            return 0
        E = bits.next_pow2(n)
        M = E >> 1
        for i in range(self.omega):
            b = bits.hash_iter64(key, i) & (E - 1)
            if b < n:
                return b
        # fold into the lower half (all valid) with a dedicated hash
        return bits.hash_iter64(key, self.omega) & (M - 1)

    def add_bucket(self) -> int:
        self.n += 1
        return self.n - 1

    def remove_bucket(self) -> int:
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        return self.n

    @property
    def size(self) -> int:
        return self.n


# ---------------------------------------------------------------------------
# PowerCH — reconstruction (Leu 2023).  Uses floating-point arithmetic in the
# hot path, as the original does (this is what the paper's Fig. 5 attributes
# its slightly slower lookups to).
# ---------------------------------------------------------------------------


@dataclass
class PowerCHRecon:
    n: int
    omega: int = 64
    name = "powerch-recon"
    exact = False

    @staticmethod
    def _unit(h: int) -> float:
        return (h >> 11) * (1.0 / (1 << 53))

    def get_bucket(self, key: int) -> int:
        n = self.n
        if n <= 1:
            return 0
        E = bits.next_pow2(n)
        M = E >> 1
        for i in range(self.omega):
            b = int(self._unit(bits.hash_iter64(key, i)) * E)
            if b < n:
                return b
        return int(self._unit(bits.hash_iter64(key, self.omega)) * M)

    def add_bucket(self) -> int:
        self.n += 1
        return self.n - 1

    def remove_bucket(self) -> int:
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        return self.n

    @property
    def size(self) -> int:
        return self.n


# ---------------------------------------------------------------------------
# JumpBackHash — reconstruction (Ertl 2024).  The distinguishing trait kept
# from the published description: candidates come from a SEQUENTIAL integer
# PRNG stream (one state, chained mixes — no indexed rehash family, no
# modulo, no floats); rejection over the enclosing power-of-two range with a
# minor-tree fold as the bounded-time fallback.
# ---------------------------------------------------------------------------


@dataclass
class JumpBackHashRecon:
    n: int
    omega: int = 64
    name = "jumpback-recon"
    exact = False

    def get_bucket(self, key: int) -> int:
        n = self.n
        if n <= 1:
            return 0
        E = bits.next_pow2(n)
        state = bits.mix64(key)
        for _ in range(self.omega):
            state = bits.mix64((state + bits.GOLDEN64) & MASK64)
            v = state & (E - 1)
            if v < n:
                return v
        return state & ((E >> 1) - 1)

    def add_bucket(self) -> int:
        self.n += 1
        return self.n - 1

    def remove_bucket(self) -> int:
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        return self.n

    @property
    def size(self) -> int:
        return self.n
