"""BinomialHash — paper-exact scalar implementation (Alg. 1 + Alg. 2).

Coluzzi, Brocco, Antonucci, Leidi — "BinomialHash: A Constant Time, Minimal
Memory Consistent Hashing Algorithm" (2024).

Two word-size flavours sharing the identical control flow:

* ``BinomialHash``    — u64 host flavour (paper-faithful word size),
* ``BinomialHash32``  — u32 flavour; the bit-exact scalar oracle for the
  vectorised JAX / Pallas device implementations.

The structure of Alg. 1:

    h0 <- h <- hash(key)
    for i in 0..omega-1:
        b <- h_i AND (E-1)
        c <- relocateWithinLevel(b, h_i)
        if c < M:  return relocateWithinLevel(h AND (M-1), h)      # block A
        if c < n:  return c                                        # block B
        h_{i+1} <- hash^{i+1}(key)
    return relocateWithinLevel(h AND (M-1), h)                     # block C

Blocks A and C use the ORIGINAL hash ``h`` (h^0), not the per-iteration hash —
this is what makes the minor-tree fold consistent across tree-level changes
(paper §5.3).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import bits

DEFAULT_OMEGA = 64  # imbalance < 2^-64 on the host control plane


def _relocate_within_level_64(b: int, h: int) -> int:
    """Alg. 2 — uniform relocation of ``b`` within its tree level."""
    if b < 2:  # levels 0 and 1 hold a single node each
        return b
    d = bits.highest_one_bit_index(b)
    f = (1 << d) - 1
    r = bits.hash_pair64(h, f)
    i = r & f
    return (1 << d) + i


def binomial_lookup64(key: int, n: int, omega: int = DEFAULT_OMEGA) -> int:
    """Paper-exact u64 lookup: key -> bucket in [0, n)."""
    if n <= 1:
        return 0
    l = (n - 1).bit_length()  # ceil(log2 n)
    E = 1 << l
    M = E >> 1
    h0 = h = bits.hash_iter64(key, 0)
    hi = h0
    for i in range(omega):
        b = hi & (E - 1)
        c = _relocate_within_level_64(b, hi)
        if c < M:  # block A — fold into the minor tree with the ORIGINAL hash
            d = h & (M - 1)
            return _relocate_within_level_64(d, h)
        if c < n:  # block B — valid bucket on the lowest level
            return c
        hi = bits.hash_iter64(key, i + 1)
    d = h & (M - 1)  # block C
    return _relocate_within_level_64(d, h)


def _relocate_within_level_32(b: int, h: int) -> int:
    if b < 2:
        return b
    d = bits.highest_one_bit_index(b)
    f = (1 << d) - 1
    r = bits.hash_pair32(h, f)
    i = r & f
    return (1 << d) + i


def binomial_lookup32(key: int, n: int, omega: int = 16) -> int:
    """u32 scalar lookup — bit-exact oracle for the device implementations."""
    if n <= 1:
        return 0
    l = (n - 1).bit_length()
    E = 1 << l
    M = E >> 1
    h0 = h = bits.hash_iter32(key & bits.MASK32, 0)
    hi = h0
    for i in range(omega):
        b = hi & (E - 1)
        c = _relocate_within_level_32(b, hi)
        if c < M:
            d = h & (M - 1)
            return _relocate_within_level_32(d, h)
        if c < n:
            return c
        hi = bits.hash_iter32(key & bits.MASK32, i + 1)
    d = h & (M - 1)
    return _relocate_within_level_32(d, h)


@dataclass
class BinomialHash:
    """Stateful-looking facade over the stateless lookup (cluster size only).

    Mirrors the engine API the paper's benchmark suite uses: ``get_bucket``,
    ``add_bucket``, ``remove_bucket`` (LIFO).
    """

    n: int
    omega: int = DEFAULT_OMEGA

    name = "binomial"
    exact = True  # implemented from the paper's published pseudocode

    def get_bucket(self, key: int) -> int:
        return binomial_lookup64(key, self.n, self.omega)

    def add_bucket(self) -> int:
        self.n += 1
        return self.n - 1

    def remove_bucket(self) -> int:
        """LIFO removal — removes the last bucket, returns its id."""
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        return self.n

    @property
    def size(self) -> int:
        return self.n


@dataclass
class BinomialHash32:
    """u32 flavour of the facade (device-oracle word size)."""

    n: int
    omega: int = 16

    name = "binomial32"
    exact = True

    def get_bucket(self, key: int) -> int:
        return binomial_lookup32(key, self.n, self.omega)

    def add_bucket(self) -> int:
        self.n += 1
        return self.n - 1

    def remove_bucket(self) -> int:
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        return self.n

    @property
    def size(self) -> int:
        return self.n
