"""JumpHash in the device word sizes — the second fused bulk engine.

Jump consistent hash (Lamping & Veach, 2014) walks a chain of candidate
buckets ``j <- floor((b+1) * 2^31 / ((k >> 33) + 1))`` driven by a 64-bit
LCG; the expected chain length is ln(n), and every step strictly increases
the candidate, so a bounded unroll loses only an astronomically rare tail.
That makes it the natural second engine for the fused single-dispatch
datapath (DESIGN.md §10): the same replacement-table divert, the same fleet
state, a different base lookup body.

``jump32`` is the device-word flavour (the ``binomial32`` counterpart):

* the LCG state rides as (lo, hi) u32 limbs — the TPU VPU has no 64-bit
  integer datapath — stepped with the same limb-multiply helpers as the
  splitmix64 ingest mix (``binomial_jax._mul64`` + an add-with-carry);
* the original's double-precision step is replaced by an f32 step
  (``f32(b+1) * (f32(2^31) / f32(r))``): IEEE-754 single arithmetic, done
  identically by numpy on the host and XLA on CPU/interpret-mode Pallas, so
  the scalar oracle and the vectorised mirror are bit-exact by construction
  (tests enforce; a real-TPU deployment should re-verify its VPU divide
  rounds IEEE-correctly).  ``b+1`` must be exact in an f32 mantissa, which
  bounds the slot space at 2^24 (``repro.core.bulk.MAX_CAPACITY``);
* the rejection loop is unrolled ``omega`` times with a masked blend —
  lanes that exhaust the budget keep their latest (always-valid) candidate,
  and the scalar oracle stops at the identical bound, so scalar == batch
  holds even on the tail.

The bounded flavour keeps JumpHash's full consistency: growing n to n+1
moves a key only onto the new bucket n (tests pin the monotone-remap
property alongside the other ``FULLY_CONSISTENT`` engines).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binomial_jax import _mul64, mix64_lo32
from repro.core.memento_jax import fused_route_impl

#: the 64-bit LCG multiplier from the paper (Lamping & Veach, 2014)
JUMP_LCG = 2862933555777941757

_F_TOP = np.float32(2.0**31)


# ---------------------------------------------------------------------------
# scalar reference — the control-plane oracle (mirrors the unrolled device
# body operation for operation; np.float32 is IEEE single like XLA's f32)
# ---------------------------------------------------------------------------


def jump_lookup32(key: int, n: int, omega: int = 16) -> int:
    """u32-key, ω-bounded, f32-step jump lookup — the ``jump32`` scalar."""
    if n <= 1:
        return 0
    k = key & 0xFFFFFFFF
    b = 0
    fn = np.float32(n)
    for _ in range(omega):
        k = (k * JUMP_LCG + 1) & ((1 << 64) - 1)
        r = (k >> 33) + 1  # uniform in [1, 2^31]
        fj = np.float32(np.float32(b + 1) * np.float32(_F_TOP / np.float32(r)))
        if fj >= fn:
            return b
        b = int(fj)
    return b  # budget exhausted: the latest candidate is always < n


@dataclass
class JumpHash32:
    """Scalar ``jump32`` engine — the oracle of the jump device datapath.

    Same facade as the other engines (``get_bucket`` / LIFO add / remove);
    ``omega`` is the unroll bound shared with the kernels (the engine-
    protocol contract: oracle and device agree on every constant).
    """

    n: int
    omega: int = 16
    name = "jump32"
    exact = False  # device-word flavour of the published algorithm

    def get_bucket(self, key: int) -> int:
        return jump_lookup32(key, self.n, self.omega)

    def add_bucket(self) -> int:
        self.n += 1
        return self.n - 1

    def remove_bucket(self) -> int:
        if self.n <= 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        return self.n

    @property
    def size(self) -> int:
        return self.n


# ---------------------------------------------------------------------------
# vectorised body — shared by the jnp mirrors below and the Pallas kernels
# (repro.kernels.jump_hash), so kernel == mirror == scalar transitively.
# ---------------------------------------------------------------------------


def jump_unrolled_body(keys_u32: jax.Array, n_u32: jax.Array, omega: int) -> jax.Array:
    """ω-unrolled jump chain: u32 keys + traced n -> u32 buckets in [0, n).

    Every lane runs all ω LCG steps (divergent exits buy nothing on a VREG
    grid); ``done`` freezes each lane's bucket at its first exiting step.
    The f32 product can reach ~2^51 on exited lanes — their (out-of-range)
    u32 cast is masked off by ``done``, and continuing lanes satisfy
    ``fj < n <= 2^24`` so their cast is exact.
    """
    lo = keys_u32.astype(jnp.uint32)
    hi = jnp.zeros_like(lo)
    b = jnp.zeros_like(lo)
    done = jnp.zeros(lo.shape, dtype=bool)
    fn = n_u32.astype(jnp.float32)
    for _ in range(omega):
        # k = k * LCG + 1 mod 2^64, in u32 limbs (add-with-carry on the +1)
        lo, hi = _mul64(lo, hi, JUMP_LCG)
        lo = lo + np.uint32(1)
        hi = hi + jnp.where(lo == 0, np.uint32(1), np.uint32(0))
        r = (hi >> np.uint32(1)) + np.uint32(1)  # (k >> 33) + 1
        fj = (b + np.uint32(1)).astype(jnp.float32) * (_F_TOP / r.astype(jnp.float32))
        exits = fj >= fn
        b = jnp.where(~done & ~exits, fj.astype(jnp.uint32), b)
        done = done | exits
    return jnp.where(n_u32 <= np.uint32(1), np.uint32(0), b)


@functools.partial(jax.jit, static_argnames=("n", "omega"))
def jump_lookup_vec(keys: jax.Array, n: int, omega: int = 16) -> jax.Array:
    """Bulk jump lookup, n static: keys (any int dtype) -> int32 buckets."""
    if n <= 1:
        return jnp.zeros(keys.shape, dtype=jnp.int32)
    out = jump_unrolled_body(
        keys.reshape(-1).astype(jnp.uint32), np.uint32(n), omega
    )
    return out.astype(jnp.int32).reshape(keys.shape)


@functools.partial(jax.jit, static_argnames=("omega",))
def jump_lookup_dyn(keys: jax.Array, n: jax.Array, omega: int = 16) -> jax.Array:
    """Bulk jump lookup with traced n (elastic resize, no recompile)."""
    out = jump_unrolled_body(
        keys.reshape(-1).astype(jnp.uint32), jnp.asarray(n, jnp.uint32), omega
    )
    return out.astype(jnp.int32).reshape(keys.shape)


# ---------------------------------------------------------------------------
# fused mirrors: jump lookup + the engine-agnostic replacement-table divert
# under one jit — the CPU/GPU flavour of the jump device datapath.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("omega", "n_words"))
def jump_memento_route(
    keys: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    omega: int = 16,
    *,
    n_words: int,
) -> jax.Array:
    """Fused jump lookup + replacement-table divert — one dispatch.

    The pure-jnp mirror of ``repro.kernels.jump_hash.jump_route_fused_2d``;
    operand contract and fleet-state semantics identical to
    ``binomial_memento_route`` (only the base lookup body differs).
    Bit-exact against the scalar ``SessionRouter(jump32, chain_bits=32,
    resolve="table")`` oracle (tests enforce).
    """
    return fused_route_impl(
        keys, packed_mask, table, state, omega, n_words, lookup=jump_unrolled_body
    )


@functools.partial(jax.jit, static_argnames=("omega", "n_words"))
def jump_ingest_route(
    ids_lo: jax.Array,
    ids_hi: jax.Array,
    packed_mask: jax.Array,
    table: jax.Array,
    state: jax.Array,
    omega: int = 16,
    *,
    n_words: int,
) -> jax.Array:
    """Fused u64-id ingest + jump lookup + divert — one dispatch, no key
    array (the jump twin of ``binomial_ingest_route``): the limb-wise
    splitmix64 derives the u32 routing key in-trace and feeds the same
    fused body."""
    keys = mix64_lo32(ids_lo, ids_hi)
    return fused_route_impl(
        keys, packed_mask, table, state, omega, n_words, lookup=jump_unrolled_body
    )
