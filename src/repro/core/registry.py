"""Name -> engine registries for the consistent-hash suite.

Two tables:

* ``ENGINES`` — the scalar engines (the paper's Fig. 5 comparison set plus
  the device-word flavours): ``make(name, n)`` builds one.
* ``BULK_ENGINES`` — the pluggable *device* engines (DESIGN.md §10): each
  ``BulkEngine`` bundles its scalar oracle with the fused jnp mirrors, the
  optional Pallas kernels and the plain bulk-lookup flavours; the generic
  dispatcher (``repro.kernels.ops``) and ``BatchRouter(engine=...)``
  resolve entries from here *per call*, so tests can swap an entry in to
  intercept dispatches.
"""
from __future__ import annotations

from typing import Callable

from repro.core.baselines import (
    AnchorHashLIFO,
    DxHashLIFO,
    FlipHashRecon,
    JumpBackHashRecon,
    JumpHash,
    ModuloHash,
    PowerCHRecon,
    RendezvousHash,
    RingHash,
)
from repro.core.binomial import BinomialHash, BinomialHash32
from repro.core.binomial_jax import binomial_lookup_dyn, binomial_lookup_vec
from repro.core.bulk import BulkEngine
from repro.core.jump_jax import (
    JumpHash32,
    jump_ingest_route,
    jump_lookup_dyn,
    jump_lookup_vec,
    jump_memento_route,
)
from repro.core.memento_jax import binomial_ingest_route, binomial_memento_route
from repro.kernels.binomial_hash import (
    binomial_bulk_lookup_pallas_dyn,
    binomial_ingest_pallas_fused,
    binomial_route_pallas_fused,
)
from repro.kernels.jump_hash import (
    jump_bulk_lookup_pallas_dyn,
    jump_ingest_pallas_fused,
    jump_route_pallas_fused,
)

ENGINES: dict[str, Callable[[int], object]] = {
    "binomial": lambda n: BinomialHash(n),
    "binomial32": lambda n: BinomialHash32(n),
    "jump": lambda n: JumpHash(n),
    "jump32": lambda n: JumpHash32(n),
    "fliphash-recon": lambda n: FlipHashRecon(n),
    "powerch-recon": lambda n: PowerCHRecon(n),
    "jumpback-recon": lambda n: JumpBackHashRecon(n),
    "anchor-lifo": lambda n: AnchorHashLIFO(n),
    "dx-lifo": lambda n: DxHashLIFO(n),
    "rendezvous": lambda n: RendezvousHash(n),
    "ring": lambda n: RingHash(n),
    "modulo": lambda n: ModuloHash(n),
}

#: constant-time engines compared in the paper's Fig. 5
CONSTANT_TIME = ["binomial", "jump", "fliphash-recon", "powerch-recon", "jumpback-recon"]

#: engines whose cross-power-of-two monotonicity is guaranteed (see DESIGN §6)
FULLY_CONSISTENT = [
    "binomial", "binomial32", "jump", "jump32", "rendezvous", "ring",
    "anchor-lifo", "dx-lifo",
]


def make(name: str, n: int):
    if name not in ENGINES:
        raise KeyError(f"unknown engine '{name}'; have {sorted(ENGINES)}")
    return ENGINES[name](n)


#: the pluggable device routing engines (DESIGN.md §10).  Every entry is
#: bit-exact against its ``scalar_engine`` oracle under table-mode failure
#: resolution across arbitrary fleet-event streams — tests enforce this for
#: each registered engine, so a new entry inherits the whole parity suite.
BULK_ENGINES: dict[str, BulkEngine] = {
    "binomial": BulkEngine(
        name="binomial",
        scalar_engine="binomial32",
        route=binomial_memento_route,
        ingest=binomial_ingest_route,
        route_pallas=binomial_route_pallas_fused,
        ingest_pallas=binomial_ingest_pallas_fused,
        lookup_dyn=binomial_lookup_dyn,
        lookup_dyn_pallas=binomial_bulk_lookup_pallas_dyn,
        lookup_vec=binomial_lookup_vec,
    ),
    "jump": BulkEngine(
        name="jump",
        scalar_engine="jump32",
        route=jump_memento_route,
        ingest=jump_ingest_route,
        route_pallas=jump_route_pallas_fused,
        ingest_pallas=jump_ingest_pallas_fused,
        lookup_dyn=jump_lookup_dyn,
        lookup_dyn_pallas=jump_bulk_lookup_pallas_dyn,
        lookup_vec=jump_lookup_vec,
    ),
}


def make_bulk(name: str) -> BulkEngine:
    """Resolve a device engine bundle by name (the ``BatchRouter(engine=)``
    / ``RouterSpec.engine`` lookup)."""
    if name not in BULK_ENGINES:
        raise KeyError(
            f"unknown bulk engine '{name}'; have {sorted(BULK_ENGINES)} "
            f"(scalar-only engines live in ENGINES)"
        )
    return BULK_ENGINES[name]
