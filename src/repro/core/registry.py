"""Name -> engine registry for the consistent-hash suite."""
from __future__ import annotations

from typing import Callable

from repro.core.baselines import (
    AnchorHashLIFO,
    DxHashLIFO,
    FlipHashRecon,
    JumpBackHashRecon,
    JumpHash,
    ModuloHash,
    PowerCHRecon,
    RendezvousHash,
    RingHash,
)
from repro.core.binomial import BinomialHash, BinomialHash32

ENGINES: dict[str, Callable[[int], object]] = {
    "binomial": lambda n: BinomialHash(n),
    "binomial32": lambda n: BinomialHash32(n),
    "jump": lambda n: JumpHash(n),
    "fliphash-recon": lambda n: FlipHashRecon(n),
    "powerch-recon": lambda n: PowerCHRecon(n),
    "jumpback-recon": lambda n: JumpBackHashRecon(n),
    "anchor-lifo": lambda n: AnchorHashLIFO(n),
    "dx-lifo": lambda n: DxHashLIFO(n),
    "rendezvous": lambda n: RendezvousHash(n),
    "ring": lambda n: RingHash(n),
    "modulo": lambda n: ModuloHash(n),
}

#: constant-time engines compared in the paper's Fig. 5
CONSTANT_TIME = ["binomial", "jump", "fliphash-recon", "powerch-recon", "jumpback-recon"]

#: engines whose cross-power-of-two monotonicity is guaranteed (see DESIGN §6)
FULLY_CONSISTENT = ["binomial", "binomial32", "jump", "rendezvous", "ring", "anchor-lifo", "dx-lifo"]


def make(name: str, n: int):
    if name not in ENGINES:
        raise KeyError(f"unknown engine '{name}'; have {sorted(ENGINES)}")
    return ENGINES[name](n)
