"""Core consistent-hashing library — the paper's contribution.

Exact scalar BinomialHash (u64 + u32), vectorised JAX u32 flavour, the
comparison suite, the Memento-style failure wrapper and the closed-form
balance theory.
"""
from repro.core.binomial import (  # noqa: F401
    BinomialHash,
    BinomialHash32,
    binomial_lookup32,
    binomial_lookup64,
)
from repro.core.binomial_jax import (  # noqa: F401
    binomial_lookup_dyn,
    binomial_lookup_vec,
)
from repro.core.bulk import BulkEngine, FleetState, RouterSpec  # noqa: F401
from repro.core.jump_jax import JumpHash32, jump_lookup_dyn, jump_lookup_vec  # noqa: F401
from repro.core.memento import MementoWrapper, ReplacementTable  # noqa: F401
from repro.core.memento_jax import memento_remap, memento_remap_table  # noqa: F401
from repro.core.registry import (  # noqa: F401
    BULK_ENGINES,
    CONSTANT_TIME,
    ENGINES,
    FULLY_CONSISTENT,
    make,
    make_bulk,
)
