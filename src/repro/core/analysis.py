"""Closed-form theory from the paper (§5.4) — balance bounds.

Eq. (1): P(M <= b < n) = (n-M)/n * [1 - ((E-n)/E)^omega]
Eq. (3): relative gap (K - K') / (k/n)
Eq. (5): sigma(n, k) = k/n * sqrt((n-M)/M * ((2M-n)/(2M))^omega)
Eq. (6): sigma_max = q * sqrt(1/(1+omega) * (omega / (2(1+omega)))^omega)

These are validated empirically by benchmarks/bench_theory.py.
"""
from __future__ import annotations

import math

from repro.core.bits import next_pow2


def tree_bounds(n: int) -> tuple[int, int]:
    """(E, M): enclosing- and minor-tree capacities for cluster size n > 1."""
    if n <= 1:
        raise ValueError("n must be > 1")
    E = next_pow2(n)
    M = E // 2
    return E, M


def p_lowest_level(n: int, omega: int) -> float:
    """Eq. (1): probability a key lands on the (partial) lowest level."""
    E, M = tree_bounds(n)
    return (n - M) / n * (1.0 - ((E - n) / E) ** omega)


def expected_keys(n: int, k: int, omega: int) -> tuple[float, float]:
    """(K, K'): expected keys per minor-tree bucket / per lowest-level bucket."""
    E, M = tree_bounds(n)
    p = p_lowest_level(n, omega)
    k_low = p / (n - M) * k if n > M else 0.0
    k_minor = (1.0 - p) / M * k
    return k_minor, k_low


def relative_imbalance(n: int, omega: int) -> float:
    """Eq. (3): (K - K') / (k/n) — independent of k. Max value is 2^-omega."""
    E, M = tree_bounds(n)
    if n == E:  # perfectly balanced when n is a power of two
        return 0.0
    r = (n - M) / M
    return (1.0 / 2**omega) * (1.0 + r) * (1.0 - r) ** omega


def sigma(n: int, k: int, omega: int) -> float:
    """Eq. (5): std-dev of per-bucket key counts (expectation model)."""
    E, M = tree_bounds(n)
    if n == E:
        return 0.0
    return (k / n) * math.sqrt((n - M) / M * ((2 * M - n) / (2 * M)) ** omega)


def sigma_max(q: float, omega: int) -> float:
    """Eq. (6): max of Eq. (5) over n in [M, 2M), with k = q*n."""
    return q * math.sqrt(1.0 / (1 + omega) * (omega / (2.0 * (1 + omega))) ** omega)


def sigma_argmax(M: int, omega: int) -> float:
    """n that maximises Eq. (5): n = (2+omega)/(1+omega) * M."""
    return (2.0 + omega) / (1.0 + omega) * M
