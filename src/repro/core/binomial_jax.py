"""Vectorised u32 BinomialHash in JAX — the on-device bulk lookup.

This is the datapath flavour (DESIGN.md §3): murmur3 fmix32 mixers, the
scalar early-exit rejection loop replaced by an ω-unrolled masked blend
(every lane runs all ω iterations; ``where`` masks select the first accepting
one).  Bit-exact against ``repro.core.binomial.binomial_lookup32`` — tests
enforce this for all shapes/dtypes/n.

Two entry points:
* ``binomial_lookup_vec(keys, n, omega)``   — n static (constant-folded masks)
* ``binomial_lookup_dyn(keys, n, omega)``   — n traced (elastic clusters
  without recompilation; masks derived with a shift-or cascade)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

GOLDEN32 = np.uint32(0x9E3779B9)


def mix32(h: jax.Array) -> jax.Array:
    """murmur3 fmix32, elementwise on uint32."""
    h = h.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def hash_iter(key: jax.Array, i) -> jax.Array:
    """hash^i(key) — i may be a python int or a traced uint32 scalar."""
    i32 = jnp.asarray(i, dtype=jnp.uint32)
    return mix32(key.astype(jnp.uint32) + i32 * GOLDEN32)


def hash_pair(h: jax.Array, f: jax.Array) -> jax.Array:
    return mix32(h.astype(jnp.uint32) ^ mix32(f.astype(jnp.uint32) + GOLDEN32))


def _or_cascade(m: jax.Array) -> jax.Array:
    """Smear the highest set bit downward: m -> 2^(floor(log2 m)+1) - 1."""
    m = m | (m >> 1)
    m = m | (m >> 2)
    m = m | (m >> 4)
    m = m | (m >> 8)
    m = m | (m >> 16)
    return m


def next_pow2_u32(n: jax.Array) -> jax.Array:
    """Smallest power of two >= n, elementwise on uint32 (shift-or cascade).

    Pure u32 shift/or ops — usable both in a jit trace and inside a Pallas
    kernel body, so the dynamic-n kernel and ``binomial_lookup_dyn`` share
    one E/M derivation (the bit that must stay identical for kernel == ref).
    """
    return _or_cascade(jnp.asarray(n, jnp.uint32) - np.uint32(1)) + np.uint32(1)


def umod32(x: jax.Array, n: jax.Array) -> jax.Array:
    """Bit-exact ``x % n`` for uint32 vectors and a scalar 1 <= n < 2**31.

    Restoring long division — shift/compare/subtract only, no integer divide,
    so it lowers on the TPU VPU (which has none).  Library building block for
    in-kernel chain-style modulo (the table divert uses the far cheaper
    ``mulhi32`` Lemire reduction instead); the pure-jnp chain remap uses
    native ``%`` (XLA has integer remainder on CPU/GPU) and tests pin the
    two equal.
    """
    x = x.astype(jnp.uint32)
    n = jnp.asarray(n, jnp.uint32)
    r = jnp.zeros_like(x)
    for k in range(31, -1, -1):
        r = (r << 1) | ((x >> np.uint32(k)) & np.uint32(1))
        r = jnp.where(r >= n, r - n, r)
    return r


def mulhi32(a: jax.Array, b: jax.Array) -> jax.Array:
    """High 32 bits of the u32xu32 product, in pure u32 ops (no u64 path).

    ``(a * b) >> 32`` via 16-bit limb decomposition — exact for all inputs.
    This is the Lemire range reduction used by the replacement-table divert:
    ``mulhi32(H, p)`` maps a uniform u32 hash onto ``[0, p)`` with four
    multiplies and a few adds/shifts, instead of an integer divide (absent
    on the TPU VPU; a *vector*-divisor ``%`` is also ~10x the cost of these
    ~11 ops on XLA:CPU, measured at 1M lanes).
    """
    a = a.astype(jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    al, ah = a & np.uint32(0xFFFF), a >> 16
    bl, bh = b & np.uint32(0xFFFF), b >> 16
    ll = al * bl
    lh = al * bh
    hl = ah * bl
    mid = (ll >> 16) + (lh & np.uint32(0xFFFF)) + (hl & np.uint32(0xFFFF))
    return ah * bh + (lh >> 16) + (hl >> 16) + (mid >> 16)


# ---------------------------------------------------------------------------
# u64 session-key mixing in u32 limb arithmetic — the device half of the
# batched ingest path (DESIGN.md §9).  The TPU VPU has no 64-bit integer
# datapath, so raw u64 session ids ride in as (lo, hi) u32 pairs and
# splitmix64 is evaluated limb-wise; the router only ever consumes the LOW
# 32 bits of the mixed key (``_coerce_keys`` truncates u64 -> u32), so the
# final xor-shift needs just the low word.
# ---------------------------------------------------------------------------


def _xorshr64(lo: jax.Array, hi: jax.Array, s: int) -> tuple[jax.Array, jax.Array]:
    """(lo, hi) ^= (lo, hi) >> s for 0 < s < 32, in u32 limbs."""
    return lo ^ ((lo >> s) | (hi << (32 - s))), hi ^ (hi >> s)


def _mul64(lo: jax.Array, hi: jax.Array, c: int) -> tuple[jax.Array, jax.Array]:
    """(lo, hi) *= c mod 2**64 for a 64-bit constant c, in u32 limbs."""
    cl, ch = np.uint32(c & 0xFFFFFFFF), np.uint32(c >> 32)
    new_hi = mulhi32(lo, cl) + lo * ch + hi * cl
    return lo * cl, new_hi


def mix64_lo32(lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Low 32 bits of ``splitmix64(hi << 32 | lo)`` in pure u32 ops.

    Bit-exact with ``uint32(repro.core.bits.mix64(id))`` per lane — the
    device-word truncation of the scalar int-session-key oracle
    (``SessionRouter.session_key``).  ~30 VPU ops per lane; usable both in a
    jit trace and inside a Pallas kernel body, which is what lets the fused
    ingest kernel hash raw u64 ids and route them in the SAME dispatch.
    """
    lo, hi = lo.astype(jnp.uint32), hi.astype(jnp.uint32)
    lo, hi = _xorshr64(lo, hi, 30)
    lo, hi = _mul64(lo, hi, 0xBF58476D1CE4E5B9)
    lo, hi = _xorshr64(lo, hi, 27)
    lo, hi = _mul64(lo, hi, 0x94D049BB133111EB)
    return lo ^ ((lo >> 31) | (hi << 1))


def highest_one_bit_index(b: jax.Array) -> jax.Array:
    """floor(log2 b) for b >= 1, exact for all u32 (shift-or + popcount)."""
    b = b.astype(jnp.uint32)
    b = b | (b >> 1)
    b = b | (b >> 2)
    b = b | (b >> 4)
    b = b | (b >> 8)
    b = b | (b >> 16)
    v = b - ((b >> 1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    v = (v * np.uint32(0x01010101)) >> 24
    return v - np.uint32(1)


def relocate_within_level(b: jax.Array, h: jax.Array) -> jax.Array:
    """Alg. 2 vectorised: uniform relocation of b within its tree level.

    The level extent is read straight off the shift-or cascade —
    ``cascade(b) = 2^(d+1)-1`` so ``f = cascade >> 1 = 2^d-1`` and
    ``top = f+1 = 2^d`` — skipping the popcount multiply and variable shift
    of ``highest_one_bit_index`` (same values, fewer VPU ops per call, and
    this is called ω+1 times per lookup).
    """
    b = b.astype(jnp.uint32)
    f = _or_cascade(jnp.maximum(b, np.uint32(1))) >> 1
    top = f + np.uint32(1)
    i = hash_pair(h, f) & f
    return jnp.where(b < 2, b, top + i)


def _unrolled_body(keys_u32: jax.Array, E: jax.Array, M: jax.Array, n_u32: jax.Array, omega: int):
    """Shared ω-unrolled core. E/M/n may be python ints or traced scalars."""
    # hash_iter(key, i) == mix32(key + i*GOLDEN32): hoist the per-iteration
    # index multiply into a running accumulator (one u32 add per iteration,
    # exact in mod-2^32 arithmetic).
    kacc = keys_u32.astype(jnp.uint32)
    h0 = mix32(kacc)
    # Blocks A and C share the same expression over the ORIGINAL hash h0:
    # relocate(h0 & (M-1), h0) — compute once.
    fold = relocate_within_level(h0 & (M - np.uint32(1)), h0)
    result = jnp.zeros_like(keys_u32)
    found = jnp.zeros(keys_u32.shape, dtype=bool)
    hi = h0
    for i in range(omega):
        b = hi & (E - np.uint32(1))
        c = relocate_within_level(b, hi)
        in_a = c < M
        in_b = c < n_u32
        newly = (~found) & (in_a | in_b)
        val = jnp.where(in_a, fold, c)
        result = jnp.where(newly, val, result)
        found = found | in_a | in_b
        if i + 1 < omega:
            kacc = kacc + GOLDEN32
            hi = mix32(kacc)
    # Block C for lanes that never accepted.
    return jnp.where(found, result, fold)


@functools.partial(jax.jit, static_argnames=("n", "omega"))
def binomial_lookup_vec(keys: jax.Array, n: int, omega: int = 16) -> jax.Array:
    """Bulk lookup, n static: keys[..] (any int dtype) -> int32 buckets in [0, n)."""
    keys_u32 = keys.astype(jnp.uint32)
    if n <= 1:
        return jnp.zeros(keys.shape, dtype=jnp.int32)
    l = (n - 1).bit_length()  # ct: host-ok — n is static (static_argnames)
    E = np.uint32(1 << l)
    M = np.uint32(1 << (l - 1))
    out = _unrolled_body(keys_u32, E, M, np.uint32(n), omega)
    return out.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("omega",))
def binomial_lookup_dyn(keys: jax.Array, n: jax.Array, omega: int = 16) -> jax.Array:
    """Bulk lookup with traced n (elastic cluster size, no recompile)."""
    keys_u32 = keys.astype(jnp.uint32)
    n_u32 = jnp.asarray(n, dtype=jnp.uint32)
    E = next_pow2_u32(n_u32)
    M = E >> 1
    out = _unrolled_body(keys_u32, E, M, n_u32, omega)
    out = jnp.where(n_u32 <= 1, np.uint32(0), out)
    return out.astype(jnp.int32)
