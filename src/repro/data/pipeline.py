"""Deterministic synthetic LM data pipeline with consistent-hash sharding.

The corpus is a virtual set of ``num_shards`` file-shards; shard -> host
assignment goes through BinomialHash so that host joins/leaves (elastic data
parallelism) move the minimal set of shards, and a straggling host's shards
can be re-assigned deterministically.

Token streams are generated from splitmix64 counters, so any (shard, step)
pair is reproducible from scratch on any host — this is what makes restarts
and shard migration trivially consistent (no reader state to hand off).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import bits
from repro.placement.assignment import Assignment


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_shards: int = 1024
    seed: int = 0


class ShardedDataPipeline:
    """Yields {tokens, targets} batches for one host of an elastic fleet."""

    def __init__(self, cfg: DataConfig, num_hosts: int, host_id: int):
        self.cfg = cfg
        self.num_hosts = num_hosts
        self.host_id = host_id
        self.assignment = Assignment(list(range(cfg.num_shards)), num_hosts, "binomial")
        self._refresh_local()

    def _refresh_local(self):
        table = self.assignment.table()
        self.local_shards = sorted(k for k, h in table.items() if h == self.host_id)

    # -- elasticity -----------------------------------------------------------
    def rescale(self, new_num_hosts: int):
        """Returns the movement plan; only moved shards change hosts."""
        plan = self.assignment.resize(new_num_hosts)
        self.num_hosts = new_num_hosts
        self._refresh_local()
        return plan

    def steal_from(self, straggler_host: int, fraction: float = 0.5):
        """Straggler mitigation: deterministically take over a fraction of a
        slow host's shards (every healthy host computes the same plan)."""
        table = self.assignment.table()
        theirs = sorted(k for k, h in table.items() if h == straggler_host)
        stolen = [
            s
            for s in theirs
            if bits.mix64(s) % 1000 < fraction * 1000
            and binomial_rehost(s, self.num_hosts, straggler_host) == self.host_id
        ]
        self.local_shards = sorted(self.local_shards + stolen)
        return stolen

    # -- batches ----------------------------------------------------------------
    def _shard_tokens(self, shard: int, step: int, n: int) -> np.ndarray:
        base = bits.mix64((shard << 32) ^ step ^ (self.cfg.seed * 0x9E3779B97F4A7C15))
        out = np.empty(n, dtype=np.int64)
        x = base
        for i in range(n):
            x = bits.mix64(x + bits.GOLDEN64)
            out[i] = x % self.cfg.vocab_size
        return out

    def local_batch_size(self) -> int:
        return self.cfg.global_batch // self.num_hosts

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Deterministic batch for (host, step): rows round-robin over the
        host's shards."""
        bs = self.local_batch_size()
        L = self.cfg.seq_len
        rows = []
        for r in range(bs):
            shard = self.local_shards[(step * bs + r) % max(len(self.local_shards), 1)]
            rows.append(self._shard_tokens(shard, step * bs + r, L + 1))
        arr = np.stack(rows).astype(np.int32)
        return {"tokens": arr[:, :-1], "targets": arr[:, 1:]}


def binomial_rehost(shard: int, n_hosts: int, excluded: int) -> int:
    """Deterministic re-host of a shard avoiding ``excluded`` (rejection chain)."""
    from repro.core.binomial import binomial_lookup64

    h = binomial_lookup64(bits.mix64(shard), n_hosts)
    i = 1
    while h == excluded:
        h = binomial_lookup64(bits.hash_iter64(shard, i), n_hosts)
        i += 1
    return h
