"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --preset smoke --steps 20

Presets:
  smoke  — reduced config, tiny batch (CI / laptop CPU)
  100m   — ~100M-param same-family config, the assignment's example scale
  full   — the assigned full config (intended for the real mesh; on CPU use
           --steps 1 if you enjoy waiting)

Wires the whole substrate: CH-sharded data pipeline, AdamW/Adafactor with
ZeRO specs under a mesh, remat, checkpointing with auto-resume, gradient
compression flag, and straggler/elastic hooks.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import apply_overrides, get_config, reduced_config
from repro.data.pipeline import DataConfig, ShardedDataPipeline
from repro.models import model as M
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import make_optimizer
from repro.training.train_step import TrainHparams, make_train_state, make_train_step


def preset_config(arch: str, preset: str):
    if preset == "full":
        return get_config(arch)
    if preset == "smoke":
        return reduced_config(arch)
    if preset == "100m":
        cfg = get_config(arch)
        kw = dict(
            num_layers=max(len(cfg.pattern) * 2, 4),
            d_model=768,
            d_ff=2048,
            vocab_size=32000,
            dtype="float32",
            param_dtype="float32",
            remat="none",
            fsdp=False,
        )
        if cfg.attention != "none":
            kw.update(num_heads=12, num_kv_heads=max(1, min(cfg.num_kv_heads, 4)), head_dim=64)
        if cfg.moe is not None:
            kw.update(moe=dataclasses.replace(cfg.moe, num_experts=16, top_k=2, d_ff_expert=512),
                      moe_layer_start=1, num_layers=4)
        if cfg.mla is not None:
            kw.update(mla=dataclasses.replace(cfg.mla, q_lora_rank=256, kv_lora_rank=128,
                                              qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64))
        if cfg.ssm is not None:
            kw.update(ssm=dataclasses.replace(cfg.ssm, chunk=64))
        if cfg.rglru is not None:
            kw.update(rglru=dataclasses.replace(cfg.rglru, lru_width=768))
        if cfg.window is not None:
            kw.update(window=256)
        if cfg.mrope_sections:
            kw.update(mrope_sections=(16, 8, 8))
        return dataclasses.replace(cfg, **kw)
    raise KeyError(preset)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compression", default="none", choices=["none", "int8", "topk"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--hosts", type=int, default=1, help="simulated data hosts")
    ap.add_argument("--override", action="append", default=[], help="cfg key=value")
    args = ap.parse_args()

    cfg = apply_overrides(preset_config(args.arch, args.preset), args.override)
    if cfg.input_mode != "tokens":
        raise SystemExit(
            f"{args.arch} takes stubbed frontend embeddings; use examples/quickstart.py "
            "(train driver supports token archs)"
        )

    dcfg = DataConfig(cfg.vocab_size, args.seq, args.batch, num_shards=max(64, 4 * args.hosts))
    hosts = [ShardedDataPipeline(dcfg, args.hosts, h) for h in range(args.hosts)]

    def global_batch(step):
        parts = [h.batch(step) for h in hosts]
        return {
            k: jnp.asarray(np.concatenate([p[k] for p in parts])) for k in ("tokens", "targets")
        }

    opt = make_optimizer(args.optimizer, lr=args.lr, warmup=min(100, args.steps // 10 + 1),
                         total=args.steps)
    hp = TrainHparams(grad_accum=args.grad_accum, compression=args.compression)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    print(f"[train] {cfg.name} preset={args.preset} params={M.count_params(params)/1e6:.1f}M")
    state = make_train_state(params, opt, hp)
    step_fn = jax.jit(make_train_step(cfg, opt, hp), donate_argnums=(0,))
    mgr = CheckpointManager(args.ckpt_dir, n_nodes=4)

    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        print(f"[train] resuming from checkpoint step {latest}")
        state = mgr.restore(latest, jax.eval_shape(lambda: state))
        start = latest

    t0 = time.time()
    for step in range(start, args.steps):
        state, metrics = step_fn(state, global_batch(step))
        if step % max(1, args.steps // 20) == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tps = (step - start + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(
                f"  step {step:5d} loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} tok/s={tps:,.0f}"
            )
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            mgr.save_async(step, state)
    mgr.save(args.steps, state)
    print(f"[train] done in {time.time()-t0:.1f}s; checkpoint at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
