import os
DUMP_DIR = os.environ.get("REPRO_DUMP_DIR", "/tmp/repro_xla_dump")
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    f"--xla_dump_to={DUMP_DIR} --xla_dump_hlo_pass_re=spmd-partitioning "
    "--xla_dump_large_constants=false"
)

# ruff: noqa: E402  (the two lines above MUST precede any jax-touching import)
"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, print memory/cost analysis, and emit the roofline table inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
"""
import argparse
import glob
import json
import shutil
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.roofline.analysis import analyze, memory_summary
from repro.sharding import rules
from repro.training.optimizer import make_optimizer, zero1_pspecs
from repro.training.train_step import TrainHparams, make_train_state, make_train_step

ADAFACTOR_THRESHOLD = 1e11  # params above this use factored optimizer state


def model_flops_total(cfg, shape, n_active: int) -> float:
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n_active * tokens


def _shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    # serving decode uses the per-expert TP weight layout (EP is useless at
    # 1-token-per-expert capacities — §Perf cell 2); train/prefill keep EP
    # (storing F-sharded for train was tried and REFUTED — §Perf cell 3)
    elayout = "tp" if (shape.kind == "decode" and cfg.moe is not None) else "ep"
    with rules.mesh_context(mesh, fsdp=cfg.fsdp, expert_layout=elayout):
        params_struct = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        pspecs = rules.params_pspecs(params_struct)
        psh = _shardings(mesh, pspecs)
        n_params = M.count_params(params_struct)
        n_active = M.active_params(cfg, params_struct)
        batch_struct = M.input_specs(cfg, shape)
        bsh = _shardings(mesh, rules.batch_pspecs(batch_struct))

        if shape.kind == "train":
            opt_name = "adafactor" if n_params > ADAFACTOR_THRESHOLD else "adamw"
            opt = make_optimizer(opt_name)
            hp = TrainHparams()
            state_struct = jax.eval_shape(
                lambda: make_train_state(M.init_params(jax.random.PRNGKey(0), cfg), opt, hp)
            )
            opt_specs = zero1_pspecs(params_struct, pspecs, state_struct["opt"])
            state_specs = {"params": pspecs, "opt": opt_specs, "step": P()}
            state_sh = _shardings(mesh, state_specs)
            step_fn = make_train_step(cfg, opt, hp)
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, bsh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_struct, batch_struct)
        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                return M.prefill(params, batch, cfg, shape.seq_len)

            out_struct = jax.eval_shape(prefill_fn, params_struct, batch_struct)
            cache_sh = _shardings(mesh, rules.cache_pspecs(out_struct[0]))
            logits_sh = NamedSharding(mesh, rules.fitted(out_struct[1].shape, "dp", "tp"))
            lowered = jax.jit(
                prefill_fn,
                in_shardings=(psh, bsh),
                out_shardings=(cache_sh, logits_sh),
            ).lower(params_struct, batch_struct)
        else:  # decode
            cache_struct = jax.eval_shape(
                lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            cache_sh = _shardings(mesh, rules.cache_pspecs(cache_struct))

            def decode_fn(params, cache, batch):
                return M.decode_step(params, cache, batch, cfg)

            out_struct = jax.eval_shape(decode_fn, params_struct, cache_struct, batch_struct)
            logits_sh = NamedSharding(mesh, rules.fitted(out_struct[1].shape, "dp", "tp"))

            lowered = jax.jit(
                decode_fn,
                in_shardings=(psh, cache_sh, bsh),
                out_shardings=(cache_sh, logits_sh),
                donate_argnums=(1,),
            ).lower(params_struct, cache_struct, batch_struct)

        t_lower = time.time() - t0
        t0 = time.time()
        before = set(glob.glob(os.path.join(DUMP_DIR, "*after_spmd-partitioning*.txt")))
        compiled = lowered.compile()
        t_compile = time.time() - t0

        new = sorted(
            set(glob.glob(os.path.join(DUMP_DIR, "*after_spmd-partitioning*.txt"))) - before,
            key=os.path.getmtime,
        )
        hlo_text = open(new[-1]).read() if new else None
        mem = memory_summary(compiled)
        roof = analyze(
            compiled, mesh.size, model_flops_total(cfg, shape, n_active),
            hlo_text=hlo_text, pod_group_size=2 if multi_pod else 1,
        )
        result = {
            "status": "ok",
            "arch": arch,
            "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": mesh.size,
            "n_params": n_params,
            "n_active": n_active,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": mem,
            "roofline": roof.row(),
        }
        if verbose:
            per_dev = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)) / 2**30
            print(
                f"  [ok] {arch} × {shape_name} × {result['mesh']}: "
                f"params={n_params/1e9:.1f}B args+temp={per_dev:.2f}GiB/dev "
                f"flops/dev={roof.flops:.3e} coll={roof.coll_bytes/2**20:.1f}MiB/dev "
                f"dominant={roof.dominant} (lower {t_lower:.0f}s compile {t_compile:.0f}s)"
            )
        return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/out/dryrun.json")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    shutil.rmtree(DUMP_DIR, ignore_errors=True)
    os.makedirs(DUMP_DIR, exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    n_ok = n_skip = n_fail = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                key = f"{arch}|{shape}|{'multi' if multi else 'single'}"
                if results.get(key, {}).get("status") == "ok":
                    n_ok += 1
                    continue  # incremental re-runs
                print(f"[dryrun] {key}")
                try:
                    r = lower_cell(arch, shape, multi)
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    r = {"status": "failed", "error": f"{type(e).__name__}: {e}"}
                results[key] = r
                if r["status"] == "ok":
                    n_ok += 1
                elif r["status"] == "skipped":
                    n_skip += 1
                    print(f"  [skip] {r['reason']}")
                else:
                    n_fail += 1
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"\n[dryrun] ok={n_ok} skipped={n_skip} FAILED={n_fail} -> {args.out}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
