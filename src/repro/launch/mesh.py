"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is pure
data parallelism over DCN.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax call.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices the host actually has (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
