"""Serving driver: a replica tier fronted by the BinomialHash session router.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --replicas 3 --requests 24 --fail-replica 1
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import model as M
from repro.serving.engine import Request, ServingTier


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--fail-replica", type=int, default=-1)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tier = ServingTier(cfg, params, args.replicas, max_len=args.prompt_len + args.new_tokens + 2)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            f"session-{i}",
            rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
            n_new=args.new_tokens,
        )
        for i in range(args.requests)
    ]

    t0 = time.time()
    out = tier.serve(reqs)
    print(f"[serve] {len(out)} requests on {args.replicas} replicas in {time.time()-t0:.1f}s")
    routes = {r.session_id: tier.router.route(r.session_id) for r in reqs}
    load = np.bincount(list(routes.values()), minlength=args.replicas)
    print(f"[serve] replica load: {list(load)} (balance via BinomialHash)")

    if args.fail_replica >= 0:
        tier.fail(args.fail_replica)
        moved = sum(1 for r in reqs if tier.router.route(r.session_id) != routes[r.session_id])
        out2 = tier.serve(reqs)
        print(
            f"[serve] replica {args.fail_replica} failed: {moved}/{len(reqs)} sessions moved "
            f"(only the victims), {len(out2)} requests still served"
        )


if __name__ == "__main__":
    main()
