"""Pallas kernel vs pure-jnp oracle: shape/dtype/n/omega/block sweeps.

The kernel runs in interpret mode on CPU (per the dry-run contract); results
are integers so equality is exact."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.binomial import binomial_lookup32
from repro.kernels.binomial_hash import binomial_bulk_lookup_pallas
from repro.kernels.ops import binomial_bulk_lookup
from repro.kernels.ref import binomial_bulk_lookup_ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("shape", [(128,), (1, 128), (256, 128), (8, 8), (3, 5, 7), (1000,), (4096,)])
@pytest.mark.parametrize("n", [2, 11, 16, 1000])
def test_kernel_shapes(shape, n):
    keys = jnp.asarray(RNG.integers(0, 2**32, size=shape, dtype=np.uint32))
    out = binomial_bulk_lookup_pallas(keys, n, interpret=True, block_rows=8)
    ref = binomial_bulk_lookup_ref(keys, n)
    assert out.shape == shape and out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.uint16, np.int8, np.uint8])
def test_kernel_dtypes(dtype):
    info = np.iinfo(dtype)
    keys = jnp.asarray(RNG.integers(info.min, info.max, size=(512,), dtype=dtype))
    out = binomial_bulk_lookup_pallas(keys, 37, interpret=True, block_rows=4)
    ref = binomial_bulk_lookup_ref(keys, 37)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("omega", [1, 2, 8, 16, 32])
def test_kernel_omega(omega):
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(2048,), dtype=np.uint32))
    out = binomial_bulk_lookup_pallas(keys, 300, omega=omega, interpret=True, block_rows=8)
    ref = binomial_bulk_lookup_ref(keys, 300, omega=omega)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # and against the scalar oracle
    scal = [binomial_lookup32(int(k), 300, omega=omega) for k in np.asarray(keys)[:64]]
    np.testing.assert_array_equal(np.asarray(out)[:64], scal)


@pytest.mark.parametrize("block_rows", [1, 2, 8, 64])
def test_kernel_block_tiling(block_rows):
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(64, 128), dtype=np.uint32))
    out = binomial_bulk_lookup_pallas(keys, 77, interpret=True, block_rows=block_rows)
    ref = binomial_bulk_lookup_ref(keys, 77)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("n", [1, 2, 3])
def test_kernel_degenerate_n(n):
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(256,), dtype=np.uint32))
    out = binomial_bulk_lookup_pallas(keys, n, interpret=True, block_rows=2)
    assert int(jnp.max(out)) < n and int(jnp.min(out)) >= 0


def test_ops_dispatcher_cpu_path():
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(333,), dtype=np.uint32))
    auto = binomial_bulk_lookup(keys, 19)  # CPU backend -> ref path
    ref = binomial_bulk_lookup_ref(keys, 19)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))


def test_kernel_buckets_uniform():
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(1 << 16,), dtype=np.uint32))
    out = np.asarray(binomial_bulk_lookup_pallas(keys, 11, interpret=True))
    counts = np.bincount(out, minlength=11)
    rel = counts.std() / counts.mean()
    assert rel < 0.05
