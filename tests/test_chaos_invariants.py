"""Chaos invariants: seeded scenario grid over both engines, zero violations.

The scenario library is ``tests/chaos.py`` (also driven at ~1000-scenario
scale by ``benchmarks/bench_chaos.py``); this suite runs a deterministic
grid small enough for tier-1 but covering every scenario kind x engine.
"""
import numpy as np
import pytest

from chaos import (
    KINDS,
    PROBE_KEYS,
    _STORYLINES,
    _StreamingRunner,
    base_buckets,
    run_scenario,
)

ENGINES = ("binomial", "jump")
SEEDS = (11, 23, 37)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("seed", SEEDS)
def test_scenario_no_violations(engine, kind, seed):
    res = run_scenario(kind, engine, seed)
    assert res.violations == []
    assert res.events > 0
    assert res.replay_checks > 0


@pytest.mark.parametrize("engine", ENGINES)
def test_flap_measures_recovery_latency(engine):
    for seed in SEEDS:
        res = run_scenario("flap", engine, seed)
        assert res.violations == []
        # every flap scenario ends with the victim re-admitted, so at least
        # one fail->recover latency sample exists and all are positive
        assert res.recovery_latencies
        assert all(lat > 0 for lat in res.recovery_latencies)


@pytest.mark.parametrize("engine", ENGINES)
def test_cascade_reaches_unavailable_and_returns(engine):
    res = run_scenario("cascade", engine, seed=5)
    assert res.violations == []
    # the cascade drives the fleet through n_alive == 0: some probe
    # attempts are (correctly) answered with FleetUnavailableError
    assert res.route_unavailable > 0
    assert res.availability < 1.0


def test_streaming_telemetry_deterministic_under_virtual_clock():
    """Two identical virtual-clock runs serialize the ENTIRE telemetry
    plane identically — histogram contents, span ring, µs timestamps,
    device load totals (the registry's determinism contract)."""
    from repro.observability import to_json

    def run_once():
        runner = _StreamingRunner("overload", "binomial", 11, 8)
        _STORYLINES["overload"](runner)
        assert runner.res.violations == []
        return to_json(
            runner.metrics, trace=runner.trace, monitor=runner.monitor
        )

    assert run_once() == run_once()


def test_base_buckets_cached_and_in_range():
    b1 = base_buckets("binomial32", 8)
    b2 = base_buckets("binomial32", 8)
    assert b1 is b2  # cache hit
    assert b1.shape == PROBE_KEYS.shape
    assert ((b1 >= 0) & (b1 < 8)).all()
    assert np.unique(b1).size > 1  # keys actually spread


def test_unknown_kind_rejected():
    with pytest.raises(ValueError, match="unknown scenario kind"):
        run_scenario("meteor", "binomial", 0)
