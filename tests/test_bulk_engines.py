"""The engine-agnostic bulk routing API (DESIGN.md §10): the jump device
engine's bit-exactness chain (scalar oracle == jnp mirror == Pallas
kernel == BatchRouter), RouterSpec construction semantics, the deprecation
shims' bit-identical forwarding, and the curated ``repro`` public surface."""
import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bits
from repro.core.bulk import FleetState, RouterSpec
from repro.core.jump_jax import (
    JumpHash32,
    jump_lookup32,
    jump_lookup_dyn,
    jump_lookup_vec,
    jump_memento_route,
)
from repro.core.memento_jax import mask_words, pack_removed_mask, pack_table
from repro.kernels import ops
from repro.kernels.jump_hash import (
    jump_bulk_lookup_pallas_dyn,
    jump_route_pallas_fused,
)
from repro.serving.batch_router import BatchRouter
from repro.serving.router import SessionRouter, hash_session_ids

RNG = np.random.default_rng(31)


def _jump_oracle(n, **kw):
    """The scalar oracle of the jump device datapath."""
    return SessionRouter(n, engine="jump32", chain_bits=32, resolve="table", **kw)


def _oracle_state(router: SessionRouter, capacity: int = 64):
    dom = router.domain
    packed = pack_removed_mask(dom.removed, capacity)
    table = pack_table(dom.replacement_table, capacity)
    state = np.array([dom.total_count, dom.alive_count], np.uint32)
    return packed, table, state


# ---------------------------------------------------------------------------
# jump lookup: scalar == jnp == Pallas(interpret) incl. pow2 boundaries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("delta", [-1, 0, 1])
def test_jump_lookup_pow2_boundaries(k, delta):
    n = (1 << k) + delta
    if n < 2:
        pytest.skip("n < 2 is the degenerate single-bucket case")
    keys = RNG.integers(0, 2**32, size=(512,), dtype=np.uint32)
    dyn = np.asarray(jump_lookup_dyn(jnp.asarray(keys), np.uint32(n)))
    vec = np.asarray(jump_lookup_vec(jnp.asarray(keys), n))
    pal = np.asarray(
        jump_bulk_lookup_pallas_dyn(
            jnp.asarray(keys), np.uint32(n), interpret=True, block_rows=2
        )
    )
    scal = [jump_lookup32(int(x), n) for x in keys]
    np.testing.assert_array_equal(dyn, scal)
    np.testing.assert_array_equal(vec, scal)
    np.testing.assert_array_equal(pal, scal)


def test_jump_lookup_respects_omega_bound():
    """Non-default ω changes the (bounded) chain identically on both sides."""
    keys = RNG.integers(0, 2**32, size=(1024,), dtype=np.uint32)
    for omega in (1, 2, 4):
        out = np.asarray(jump_lookup_dyn(jnp.asarray(keys), np.uint32(1000), omega=omega))
        scal = [jump_lookup32(int(x), 1000, omega) for x in keys]
        np.testing.assert_array_equal(out, scal)
        assert (out >= 0).all() and (out < 1000).all()


def test_jump_engine_scalar_facade():
    eng = JumpHash32(5, omega=8)
    assert eng.size == 5
    assert eng.get_bucket(123) == jump_lookup32(123, 5, 8)
    assert eng.add_bucket() == 5 and eng.remove_bucket() == 5
    with pytest.raises(ValueError, match="last bucket"):
        JumpHash32(1).remove_bucket()


# ---------------------------------------------------------------------------
# fused jump route: jnp mirror == Pallas kernel == scalar table oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("removed", [[], [0], [3], [1, 4, 7], list(range(6))])
def test_jump_fused_route_matches_oracle(removed):
    oracle = _jump_oracle(12)
    for r in removed:
        oracle.fail(r)
    packed, table, state = _oracle_state(oracle)
    keys = RNG.integers(0, 2**32, size=(2048,), dtype=np.uint32)
    kw = dict(omega=16, n_words=mask_words(64))
    jnp_out = np.asarray(
        jump_memento_route(
            jnp.asarray(keys), jnp.asarray(packed), jnp.asarray(table),
            jnp.asarray(state), **kw,
        )
    )
    pal_out = np.asarray(
        jump_route_pallas_fused(
            jnp.asarray(keys), jnp.asarray(packed), jnp.asarray(table),
            jnp.asarray(state), mask_words(64), 64, interpret=True,
            block_rows=4,
        )
    )
    expect = [oracle.domain.locate(int(k)) for k in keys]
    np.testing.assert_array_equal(jnp_out, expect)
    np.testing.assert_array_equal(pal_out, expect)
    assert not np.isin(jnp_out, removed).any()


@pytest.mark.parametrize("interpret", [False, True])
def test_jump_batch_router_event_stream_parity(interpret):
    """BatchRouter(engine='jump') == the jump32 scalar oracle through a
    randomized fleet-event stream (both dispatch flavours)."""
    kw = dict(interpret=True, block_rows=8) if interpret else {}
    router = BatchRouter(10, engine="jump", **kw)
    oracle = _jump_oracle(10)
    keys = RNG.integers(0, 2**64, size=(4096,), dtype=np.uint64)
    rng = np.random.default_rng(5)
    sample = rng.choice(len(keys), size=256, replace=False)
    for _ in range(10):
        removed = sorted(router.domain.removed)
        alive = [
            b for b in range(router.domain.total_count) if b not in removed
        ]
        roll = rng.random()
        if removed and roll < 0.35:
            ev, arg = "recover", int(rng.choice(removed))
        elif roll < 0.6 and len(alive) > 2:
            ev, arg = "fail", int(rng.choice(alive[:-1]))
        elif roll < 0.8 and router.domain.total_count < router.capacity:
            ev, arg = "scale_up", None
        elif router.scalar.alive > 2:
            ev, arg = "scale_down", None
        else:
            ev, arg = "scale_up", None
        for r in (router, oracle):
            getattr(r, ev)(*(() if arg is None else (arg,)))
        out = router.route_keys_np(keys)
        expect = [oracle.domain.locate(int(keys[j])) for j in sample]
        np.testing.assert_array_equal(out[sample], expect)


def test_jump_route_ids_matches_prehash():
    router = BatchRouter(16, engine="jump")
    router.fail(3)
    ids = RNG.integers(0, 2**64, size=(4096,), dtype=np.uint64)
    fused = np.asarray(router.route_ids(ids))
    prehash = router.route_keys_np(hash_session_ids(ids))
    np.testing.assert_array_equal(fused, prehash)


def test_jump_batch_router_pow2_fleet_boundaries():
    """Parity at fleet sizes crossing pow2 boundaries (the E/M edge)."""
    for n in (2, 3, 4, 7, 8, 9, 31, 32, 33):
        router = BatchRouter(n, capacity=128, engine="jump")
        oracle = _jump_oracle(n)
        keys = RNG.integers(0, 2**64, size=(1024,), dtype=np.uint64)
        np.testing.assert_array_equal(
            router.route_keys_np(keys),
            [oracle.domain.locate(int(k)) for k in keys],
        )


# ---------------------------------------------------------------------------
# RouterSpec semantics
# ---------------------------------------------------------------------------


def test_router_spec_equals_kwargs_construction():
    spec = RouterSpec(engine="jump", capacity=128, omega=8)
    a = BatchRouter(6, spec)
    b = BatchRouter(6, engine="jump", capacity=128, omega=8)
    assert a.spec == b.spec
    keys = RNG.integers(0, 2**64, size=(1024,), dtype=np.uint64)
    np.testing.assert_array_equal(a.route_keys_np(keys), b.route_keys_np(keys))


def test_router_spec_conflicts_and_validation():
    with pytest.raises(ValueError, match="not both"):
        BatchRouter(4, RouterSpec(), engine="jump")
    with pytest.raises(KeyError, match="unknown bulk engine"):
        BatchRouter(4, engine="binomial64k")
    with pytest.raises(ValueError, match="power of two"):
        RouterSpec(capacity=48)
    with pytest.raises(ValueError, match="omega"):
        RouterSpec(omega=0)
    with pytest.raises(ValueError, match="block_rows"):
        RouterSpec(block_rows=0)
    # frozen: specs are hashable config values, not mutable bags
    with pytest.raises(dataclasses.FrozenInstanceError):
        RouterSpec().capacity = 128
    assert RouterSpec(capacity=64).n_words == 2
    assert RouterSpec(capacity=64).n_slots == 64


# ---------------------------------------------------------------------------
# deprecation shims: bit-identical forwarding, warn once
# ---------------------------------------------------------------------------


def _shim_operands():
    oracle = SessionRouter(12, engine="binomial32", chain_bits=32, resolve="table")
    for r in (2, 7):
        oracle.fail(r)
    packed, table, state = _oracle_state(oracle)
    return (
        jnp.asarray(packed), jnp.asarray(table), jnp.asarray(state),
    )


def test_binomial_route_bulk_shim_is_bit_identical():
    packed, table, state = _shim_operands()
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(2048,), dtype=np.uint32))
    ops._warned.clear()
    with pytest.warns(DeprecationWarning, match="binomial_route_bulk"):
        old = ops.binomial_route_bulk(
            keys, packed, table, state,
            n_words=mask_words(64), n_slots=64, use_pallas=False,
        )
    new = ops.route_bulk(
        keys,
        FleetState(packed, table, state),
        RouterSpec(engine="binomial", capacity=64, use_pallas=False),
    )
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))
    # warn ONCE: the second legacy call passes silently
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        ops.binomial_route_bulk(
            keys, packed, table, state,
            n_words=mask_words(64), n_slots=64, use_pallas=False,
        )


def test_binomial_route_ingest_bulk_shim_is_bit_identical():
    packed, table, state = _shim_operands()
    ids = RNG.integers(0, 2**64, size=(2048,), dtype=np.uint64)
    lo, hi = bits.np_split64(ids)
    ops._warned.clear()
    with pytest.warns(DeprecationWarning, match="binomial_route_ingest_bulk"):
        old = ops.binomial_route_ingest_bulk(
            jnp.asarray(lo), jnp.asarray(hi), packed, table, state,
            n_words=mask_words(64), n_slots=64, use_pallas=False,
        )
    new = ops.route_ingest_bulk(
        jnp.asarray(lo), jnp.asarray(hi),
        FleetState(packed, table, state),
        RouterSpec(engine="binomial", capacity=64, use_pallas=False),
    )
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_make_sharded_route_shim_is_bit_identical():
    import jax

    mesh = jax.make_mesh((1,), ("data",))
    packed, table, state = _shim_operands()
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(1024,), dtype=np.uint32))
    spec = RouterSpec(engine="binomial", capacity=64, use_pallas=False)
    ops._warned.clear()
    with pytest.warns(DeprecationWarning, match="make_sharded_route"):
        legacy = ops.make_sharded_route(
            mesh, "data", n_words=mask_words(64), n_slots=64, use_pallas=False
        )
    old = legacy(keys, packed, table, state)
    new = ops.make_sharded_route(mesh, spec)(
        keys, FleetState(packed, table, state)
    )
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_shim_accepts_non_pow2_n_slots_on_every_path():
    """Pre-spec callers could pack for any slot bound (lane-padded, not
    pow2-padded); the shim re-pads to the rounded-up capacity, so both
    dispatch flavours keep returning the pre-spec results."""
    oracle = SessionRouter(
        200, engine="binomial32", chain_bits=32, resolve="table"
    )
    for r in (3, 77, 150):
        oracle.fail(r)
    dom = oracle.domain
    packed = pack_removed_mask(dom.removed, 300)  # width 128 words
    table = pack_table(dom.replacement_table, 300)  # width 384 < pow2(300)
    state = np.array([dom.total_count, dom.alive_count], np.uint32)
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(1024,), dtype=np.uint32))
    kw = dict(n_words=mask_words(300), n_slots=300)
    ops._warned.clear()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        jnp_out = ops.binomial_route_bulk(
            keys, packed, table, state, use_pallas=False, **kw
        )
        pal_out = ops.binomial_route_bulk(
            keys, packed, table, state, interpret=True, block_rows=4, **kw
        )
    expect = [dom.locate(int(k)) for k in np.asarray(keys)]
    np.testing.assert_array_equal(np.asarray(jnp_out), expect)
    np.testing.assert_array_equal(np.asarray(pal_out), expect)


def test_shim_rejects_inconsistent_n_words():
    packed, table, state = _shim_operands()
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(128,), dtype=np.uint32))
    ops._warned.clear()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with pytest.raises(ValueError, match="disagrees with n_slots"):
            ops.binomial_route_bulk(
                keys, packed, table, state, n_words=7, n_slots=64,
                use_pallas=False,
            )


# ---------------------------------------------------------------------------
# MoE hash router: pluggable engine
# ---------------------------------------------------------------------------


def test_moe_hash_router_jump_engine():
    import jax
    from repro.configs import reduced_config
    from repro.models.layers import moe

    cfg = reduced_config("qwen3-moe-235b-a22b")
    token_ids = jnp.asarray(RNG.integers(0, 50000, size=(2, 16), dtype=np.int32))
    x = jnp.zeros((2, 16, cfg.d_model), jnp.float32)

    def ids_for(**moe_kw):
        mcfg = dataclasses.replace(cfg.moe, router="hash", **moe_kw)
        c = dataclasses.replace(cfg, moe=mcfg)
        p = moe.init_moe(jax.random.PRNGKey(0), c)
        ids, gates, aux = moe.route(p, x, token_ids, 3, c)
        return np.asarray(ids)

    jump_static = ids_for(router_hash_engine="jump")
    jump_dyn = ids_for(router_hash_engine="jump", router_dynamic_n=True)
    np.testing.assert_array_equal(jump_static, jump_dyn)
    assert (jump_static >= 0).all()
    assert (jump_static < cfg.moe.num_experts).all()
    # the config actually switches the lookup family
    assert not np.array_equal(jump_static, ids_for(router_hash_engine="binomial"))
    with pytest.raises(KeyError, match="unknown bulk engine"):
        ids_for(router_hash_engine="nope")


# ---------------------------------------------------------------------------
# curated public surface
# ---------------------------------------------------------------------------


def test_repro_public_api_resolves():
    import repro

    assert set(repro.__all__) == set(repro._EXPORTS)
    for name in repro.__all__:
        assert getattr(repro, name) is not None
    assert repro.BatchRouter is BatchRouter
    assert repro.RouterSpec is RouterSpec
    assert "BatchRouter" in dir(repro)
    with pytest.raises(AttributeError):
        repro.not_a_thing
