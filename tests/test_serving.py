"""Serving tier: session affinity, failure rerouting, end-to-end generation."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import model as M
from repro.serving.engine import Replica, Request, ServingTier
from repro.serving.router import SessionRouter


def test_session_affinity():
    r = SessionRouter(8)
    sessions = [f"user-{i}" for i in range(200)]
    first = {s: r.route(s) for s in sessions}
    for _ in range(3):
        assert all(r.route(s) == first[s] for s in sessions)
    assert r.stats.moved_sessions == 0


def test_failure_moves_only_affected_sessions():
    r = SessionRouter(8)
    sessions = [f"s{i}" for i in range(2000)]
    before = {s: r.route(s) for s in sessions}
    r.fail(2)
    for s in sessions:
        now = r.route(s)
        if before[s] != 2:
            assert now == before[s]
        else:
            assert now != 2
    r.recover(2)
    assert all(r.route(s) == before[s] for s in sessions)


def test_scale_up_balance():
    r = SessionRouter(4)
    sessions = [f"s{i}" for i in range(4000)]
    before = {s: r.route(s) for s in sessions}
    new = r.scale_up()
    moved = [s for s in sessions if r.route(s) != before[s]]
    assert all(r.route(s) == new for s in moved)
    assert 0.1 < len(moved) / len(sessions) < 0.3  # ~1/5


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced_config("stablelm-3b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_replica_generates(tiny_model):
    cfg, params = tiny_model
    rep = Replica(cfg, params, max_len=32)
    prompts = np.arange(12, dtype=np.int32).reshape(2, 6) % cfg.vocab_size
    out = rep.generate(prompts, n_new=5)
    assert out.shape == (2, 5)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()
    # determinism
    out2 = rep.generate(prompts, n_new=5)
    assert (out == out2).all()


def test_serving_tier_end_to_end(tiny_model):
    cfg, params = tiny_model
    tier = ServingTier(cfg, params, n_replicas=3, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [
        Request(f"sess-{i}", rng.integers(0, cfg.vocab_size, size=6).astype(np.int32), n_new=4)
        for i in range(9)
    ]
    res = tier.serve(reqs)
    assert set(res) == {r.session_id for r in reqs}
    assert all(v.shape == (4,) for v in res.values())
    # same session rides the same replica; replies deterministic
    res2 = tier.serve(reqs)
    for k in res:
        assert (res[k] == res2[k]).all()


def test_serving_tier_empty_batch(tiny_model):
    """serve([]) must be a clean no-op, not a zero-row kernel dispatch."""
    cfg, params = tiny_model
    tier = ServingTier(cfg, params, n_replicas=2, max_len=32)
    assert tier.serve([]) == {}
    assert tier.router.route_batch([]).shape == (0,)
    assert tier.router.stats.lookups == 0


def test_serving_tier_failover(tiny_model):
    cfg, params = tiny_model
    tier = ServingTier(cfg, params, n_replicas=3, max_len=32)
    rng = np.random.default_rng(1)
    reqs = [
        Request(f"sess-{i}", rng.integers(0, cfg.vocab_size, size=5).astype(np.int32), n_new=3)
        for i in range(6)
    ]
    routes_before = {r.session_id: tier.router.route(r.session_id) for r in reqs}
    victim = routes_before[reqs[0].session_id]
    tier.fail(victim)
    res = tier.serve(reqs)  # still serves everyone
    assert set(res) == {r.session_id for r in reqs}
    for r in reqs:
        now = tier.router.route(r.session_id)
        if routes_before[r.session_id] != victim:
            assert now == routes_before[r.session_id]
        else:
            assert now != victim


def test_serving_tier_elastic_scale_and_last_slot_fail(tiny_model):
    """Replica list stays in lockstep with the router's slot space across
    scale events AND last-slot failures (which are LIFO retirements)."""
    cfg, params = tiny_model
    tier = ServingTier(cfg, params, n_replicas=3, max_len=32)
    new = tier.scale_up(params)
    assert new == 3 and len(tier.replicas) == 4
    tier.fail(3)  # last slot: true LIFO removal, slot space shrinks
    assert tier.router.domain.total_count == 3
    assert len(tier.replicas) == 3
    assert tier.scale_up(params) == 3  # no stale replica misalignment
    assert len(tier.replicas) == 4
    tier.fail(1)  # interior slot: tombstone, list untouched
    assert len(tier.replicas) == 4
    gone = tier.scale_down()  # retires slot 3
    assert gone == 3 and len(tier.replicas) == 3
    rng = np.random.default_rng(2)
    reqs = [
        Request(f"e-{i}", rng.integers(0, cfg.vocab_size, size=5).astype(np.int32), n_new=2)
        for i in range(6)
    ]
    res = tier.serve(reqs)  # still serves everyone on replicas {0, 2}
    assert set(res) == {r.session_id for r in reqs}
    assert all(tier.router.route(r.session_id) in (0, 2) for r in reqs)


def test_tier_events_flow_through_attached_lifecycle(tiny_model):
    """Tier-level fail/recover/scale are journaled via the lifecycle
    manager, not smuggled past it straight to the router (a bypassed event
    would break replay parity and never sync the placement repairer)."""
    cfg, params = tiny_model
    tier = ServingTier(cfg, params, n_replicas=4, max_len=32)
    mgr = tier.attach_lifecycle()
    tier.fail(1)
    tier.recover(1)
    assert tier.scale_up(params) == 4
    assert tier.scale_down() == 4
    assert mgr.epoch == 4  # every tier event landed in the journal...
    mgr.verify_replay()  # ...and the journal replays to the live state


def test_repair_converges_under_pure_serve_traffic(tiny_model):
    """Satellite regression: an attached PlacementRepairer's backlog drains
    through ``tier.serve`` alone — serve's lifecycle tick IS the repair
    cadence; no manual ``repairer.tick()`` anywhere."""
    from repro.placement.store import StorePlacement
    from repro.serving.lifecycle import ManualClock, PlacementRepairer

    cfg, params = tiny_model
    tier = ServingTier(cfg, params, n_replicas=6, max_len=32)
    # manual clock: serve's detector polls must not mistake slow test wall
    # time for heartbeat silence
    mgr = tier.attach_lifecycle(clock=ManualClock())
    store = StorePlacement(tier.router, r=3)
    keys = np.random.default_rng(5).integers(0, 1 << 32, 256, np.uint32)
    store.register(keys)
    repairer = PlacementRepairer(store, mgr, budget_per_tick=64)

    tier.fail(2)  # a TIER event must seed the repair backlog by itself
    assert repairer.backlog > 0
    rng = np.random.default_rng(6)
    reqs = [
        Request(f"r-{i}", rng.integers(0, cfg.vocab_size, size=4).astype(np.int32), n_new=1)
        for i in range(4)
    ]
    for _ in range(20):
        if not repairer.backlog:
            break
        tier.serve(reqs)
    assert repairer.backlog == 0
    assert (store.reachable_counts() == 3).all()
    repairer.verify_placement_replay()
