"""MoE layer: dispatch correctness vs a dense reference, hash-router balance
(the paper's Eq. 3 bound) and elastic expert scaling (monotonicity)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import analysis
from repro.core.binomial_jax import binomial_lookup_dyn, binomial_lookup_vec, mix32
from repro.models.layers import moe as moe_mod
from repro.models.layers.moe import (
    GOLDEN32,
    _capacity,
    _dispatch_local,
    apply_moe,
    init_moe,
    route,
)


def _cfg(router="topk", E=8, k=2, cf=8.0):
    cfg = reduced_config("qwen3-moe-235b-a22b")
    return dataclasses.replace(
        cfg,
        moe=dataclasses.replace(cfg.moe, router=router, num_experts=E, top_k=k, capacity_factor=cf),
    )


def _dense_reference(p, x, expert_ids, gates):
    """Naive per-token loop over selected experts (no capacity)."""
    N, D = x.shape
    out = np.zeros((N, D), np.float32)
    wi, wg, wo = np.asarray(p["experts_wi"]), np.asarray(p["experts_wg"]), np.asarray(p["experts_wo"])
    xs = np.asarray(x)
    for t in range(N):
        for e, g in zip(np.asarray(expert_ids)[t], np.asarray(gates)[t]):
            h = xs[t] @ wi[e]
            h = (h / (1 + np.exp(-h))) * (xs[t] @ wg[e])  # silu gate
            out[t] += g * (h @ wo[e])
    return out


def test_dispatch_matches_dense_reference():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    N, D = 24, cfg.d_model
    x = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32)) * 0.1
    eids = jnp.asarray(rng.integers(0, 8, (N, 2)).astype(np.int32))
    gates = jnp.asarray(rng.uniform(0.2, 0.8, (N, 2)).astype(np.float32))
    C = _capacity(cfg, N)
    y = _dispatch_local(x, eids, gates, p["experts_wi"], p["experts_wg"], p["experts_wo"], 0, 8, C)
    ref = _dense_reference(p, x, eids, gates)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_dispatch_sharded_offsets_compose():
    """Splitting experts into two halves (EP shards) must sum to the full result."""
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    N, D = 16, cfg.d_model
    x = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32)) * 0.1
    eids = jnp.asarray(rng.integers(0, 8, (N, 2)).astype(np.int32))
    gates = jnp.asarray(rng.uniform(size=(N, 2)).astype(np.float32))
    C = _capacity(cfg, N)
    full = _dispatch_local(x, eids, gates, p["experts_wi"], p["experts_wg"], p["experts_wo"], 0, 8, C)
    lo = _dispatch_local(x, eids, gates, p["experts_wi"][:4], p["experts_wg"][:4], p["experts_wo"][:4], 0, 4, C)
    hi = _dispatch_local(x, eids, gates, p["experts_wi"][4:], p["experts_wg"][4:], p["experts_wo"][4:], 4, 4, C)
    np.testing.assert_allclose(np.asarray(lo + hi), np.asarray(full), rtol=2e-4, atol=2e-4)


def test_capacity_drops_overflow():
    cfg = _cfg(cf=0.25)  # tiny capacity -> drops
    p = init_moe(jax.random.PRNGKey(0), cfg)
    N, D = 32, cfg.d_model
    x = jnp.ones((N, D), jnp.float32) * 0.1
    # every token sends one assignment to expert 0 and one to expert 1
    eids = jnp.tile(jnp.asarray([[0, 1]], jnp.int32), (N, 1))
    gates = jnp.full((N, 2), 0.5, jnp.float32)
    C = _capacity(cfg, N)
    y = _dispatch_local(x, eids, gates, p["experts_wi"], p["experts_wg"], p["experts_wo"], 0, 8, C)
    norms = jnp.linalg.norm(y, axis=-1)
    assert int(jnp.sum(norms > 1e-7)) == min(N, C)  # only C tokens per expert served


def test_hash_router_balance_matches_paper_bound():
    """Expert load from the BinomialHash router obeys the Eq. (3) regime."""
    cfg = _cfg(router="hash", E=11, k=1)
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, 50000, (64, 256)), jnp.int32)
    eids, gates, aux = route({}, None, tokens, 0, cfg)
    assert float(aux) == 0.0  # no aux loss needed
    counts = np.bincount(np.asarray(eids).reshape(-1), minlength=11)
    rel_std = counts.std() / counts.mean()
    assert rel_std < 0.05, rel_std


def test_hash_router_elastic_expert_scaling():
    """Growing the expert pool E -> E+1 moves only ~1/(E+1) of assignments,
    all onto the NEW expert (the paper's monotonicity, in-graph)."""
    tokens = jnp.asarray(np.random.default_rng(1).integers(0, 1 << 31, (1, 20000)), jnp.int32)
    keys = mix32(tokens.astype(jnp.uint32) ^ np.uint32(12345))
    for E in (8, 11, 16):
        a = np.asarray(binomial_lookup_vec(keys, E))
        b = np.asarray(binomial_lookup_vec(keys, E + 1))
        moved = a != b
        assert (b[moved] == E).all()
        assert moved.mean() < 1.6 / (E + 1)


def test_hash_router_deterministic_across_layers():
    cfg = _cfg(router="hash", E=8, k=2)
    tokens = jnp.asarray(np.arange(128).reshape(2, 64), jnp.int32)
    e1, _, _ = route({}, None, tokens, 3, cfg)
    e2, _, _ = route({}, None, tokens, 3, cfg)
    e3, _, _ = route({}, None, tokens, 4, cfg)
    assert (np.asarray(e1) == np.asarray(e2)).all()
    assert (np.asarray(e1) != np.asarray(e3)).any()  # layer salt decorrelates


def _per_k_reference(tokens, layer_salt, E, K, dynamic, omega=16):
    """The pre-fusion per-k loop, verbatim — the bit-exactness oracle for
    the single-dispatch (B,S,K) hash router."""
    keys = tokens.astype(jnp.uint32)
    salt0 = jnp.asarray(layer_salt, jnp.uint32) * np.uint32(1000003)
    ids = []
    for k in range(K):
        salt = (salt0 + np.uint32(k * 7919 + 1)) * GOLDEN32
        kk = mix32(keys ^ salt)
        if dynamic:
            ids.append(binomial_lookup_dyn(kk, jnp.uint32(E), omega=omega))
        else:
            ids.append(binomial_lookup_vec(kk, E, omega=omega))
    return jnp.stack(ids, axis=-1)


@pytest.mark.parametrize("dynamic", [False, True], ids=["static_E", "dynamic_E"])
@pytest.mark.parametrize("E,K", [(8, 1), (11, 3), (64, 8)])
def test_hash_router_fused_k_matches_per_k_loop(dynamic, E, K):
    """The broadcast-salted (B,S,K) single-dispatch router is bit-exact with
    the former K-iteration loop, for static and dynamic expert counts."""
    cfg = _cfg(router="hash", E=E, k=K)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, router_dynamic_n=dynamic)
    )
    tokens = jnp.asarray(
        np.random.default_rng(E * 31 + K).integers(0, 150000, (3, 127)), jnp.int32
    )
    for salt in (0, 5):
        eids, gates, aux = route({}, None, tokens, salt, cfg)
        ref = _per_k_reference(tokens, salt, E, K, dynamic)
        np.testing.assert_array_equal(np.asarray(eids), np.asarray(ref))
        assert eids.shape == (3, 127, K) and eids.dtype == jnp.int32
        assert float(aux) == 0.0
        np.testing.assert_allclose(np.asarray(gates), 1.0 / K)


@pytest.mark.parametrize("dynamic", [False, True], ids=["static_E", "dynamic_E"])
def test_hash_router_is_one_lookup_dispatch_for_all_k(dynamic, monkeypatch):
    """All K expert choices come from ONE router lookup call (the fused
    (B,S,K) dispatch), not K — and only the matching flavour is touched.

    The router resolves its lookup from ``BULK_ENGINES`` per call, so
    swapping the entry intercepts the dispatches."""
    from repro.core import registry

    calls = {"vec": 0, "dyn": 0}
    real_vec, real_dyn = binomial_lookup_vec, binomial_lookup_dyn

    def counting_vec(*a, **k):
        calls["vec"] += 1
        return real_vec(*a, **k)

    def counting_dyn(*a, **k):
        calls["dyn"] += 1
        return real_dyn(*a, **k)

    monkeypatch.setitem(
        registry.BULK_ENGINES,
        "binomial",
        dataclasses.replace(
            registry.BULK_ENGINES["binomial"],
            lookup_vec=counting_vec,
            lookup_dyn=counting_dyn,
        ),
    )
    cfg = _cfg(router="hash", E=32, k=8)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, router_dynamic_n=dynamic)
    )
    tokens = jnp.asarray(np.arange(256).reshape(2, 128), jnp.int32)
    eids, _, _ = route({}, None, tokens, 2, cfg)
    assert eids.shape == (2, 128, 8)
    assert calls == ({"vec": 0, "dyn": 1} if dynamic else {"vec": 1, "dyn": 0})


def test_apply_moe_full_layer_shapes():
    cfg = _cfg(router="sigmoid")
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.1
    toks = jnp.zeros((2, 16), jnp.int32)
    y, aux = apply_moe(p, x, toks, 0, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
