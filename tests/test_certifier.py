"""Constant-time certifier tests (repro.analysis; DESIGN.md §11).

Two directions of proof:

* every *registered* engine datapath certifies clean (the real contract),
  and the paper-faithful chain baseline passes only through its explicit,
  reasoned waiver — never silently;
* *seeded violations* — a data-dependent ``while_loop``, an f64 leak, a
  quadratic unroll, a host callback, an in-trace transfer — each trip
  exactly the invariant built to catch them, and the same seeded engine
  makes the CLI exit nonzero.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.certify import (
    EngineContract,
    certify_all,
    certify_callable,
)
from repro.analysis.lint import lint_paths, lint_source
from repro.analysis.markers import constant_time_waiver, waivers_of
from repro.analysis.report import FAIL, PASS, SKIPPED, WAIVED

#: tiny contract for fixture traces — invariants don't care about scale
SMALL = EngineContract(batch=8, capacity=64, block_rows=8)


def _check(report, invariant):
    (res,) = [c for c in report.checks if c.invariant == invariant]
    return res


def _tracer(fn, *operands):
    """omega -> closed jaxpr of ``fn(*operands, omega)``."""
    return lambda om: jax.make_jaxpr(lambda *a: fn(*a, om))(*operands)


KEYS8 = np.arange(8, dtype=np.uint32)


# ---------------------------------------------------------------------------
# seeded violations — each trips exactly its invariant
# ---------------------------------------------------------------------------


def _while_route(keys, omega):
    """Trip count depends on key VALUES — the storm-cliff bug class."""

    def cond(carry):
        k, _ = carry
        return jnp.any(k > 0)

    def body(carry):
        k, i = carry
        return (k >> 1).astype(jnp.uint32), i + np.uint32(1)

    _, steps = jax.lax.while_loop(
        cond, body, (keys.astype(jnp.uint32), np.uint32(0))
    )
    return jnp.full(keys.shape, steps.astype(jnp.int32))


def test_data_dependent_while_fails_certification():
    report = certify_callable(
        "fixture", "route/jnp", _tracer(_while_route, KEYS8), contract=SMALL
    )
    res = _check(report, "while-free")
    assert res.status == FAIL
    assert "while" in res.detail
    assert not report.ok


def test_waiver_downgrades_while_to_waived_with_reason():
    report = certify_callable(
        "fixture",
        "route/jnp",
        _tracer(_while_route, KEYS8),
        contract=SMALL,
        waivers={"while-free": "fixture: bounded by construction"},
    )
    res = _check(report, "while-free")
    assert res.status == WAIVED
    assert res.waiver == "fixture: bounded by construction"
    assert report.ok  # waived is not failed...
    assert report.to_dict()["while-free"]["waiver"]  # ...but never silent


def _f64_route(keys, omega):
    """Accumulates in float64 — breaks the u32-limb dtype closure."""
    acc = keys.astype(jnp.float64)
    for _ in range(omega):
        acc = acc * 1.0000001 + 1.0
    return acc.astype(jnp.int32)


def test_f64_leak_fails_dtype_closed():
    report = certify_callable(
        "fixture", "route/jnp", _tracer(_f64_route, KEYS8), contract=SMALL
    )
    res = _check(report, "dtype-closed")
    assert res.status == FAIL
    assert "float64" in res.detail


def _quadratic_route(keys, omega):
    """O(ω²) ops — unroll depth is NOT the declared ω."""
    out = keys.astype(jnp.uint32)
    for i in range(omega):
        for _ in range(i + 1):
            out = out + np.uint32(1)
    return out.astype(jnp.int32)


def test_quadratic_unroll_fails_affine():
    report = certify_callable(
        "fixture", "route/jnp", _tracer(_quadratic_route, KEYS8), contract=SMALL
    )
    assert _check(report, "unroll-affine").status == FAIL


def _callback_route(keys, omega):
    jax.debug.print("routing {n} keys", n=keys.shape[0])
    return keys.astype(jnp.int32)


def test_host_callback_fails():
    report = certify_callable(
        "fixture",
        "route/jnp",
        _tracer(_callback_route, KEYS8),
        contract=SMALL,
        check_affine=False,
    )
    assert _check(report, "callback-free").status == FAIL


def _transfer_route(keys, omega):
    lut = jax.device_put(np.arange(8, dtype=np.int32))
    return lut[keys.astype(jnp.int32) % 8]


def test_in_trace_device_put_fails_transfer_count():
    report = certify_callable(
        "fixture",
        "route/jnp",
        _tracer(_transfer_route, KEYS8),
        contract=SMALL,
        check_affine=False,
    )
    res = _check(report, "transfer-count")
    assert res.status == FAIL
    assert "1 device_put" in res.detail


# ---------------------------------------------------------------------------
# the real contract: every registered engine certifies clean
# ---------------------------------------------------------------------------


def test_every_registered_engine_certifies():
    from repro.core.registry import BULK_ENGINES

    report = certify_all()
    assert report.ok, report.render()
    by_engine = {}
    for t in report.targets:
        by_engine.setdefault(t.engine, set()).add(t.target)
    # jnp mirror AND pallas kernel certified for every datapath of every entry
    for name in BULK_ENGINES:
        assert by_engine[name] >= {
            "route/jnp", "ingest/jnp", "lookup_dyn/jnp",
            "route/pallas", "ingest/pallas", "lookup_dyn/pallas",
        }


def test_chain_baseline_passes_only_via_waiver():
    report = certify_all(engines=[])
    (chain,) = [t for t in report.targets if t.target == "chain/memento_remap"]
    res = _check(chain, "while-free")
    assert res.status == WAIVED
    assert "max_chain" in res.waiver
    assert _check(chain, "unroll-affine").status == SKIPPED
    # remove the waiver and the same trace goes red — the marker is
    # load-bearing, not decorative
    from repro.analysis.certify import certify_chain_baseline
    from repro.core import memento_jax

    unmarked = certify_callable(
        "binomial",
        "chain/memento_remap",
        lambda om: jax.make_jaxpr(
            lambda k, b, m, n, f: memento_jax.memento_remap(k, b, m, n, f)
        )(
            KEYS8,
            np.zeros(8, np.int32),
            np.zeros(64, bool),
            np.uint32(8),
            np.uint32(0),
        ),
        contract=SMALL,
        waivers={},
        check_affine=False,
    )
    assert _check(unmarked, "while-free").status == FAIL
    assert certify_chain_baseline().ok


# ---------------------------------------------------------------------------
# waiver markers
# ---------------------------------------------------------------------------


def test_waiver_requires_reason():
    with pytest.raises(ValueError, match="reason"):
        constant_time_waiver("")(lambda: None)


def test_waivers_seen_through_jit_wrapping():
    @jax.jit
    @constant_time_waiver("test: bounded", invariant="while-free")
    def fn(x):
        return x

    assert waivers_of(fn) == {"while-free": "test: bounded"}
    assert waivers_of(lambda: None) == {}


# ---------------------------------------------------------------------------
# AST lint (layer 2)
# ---------------------------------------------------------------------------


def _rules(findings):
    return {f.rule for f in findings}


def test_lint_flags_host_sync_in_hot_function():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def route(keys):\n"
        "    n = keys.item()\n"
        "    return keys\n"
    )
    findings = lint_source(src)
    assert _rules(findings) == {"host-sync"}
    assert findings[0].line == 4


def test_lint_waiver_comment_suppresses():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def route(n):\n"
        "    l = (n - 1).bit_length()  # ct: host-ok — n is static\n"
        "    return l\n"
    )
    assert lint_source(src) == []


def test_lint_ignores_cold_functions():
    src = "def oracle(key):\n    return int(key) & 0xFFFFFFFF\n"
    assert lint_source(src) == []


def test_lint_flags_bare_wide_literal_in_limb_arithmetic():
    src = (
        "def _mix_body(x):\n"
        "    return x * 0x9E3779B97F4A7C15\n"
    )
    findings = lint_source(src)
    assert _rules(findings) == {"bare-int"}


def test_lint_accepts_cast_wrapped_literal():
    src = (
        "import numpy as np\n"
        "def _mix_body(x):\n"
        "    return x * np.uint32(0x9E3779B9) + np.uint32(0xFFFFFFFF & 1)\n"
    )
    assert lint_source(src) == []


def test_lint_flags_config_mutation():
    src = "import jax\njax.config.update('jax_enable_x64', True)\n"
    findings = lint_source(src)
    assert _rules(findings) == {"config-mutation"}


def test_repo_hot_paths_lint_clean():
    assert lint_paths() == []


# ---------------------------------------------------------------------------
# HLO gate (layer 3) + strict trip-count recovery
# ---------------------------------------------------------------------------


def test_trip_count_recovery_counted_vs_data_dependent():
    from repro.roofline.hlo_parse import parse_module, while_trip_counts

    def counted(x):
        return jax.lax.fori_loop(0, 1000, lambda i, c: c * 1.0001 + 1.0, x)

    def datadep(x):
        return jax.lax.while_loop(lambda c: c < 100.0, lambda c: c * 1.1 + 1.0, x)

    comps, _ = parse_module(jax.jit(counted).lower(np.float32(2.0)).compile().as_text())
    [(_, _, trips)] = while_trip_counts(comps)
    assert trips == 1000
    comps, _ = parse_module(jax.jit(datadep).lower(np.float32(2.0)).compile().as_text())
    [(_, _, trips)] = while_trip_counts(comps)
    assert trips is None  # unbounded: the gate must not invent a count


def test_hlo_gate_binomial_severity_flat():
    from repro.analysis.hlo_gate import gate_engine

    result = gate_engine("binomial", batch=512)
    assert result.ok, [c.detail for c in result.checks]
    assert _check(result, "hlo-severity-flat").status == PASS
    assert result.op_count > 0


# ---------------------------------------------------------------------------
# CLI: exit 0 on the repo, nonzero on a seeded-violation engine
# ---------------------------------------------------------------------------


def test_cli_certifies_registered_engine(capsys):
    from repro.analysis.__main__ import main

    assert main(["--engine", "jump", "--skip-hlo", "--skip-lint"]) == 0
    out = capsys.readouterr().out
    assert "verdict: CERTIFIED" in out


def test_cli_fails_on_seeded_violation_engine(capsys, monkeypatch):
    from repro.analysis.__main__ import main
    from repro.core import registry

    def bad_route(keys, packed, table, state, omega=16, *, n_words):
        del packed, table, state, n_words
        return _while_route(keys, omega)

    broken = dataclasses.replace(
        registry.BULK_ENGINES["binomial"],
        name="broken",
        route=bad_route,
        ingest=None,
        route_pallas=None,
        ingest_pallas=None,
        lookup_dyn=None,
        lookup_dyn_pallas=None,
    )
    monkeypatch.setitem(registry.BULK_ENGINES, "broken", broken)
    assert (
        main(
            ["--engine", "broken", "--skip-hlo", "--skip-lint",
             "--no-chain-baseline"]
        )
        == 1
    )
    assert "verdict: FAILED" in capsys.readouterr().out


def test_cli_writes_structured_report(tmp_path, capsys):
    import json

    from repro.analysis.__main__ import main

    out = tmp_path / "ct.json"
    assert (
        main(
            ["--engine", "jump", "--skip-hlo", "--skip-lint",
             "--no-chain-baseline", "--report", str(out), "--json"]
        )
        == 0
    )
    data = json.loads(out.read_text())
    assert data["ok"] is True
    assert "while-free" in data["engines"]["jump"]["route/jnp"]
    assert json.loads(capsys.readouterr().out) == data
