"""Storm-path bit-exactness property: hypothesis-driven randomized
fail/recover/scale event streams assert the table-based device path equals
``SessionRouter.locate`` per key — including the all-removed-but-one and
max-removed-fraction edges — and that the ``ReplacementTable`` permutation
invariants survive arbitrary event histories."""
import numpy as np
import pytest

from repro.serving.batch_router import BatchRouter
from repro.serving.router import SessionRouter

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is a test dependency
    HAVE_HYPOTHESIS = False

RNG = np.random.default_rng(31)
KEYS = RNG.integers(0, 2**64, size=(512,), dtype=np.uint64)


def _oracle(n):
    return SessionRouter(n, engine="binomial32", chain_bits=32, resolve="table")


def _apply_random_event(rng_val: int, kind: int, router, oracle) -> str:
    """Interpret a raw hypothesis draw as a currently-valid fleet event."""
    dom = router.domain
    removed = sorted(dom.removed)
    if kind == 0 and removed:  # recover
        r = removed[rng_val % len(removed)]
        router.recover(r), oracle.recover(r)
        return f"recover({r})"
    if kind == 1 and dom.total_count < router.capacity:  # scale_up
        router.scale_up(), oracle.scale_up()
        return "scale_up"
    if kind == 2 and router.alive > 2:  # scale_down (LIFO)
        router.scale_down(), oracle.scale_down()
        return "scale_down"
    if router.alive > 1:  # fail an arbitrary alive slot — LIFO edge included
        alive = [b for b in range(dom.total_count) if b not in dom.removed]
        r = alive[rng_val % len(alive)]
        router.fail(r), oracle.fail(r)
        return f"fail({r})"
    return "noop"


def _check_stream(n0: int, events, check_tables: bool = True):
    """Shared checker: after EVERY event in the stream, the fused device
    path equals the scalar oracle key-for-key (jnp mirror; the
    interpret-mode Pallas kernel is pinned equal to the mirror elsewhere),
    and the ReplacementTable permutation invariants hold."""
    router = BatchRouter(n0, capacity=64)
    oracle = _oracle(n0)
    trail = []
    for kind, val in events:
        trail.append(_apply_random_event(val, kind, router, oracle))
        out = router.route_keys_np(KEYS)
        expect = np.array([oracle.domain.locate(int(k)) for k in KEYS])
        np.testing.assert_array_equal(out, expect, err_msg=str(trail))
        assert not np.isin(out, sorted(router.domain.removed)).any(), trail
        if not check_tables:
            continue
        dom = router.domain
        t = dom.replacement_table
        n = dom.total_count
        assert len(t.slots) == n and len(t.pos) == n
        assert sorted(t.slots) == list(range(n))  # a permutation
        assert all(t.slots[t.pos[s]] == s for s in range(n))  # inverse
        assert set(t.slots[: t.n_alive]) == set(range(n)) - dom.removed
        assert t.n_alive == dom.alive_count >= 1


def test_seeded_event_storms_track_scalar_oracle():
    """Deterministic fallback sweep of the property (runs even without
    hypothesis): 20 seeded random streams over varying fleet sizes."""
    for seed in range(20):
        rng = np.random.default_rng(seed)
        n0 = int(rng.integers(2, 25))
        events = [
            (int(rng.integers(0, 4)), int(rng.integers(0, 2**16)))
            for _ in range(int(rng.integers(1, 13)))
        ]
        _check_stream(n0, events)


if HAVE_HYPOTHESIS:

    @given(
        st.integers(min_value=2, max_value=24),
        st.lists(
            st.tuples(st.integers(0, 3), st.integers(0, 2**16)),
            min_size=1,
            max_size=12,
        ),
    )
    @settings(max_examples=40, deadline=None)
    def test_device_path_tracks_scalar_oracle_through_event_storms(n0, events):
        _check_stream(n0, events)


def test_max_removed_fraction_edge_capacity_fleet():
    """Fill the slot space to capacity, then fail all but one — the densest
    removed set the device table can represent."""
    cap = 64
    router = BatchRouter(4, capacity=cap)
    oracle = _oracle(4)
    for _ in range(cap - 4):
        router.scale_up(), oracle.scale_up()
    assert router.domain.total_count == cap
    survivor = 17
    rng = np.random.default_rng(3)
    order = [b for b in range(cap - 1) if b != survivor]
    rng.shuffle(order)
    for b in order:  # tombstone everything but the survivor and the last slot
        router.fail(b), oracle.fail(b)
    assert router.alive == 2
    out = router.route_keys_np(KEYS)
    assert set(np.unique(out)) <= {survivor, cap - 1}
    # failing the LAST slot is a LIFO removal that garbage-collects the whole
    # tombstone suffix — the slot space collapses to [0, survivor]
    router.fail(cap - 1), oracle.fail(cap - 1)
    assert router.alive == 1
    assert router.domain.total_count == survivor + 1
    out = router.route_keys_np(KEYS)
    assert (out == survivor).all()
    # recover a random subset and re-check exactness at high removed fraction
    for b in (3, 11, 0, 16, 8):
        router.recover(b), oracle.recover(b)
        out = router.route_keys_np(KEYS)
        expect = [oracle.domain.locate(int(k)) for k in KEYS]
        np.testing.assert_array_equal(out, expect)


def test_single_failure_disruption_is_minimal_and_recovery_exact():
    """Table resolution keeps the headline disruption property: one failure
    moves only the failed slot's keys; its recovery restores them exactly."""
    router = BatchRouter(16)
    before = router.route_keys_np(KEYS)
    router.fail(5)
    after = router.route_keys_np(KEYS)
    moved = before != after
    assert moved.any()
    assert (before[moved] == 5).all()  # only the victim's keys moved
    assert (after != 5).all()
    router.recover(5)
    np.testing.assert_array_equal(router.route_keys_np(KEYS), before)
