"""Batched key-ingest bit-exactness: the vectorised session-id hashing
(padded byte-matrix FNV-1a / np_mix64), the u32-limb device splitmix64, the
fused hash+route ingest kernel, the bulk open-addressing observability
store, and the zero-row edge — all pinned to the scalar oracles
(``SessionRouter.session_key``, ``bits.mix64``, the per-key dict-loop
semantics) by hypothesis property streams with seeded fallbacks."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bits
from repro.core.binomial_jax import mix64_lo32
from repro.core.bulk import FleetState, RouterSpec
from repro.core.memento_jax import pack_removed_mask, pack_table
from repro.kernels import ops
from repro.kernels.ref import binomial_ingest_route_ref
from repro.serving.batch_router import BatchRouter
from repro.serving.router import SessionRouter, encode_session_ids, hash_session_ids
from repro.serving.session_store import SessionStore

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is a test dependency
    HAVE_HYPOTHESIS = False

RNG = np.random.default_rng(23)


def _scalar_keys(ids) -> np.ndarray:
    # the scalar oracle takes python str/int (numpy scalars would overflow
    # its pure-python 64-bit masking)
    return np.array(
        [SessionRouter.session_key(s if isinstance(s, str) else int(s)) for s in ids],
        dtype=np.uint64,
    )


# ---------------------------------------------------------------------------
# vectorised hashing vs the scalar session_key oracle
# ---------------------------------------------------------------------------


def test_hash_session_ids_string_boundaries():
    """Length/padding edges: empty string, 1 byte, multi-byte unicode, long
    ragged rows — the padded-matrix FNV must ignore padding bytes exactly."""
    ids = ["", "a", "ab", "ü", "€", "漢字" * 7, "x" * 257, "user-0", "user-0\x00"]
    np.testing.assert_array_equal(hash_session_ids(ids), _scalar_keys(ids))


def test_hash_session_ids_int_and_array_paths():
    ids = [0, 1, 2**31, 2**63 + 17, 2**64 - 1]
    np.testing.assert_array_equal(hash_session_ids(ids), _scalar_keys(ids))
    arr = RNG.integers(0, 2**64, size=1024, dtype=np.uint64)
    np.testing.assert_array_equal(hash_session_ids(arr), _scalar_keys(arr))
    narrow = RNG.integers(0, 2**31, size=64, dtype=np.int32)
    np.testing.assert_array_equal(hash_session_ids(narrow), _scalar_keys(narrow))


def test_hash_session_ids_mixed_batch_reinterleaves():
    ids = ["s-0", 42, "s-1", 2**40, "", 7, "漢"]
    np.testing.assert_array_equal(hash_session_ids(ids), _scalar_keys(ids))


def test_hash_session_ids_accepts_any_iterable():
    """Generators and sets worked through the old per-item loop; the batch
    path must keep accepting them (regression guard)."""
    ids = [f"g-{i}" for i in range(40)]
    np.testing.assert_array_equal(
        hash_session_ids(s for s in ids), _scalar_keys(ids)
    )
    got = sorted(hash_session_ids(set(ids)).tolist())
    assert got == sorted(_scalar_keys(ids).tolist())
    router = BatchRouter(4)
    out = router.route_batch(s for s in ids)
    np.testing.assert_array_equal(out, router.route_batch(ids))


def test_hash_session_ids_empty_batch():
    assert hash_session_ids([]).shape == (0,)
    assert hash_session_ids([]).dtype == np.uint64
    assert hash_session_ids(np.empty(0, np.uint64)).shape == (0,)


def test_encode_session_ids_matrix_layout():
    mat, lengths = encode_session_ids(["abc", "", "de"])
    assert mat.shape == (3, 3)
    assert list(lengths) == [3, 0, 2]
    assert bytes(mat[0]) == b"abc"
    assert bytes(mat[1]) == b"\x00\x00\x00"  # padding stays zero
    assert bytes(mat[2]) == b"de\x00"


def test_seeded_random_unicode_and_int_ids_match_scalar():
    """Seeded fallback for the hypothesis property below: random unicode
    strings (including astral-plane codepoints) and full-range ints."""
    rng = np.random.default_rng(11)
    for _ in range(20):
        n = int(rng.integers(1, 80))
        ids = []
        for _ in range(n):
            if rng.random() < 0.5:
                cps = rng.integers(1, 0x10FFFF, size=rng.integers(0, 24))
                ids.append(
                    "".join(chr(c) for c in cps if not 0xD800 <= c <= 0xDFFF)
                )
            else:
                ids.append(int(rng.integers(0, 2**64, dtype=np.uint64)))
        np.testing.assert_array_equal(hash_session_ids(ids), _scalar_keys(ids))


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(
        st.lists(
            st.one_of(
                st.text(max_size=48),
                st.integers(min_value=0, max_value=2**64 - 1),
            ),
            max_size=64,
        )
    )
    def test_hypothesis_hash_session_ids_matches_scalar(ids):
        np.testing.assert_array_equal(hash_session_ids(ids), _scalar_keys(ids))

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=2**64 - 1), max_size=48))
    def test_hypothesis_mix64_limb_pair_matches_scalar(ids64):
        ids = np.array(ids64, dtype=np.uint64).reshape(-1)
        lo, hi = bits.np_split64(ids)
        got = np.asarray(mix64_lo32(jnp.asarray(lo), jnp.asarray(hi)))
        want = np.array(
            [bits.mix64(int(i)) & 0xFFFFFFFF for i in ids], dtype=np.uint32
        )
        np.testing.assert_array_equal(got, want)


def test_mix64_limb_pair_edges():
    edges = np.array([0, 1, 2**32 - 1, 2**32, 2**63, 2**64 - 1], dtype=np.uint64)
    lo, hi = bits.np_split64(edges)
    got = np.asarray(mix64_lo32(jnp.asarray(lo), jnp.asarray(hi)))
    want = np.array([bits.mix64(int(i)) & 0xFFFFFFFF for i in edges], np.uint32)
    np.testing.assert_array_equal(got, want)


def test_np_mix64_matches_scalar():
    ids = RNG.integers(0, 2**64, size=2048, dtype=np.uint64)
    want = np.array([bits.mix64(int(i)) for i in ids], dtype=np.uint64)
    np.testing.assert_array_equal(bits.np_mix64(ids), want)


# ---------------------------------------------------------------------------
# fused ingest dispatch (hash + lookup + divert in one kernel)
# ---------------------------------------------------------------------------


def _oracle(n):
    return SessionRouter(n, engine="binomial32", chain_bits=32, resolve="table")


def test_fused_ingest_paths_agree_with_scalar_oracle():
    """jnp jit == pallas(interpret) == unjitted ref == scalar locate(mix64)."""
    oracle = _oracle(12)
    for r in (1, 4, 9):
        oracle.fail(r)
    dom = oracle.domain
    packed = pack_removed_mask(dom.removed, 64)
    table = pack_table(dom.replacement_table, 64)
    state = np.array([dom.total_count, dom.alive_count], np.uint32)
    ids = RNG.integers(0, 2**64, size=2048, dtype=np.uint64)
    lo, hi = bits.np_split64(ids)
    expect = [dom.locate(bits.mix64(int(i))) for i in ids]
    fleet = FleetState(
        packed=jnp.asarray(packed),
        table=jnp.asarray(table),
        state=jnp.asarray(state),
    )
    jnp_out = ops.route_ingest_bulk(
        jnp.asarray(lo), jnp.asarray(hi), fleet,
        RouterSpec(capacity=64, use_pallas=False),
    )
    pl_out = ops.route_ingest_bulk(
        jnp.asarray(lo), jnp.asarray(hi), fleet,
        RouterSpec(capacity=64, interpret=True, block_rows=4),
    )
    ref_out = binomial_ingest_route_ref(lo, hi, packed, table, state)
    np.testing.assert_array_equal(np.asarray(jnp_out), expect)
    np.testing.assert_array_equal(np.asarray(pl_out), expect)
    np.testing.assert_array_equal(np.asarray(ref_out), expect)


def test_route_ids_matches_prehash_route_keys_across_events():
    """BatchRouter.route_ids (device-fused hash+route) == hashing on the
    host then route_keys, through a fleet-event stream."""
    router = BatchRouter(16, interpret=True, block_rows=8)
    ids = RNG.integers(0, 2**64, size=4096, dtype=np.uint64)
    for ev, arg in [("fail", 3), ("scale_up", None), ("fail", 9), ("recover", 3)]:
        getattr(router, ev)(*(() if arg is None else (arg,)))
        fused = np.asarray(router.route_ids(ids))
        prehash = router.route_keys_np(hash_session_ids(ids))
        np.testing.assert_array_equal(fused, prehash)


def test_route_ids_rejects_mesh():
    import jax

    mesh = jax.make_mesh((1,), ("data",))
    router = BatchRouter(8, mesh=mesh)
    with pytest.raises(ValueError, match="single-host"):
        router.route_ids(np.arange(8, dtype=np.uint64))


# ---------------------------------------------------------------------------
# the bulk observability store vs the sequential dict-loop semantics
# ---------------------------------------------------------------------------


class _DictLoop:
    """The pre-vectorisation note_routes body, verbatim (the semantics
    oracle: first-come insertion under the cap, one count per move)."""

    def __init__(self, cap):
        self.last, self.cap, self.moved = {}, cap, 0

    def record(self, keys, replicas):
        before = self.moved
        for key, replica in zip(keys, replicas):
            key, replica = int(key), int(replica)
            prev = self.last.get(key)
            if prev is None:
                if len(self.last) < self.cap:
                    self.last[key] = replica
                continue
            if prev != replica:
                self.moved += 1
                self.last[key] = replica
        return self.moved - before


def _run_store_stream(rng, cap, batches=25):
    store = SessionStore(max_entries=cap, initial_slots=4)
    ref = _DictLoop(cap)
    for epoch in range(batches):
        n = int(rng.integers(1, 300))
        # heavy duplication; replica deterministic per (key, epoch) like a
        # routed batch (duplicates within a batch always carry equal values)
        keys = rng.integers(0, 64, size=n).astype(np.uint64) * np.uint64(
            0x9E3779B97F4A7C15
        )
        reps = ((keys.astype(np.int64) + epoch // 5) % 7).astype(np.int32)
        assert store.record(keys, reps) == ref.record(keys, reps)
        assert store.count == len(ref.last)
    probe = rng.integers(0, 96, size=64).astype(np.uint64) * np.uint64(
        0x9E3779B97F4A7C15
    )
    got = store.lookup(probe)
    want = np.array([ref.last.get(int(k), -1) for k in probe], np.int32)
    np.testing.assert_array_equal(got, want)


def test_seeded_session_store_matches_dict_loop():
    for seed, cap in ((0, 1 << 20), (1, 40), (2, 7), (3, 1)):
        _run_store_stream(np.random.default_rng(seed), cap)


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32), st.integers(1, 200))
    def test_hypothesis_session_store_matches_dict_loop(seed, cap):
        _run_store_stream(np.random.default_rng(seed), cap, batches=8)


def test_session_store_record_one_matches_bulk_semantics():
    """The scalar fast path (the per-request route() walk) tracks the dict
    loop through interleaved scalar/bulk updates, cap and grow included."""
    rng = np.random.default_rng(5)
    store = SessionStore(max_entries=30, initial_slots=2)
    ref = _DictLoop(30)
    for epoch in range(400):
        k = int(rng.integers(0, 48)) * 0x9E3779B97F4A7C15 % 2**64
        v = int((k + epoch // 7) % 5)
        assert store.record_one(k, v) == ref.record([k], [v])
        if epoch % 25 == 0:  # interleave a bulk batch
            keys = rng.integers(0, 48, size=20).astype(np.uint64) * np.uint64(
                0x9E3779B97F4A7C15
            )
            vals = ((keys.astype(np.int64) + epoch // 7) % 5).astype(np.int32)
            assert store.record(keys, vals) == ref.record(keys, vals)
        assert store.count == len(ref.last)


def test_session_store_cap_is_first_come():
    store = SessionStore(max_entries=2, initial_slots=4)
    assert store.record(np.array([10, 20, 30], np.uint64), np.array([1, 2, 3])) == 0
    assert store.count == 2  # 30 fell past the cap, untracked
    # tracked keys still count moves; the untracked one never does
    assert store.record(np.array([10, 20, 30], np.uint64), np.array([5, 2, 9])) == 1
    np.testing.assert_array_equal(
        store.lookup(np.array([10, 20, 30], np.uint64)), [5, 2, -1]
    )


def test_session_store_grows_past_initial_slots():
    store = SessionStore(max_entries=1 << 20, initial_slots=2)
    keys = RNG.integers(0, 2**64, size=5000, dtype=np.uint64)
    keys = np.unique(keys)
    vals = (keys % np.uint64(11)).astype(np.int32)
    assert store.record(keys, vals) == 0
    assert store.count == keys.size
    np.testing.assert_array_equal(store.lookup(keys), vals)
    assert store._keys.size >= 2 * keys.size  # load factor held <= 1/2


def test_router_moved_sessions_across_cap(monkeypatch):
    """SessionRouter honours LAST_MAX through the vectorised store."""
    monkeypatch.setattr(SessionRouter, "LAST_MAX", 5)
    r = SessionRouter(8)
    sessions = [f"cap-{i}" for i in range(12)]
    for s in sessions:
        r.route(s)
    assert r.stats.moved_sessions == 0
    assert len(r._last) == 5


# ---------------------------------------------------------------------------
# zero-row batches (the empty-batch regression: ISSUE 4 satellite)
# ---------------------------------------------------------------------------


def test_empty_batch_route_paths():
    router = BatchRouter(8)
    out = router.route_batch([])
    assert isinstance(out, np.ndarray) and out.shape == (0,) and out.dtype == np.int32
    dev = router.route_keys(np.empty(0, dtype=np.uint32))
    assert dev.shape == (0,) and np.asarray(dev).size == 0
    ids = router.route_ids(np.empty(0, dtype=np.uint64))
    assert np.asarray(ids).size == 0
    assert router.route_keys_np(np.empty((0,), np.uint64)).shape == (0,)
    # stats untouched by empty dispatches
    assert router.stats.lookups == 0
    # and note_routes with nothing to note is a no-op
    router.scalar.note_routes((), ())
    assert router.stats.moved_sessions == 0


def test_empty_batch_sharded_route():
    import jax

    mesh = jax.make_mesh((1,), ("data",))
    router = BatchRouter(8, mesh=mesh)
    assert np.asarray(router.route_keys(np.empty(0, np.uint32))).size == 0
    assert router.route_batch([]).size == 0
