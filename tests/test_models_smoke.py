"""Per-architecture smoke tests (reduced configs, CPU): one train step with
finite loss + correct shapes, and cached-decode consistency vs full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config, SHAPES, shape_applicable
from repro.models import model as M
from repro.models.blocks import build_segments
from repro.models.layers.common import unembed


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))}
    if cfg.input_mode == "tokens":
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    else:
        batch["embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32) * 0.02)
        if cfg.input_mode == "embeds_mrope":
            batch["positions"] = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = reduced_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = M.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_forward(arch):
    cfg = reduced_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S, S0 = 2, 16, 8
    batch = _batch(cfg, B, S, seed=1)
    hidden, _, _, _ = M.trunk_train(params, batch, cfg)
    full_logits = unembed(params["embed"], hidden, cfg)
    pre = {
        k: (v[:, :S0] if k != "positions" else v[:, :, :S0])
        for k, v in batch.items()
        if k != "targets"
    }
    cache, logits = M.prefill(params, pre, cfg, max_len=S)
    errs = [float(jnp.max(jnp.abs(logits - full_logits[:, S0 - 1])))]
    for t in range(S0, S):
        step = (
            {"tokens": batch["tokens"][:, t : t + 1]}
            if cfg.input_mode == "tokens"
            else {"embeds": batch["embeds"][:, t : t + 1]}
        )
        cache, lg = M.decode_step(params, cache, step, cfg)
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert max(errs) < 2e-3, (arch, errs)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_segments_cover_all_layers(arch):
    cfg = get_config(arch)
    segs = build_segments(cfg)
    total = sum(len(s.unit) * s.count for s in segs)
    assert total == cfg.num_layers, (arch, segs)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact assigned hyperparameters."""
    spec = {
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    }[arch]
    cfg = get_config(arch)
    ff = cfg.moe.d_ff_expert if cfg.moe is not None else cfg.d_ff
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, ff, cfg.vocab_size) == spec
    if arch == "deepseek-v3-671b":
        assert cfg.moe.num_experts == 256 and cfg.moe.top_k == 8 and cfg.moe.shared_experts == 1
        assert cfg.attention == "mla" and cfg.mtp_depth == 1
    if arch == "qwen3-moe-235b-a22b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 8
    if arch == "mamba2-1.3b":
        assert cfg.ssm.d_state == 128


def test_param_counts_in_family_range():
    """Full configs land near their nameplate sizes (embedding included)."""
    expect = {
        "deepseek-coder-33b": (30e9, 36e9),
        "starcoder2-7b": (6.5e9, 8.5e9),
        "qwen2.5-14b": (13e9, 16e9),
        "stablelm-3b": (2.5e9, 3.4e9),
        "deepseek-v3-671b": (640e9, 700e9),
        "qwen3-moe-235b-a22b": (220e9, 250e9),
        "recurrentgemma-9b": (9e9, 12e9),
        "mamba2-1.3b": (1.1e9, 1.5e9),
        "musicgen-medium": (1.2e9, 1.7e9),
        "qwen2-vl-7b": (7e9, 8.5e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        struct = jax.eval_shape(lambda cfg=cfg: M.init_params(jax.random.PRNGKey(0), cfg))
        n = M.count_params(struct)
        assert lo < n < hi, (arch, n)


def test_long_500k_applicability_rule():
    runnable = {a for a in ARCHS if shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runnable == {"starcoder2-7b", "recurrentgemma-9b", "mamba2-1.3b"}
