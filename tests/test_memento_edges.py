"""Memento remap edge cases: max_chain exhaustion -> first_alive fallback,
all-removed-but-one fleets, and the alive-slot property under hypothesis —
covering both the two-pass ``memento_remap`` and the fused route."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MementoWrapper, make
from repro.core.binomial_jax import binomial_lookup_dyn
from repro.core.memento_jax import (
    binomial_memento_route,
    mask_words,
    memento_remap,
    pack_removed_mask,
)
from repro.kernels.binomial_hash import binomial_route_pallas_fused
from repro.serving.batch_router import BatchRouter

RNG = np.random.default_rng(23)
CAP = 64


def _wrapper(n, removed, max_chain=4096):
    eng = MementoWrapper(lambda m: make("binomial32", m), n, max_chain=max_chain,
                         chain_bits=32)
    for b in removed:
        eng.remove_bucket(b)
    return eng


def _remap(keys, eng, max_chain):
    mask = np.zeros((CAP,), dtype=bool)
    mask[list(eng.removed)] = True
    buckets = binomial_lookup_dyn(keys, np.uint32(eng.n_total))
    return np.asarray(
        memento_remap(keys, buckets, mask, np.uint32(eng.n_total),
                      np.uint32(eng.first_alive()), max_chain=max_chain)
    )


def _fused(keys, eng, max_chain):
    packed = pack_removed_mask(eng.removed, CAP)
    state = np.array([eng.n_total, eng.first_alive()], np.uint32)
    return np.asarray(
        binomial_memento_route(jnp.asarray(keys), jnp.asarray(packed),
                               jnp.asarray(state), max_chain=max_chain)
    )


# ---------------------------------------------------------------------------
# max_chain exhaustion -> first_alive fallback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("max_chain", [0, 1, 2])
@pytest.mark.parametrize("removed", [[0], [0, 1, 2], [3, 5]])
def test_max_chain_exhaustion_falls_back_to_first_alive(max_chain, removed):
    """With a tiny chain budget, lanes that exhaust it must land on
    first_alive — identically on scalar, two-pass and fused paths."""
    eng = _wrapper(8, removed, max_chain=max_chain)
    keys = RNG.integers(0, 2**32, size=(2048,), dtype=np.uint32)
    scal = np.array([eng.get_bucket(int(k)) for k in keys])
    np.testing.assert_array_equal(_remap(keys, eng, max_chain), scal)
    np.testing.assert_array_equal(_fused(keys, eng, max_chain), scal)
    # max_chain=0 forces EVERY removed-slot lane onto first_alive
    if max_chain == 0:
        base = np.asarray(binomial_lookup_dyn(keys, np.uint32(eng.n_total)))
        hit = np.isin(base, list(eng.removed))
        assert hit.any()
        assert (scal[hit] == eng.first_alive()).all()


def test_batch_router_parity_with_exhausting_chain():
    """BatchRouter(max_chain=0) stays bit-exact with its scalar oracle —
    the fallback rides through the whole datapath, not just the remap."""
    router = BatchRouter(8, max_chain=0, interpret=True, block_rows=2)
    router.fail(0)
    router.fail(4)
    keys = RNG.integers(0, 2**64, size=(1024,), dtype=np.uint64)
    out = router.route_keys_np(keys)
    expect = [router.domain.locate(int(k)) for k in keys]
    np.testing.assert_array_equal(out, expect)
    assert 0 not in out and 4 not in out


# ---------------------------------------------------------------------------
# all-removed-but-one fleets
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("survivor", [0, 3, 7])
def test_all_removed_but_one_routes_everything_to_survivor(survivor):
    n = 8
    eng = _wrapper(n, [b for b in range(n) if b != survivor])
    keys = RNG.integers(0, 2**32, size=(4096,), dtype=np.uint32)
    out = _fused(keys, eng, 4096)
    assert (out == survivor).all()
    np.testing.assert_array_equal(out, _remap(keys, eng, 4096))
    scal = np.array([eng.get_bucket(int(k)) for k in keys])
    np.testing.assert_array_equal(out, scal)


def test_all_removed_but_one_via_batch_router_events():
    router = BatchRouter(8, interpret=True, block_rows=2)
    for r in range(7):
        router.fail(r)
    assert router.alive == 1
    keys = RNG.integers(0, 2**64, size=(2048,), dtype=np.uint64)
    assert (router.route_keys_np(keys) == 7).all()
    router.recover(3)
    out = router.route_keys_np(keys)
    assert set(np.unique(out)) <= {3, 7}
    expect = [router.domain.locate(int(k)) for k in keys]
    np.testing.assert_array_equal(out, expect)


# ---------------------------------------------------------------------------
# property: remapped outputs always land on alive slots
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is a test dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def fleets(draw):
        n = draw(st.integers(min_value=2, max_value=CAP))
        n_removed = draw(st.integers(min_value=0, max_value=n - 1))
        removed = draw(
            st.sets(st.integers(min_value=0, max_value=n - 1),
                    min_size=n_removed, max_size=n_removed)
        )
        return n, sorted(removed)

    @given(fleets(), st.integers(min_value=0, max_value=2**32 - 1),
           st.integers(min_value=0, max_value=3))
    @settings(max_examples=150, deadline=None)
    def test_remap_always_lands_on_alive_slots(fleet, key_seed, max_chain_pow):
        n, removed = fleet
        max_chain = 4096 if max_chain_pow == 0 else (1 << max_chain_pow)
        eng = _wrapper(n, removed, max_chain=max_chain)
        keys = np.asarray(
            np.random.default_rng(key_seed).integers(0, 2**32, size=(256,)),
            dtype=np.uint32,
        )
        out = _fused(keys, eng, max_chain)
        alive = np.array(eng.alive())
        assert np.isin(out, alive).all(), (n, removed, max_chain)
        np.testing.assert_array_equal(out, _remap(keys, eng, max_chain))
