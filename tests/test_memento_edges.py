"""Failure-resolution edge cases, both flavours:

* chain (``memento_remap`` — library flavour): max_chain exhaustion ->
  first_alive fallback, bit-exact vs ``MementoWrapper(chain_bits=32)``;
* table (``binomial_memento_route`` / ``memento_remap_table`` — the serving
  datapath): all-removed-but-one fleets, the deep second redirect, and the
  alive-slot property under hypothesis.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MementoWrapper, make
from repro.core.binomial_jax import binomial_lookup_dyn
from repro.core.memento_jax import (
    binomial_memento_route,
    mask_words,
    memento_remap,
    memento_remap_table,
    pack_removed_mask,
    pack_table,
)
from repro.serving.batch_router import BatchRouter

RNG = np.random.default_rng(23)
CAP = 64


def _wrapper(n, removed, max_chain=4096, resolve="chain"):
    eng = MementoWrapper(lambda m: make("binomial32", m), n, max_chain=max_chain,
                         chain_bits=32, resolve=resolve)
    for b in removed:
        eng.remove_bucket(b)
    return eng


def _remap(keys, eng, max_chain):
    """Two-pass CHAIN remap (library flavour, scalar oracle = chain mode)."""
    mask = np.zeros((CAP,), dtype=bool)
    mask[list(eng.removed)] = True
    buckets = binomial_lookup_dyn(keys, np.uint32(eng.n_total))
    return np.asarray(
        memento_remap(keys, buckets, mask, np.uint32(eng.n_total),
                      np.uint32(eng.first_alive()), max_chain=max_chain)
    )


def _table_state(eng):
    packed = pack_removed_mask(eng.removed, CAP)
    table = pack_table(eng.table, CAP)
    state = np.array([eng.n_total, eng.size], np.uint32)
    return packed, table, state


def _fused(keys, eng):
    """Fused TABLE route (serving flavour, scalar oracle = table mode)."""
    packed, table, state = _table_state(eng)
    return np.asarray(
        binomial_memento_route(jnp.asarray(keys), jnp.asarray(packed),
                               jnp.asarray(table), jnp.asarray(state),
                               n_words=mask_words(CAP))
    )


def _remap_table(keys, eng):
    """Two-pass TABLE remap (the fused kernel's two-dispatch baseline)."""
    packed, table, state = _table_state(eng)
    buckets = binomial_lookup_dyn(keys, np.uint32(eng.n_total))
    return np.asarray(
        memento_remap_table(jnp.asarray(keys), buckets, jnp.asarray(packed),
                            jnp.asarray(table), jnp.asarray(state),
                            n_words=mask_words(CAP))
    )


# ---------------------------------------------------------------------------
# chain flavour: max_chain exhaustion -> first_alive fallback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("max_chain", [0, 1, 2])
@pytest.mark.parametrize("removed", [[0], [0, 1, 2], [3, 5]])
def test_max_chain_exhaustion_falls_back_to_first_alive(max_chain, removed):
    """With a tiny chain budget, lanes that exhaust it must land on
    first_alive — identically on the scalar chain and the device remap."""
    eng = _wrapper(8, removed, max_chain=max_chain)
    keys = RNG.integers(0, 2**32, size=(2048,), dtype=np.uint32)
    scal = np.array([eng.get_bucket(int(k)) for k in keys])
    np.testing.assert_array_equal(_remap(keys, eng, max_chain), scal)
    # max_chain=0 forces EVERY removed-slot lane onto first_alive
    if max_chain == 0:
        base = np.asarray(binomial_lookup_dyn(keys, np.uint32(eng.n_total)))
        hit = np.isin(base, list(eng.removed))
        assert hit.any()
        assert (scal[hit] == eng.first_alive()).all()


def test_batch_router_parity_with_degenerate_max_chain():
    """BatchRouter(max_chain=0) stays bit-exact with its scalar oracle —
    the table divert has a hard two-redirect bound, so a degenerate chain
    budget changes nothing on the serving datapath."""
    router = BatchRouter(8, max_chain=0, interpret=True, block_rows=8)
    router.fail(0)
    router.fail(4)
    keys = RNG.integers(0, 2**64, size=(1024,), dtype=np.uint64)
    out = router.route_keys_np(keys)
    expect = [router.domain.locate(int(k)) for k in keys]
    np.testing.assert_array_equal(out, expect)
    assert 0 not in out and 4 not in out


# ---------------------------------------------------------------------------
# table flavour: all-removed-but-one fleets and the deep second redirect
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("survivor", [0, 3, 7])
def test_all_removed_but_one_routes_everything_to_survivor(survivor):
    n = 8
    eng = _wrapper(n, [b for b in range(n) if b != survivor], resolve="table")
    keys = RNG.integers(0, 2**32, size=(4096,), dtype=np.uint32)
    out = _fused(keys, eng)
    assert (out == survivor).all()
    np.testing.assert_array_equal(out, _remap_table(keys, eng))
    scal = np.array([eng.get_bucket(int(k)) for k in keys])
    np.testing.assert_array_equal(out, scal)


def test_all_removed_but_one_via_batch_router_events():
    router = BatchRouter(8, interpret=True, block_rows=8)
    for r in range(7):
        router.fail(r)
    assert router.alive == 1
    keys = RNG.integers(0, 2**64, size=(2048,), dtype=np.uint64)
    assert (router.route_keys_np(keys) == 7).all()
    router.recover(3)
    out = router.route_keys_np(keys)
    assert set(np.unique(out)) <= {3, 7}
    expect = [router.domain.locate(int(k)) for k in keys]
    np.testing.assert_array_equal(out, expect)


def test_deep_second_redirect_is_exercised_and_exact():
    """With most slots removed, redirect 1 frequently lands on a removed
    position — the deep branch (redirect 2) must fire and stay bit-exact."""
    n = 32
    removed = [b for b in range(n) if b % 4 != 0]  # 75% removed
    eng = _wrapper(n, removed, resolve="table")
    keys = RNG.integers(0, 2**32, size=(8192,), dtype=np.uint32)
    # count scalar-side deep redirects to prove the branch is hit
    from repro.core import bits

    deep = 0
    for k in keys[:2048]:
        b = eng.base.get_bucket(int(k))
        if b in eng.removed:
            h = bits.hash_pair32(int(k), b)
            if bits.mulhi32(h, eng.table.n_total) >= eng.table.n_alive:
                deep += 1
    assert deep > 50
    out = _fused(keys, eng)
    scal = np.array([eng.get_bucket(int(k)) for k in keys])
    np.testing.assert_array_equal(out, scal)
    alive = np.array(eng.alive())
    assert np.isin(out, alive).all()


# ---------------------------------------------------------------------------
# property: resolved outputs always land on alive slots (both flavours)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is a test dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def fleets(draw):
        n = draw(st.integers(min_value=2, max_value=CAP))
        n_removed = draw(st.integers(min_value=0, max_value=n - 1))
        removed = draw(
            st.sets(st.integers(min_value=0, max_value=n - 1),
                    min_size=n_removed, max_size=n_removed)
        )
        return n, sorted(removed)

    @given(fleets(), st.integers(min_value=0, max_value=2**32 - 1))
    @settings(max_examples=150, deadline=None)
    def test_table_route_always_lands_on_alive_slots(fleet, key_seed):
        n, removed = fleet
        eng = _wrapper(n, removed, resolve="table")
        keys = np.asarray(
            np.random.default_rng(key_seed).integers(0, 2**32, size=(256,)),
            dtype=np.uint32,
        )
        out = _fused(keys, eng)
        alive = np.array(eng.alive())
        assert np.isin(out, alive).all(), (n, removed)
        np.testing.assert_array_equal(out, _remap_table(keys, eng))
        scal = np.array([eng.get_bucket(int(k)) for k in keys])
        np.testing.assert_array_equal(out, scal)

    @given(fleets(), st.integers(min_value=0, max_value=2**32 - 1),
           st.integers(min_value=0, max_value=3))
    @settings(max_examples=50, deadline=None)
    def test_chain_remap_always_lands_on_alive_slots(fleet, key_seed, max_chain_pow):
        n, removed = fleet
        max_chain = 4096 if max_chain_pow == 0 else (1 << max_chain_pow)
        eng = _wrapper(n, removed, max_chain=max_chain)
        keys = np.asarray(
            np.random.default_rng(key_seed).integers(0, 2**32, size=(128,)),
            dtype=np.uint32,
        )
        out = _remap(keys, eng, max_chain)
        alive = np.array(eng.alive())
        assert np.isin(out, alive).all(), (n, removed, max_chain)
        scal = np.array([eng.get_bucket(int(k)) for k in keys])
        np.testing.assert_array_equal(out, scal)
