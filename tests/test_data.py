"""Data pipeline: determinism, elastic rescale, straggler stealing."""
import numpy as np

from repro.data.pipeline import DataConfig, ShardedDataPipeline


def _cfg(**kw):
    d = dict(vocab_size=1000, seq_len=32, global_batch=8, num_shards=64, seed=3)
    d.update(kw)
    return DataConfig(**d)


def test_deterministic_batches():
    p1 = ShardedDataPipeline(_cfg(), num_hosts=4, host_id=1)
    p2 = ShardedDataPipeline(_cfg(), num_hosts=4, host_id=1)
    for step in (0, 1, 17):
        b1, b2 = p1.batch(step), p2.batch(step)
        assert (b1["tokens"] == b2["tokens"]).all()
        assert (b1["targets"] == b2["targets"]).all()
        assert (b1["tokens"][:, 1:] == b1["targets"][:, :-1]).all()  # shifted LM pair


def test_hosts_cover_all_shards_disjointly():
    pipes = [ShardedDataPipeline(_cfg(), 4, h) for h in range(4)]
    all_shards = sorted(s for p in pipes for s in p.local_shards)
    assert all_shards == list(range(64))


def test_rescale_moves_minimal():
    p = ShardedDataPipeline(_cfg(), 4, 0)
    before = set(p.local_shards)
    plan = p.rescale(5)
    assert plan.destinations() <= {4}
    after = set(p.local_shards)
    assert after <= before  # host 0 only loses shards to the new host
    assert plan.moved_fraction < 0.35


def test_straggler_stealing_is_consistent():
    """All healthy hosts compute the same steal plan without coordination."""
    pipes = [ShardedDataPipeline(_cfg(), 4, h) for h in range(4)]
    straggler = 2
    stolen = {h: set(pipes[h].steal_from(straggler)) for h in range(4) if h != straggler}
    # disjoint
    for a in stolen:
        for b in stolen:
            if a != b:
                assert not (stolen[a] & stolen[b])
    # stolen shards all belonged to the straggler
    theirs = set(ShardedDataPipeline(_cfg(), 4, straggler).local_shards)
    assert set().union(*stolen.values()) <= theirs
    assert len(set().union(*stolen.values())) >= 1


def test_tokens_in_range():
    p = ShardedDataPipeline(_cfg(), 2, 0)
    b = p.batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 1000
    assert b["tokens"].shape == (4, 32)
