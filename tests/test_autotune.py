"""block_rows autotuner: argmin selection, measure-once persistence across
processes, candidate filtering, and the BatchRouter wiring rules (explicit
value wins; jnp fallback and interpret mode never tune)."""
import json

import numpy as np
import pytest

from repro.kernels import autotune
from repro.serving.batch_router import BatchRouter


def _fake_measure(times: dict, calls: list):
    def measure(block_rows: int) -> None:
        calls.append(block_rows)
        measure.clock = getattr(measure, "clock", 0.0) + times[block_rows]

    return measure


def test_tuner_picks_fastest_candidate_and_persists(tmp_path, monkeypatch):
    path = str(tmp_path / "cache.json")
    # fake timer: pretend 256 is the fastest tiling
    times = {128: 5e-4, 256: 1e-4, 512: 3e-4, 1024: 9e-4, 2048: 9e-4}
    ticker = {"t": 0.0}

    def fake_clock():
        return ticker["t"]

    calls = []

    def measure(c):
        calls.append(c)
        ticker["t"] += times[c]

    monkeypatch.setattr(autotune.time, "perf_counter", fake_clock)
    got = autotune.tuned_block_rows("tpu", rows=8192, capacity=64,
                                    measure=measure, path=path)
    assert got == 256
    # warmup + repeats per candidate, every candidate tried exactly once
    assert sorted(set(calls)) == sorted(autotune.CANDIDATES)
    with open(path) as f:
        cache = json.load(f)
    key = f"{autotune.CACHE_SCHEMA}/tpu/fused/rows=8192/capacity=64"
    assert cache[key]["block_rows"] == 256

    # second call: pure cache hit — measure must NOT run again
    calls.clear()
    got2 = autotune.tuned_block_rows("tpu", rows=8192, capacity=64,
                                     measure=measure, path=path)
    assert got2 == 256 and calls == []


def test_tuner_filters_candidates_larger_than_the_batch(tmp_path, monkeypatch):
    path = str(tmp_path / "cache.json")
    ticker = {"t": 0.0}
    monkeypatch.setattr(autotune.time, "perf_counter", lambda: ticker["t"])
    tried = []

    def measure(c):
        tried.append(c)
        ticker["t"] += 1e-4

    autotune.tuned_block_rows("tpu", rows=200, capacity=64,
                              measure=measure, path=path)
    assert max(tried) <= 256  # 512+ row blocks only pad dead lanes at 200 rows


def test_tuner_distinguishes_cache_keys(tmp_path, monkeypatch):
    path = str(tmp_path / "cache.json")
    ticker = {"t": 0.0}
    monkeypatch.setattr(autotune.time, "perf_counter", lambda: ticker["t"])

    def measure(c):
        ticker["t"] += (1e-4 if c == 128 else 5e-4)

    a = autotune.tuned_block_rows("tpu", rows=4096, capacity=64,
                                  measure=measure, path=path)

    def measure2(c):
        ticker["t"] += (1e-4 if c == 1024 else 5e-4)

    b = autotune.tuned_block_rows("tpu", rows=4096, capacity=256,
                                  measure=measure2, path=path)
    assert a == 128 and b == 1024
    # a different datapath variant must NOT inherit the fused verdict
    c = autotune.tuned_block_rows("tpu", rows=4096, capacity=64,
                                  measure=measure2, path=path,
                                  variant="two_pass")
    assert c == 1024
    with open(path) as f:
        assert len(json.load(f)) == 3


def test_batch_router_block_rows_resolution(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "cache.json"))
    # explicit value wins, no tuning
    r = BatchRouter(8, block_rows=256)
    assert r._resolve_block_rows(4096) == 256
    # jnp fallback (CPU backend): default, no tuning
    r = BatchRouter(8)
    assert r._resolve_block_rows(4096) == autotune.DEFAULT_BLOCK_ROWS
    # interpret mode is a test harness: default, no tuning
    r = BatchRouter(8, interpret=True)
    assert r._resolve_block_rows(4096) == autotune.DEFAULT_BLOCK_ROWS
    # Pallas path selected -> the tuner runs (stubbed) and is memoised per rows
    r = BatchRouter(8, use_pallas=True)
    seen = []

    def fake_tuned(backend, rows, capacity, measure, **kw):
        seen.append((backend, rows, capacity))
        return 8

    monkeypatch.setattr(autotune, "tuned_block_rows", fake_tuned)
    assert r._resolve_block_rows(4096) == 8
    assert r._resolve_block_rows(4096) == 8  # memoised: tuner ran once
    assert len(seen) == 1 and seen[0][1:] == (4096, 64)


def test_tuner_survives_corrupt_cache(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    path.write_text("{ not json")
    ticker = {"t": 0.0}
    monkeypatch.setattr(autotune.time, "perf_counter", lambda: ticker["t"])

    def measure(c):
        ticker["t"] += 1e-4

    got = autotune.tuned_block_rows("tpu", rows=1024, capacity=64,
                                    measure=measure, path=str(path))
    assert got in autotune.CANDIDATES
    with open(path) as f:
        json.load(f)  # rewritten as valid json


def test_default_cache_path_is_env_overridable(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "/tmp/somewhere.json")
    assert autotune.cache_path() == "/tmp/somewhere.json"
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE")
    assert autotune.cache_path().endswith("block_rows.json")
