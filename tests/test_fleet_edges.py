"""All-failed / one-alive fleet edges across both engines and every route
entry point: typed ``FleetUnavailableError`` instead of undefined behavior.
"""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import model as M
from repro.serving.batch_router import BatchRouter
from repro.serving.engine import Request, ServingTier
from repro.serving.lifecycle import FleetUnavailableError
from repro.serving.router import SessionRouter

ENGINES = ("binomial", "jump")
KEYS = np.random.default_rng(77).integers(0, 1 << 32, 512, dtype=np.uint32)
IDS = np.random.default_rng(78).integers(0, 1 << 63, 256, dtype=np.uint64)


def fail_all(r: BatchRouter) -> None:
    # fail low slots first so the last one takes the tombstone branch
    # (slot space intact, n_alive == 0) rather than a LIFO shrink
    for s in range(r.domain.total_count):
        r.fail(s)


@pytest.mark.parametrize("engine", ENGINES)
def test_batch_router_all_failed_raises_typed(engine):
    r = BatchRouter(4, engine=engine)
    fail_all(r)
    assert r.alive == 0
    assert r.domain.total_count == 4  # tombstones, not a shrink
    with pytest.raises(FleetUnavailableError):
        r.route_keys(KEYS)
    with pytest.raises(FleetUnavailableError):
        r.route_keys_np(KEYS)
    with pytest.raises(FleetUnavailableError):
        r.route_ids(IDS)
    with pytest.raises(FleetUnavailableError):
        r.route_batch([f"s{i}" for i in range(16)])
    # the guard fires before any device dispatch — epoch is attached
    with pytest.raises(FleetUnavailableError) as exc:
        r.route_keys(KEYS)
    assert exc.value.epoch == r.routing_epoch == 4


@pytest.mark.parametrize("engine", ENGINES)
def test_batch_router_single_survivor_routes_everything_to_it(engine):
    r = BatchRouter(5, engine=engine)
    for s in (0, 1, 3, 4):
        r.fail(s)
    assert r.alive == 1
    assert set(r.route_keys_np(KEYS).tolist()) == {2}
    assert set(np.asarray(r.route_ids(IDS)).tolist()) == {2}
    assert set(r.route_batch([f"u{i}" for i in range(64)]).tolist()) == {2}


@pytest.mark.parametrize("engine", ENGINES)
def test_batch_router_recover_from_empty_restores_bit_exact(engine):
    r = BatchRouter(6, engine=engine)
    before = r.route_keys_np(KEYS)
    fail_all(r)
    with pytest.raises(FleetUnavailableError):
        r.route_keys(KEYS)
    for s in range(6):
        r.recover(s)
    assert r.alive == 6
    np.testing.assert_array_equal(r.route_keys_np(KEYS), before)


@pytest.mark.parametrize("engine", ENGINES)
def test_batch_router_empty_batch_on_empty_fleet_still_typed(engine):
    r = BatchRouter(3, engine=engine)
    fail_all(r)
    # zero keys to route, but the fleet is still unavailable: the typed
    # error wins (callers must not infer health from an empty answer)
    with pytest.raises(FleetUnavailableError):
        r.route_keys(np.empty(0, dtype=np.uint32))


def test_session_router_all_failed_raises_typed():
    r = SessionRouter(3, engine="binomial32", chain_bits=32, resolve="table",
                      allow_empty=True)
    for s in range(3):
        r.fail(s)
    assert r.alive == 0
    with pytest.raises(FleetUnavailableError):
        r.route("sess-1")
    r.recover(1)
    assert r.route("sess-1") == 1


def test_session_router_default_still_refuses_last_removal():
    r = SessionRouter(2)
    r.fail(0)
    with pytest.raises(ValueError, match="last alive bucket"):
        r.fail(1)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced_config("stablelm-3b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.parametrize("engine", ENGINES)
def test_serving_tier_all_failed_raises_typed(tiny_model, engine):
    cfg, params = tiny_model
    tier = ServingTier(cfg, params, n_replicas=3, max_len=32, engine=engine)
    rng = np.random.default_rng(1)
    reqs = [
        Request(f"s{i}", rng.integers(0, cfg.vocab_size, 4).astype(np.int32), n_new=2)
        for i in range(4)
    ]
    assert set(tier.serve(reqs)) == {r.session_id for r in reqs}
    for s in range(3):
        tier.fail(s)
    with pytest.raises(FleetUnavailableError):
        tier.serve(reqs)
    tier.recover(2)
    res = tier.serve(reqs)
    assert set(res) == {r.session_id for r in reqs}


def test_serving_tier_single_survivor_serves_all(tiny_model):
    cfg, params = tiny_model
    tier = ServingTier(cfg, params, n_replicas=3, max_len=32)
    rng = np.random.default_rng(2)
    reqs = [
        Request(f"u{i}", rng.integers(0, cfg.vocab_size, 4).astype(np.int32), n_new=2)
        for i in range(6)
    ]
    tier.fail(0)
    tier.fail(2)
    res = tier.serve(reqs)
    assert set(res) == {r.session_id for r in reqs}
    assert tier.replicas[1].steps_served > 0
    assert tier.replicas[0].steps_served == 0


def test_serving_tier_lifecycle_detector_reroutes(tiny_model):
    from repro.serving.lifecycle import LifecycleConfig, ManualClock

    cfg, params = tiny_model
    tier = ServingTier(cfg, params, n_replicas=3, max_len=32)
    clk = ManualClock()
    mgr = tier.attach_lifecycle(LifecycleConfig(), clock=clk)
    hb = mgr.config.heartbeat
    rng = np.random.default_rng(3)
    reqs = [
        Request(f"r{i}", rng.integers(0, cfg.vocab_size, 4).astype(np.int32), n_new=2)
        for i in range(6)
    ]
    tier.serve(reqs)
    # replica 1 stops beating; the next serve tick removes it
    clk.advance(hb.fail_after + 1)
    tier.heartbeat(0)
    tier.heartbeat(2)
    res = tier.serve(reqs)
    assert set(res) == {r.session_id for r in reqs}
    assert mgr.n_alive == 2
    assert 1 in tier.router.domain.removed
    mgr.verify_replay()


def test_serving_tier_requires_attach_before_heartbeat(tiny_model):
    cfg, params = tiny_model
    tier = ServingTier(cfg, params, n_replicas=2, max_len=32)
    with pytest.raises(RuntimeError, match="attach_lifecycle"):
        tier.heartbeat(0)
