"""Observability tier: registry, spans, device load pass, alarms, export.

Covers DESIGN.md §15 — the metric registry semantics, the instrumented
fused route (bit-exactness + bincount parity + the zero-upload drain
protocol), the theory-bound alarms (balance envelope, delta/n disruption
bound), the span trace ring, JSON/Prometheus exposition, the certifier's
``observability/load_pass`` target and the lazy top-level exports.
"""
import numpy as np
import pytest

import repro
from repro.core.bulk import RouterSpec
from repro.observability import (
    BalanceDriftAlarm,
    DisruptionBoundAlarm,
    LoadConfig,
    LoadMonitor,
    MetricsRegistry,
    SpanTrace,
    disruption_bound,
    expected_peak_over_mean,
    snapshot,
    to_json,
    to_prometheus,
)
from repro.serving.batch_router import BatchRouter
from repro.serving.streaming import VirtualClockUs

ENGINES = ("binomial", "jump")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    clock = VirtualClockUs()
    reg = MetricsRegistry(clock=clock)
    c = reg.counter("reqs_total", tenant="a")
    c.inc()
    c.inc(4)
    clock.advance_us(10)
    g = reg.gauge("depth")
    g.set(7)
    h = reg.histogram("lat_us", bounds=(10, 100))
    for v in (5, 50, 500):
        h.observe(v)
    assert c.value == 5
    assert g.value == 7
    assert h.count == 3
    assert h.sum == 555
    assert h.bucket_counts == [1, 1, 1]
    assert h.mean == pytest.approx(185.0)
    # identity: same (name, labels) -> same object
    assert reg.counter("reqs_total", tenant="a") is c
    assert reg.counter("reqs_total", tenant="b") is not c


def test_counter_rejects_negative_and_kind_is_pinned():
    reg = MetricsRegistry()
    reg.counter("x_total").inc()
    with pytest.raises(ValueError):
        reg.counter("x_total").inc(-1)
    with pytest.raises(ValueError):
        reg.gauge("x_total")  # name already pinned as a counter family


def test_histogram_bounds_pinned_at_first_creation():
    reg = MetricsRegistry()
    h = reg.histogram("lat", bounds=(1, 2))
    assert reg.histogram("lat") is h  # later callers inherit the bounds
    with pytest.raises(ValueError):
        reg.histogram("lat", bounds=(1, 2, 3))


def test_family_and_total_aggregate_views():
    reg = MetricsRegistry()
    reg.counter("shed_total", tenant="a", reason="late").inc(2)
    reg.counter("shed_total", tenant="b", reason="late").inc(3)
    reg.counter("shed_total", tenant="a", reason="rate").inc(5)
    assert reg.total("shed_total") == 10
    assert reg.total("shed_total", tenant="a") == 7
    assert reg.total("shed_total", reason="late") == 5
    assert len(reg.family("shed_total")) == 3
    assert reg.total("never_seen") == 0


def test_registry_timestamps_come_from_the_injected_clock():
    clock = VirtualClockUs()
    reg = MetricsRegistry(clock=clock)
    c = reg.counter("ticks_total")
    clock.advance_us(123)
    c.inc()
    assert c.last_update_us == 123


# ---------------------------------------------------------------------------
# span trace
# ---------------------------------------------------------------------------


def test_span_trace_ring_and_monotone_counts():
    t = SpanTrace(capacity=4)
    for i in range(10):
        t.record("request", i, i + 1, tenant="a", replica=i % 3)
    t.record("admit", 100, 100)
    assert t.count("request") == 10  # totals survive ring recycling
    assert t.count("admit") == 1
    assert t.count() == 11
    assert t.dropped == 7
    retained = t.spans("request")
    assert len(retained) + len(t.spans("admit")) == 4
    # oldest-first within the ring
    starts = [s.t_start_us for s in retained]
    assert starts == sorted(starts)
    span = retained[-1]
    assert span.duration_us == 1
    assert span.tag("replica") == 9 % 3
    assert t.spans(tenant="nobody") == []


# ---------------------------------------------------------------------------
# instrumented route + drain protocol
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_instrumented_route_bit_exact_and_bincount_parity(engine):
    spec = RouterSpec(engine=engine, capacity=64, omega=16)
    plain = BatchRouter(12, spec)
    router = BatchRouter(12, spec)
    for r in (plain, router):
        r.fail(3)
        r.fail(7)
    mon = LoadMonitor(router, config=LoadConfig(drain_every=1 << 30))
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 1 << 32, size=4096, dtype=np.uint32)
    expect = np.asarray(plain.route_keys(keys))
    got = np.asarray(router.route_keys(keys))
    np.testing.assert_array_equal(got, expect)
    window = mon.drain()
    np.testing.assert_array_equal(
        window, np.bincount(expect, minlength=router.capacity).astype(np.uint32)
    )
    assert mon.total_keys == keys.size


@pytest.mark.parametrize("engine", ENGINES)
def test_drain_cadence_and_zero_upload_reset(engine):
    router = BatchRouter(8, engine=engine)
    mon = LoadMonitor(router, config=LoadConfig(drain_every=3))
    keys = np.arange(256, dtype=np.uint32)
    for _ in range(2):
        router.route_keys(keys)
    assert mon.drains == 0  # below the cadence: accumulating on device
    router.route_keys(keys)
    assert mon.drains == 1  # third batch triggered the window drain
    assert mon.total_keys == 3 * keys.size
    # the reset re-points at the pinned zeros buffer: no upload happened
    assert mon.counts_dev is mon._zeros_dev
    assert int(np.asarray(mon.counts_dev).sum()) == 0
    assert mon.metrics.total("load_keys_total") == 3 * keys.size
    assert mon.metrics.gauge("load_peak_over_mean").value >= 1.0
    mon.reset()
    assert mon.total_keys == 0 and not mon.totals.any()


def test_detach_restores_uninstrumented_dispatch():
    router = BatchRouter(8, engine="binomial")
    mon = LoadMonitor(router, config=LoadConfig(drain_every=1 << 30))
    keys = np.arange(128, dtype=np.uint32)
    router.route_keys(keys)
    mon.detach()
    router.route_keys(keys)
    mon.drain()
    assert mon.total_keys == keys.size  # second batch was not accumulated


@pytest.mark.parametrize("engine", ENGINES)
def test_sampled_accumulate_is_scaled_stride_bincount(engine):
    """Above the exact cutoff the accumulator holds the deterministic
    ``[::2**shift]`` stride bincount at weight ``2**shift`` (key units),
    mixing coherently with exact batches in the same window — and the
    replica ids stay bit-exact with the bare route."""
    plain = BatchRouter(12, engine=engine)
    router = BatchRouter(12, engine=engine)
    mon = LoadMonitor(
        router,
        config=LoadConfig(
            drain_every=1 << 30, exact_cutoff=1024, sample_shift=3
        ),
    )
    rng = np.random.default_rng(11)
    bulk = rng.integers(0, 1 << 32, size=4096, dtype=np.uint32)
    small = rng.integers(0, 1 << 32, size=512, dtype=np.uint32)
    cap = router.capacity
    expect_bulk = np.asarray(plain.route_keys(bulk))
    expect_small = np.asarray(plain.route_keys(small))
    np.testing.assert_array_equal(np.asarray(router.route_keys(bulk)), expect_bulk)
    np.testing.assert_array_equal(np.asarray(router.route_keys(small)), expect_small)
    window = mon.drain().astype(np.int64)
    scaled = np.bincount(expect_bulk[::8], minlength=cap) * 8
    exact = np.bincount(expect_small, minlength=cap)
    np.testing.assert_array_equal(window, scaled + exact)
    # the stride estimate stays in key units: totals sum to the key count
    assert int(window.sum()) == bulk.size + small.size


def test_effective_shift_honors_exact_cutoff():
    router = BatchRouter(8, engine="binomial")
    mon = LoadMonitor(
        router, config=LoadConfig(exact_cutoff=1 << 15, sample_shift=6)
    )
    assert mon.effective_shift(1 << 15) == 0
    assert mon.effective_shift((1 << 15) + 1) == 6
    mon.detach()


def test_load_config_rejects_bad_sampling_knobs():
    with pytest.raises(ValueError, match="sample_shift"):
        LoadConfig(sample_shift=-1)
    with pytest.raises(ValueError, match="exact_cutoff"):
        LoadConfig(exact_cutoff=-1)


def test_attach_rejects_two_pass_baseline():
    router = BatchRouter(8, engine="binomial", fused=False)
    with pytest.raises(ValueError, match="fused"):
        LoadMonitor(router)


# ---------------------------------------------------------------------------
# theory-bound alarms
# ---------------------------------------------------------------------------


def test_envelope_helpers():
    assert expected_peak_over_mean(0, 8) == 1.0
    assert expected_peak_over_mean(1 << 20, 1) == 1.0
    e = expected_peak_over_mean(1 << 20, 64)
    assert 1.0 < e < 1.1
    assert disruption_bound(1, 16, 16, slack=2.0) == pytest.approx(0.125)
    assert disruption_bound(100, 4, 4, slack=2.0) == 1.0  # capped


def test_balance_alarm_fires_on_skew_and_holds_on_uniform():
    alarms = []
    router = BatchRouter(8, engine="binomial")
    mon = LoadMonitor(
        router,
        config=LoadConfig(drain_every=1 << 30, min_alarm_keys=100),
        on_alarm=alarms.append,
    )
    alive = mon._alive_slots()
    # uniform totals: comfortably inside the envelope
    mon.totals[alive] = 1_000
    mon._check_balance(mon.peak_over_mean(alive), alive)
    assert alarms == []
    # all the load on one shard: peak/mean == n_alive, way outside
    mon.totals[:] = 0
    mon.totals[alive[0]] = 8_000
    ratio = mon.peak_over_mean(alive)
    assert ratio == pytest.approx(len(alive))
    mon._check_balance(ratio, alive)
    assert len(alarms) == 1
    alarm = alarms[0]
    assert isinstance(alarm, BalanceDriftAlarm)
    assert alarm.peak_over_mean == pytest.approx(ratio)
    assert alarm.n_alive == len(alive)
    assert alarm.peak_over_mean > alarm.threshold > alarm.expected
    assert mon.metrics.total("balance_alarms_total") == 1


def test_balance_alarm_raises_without_callback():
    router = BatchRouter(4, engine="binomial")
    mon = LoadMonitor(
        router, config=LoadConfig(drain_every=1 << 30, min_alarm_keys=1)
    )
    alive = mon._alive_slots()
    mon.totals[alive[0]] = 5_000
    with pytest.raises(BalanceDriftAlarm, match="peak/mean"):
        mon._check_balance(mon.peak_over_mean(alive), alive)


def test_disruption_alarm_fires_on_seeded_pathological_remap():
    alarms = []
    router = BatchRouter(16, engine="binomial")
    mon = LoadMonitor(
        router,
        config=LoadConfig(drain_every=1 << 30, n_probe=256),
        on_alarm=alarms.append,
    )
    prev = np.zeros(256, np.int32)
    # a full remap after ONE membership event: moved fraction 1.0 vs the
    # delta/n bound 2/16 = 0.125 — the pathological case the bound exists
    # to catch (a naive mod-N rehash moves ~everything per event)
    moved = mon.tracker.observe(prev, prev + 1, 1, 16, 16, epoch=9)
    assert moved == 1.0
    assert len(alarms) == 1
    alarm = alarms[0]
    assert isinstance(alarm, DisruptionBoundAlarm)
    assert alarm.moved_fraction == 1.0
    assert alarm.bound == pytest.approx(0.125)
    assert alarm.epoch == 9
    assert mon.metrics.gauge("load_moved_fraction").value == 1.0
    assert mon.metrics.total("disruption_alarms_total") == 1
    # a compliant window: one shard's share moved, inside the bound
    now = prev.copy()
    now[:16] = 1
    mon.tracker.observe(prev, now, 1, 16, 16)
    assert len(alarms) == 1  # no new alarm


@pytest.mark.parametrize("engine", ENGINES)
def test_live_tracker_stays_inside_bound_on_single_fail(engine):
    alarms = []
    router = BatchRouter(16, engine=engine)
    mon = LoadMonitor(
        router, config=LoadConfig(drain_every=1 << 30), on_alarm=alarms.append
    )
    router.route_keys(np.arange(64, dtype=np.uint32))
    mon.drain()  # baselines the probe routes
    router.fail(5)
    router.route_keys(np.arange(64, dtype=np.uint32))
    mon.drain()  # epoch advanced: live moved-fraction check
    assert alarms == []
    frac = mon.metrics.gauge("load_moved_fraction").value
    bound = mon.metrics.gauge("load_moved_bound").value
    assert 0.0 < frac <= bound  # the fail's share moved, within delta/n


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------


def _small_stack():
    clock = VirtualClockUs()
    reg = MetricsRegistry(clock=clock)
    reg.counter("served_total", tenant="a").inc(3)
    reg.gauge("depth").set(2)
    reg.histogram("lat_us", bounds=(10, 100)).observe(42)
    trace = SpanTrace(capacity=8)
    trace.record("request", 0, 42, tenant="a")
    return reg, trace


def test_snapshot_and_json_shape():
    reg, trace = _small_stack()
    snap = snapshot(reg, trace=trace)
    series = {(s["name"], tuple(sorted(s["labels"].items()))): s
              for s in snap["metrics"]}
    assert series[("served_total", (("tenant", "a"),))]["value"] == 3
    hist = series[("lat_us", ())]
    assert hist["count"] == 1 and hist["sum"] == 42
    assert hist["bucket_counts"] == [0, 1, 0]
    assert snap["trace"]["recorded"] == 1
    assert snap["trace"]["spans"][0]["tenant"] == "a"
    text = to_json(reg, trace=trace)
    assert to_json(reg, trace=trace) == text  # deterministic


def test_prometheus_exposition():
    reg, _ = _small_stack()
    text = to_prometheus(reg)
    assert "# TYPE served_total counter" in text
    assert 'served_total{tenant="a"} 3' in text
    assert "# TYPE depth gauge" in text
    assert "# TYPE lat_us histogram" in text
    assert 'lat_us_bucket{le="100"} 1' in text
    assert 'lat_us_bucket{le="+Inf"} 1' in text
    assert "lat_us_count 1" in text and "lat_us_sum 42" in text


# ---------------------------------------------------------------------------
# certifier + public surface
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_certifier_load_pass_green(engine):
    from repro.analysis.certify import certify_load_pass

    rep = certify_load_pass(engine)
    assert rep.target == "observability/load_pass"
    assert {c.invariant: c.status for c in rep.checks} == {
        "while-free": "pass",
        "unroll-affine": "pass",
        "dtype-closed": "pass",
        "callback-free": "pass",
        "transfer-count": "pass",
    }


def test_lazy_top_level_exports():
    for name in (
        "MetricsRegistry",
        "LoadMonitor",
        "LoadConfig",
        "SpanTrace",
        "BalanceDriftAlarm",
        "DisruptionBoundAlarm",
        "route_load_bulk",
    ):
        assert name in repro.__all__
        assert getattr(repro, name) is not None
    with pytest.raises(AttributeError):
        repro.no_such_export
