"""Batched recompile-free routing datapath: dynamic-n kernel, device Memento
remap, BatchRouter — bit-exactness vs the scalar oracles and no-retrace
guarantees across fleet events."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MementoWrapper, make
from repro.core.binomial import binomial_lookup32
from repro.core.binomial_jax import binomial_lookup_dyn
from repro.core.memento_jax import memento_remap, memento_remap_table
from repro.kernels.binomial_hash import (
    binomial_bulk_lookup_dyn_2d,
    binomial_bulk_lookup_pallas,
    binomial_bulk_lookup_pallas_dyn,
)
from repro.serving.batch_router import BatchRouter
from repro.serving.router import SessionRouter

RNG = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# dynamic-n Pallas kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("delta", [-1, 0, 1])
def test_dyn_kernel_pow2_boundaries(k, delta):
    """Bit-exact vs the scalar u32 oracle at n in {2^k-1, 2^k, 2^k+1}."""
    n = (1 << k) + delta
    if n < 2:
        pytest.skip("n < 2 is the degenerate single-bucket case")
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(512,), dtype=np.uint32))
    out = np.asarray(binomial_bulk_lookup_pallas_dyn(keys, n, interpret=True, block_rows=2))
    scal = [binomial_lookup32(int(x), n) for x in np.asarray(keys)]
    np.testing.assert_array_equal(out, scal)


@pytest.mark.parametrize("n", [1, 2, 7, 37, 128, 1000])
def test_dyn_kernel_matches_static(n):
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(16, 128), dtype=np.uint32))
    dyn = binomial_bulk_lookup_pallas_dyn(keys, n, interpret=True, block_rows=8)
    static = binomial_bulk_lookup_pallas(keys, n, interpret=True, block_rows=8)
    np.testing.assert_array_equal(np.asarray(dyn), np.asarray(static))


def test_dyn_kernel_no_retrace_across_resizes():
    """One executable serves every cluster size (the recompile-free core)."""
    keys = jnp.asarray(RNG.integers(0, 2**32, size=(16, 128), dtype=np.uint32))
    binomial_bulk_lookup_dyn_2d(keys, 3, interpret=True, block_rows=8)
    before = binomial_bulk_lookup_dyn_2d._cache_size()
    for n in [4, 7, 8, 9, 64, 1000, 2, 5]:  # crosses several pow2 boundaries
        binomial_bulk_lookup_dyn_2d(keys, n, interpret=True, block_rows=8)
    assert binomial_bulk_lookup_dyn_2d._cache_size() == before


# ---------------------------------------------------------------------------
# device-side Memento remap
# ---------------------------------------------------------------------------


def _scalar_oracle(n, removed):
    eng = MementoWrapper(lambda m: make("binomial32", m), n, chain_bits=32)
    for b in removed:
        eng.remove_bucket(b)
    return eng


def _device_route(keys_u32, eng, capacity=64):
    mask = np.zeros((capacity,), dtype=bool)
    mask[list(eng.removed)] = True
    buckets = binomial_lookup_dyn(keys_u32, np.uint32(eng.n_total))
    return np.asarray(
        memento_remap(keys_u32, buckets, mask, np.uint32(eng.n_total),
                      np.uint32(eng.first_alive()))
    )


@pytest.mark.parametrize("removed", [[], [0], [3], [1, 4], [0, 1, 2, 3, 4, 5]])
def test_remap_matches_scalar_wrapper(removed):
    n = 8
    eng = _scalar_oracle(n, removed)
    keys = RNG.integers(0, 2**32, size=(4096,), dtype=np.uint32)
    dev = _device_route(keys, eng)
    scal = [eng.get_bucket(int(k)) for k in keys]
    np.testing.assert_array_equal(dev, scal)
    assert not np.isin(dev, removed).any()


def test_remap_randomized_fail_recover_sequence():
    n = 16
    eng = _scalar_oracle(n, [])
    keys = RNG.integers(0, 2**32, size=(2048,), dtype=np.uint32)
    rng = np.random.default_rng(3)
    for _ in range(12):
        if eng.removed and rng.random() < 0.4:
            eng.restore_bucket(int(rng.choice(list(eng.removed))))
        elif eng.size > 2:
            alive = [b for b in range(eng.n_total) if b not in eng.removed]
            eng.remove_bucket(int(rng.choice(alive[:-1] or alive)))
        dev = _device_route(keys, eng)
        scal = [eng.get_bucket(int(k)) for k in keys]
        np.testing.assert_array_equal(dev, scal)


def test_remap_no_retrace_across_events():
    n = 8
    keys = RNG.integers(0, 2**32, size=(1024,), dtype=np.uint32)
    eng = _scalar_oracle(n, [2])
    _device_route(keys, eng)
    before = memento_remap._cache_size()
    for removed in [[2, 5], [5], [], [0, 1, 6]]:
        _device_route(keys, _scalar_oracle(n, removed))
    _device_route(keys, _scalar_oracle(12, [3]))  # resize, same capacity table
    assert memento_remap._cache_size() == before


# ---------------------------------------------------------------------------
# BatchRouter vs scalar SessionRouter
# ---------------------------------------------------------------------------


def _apply_events(router, events):
    for ev, arg in events:
        getattr(router, ev)(*(() if arg is None else (arg,)))


EVENTS = [
    ("fail", 2),
    ("scale_up", None),
    ("fail", 5),
    ("scale_down", None),
    ("recover", 2),
    ("scale_up", None),
    ("fail", 0),
    ("scale_up", None),
    ("recover", 0),
]


def test_batch_router_matches_scalar_session_router():
    """Key-for-key parity with SessionRouter(binomial32, table resolve)."""
    batch = BatchRouter(8)
    scalar = SessionRouter(8, engine="binomial32", chain_bits=32, resolve="table")
    sessions = [f"user-{i}" for i in range(500)]
    np.testing.assert_array_equal(
        batch.route_batch(sessions), [scalar.route(s) for s in sessions]
    )
    _apply_events(batch, EVENTS)
    _apply_events(scalar, EVENTS)
    np.testing.assert_array_equal(
        batch.route_batch(sessions), [scalar.route(s) for s in sessions]
    )
    # scalar path on the BatchRouter itself agrees with its own batch path
    assert [batch.route(s) for s in sessions[:50]] == list(batch.route_batch(sessions[:50]))


def test_batch_router_failure_reroutes_minimally():
    r = BatchRouter(8)
    sessions = [f"s{i}" for i in range(2000)]
    before = r.route_batch(sessions)
    r.fail(3)
    after = r.route_batch(sessions)
    moved = before != after
    assert (before[moved] == 3).all()  # only victims of the dead replica move
    assert (after != 3).all()
    r.recover(3)
    np.testing.assert_array_equal(r.route_batch(sessions), before)


def test_batch_router_non_default_omega_max_chain_parity():
    """omega/max_chain reach the scalar oracle too — scalar == batch holds."""
    r = BatchRouter(9, omega=2, max_chain=64)
    r.fail(1)
    keys = RNG.integers(0, 2**64, size=(4096,), dtype=np.uint64)
    out = r.route_keys(keys)
    expect = [r.domain.locate(int(k)) for k in keys]
    np.testing.assert_array_equal(out, expect)


def test_batch_router_moved_sessions_metric():
    """route_batch keeps the moved_sessions observability metric alive."""
    r = BatchRouter(8)
    sessions = [f"m{i}" for i in range(1000)]
    before = r.route_batch(sessions)
    assert r.stats.moved_sessions == 0
    r.fail(4)
    after = r.route_batch(sessions)
    moved = int((before != after).sum())
    assert moved > 0
    assert r.stats.moved_sessions == moved


def test_batch_router_capacity_guard():
    r = BatchRouter(4, capacity=8)
    for _ in range(4):
        r.scale_up()
    with pytest.raises(ValueError, match="capacity"):
        r.scale_up()


@pytest.mark.slow
def test_batch_router_1m_keys_zero_retrace_acceptance():
    """Acceptance: 1M-key batches through the FUSED Pallas kernel (and the
    two-pass baseline) with zero retraces across >= 8 scale/fail events,
    bit-exact with the scalar router."""
    from repro.kernels.binomial_hash import binomial_route_fused_2d

    router = BatchRouter(8, interpret=True)  # force the fused Pallas kernel (CPU)
    two_pass = BatchRouter(8, interpret=True, fused=False)
    scalar = SessionRouter(8, engine="binomial32", chain_bits=32, resolve="table")
    keys = RNG.integers(0, 2**64, size=(1 << 20,), dtype=np.uint64)

    router.route_keys(keys)  # compile once
    two_pass.route_keys(keys)
    fused_before = binomial_route_fused_2d._cache_size()
    kernel_before = binomial_bulk_lookup_dyn_2d._cache_size()
    remap_before = memento_remap_table._cache_size()

    sample = RNG.choice(len(keys), size=512, replace=False)
    assert len(EVENTS) >= 8
    for ev, arg in EVENTS:
        _apply_events(router, [(ev, arg)])
        _apply_events(two_pass, [(ev, arg)])
        _apply_events(scalar, [(ev, arg)])
        out = np.asarray(router.route_keys(keys))
        assert out.shape == keys.shape
        expect = [scalar.domain.locate(int(keys[j])) for j in sample]
        np.testing.assert_array_equal(out[sample], expect)
        np.testing.assert_array_equal(
            np.asarray(two_pass.route_keys(keys))[sample], expect
        )

    assert binomial_route_fused_2d._cache_size() == fused_before
    assert binomial_bulk_lookup_dyn_2d._cache_size() == kernel_before
    assert memento_remap_table._cache_size() == remap_before


# ---------------------------------------------------------------------------
# MoE hash router: dynamic-n flavour matches the static one
# ---------------------------------------------------------------------------


def test_moe_hash_router_dynamic_matches_static():
    import dataclasses

    import jax
    from repro.configs import reduced_config
    from repro.models.layers import moe

    cfg = reduced_config("qwen3-moe-235b-a22b")
    mcfg = dataclasses.replace(cfg.moe, router="hash")
    token_ids = jnp.asarray(RNG.integers(0, 50000, size=(2, 16), dtype=np.int32))
    x = jnp.zeros((2, 16, cfg.d_model), jnp.float32)
    p = moe.init_moe(jax.random.PRNGKey(0), dataclasses.replace(cfg, moe=mcfg))
    ids_s, _, _ = moe.route(p, x, token_ids, 3, dataclasses.replace(cfg, moe=mcfg))
    mdyn = dataclasses.replace(mcfg, router_dynamic_n=True)
    ids_d, _, _ = moe.route(p, x, token_ids, 3, dataclasses.replace(cfg, moe=mdyn))
    np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_d))
