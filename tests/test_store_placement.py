"""R-way replicated store placement (DESIGN.md §13): distinctness, typed
degradation, repair convergence, migration accounting, replay parity."""
import numpy as np
import pytest

from repro.core.bulk import PlacementSpec, RouterSpec
from repro.placement import assignment
from repro.placement.assignment import Move, MovementPlan
from repro.placement.store import NO_HOLDER, StorePlacement, family_salts
from repro.serving.batch_router import BatchRouter
from repro.serving.lifecycle import (
    FleetUnavailableError,
    LifecycleConfig,
    LifecycleManager,
    PlacementDegradedError,
    PlacementExhaustedError,
    PlacementRepairer,
)

ENGINES = ("binomial", "jump")
KEYS = np.random.default_rng(3).integers(0, 1 << 32, size=512, dtype=np.uint32)


def mk(n, engine="binomial", r=3, capacity=64, **kw):
    router = BatchRouter(n, engine=engine, capacity=capacity)
    mgr = LifecycleManager(router, LifecycleConfig(min_alive_floor=1))
    store = StorePlacement(router, r=r, **kw)
    return router, mgr, store


def distinct_per_row(replicas) -> np.ndarray:
    reps = np.asarray(replicas)
    return np.array([len(set(row.tolist())) for row in reps])


# -- the device pass: distinctness + alive-only -------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_placement_rows_distinct_and_alive(engine):
    _router, mgr, store = mk(8, engine=engine)
    batch = store.place(KEYS)
    assert batch.mode == "normal"
    assert batch.n_distinct == 3
    assert (distinct_per_row(batch.replicas) == 3).all()
    reps = np.asarray(batch.replicas)
    assert reps.shape == (KEYS.size, 3)
    assert ((reps >= 0) & (reps < 8)).all()
    # after failures every replica still lands on an ALIVE shard
    mgr.fail(2)
    mgr.fail(5)
    batch = store.place(KEYS)
    reps = np.asarray(batch.replicas)
    assert (distinct_per_row(reps) == 3).all()
    assert 2 not in set(reps.reshape(-1).tolist())
    assert 5 not in set(reps.reshape(-1).tolist())


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("n_alive", (2, 3, 4, 5, 8, 16))
def test_distinctness_guarantee_across_fleet_sizes(engine, n_alive):
    # default max_resalt: distinctness is DETERMINISTIC — every key gets
    # exactly min(r, n_alive) distinct shards, never a silent duplicate
    _router, _mgr, store = mk(n_alive, engine=engine)
    batch = store.place(KEYS)
    assert (distinct_per_row(batch.replicas) == min(3, n_alive)).all()


def test_r_equals_n_total_covers_every_shard():
    _router, _mgr, store = mk(4, r=4, capacity=4)
    batch = store.place(KEYS)
    reps = np.asarray(batch.replicas)
    # r == n_total: every row is a permutation of ALL four shards
    assert (np.sort(reps, axis=1) == np.arange(4)).all()


def test_family_salts_distinct():
    s = family_salts(8)
    assert np.unique(s).size == 8


# -- typed degradation --------------------------------------------------------


def test_r_exceeds_n_alive_degrades():
    _router, mgr, store = mk(8)
    for s in (0, 1, 2, 3, 4, 5):
        mgr.fail(s)
    assert mgr.n_alive == 2
    batch = store.place(KEYS)
    assert batch.mode == "degraded"
    assert batch.n_distinct == 2
    assert (distinct_per_row(batch.replicas) == 2).all()


def test_strict_raises_typed_degraded():
    _router, mgr, store = mk(4, strict=True)
    mgr.fail(1)
    mgr.fail(2)
    with pytest.raises(PlacementDegradedError) as ei:
        store.place(KEYS)
    assert ei.value.n_alive == 2
    assert ei.value.r == 3


def test_unavailable_stays_typed():
    _router, mgr, store = mk(2)
    store.register(KEYS[:32])
    mgr.fail(0)
    mgr.fail(1)
    assert mgr.n_alive == 0
    with pytest.raises(FleetUnavailableError):
        store.place(KEYS)
    with pytest.raises(FleetUnavailableError):
        store.read(0)


def test_resalt_exhaustion_is_typed_not_silent():
    # an explicitly too-tight probe bound: the collision is REPORTED as a
    # typed error, never resolved to a silent duplicate
    _router, _mgr, store = mk(4, r=2, max_resalt=0)
    with pytest.raises(PlacementExhaustedError) as ei:
        store.place(np.arange(1024, dtype=np.uint32))
    assert ei.value.n_keys > 0
    assert ei.value.max_resalt == 0
    # the raw expert path surfaces the per-key flags instead of raising
    replicas, exhausted = store.place_keys(np.arange(1024, dtype=np.uint32))
    ex = np.asarray(exhausted)
    assert ex.any()
    dup = distinct_per_row(replicas) == 1
    # exhausted keys are exactly the duplicated rows — nothing silent
    assert (dup == ex).all()


# -- degraded reads -----------------------------------------------------------


def test_all_but_one_holders_failed_still_readable():
    _router, mgr, store = mk(8)
    store.register(KEYS[:64])
    holders = store.holders[0].tolist()
    for s in holders[1:]:
        mgr.fail(int(s))
    found, mode = store.read(0)
    assert found.tolist() == [holders[0]]
    assert mode == "degraded"
    assert store.reachable_counts().min() >= 1


def test_read_normal_mode_when_fully_replicated():
    _router, _mgr, store = mk(8)
    store.register(KEYS[:16])
    found, mode = store.read(3)
    assert mode == "normal"
    assert len(set(found.tolist())) == 3


# -- migration plan -----------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_migration_diff_is_membership_not_positional(engine):
    router, mgr, store = mk(16, engine=engine, capacity=32)
    store.register(KEYS)
    mgr.scale_up()
    plan = store.plan_migration()
    # recompute the membership diff on the host and compare
    for i in range(plan.keys.size):
        old_row = set(plan.old[i].tolist())
        for j in range(3):
            assert plan.moved[i, j] == (int(plan.new[i, j]) not in old_row)
    assert plan.epoch == router.routing_epoch
    assert 0 < plan.moved_pairs < plan.total_pairs
    assert plan.moved_fraction == plan.moved_pairs / plan.total_pairs


def test_per_shard_moves_matches_mask():
    _router, mgr, store = mk(8, capacity=16)
    store.register(KEYS)
    mgr.scale_up()
    plan = store.plan_migration()
    sched = plan.per_shard_moves()
    assert sum(len(v) for v in sched.values()) == plan.moved_pairs
    for dst, moves in sched.items():
        assert dst in set(plan.new[plan.moved].tolist())
        assert all(isinstance(k, int) for k, _src in moves)


def test_as_movement_plan_shares_accounting():
    _router, mgr, store = mk(8, capacity=16)
    store.register(KEYS)
    mgr.scale_up()
    plan = store.plan_migration()
    mv = plan.as_movement_plan()
    assert mv.moved_count == plan.moved_pairs
    assert mv.total_keys == plan.total_pairs
    assert mv.destinations() <= set(plan.new.reshape(-1).tolist())


# -- MovementPlan unification -------------------------------------------------


def test_movement_plan_from_diff():
    keys = np.arange(6, dtype=np.uint64)
    before = np.array([0, 1, 2, 0, 1, 2])
    after = np.array([0, 1, 3, 3, 1, 2])
    plan = MovementPlan.from_diff(keys, before, after)
    assert plan.moved_count == 2
    assert plan.total_keys == 6
    assert plan.destinations() == {3}
    assert plan.sources() == {0, 2}
    assert {(m.key, m.src, m.dst) for m in plan.moves} == {(2, 2, 3), (3, 0, 3)}


def test_movement_plan_legacy_shim_warns_once():
    assignment._warned.discard("MovementPlan(moves, total_keys)")
    with pytest.warns(DeprecationWarning, match="from_diff"):
        plan = MovementPlan([Move(1, 0, 2)], 10)
    assert plan.moved_count == 1
    assert plan.moved_fraction == 0.1
    # warn-once: the second legacy construction is silent
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        MovementPlan([Move(1, 0, 2)], 10)


# -- repair scheduler ---------------------------------------------------------


def test_repairer_budget_and_oldest_first():
    _router, mgr, store = mk(8)
    store.register(KEYS)
    rep = PlacementRepairer(store, mgr, budget_per_tick=5)
    assert rep.backlog == 0
    mgr.fail(1)  # first membership event -> older epoch
    epoch1 = mgr.epoch
    mid = rep.backlog
    assert mid > 0
    done = rep.tick()
    assert 0 < len(done) <= 5
    assert all(t.epoch == epoch1 for t in done)
    mgr.fail(3)  # second event: NEW under-replication at a later epoch
    assert rep.backlog > 0
    emitted = []
    while rep.backlog:
        emitted.extend(rep.tick())
    epochs = [t.epoch for t in emitted]
    assert epochs == sorted(epochs)  # oldest-first across the whole drain
    assert max(rep.batches) <= 5
    assert (store.reachable_counts() == 3).all()
    assert rep.lost == 0


def test_repair_convergence_after_churn():
    _router, mgr, store = mk(8, capacity=16)
    store.register(KEYS)
    rep = PlacementRepairer(store, mgr, budget_per_tick=16)
    mgr.fail(2)
    mgr.scale_up()
    mgr.fail(5)
    mgr.recover(2)
    rep.quiesce()
    n_eff = min(3, mgr.n_alive)
    assert (store.reachable_counts() == n_eff).all()
    assert rep.backlog == 0


def test_repairer_ticks_through_manager():
    _router, mgr, store = mk(8)
    store.register(KEYS[:64])
    rep = PlacementRepairer(store, mgr, budget_per_tick=1_000_000)
    mgr.fail(4)
    assert rep.backlog > 0
    mgr.tick()  # the manager drives attached repairers
    assert rep.backlog == 0
    assert (store.reachable_counts() == 3).all()


def test_repairer_requires_same_router():
    _router, mgr, store = mk(8)
    other_router, _other_mgr, _other_store = mk(8)
    other_store = StorePlacement(other_router, r=3)
    with pytest.raises(ValueError, match="SAME router"):
        PlacementRepairer(other_store, mgr)


# -- journal replay parity ----------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_placement_replay_parity_across_crash(engine):
    _router, mgr, store = mk(8, engine=engine, capacity=16)
    store.register(KEYS)
    rep = PlacementRepairer(store, mgr, budget_per_tick=64)
    mgr.fail(1)
    mgr.scale_up()
    snap = mgr.snapshot()
    mgr.fail(6)
    mgr.recover(1)
    rep.quiesce()
    # genesis replay AND snapshot+tail replay both reproduce the live
    # R-way placement bit-exactly
    rep.verify_placement_replay()
    rep.verify_placement_replay(snap)


# -- spec validation ----------------------------------------------------------


def test_placement_spec_validation():
    with pytest.raises(ValueError, match="r must be"):
        PlacementSpec(r=0)
    with pytest.raises(ValueError, match="capacity"):
        PlacementSpec(router=RouterSpec(capacity=4), r=5)
    with pytest.raises(ValueError, match="max_resalt"):
        PlacementSpec(max_resalt=-1)
    spec = PlacementSpec(r=4)
    assert spec.resolved_max_resalt == 4
    assert PlacementSpec(r=4, max_resalt=9).resolved_max_resalt == 9
    hash(spec)  # static-arg hashability


def test_sync_targets_purges_retired_slots():
    _router, mgr, store = mk(4, capacity=4)
    store.register(KEYS[:64])
    mgr.fail(3)  # top slot: LIFO retirement shrinks the fleet
    assert store.router.domain.total_count == 3
    store.sync_targets()
    assert (store.holders < 3).all()  # no holder references the retired id
    assert (store.holders != NO_HOLDER).sum() > 0


# -- certifier target ---------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_certifier_placement_target(engine):
    from repro.analysis.certify import certify_placement_route

    report = certify_placement_route(engine)
    assert report.target == "placement/route_replicas"
    assert report.ok, [c.invariant for c in report.failures()]
