"""Checkpoint manager: atomic save/restore, resume, CH shard placement."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import model as M
from repro.training.checkpoint import CheckpointManager, _place
from repro.training.optimizer import make_optimizer
from repro.training.train_step import TrainHparams, make_train_state, make_train_step


def _state(cfg, seed=0):
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    opt = make_optimizer("adamw")
    return make_train_state(params, opt, TrainHparams()), opt


def test_roundtrip(tmp_path):
    cfg = reduced_config("mamba2-1.3b")
    state, _ = _state(cfg)
    mgr = CheckpointManager(str(tmp_path), n_nodes=4)
    mgr.save(7, state)
    assert mgr.latest_step() == 7
    restored = mgr.restore(7, jax.eval_shape(lambda: state))
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), state, restored)
    assert all(jax.tree.leaves(same))


def test_resume_after_training(tmp_path):
    cfg = reduced_config("stablelm-3b")
    state, opt = _state(cfg)
    step = jax.jit(make_train_step(cfg, opt, TrainHparams()))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (2, 17)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]), "targets": jnp.asarray(toks[:, 1:])}
    mgr = CheckpointManager(str(tmp_path), n_nodes=3)
    for _ in range(3):
        state, _ = step(state, batch)
    mgr.save(3, state)
    state_a, _ = step(state, batch)  # one more step, "crash" after
    # resume path: fresh process restores step 3 and repeats
    restored = mgr.restore(mgr.latest_step(), jax.eval_shape(lambda: state))
    state_b, _ = step(restored, batch)
    d = max(
        jax.tree.leaves(
            jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))), state_a["params"], state_b["params"])
        )
    )
    assert d == 0.0


def test_atomicity_tmpdir_never_visible(tmp_path):
    cfg = reduced_config("mamba2-1.3b")
    state, _ = _state(cfg)
    mgr = CheckpointManager(str(tmp_path), n_nodes=2)
    mgr.save(1, state)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_async_save(tmp_path):
    cfg = reduced_config("mamba2-1.3b")
    state, _ = _state(cfg)
    mgr = CheckpointManager(str(tmp_path), n_nodes=2)
    t = mgr.save_async(5, state)
    t.join(timeout=60)
    assert mgr.latest_step() == 5


def test_storage_resize_minimal_moves(tmp_path):
    cfg = reduced_config("qwen2.5-14b")
    state, _ = _state(cfg)
    mgr = CheckpointManager(str(tmp_path), n_nodes=8)
    like = jax.eval_shape(lambda: state)
    n_leaves = len(jax.tree.leaves(like))
    moves = mgr.plan_resize(like, 9)
    # monotonicity: every move targets the NEW node only
    assert all(dst == 8 for _, _, dst in moves)
    assert len(moves) <= n_leaves  # and roughly 1/9th of leaves move
    # shrink: moves only away from the removed node
    moves = mgr.plan_resize(like, 7)
    assert all(src == 7 for _, src, _ in moves)


def test_placement_deterministic():
    assert _place("['params']['seg0']['sub0']['wq']", 5) == _place(
        "['params']['seg0']['sub0']['wq']", 5
    )
