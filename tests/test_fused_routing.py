"""Fused single-dispatch routing kernel + device-resident BatchRouter state:
bit-exactness vs the scalar SessionRouter oracle (table resolution — the
serving-datapath semantics), the one-dispatch-per-batch guarantee, and zero
retraces / zero state re-uploads across fleet events."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.binomial_jax import mulhi32, umod32
from repro.core.memento_jax import (
    binomial_memento_route,
    mask_words,
    pack_removed_mask,
    pack_table,
)
from repro.kernels import ops
from repro.kernels.binomial_hash import (
    binomial_route_fused_2d,
    binomial_route_pallas_fused,
)
from repro.kernels.ref import binomial_route_ref
from repro.serving import batch_router as br_mod
from repro.serving.batch_router import BatchRouter
from repro.serving.router import SessionRouter

RNG = np.random.default_rng(7)


def _oracle(n, **kw):
    """The scalar oracle of the device datapath: u32 engine + table resolve."""
    return SessionRouter(n, engine="binomial32", chain_bits=32, resolve="table", **kw)


def _oracle_state(router: SessionRouter, capacity: int = 64):
    dom = router.domain
    packed = pack_removed_mask(dom.removed, capacity)
    table = pack_table(dom.replacement_table, capacity)
    state = np.array([dom.total_count, dom.alive_count], np.uint32)
    return packed, table, state


# ---------------------------------------------------------------------------
# divide-free building blocks (umod32 for the chain remap, mulhi32 for the
# table divert's Lemire range reduction)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 3, 5, 16, 37, 1000, (1 << 16) + 1, (1 << 31) - 1])
def test_umod32_matches_native_mod(n):
    x = RNG.integers(0, 2**32, size=(2048,), dtype=np.uint32)
    out = np.asarray(umod32(jnp.asarray(x), np.uint32(n)))
    np.testing.assert_array_equal(out, x % np.uint32(n))


def test_mulhi32_matches_u64_reference():
    a = RNG.integers(0, 2**32, size=(4096,), dtype=np.uint32)
    b = RNG.integers(0, 2**32, size=(4096,), dtype=np.uint32)
    ref = ((a.astype(np.uint64) * b.astype(np.uint64)) >> 32).astype(np.uint32)
    np.testing.assert_array_equal(
        np.asarray(mulhi32(jnp.asarray(a), jnp.asarray(b))), ref
    )
    # edge operands: 0, 1, 2^31, 2^32-1
    e = np.array([0, 1, 1 << 31, (1 << 32) - 1], dtype=np.uint32)
    ee = np.stack(np.meshgrid(e, e)).reshape(2, -1)
    ref = ((ee[0].astype(np.uint64) * ee[1].astype(np.uint64)) >> 32).astype(np.uint32)
    np.testing.assert_array_equal(
        np.asarray(mulhi32(jnp.asarray(ee[0]), jnp.asarray(ee[1]))), ref
    )


# ---------------------------------------------------------------------------
# fused kernel vs the scalar SessionRouter oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 3, 5])
@pytest.mark.parametrize("delta", [-1, 0, 1])
def test_fused_kernel_pow2_boundaries(k, delta):
    """Bit-exact vs SessionRouter at n in {2^k-1, 2^k, 2^k+1}, with failures."""
    n = (1 << k) + delta
    if n < 2:
        pytest.skip("n < 2 is the degenerate single-bucket case")
    oracle = _oracle(n)
    if n > 2:
        oracle.fail(n // 2)
    packed, table, state = _oracle_state(oracle)
    keys = RNG.integers(0, 2**32, size=(512,), dtype=np.uint32)
    out = np.asarray(
        binomial_route_pallas_fused(
            jnp.asarray(keys), jnp.asarray(packed), jnp.asarray(table),
            jnp.asarray(state),
            n_words=mask_words(64), n_slots=64, interpret=True, block_rows=2,
        )
    )
    expect = [oracle.domain.locate(int(x)) for x in keys]
    np.testing.assert_array_equal(out, expect)


def test_fused_kernel_randomized_fail_recover_stream():
    """The fused kernel tracks the oracle through a random event stream."""
    router = BatchRouter(16, interpret=True, block_rows=8)
    oracle = _oracle(16)
    keys = RNG.integers(0, 2**64, size=(2048,), dtype=np.uint64)
    rng = np.random.default_rng(5)
    for _ in range(15):
        removed = sorted(router.domain.removed)
        roll = rng.random()
        if removed and roll < 0.35:
            r = int(rng.choice(removed))
            router.recover(r), oracle.recover(r)
        elif roll < 0.55 and router.domain.total_count < router.capacity:
            router.scale_up(), oracle.scale_up()
        elif roll < 0.7 and router.alive > 2:
            router.scale_down(), oracle.scale_down()
        elif router.alive > 2:
            alive = [
                b for b in range(router.domain.total_count - 1)
                if b not in router.domain.removed
            ]
            r = int(rng.choice(alive))
            router.fail(r), oracle.fail(r)
        out = router.route_keys_np(keys)
        expect = [oracle.domain.locate(int(k)) for k in keys]
        np.testing.assert_array_equal(out, expect)


def test_fused_paths_agree_with_ref_and_two_pass():
    """pallas(interpret) == jnp jit == unjitted ref == two-pass BatchRouter."""
    oracle = _oracle(12)
    for r in (1, 4, 9):
        oracle.fail(r)
    packed, table, state = _oracle_state(oracle)
    keys = RNG.integers(0, 2**32, size=(4096,), dtype=np.uint32)
    kj = jnp.asarray(keys)
    fused_pl = np.asarray(
        binomial_route_pallas_fused(
            kj, jnp.asarray(packed), jnp.asarray(table), jnp.asarray(state),
            n_words=mask_words(64), n_slots=64, interpret=True, block_rows=4,
        )
    )
    fused_jnp = np.asarray(
        binomial_memento_route(
            kj, jnp.asarray(packed), jnp.asarray(table), jnp.asarray(state),
            n_words=mask_words(64),
        )
    )
    ref = np.asarray(binomial_route_ref(kj, packed, table, state))
    two_pass = BatchRouter(12, fused=False)
    for r in (1, 4, 9):
        two_pass.fail(r)
    np.testing.assert_array_equal(fused_pl, fused_jnp)
    np.testing.assert_array_equal(fused_pl, ref)
    np.testing.assert_array_equal(fused_pl, two_pass.route_keys_np(keys))


def test_fused_multiword_mask_and_table_cascade():
    """capacity > 32 exercises the multi-word mask cascade AND the deep
    (two-redirect) branch of the table gather cascade in the kernel."""
    cap = 256
    oracle = _oracle(100)
    for r in (0, 31, 32, 63, 64, 95, 97):
        oracle.fail(r)
    packed, table, state = _oracle_state(oracle, capacity=cap)
    assert mask_words(cap) == 8
    keys = RNG.integers(0, 2**32, size=(1024,), dtype=np.uint32)
    out = np.asarray(
        binomial_route_pallas_fused(
            jnp.asarray(keys), jnp.asarray(packed), jnp.asarray(table),
            jnp.asarray(state),
            n_words=mask_words(cap), n_slots=cap, interpret=True, block_rows=2,
        )
    )
    expect = [oracle.domain.locate(int(x)) for x in keys]
    np.testing.assert_array_equal(out, expect)


# ---------------------------------------------------------------------------
# the single-dispatch + device-resident-state guarantees
# ---------------------------------------------------------------------------


EVENTS = [
    ("fail", 2),
    ("scale_up", None),
    ("fail", 5),
    ("scale_down", None),
    ("recover", 2),
    ("scale_up", None),
]


def test_route_keys_is_exactly_one_dispatch_per_batch(monkeypatch):
    """The fused path issues ONE device dispatch per batch and never touches
    the two-pass entry points — asserted across scale/fail/recover events.

    The spec dispatcher resolves its engine bundle from ``BULK_ENGINES``
    per call, so swapping the entry intercepts every dispatch."""
    import dataclasses

    from repro.core import registry

    router = BatchRouter(8, interpret=True, block_rows=8)
    keys = RNG.integers(0, 2**64, size=(4096,), dtype=np.uint64)
    router.route_keys(keys)  # compile once

    calls = {"fused": 0}
    real = ops.binomial_route_pallas_fused

    def counting(*a, **k):
        calls["fused"] += 1
        return real(*a, **k)

    def forbidden(*a, **k):  # pragma: no cover - the assertion IS the test
        raise AssertionError("two-pass entry point reached on the fused path")

    monkeypatch.setitem(
        registry.BULK_ENGINES,
        "binomial",
        dataclasses.replace(
            registry.BULK_ENGINES["binomial"],
            route_pallas=counting,
            route=forbidden,  # interpret mode must take the kernel, not jnp
            lookup_dyn=forbidden,
            lookup_dyn_pallas=forbidden,
        ),
    )
    monkeypatch.setattr(br_mod, "memento_remap_table", forbidden)

    before = binomial_route_fused_2d._cache_size()
    n_batches = 0
    for ev, arg in EVENTS:
        getattr(router, ev)(*(() if arg is None else (arg,)))
        router.route_keys(keys)
        n_batches += 1
    assert calls["fused"] == n_batches  # exactly one dispatch per batch
    assert binomial_route_fused_2d._cache_size() == before  # zero retraces


def test_route_keys_zero_per_batch_state_uploads():
    """Device fleet state is pinned at event time; route_keys re-uses the
    same buffers — no per-batch host->device rebuild/upload."""
    router = BatchRouter(8, interpret=True, block_rows=8)
    keys = RNG.integers(0, 2**64, size=(2048,), dtype=np.uint64)
    packed, table, state = router._packed_dev, router._table_dev, router._state_dev
    for _ in range(3):
        router.route_keys(keys)
        assert router._packed_dev is packed
        assert router._table_dev is table
        assert router._state_dev is state
    router.fail(3)  # event: state may be re-pinned...
    packed, table, state = router._packed_dev, router._table_dev, router._state_dev
    assert packed is not None and table is not None and state is not None
    for _ in range(3):  # ...but batches still don't touch it
        router.route_keys(keys)
        assert router._packed_dev is packed
        assert router._table_dev is table
        assert router._state_dev is state


def test_route_keys_jax_in_jax_out():
    """jax.Array in -> jax.Array out, no host round-trip forced; the numpy
    wrapper and the device path agree."""
    import jax

    router = BatchRouter(8)
    router.fail(2)
    keys_np = RNG.integers(0, 2**32, size=(1024,), dtype=np.uint32)
    keys_dev = jnp.asarray(keys_np)
    out_dev = router.route_keys(keys_dev)
    assert isinstance(out_dev, jax.Array)
    out_np = router.route_keys_np(keys_np)
    assert isinstance(out_np, np.ndarray)
    np.testing.assert_array_equal(np.asarray(out_dev), out_np)


def test_fail_last_slot_is_lifo_removal_not_stale_bit():
    """Failing the last slot shrinks the slot space in the control plane;
    the device mask/table must not keep stale entries that poison a later
    scale-up."""
    router = BatchRouter(8, interpret=True, block_rows=8)
    oracle = _oracle(8)
    keys = RNG.integers(0, 2**64, size=(1024,), dtype=np.uint64)
    for ev in (("fail", 7), ("scale_up", None), ("fail", 3), ("fail", 7)):
        getattr(router, ev[0])(*(() if ev[1] is None else (ev[1],)))
        getattr(oracle, ev[0])(*(() if ev[1] is None else (ev[1],)))
        np.testing.assert_array_equal(
            router.route_keys_np(keys), [oracle.domain.locate(int(k)) for k in keys]
        )


def test_coerce_keys_skips_redundant_conversions():
    router = BatchRouter(4)
    ku32 = np.ascontiguousarray(RNG.integers(0, 2**32, size=64, dtype=np.uint32))
    assert router._coerce_keys(ku32) is ku32  # no u64->u32 double conversion
    kdev = jnp.asarray(ku32)
    assert router._coerce_keys(kdev) is kdev  # no host round-trip at all
    wide = RNG.integers(0, 2**64, size=64, dtype=np.uint64)
    np.testing.assert_array_equal(router._coerce_keys(wide), wide.astype(np.uint32))


# ---------------------------------------------------------------------------
# constructor validation (clear errors at construction, not deep in a trace)
# ---------------------------------------------------------------------------


def test_batch_router_rejects_bad_block_rows():
    with pytest.raises(ValueError, match="multiple of 8"):
        BatchRouter(8, block_rows=12)
    with pytest.raises(ValueError, match="multiple of 8"):
        BatchRouter(8, block_rows=0)
    with pytest.raises(ValueError, match="multiple of 8"):
        BatchRouter(8, block_rows=-8)
    BatchRouter(8, block_rows=8)  # the smallest legal tiling


def test_batch_router_rejects_bad_max_chain():
    with pytest.raises(ValueError, match="max_chain must be >= 0"):
        BatchRouter(8, max_chain=-1)
    BatchRouter(8, max_chain=0)  # zero is a legal (degenerate) budget


def test_batch_router_rejects_non_pow2_capacity():
    with pytest.raises(ValueError, match="power of two"):
        BatchRouter(8, capacity=48)
    with pytest.raises(ValueError, match="power of two"):
        BatchRouter(8, capacity=0)
    BatchRouter(8, capacity=16)


def test_batch_router_rejects_bad_n_replicas():
    with pytest.raises(ValueError, match="n_replicas"):
        BatchRouter(0)
    with pytest.raises(ValueError, match="exceeds capacity"):
        BatchRouter(100, capacity=64)


def test_batch_router_rejects_meaningless_mesh_combinations():
    """fused=False and donate_keys are sharded-vs-single-host specific —
    silently ignoring them would invalidate benchmark comparisons."""
    import jax

    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="single-host only"):
        BatchRouter(8, mesh=mesh, fused=False)
    with pytest.raises(ValueError, match="donate_keys"):
        BatchRouter(8, donate_keys=True)
