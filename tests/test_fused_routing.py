"""Fused single-dispatch routing kernel + device-resident BatchRouter state:
bit-exactness vs the scalar SessionRouter oracle, the one-dispatch-per-batch
guarantee, and zero retraces / zero state re-uploads across fleet events."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.binomial_jax import umod32
from repro.core.memento_jax import (
    binomial_memento_route,
    mask_words,
    pack_removed_mask,
)
from repro.kernels import ops
from repro.kernels.binomial_hash import (
    binomial_route_fused_2d,
    binomial_route_pallas_fused,
)
from repro.kernels.ref import binomial_route_ref
from repro.serving import batch_router as br_mod
from repro.serving.batch_router import BatchRouter
from repro.serving.router import SessionRouter

RNG = np.random.default_rng(7)


def _oracle_state(router: SessionRouter, capacity: int = 64):
    dom = router.domain
    packed = pack_removed_mask(dom.removed, capacity)
    state = np.array([dom.total_count, dom.first_alive()], np.uint32)
    return packed, state


# ---------------------------------------------------------------------------
# divide-free modulo (the in-kernel chain step building block)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 3, 5, 16, 37, 1000, (1 << 16) + 1, (1 << 31) - 1])
def test_umod32_matches_native_mod(n):
    x = RNG.integers(0, 2**32, size=(2048,), dtype=np.uint32)
    out = np.asarray(umod32(jnp.asarray(x), np.uint32(n)))
    np.testing.assert_array_equal(out, x % np.uint32(n))


# ---------------------------------------------------------------------------
# fused kernel vs the scalar SessionRouter oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 3, 5])
@pytest.mark.parametrize("delta", [-1, 0, 1])
def test_fused_kernel_pow2_boundaries(k, delta):
    """Bit-exact vs SessionRouter at n in {2^k-1, 2^k, 2^k+1}, with failures."""
    n = (1 << k) + delta
    if n < 2:
        pytest.skip("n < 2 is the degenerate single-bucket case")
    oracle = SessionRouter(n, engine="binomial32", chain_bits=32)
    if n > 2:
        oracle.fail(n // 2)
    packed, state = _oracle_state(oracle)
    keys = RNG.integers(0, 2**32, size=(512,), dtype=np.uint32)
    out = np.asarray(
        binomial_route_pallas_fused(
            jnp.asarray(keys), jnp.asarray(packed), jnp.asarray(state),
            n_words=mask_words(64), interpret=True, block_rows=2,
        )
    )
    expect = [oracle.domain.locate(int(x)) for x in keys]
    np.testing.assert_array_equal(out, expect)


def test_fused_kernel_randomized_fail_recover_stream():
    """The fused kernel tracks the oracle through a random event stream."""
    router = BatchRouter(16, interpret=True, block_rows=2)
    oracle = SessionRouter(16, engine="binomial32", chain_bits=32)
    keys = RNG.integers(0, 2**64, size=(2048,), dtype=np.uint64)
    rng = np.random.default_rng(5)
    for _ in range(15):
        removed = sorted(router.domain.removed)
        roll = rng.random()
        if removed and roll < 0.35:
            r = int(rng.choice(removed))
            router.recover(r), oracle.recover(r)
        elif roll < 0.55 and router.domain.total_count < router.capacity:
            router.scale_up(), oracle.scale_up()
        elif roll < 0.7 and router.alive > 2:
            router.scale_down(), oracle.scale_down()
        elif router.alive > 2:
            alive = [
                b for b in range(router.domain.total_count - 1)
                if b not in router.domain.removed
            ]
            r = int(rng.choice(alive))
            router.fail(r), oracle.fail(r)
        out = router.route_keys_np(keys)
        expect = [oracle.domain.locate(int(k)) for k in keys]
        np.testing.assert_array_equal(out, expect)


def test_fused_paths_agree_with_ref_and_two_pass():
    """pallas(interpret) == jnp jit == unjitted ref == two-pass BatchRouter."""
    oracle = SessionRouter(12, engine="binomial32", chain_bits=32)
    for r in (1, 4, 9):
        oracle.fail(r)
    packed, state = _oracle_state(oracle)
    keys = RNG.integers(0, 2**32, size=(4096,), dtype=np.uint32)
    kj = jnp.asarray(keys)
    fused_pl = np.asarray(
        binomial_route_pallas_fused(
            kj, jnp.asarray(packed), jnp.asarray(state),
            n_words=mask_words(64), interpret=True, block_rows=4,
        )
    )
    fused_jnp = np.asarray(
        binomial_memento_route(kj, jnp.asarray(packed), jnp.asarray(state))
    )
    ref = np.asarray(binomial_route_ref(kj, packed, state))
    two_pass = BatchRouter(12, fused=False)
    for r in (1, 4, 9):
        two_pass.fail(r)
    np.testing.assert_array_equal(fused_pl, fused_jnp)
    np.testing.assert_array_equal(fused_pl, ref)
    np.testing.assert_array_equal(fused_pl, two_pass.route_keys_np(keys))


def test_fused_multiword_mask_cascade():
    """capacity > 32 exercises the multi-word select cascade in the kernel."""
    cap = 256
    oracle = SessionRouter(100, engine="binomial32", chain_bits=32)
    for r in (0, 31, 32, 63, 64, 95, 97):
        oracle.fail(r)
    packed, state = _oracle_state(oracle, capacity=cap)
    assert mask_words(cap) == 8
    keys = RNG.integers(0, 2**32, size=(1024,), dtype=np.uint32)
    out = np.asarray(
        binomial_route_pallas_fused(
            jnp.asarray(keys), jnp.asarray(packed), jnp.asarray(state),
            n_words=mask_words(cap), interpret=True, block_rows=2,
        )
    )
    expect = [oracle.domain.locate(int(x)) for x in keys]
    np.testing.assert_array_equal(out, expect)


# ---------------------------------------------------------------------------
# the single-dispatch + device-resident-state guarantees
# ---------------------------------------------------------------------------


EVENTS = [
    ("fail", 2),
    ("scale_up", None),
    ("fail", 5),
    ("scale_down", None),
    ("recover", 2),
    ("scale_up", None),
]


def test_route_keys_is_exactly_one_dispatch_per_batch(monkeypatch):
    """The fused path issues ONE device dispatch per batch and never touches
    the two-pass entry points — asserted across scale/fail/recover events."""
    router = BatchRouter(8, interpret=True, block_rows=8)
    keys = RNG.integers(0, 2**64, size=(4096,), dtype=np.uint64)
    router.route_keys(keys)  # compile once

    calls = {"fused": 0}
    real = ops.binomial_route_pallas_fused

    def counting(*a, **k):
        calls["fused"] += 1
        return real(*a, **k)

    def forbidden(*a, **k):  # pragma: no cover - the assertion IS the test
        raise AssertionError("two-pass entry point reached on the fused path")

    monkeypatch.setattr(ops, "binomial_route_pallas_fused", counting)
    monkeypatch.setattr(ops, "binomial_bulk_lookup_pallas_dyn", forbidden)
    monkeypatch.setattr(ops, "binomial_lookup_dyn", forbidden)
    monkeypatch.setattr(br_mod, "binomial_bulk_lookup_dyn", forbidden)
    monkeypatch.setattr(br_mod, "memento_remap", forbidden)

    before = binomial_route_fused_2d._cache_size()
    n_batches = 0
    for ev, arg in EVENTS:
        getattr(router, ev)(*(() if arg is None else (arg,)))
        router.route_keys(keys)
        n_batches += 1
    assert calls["fused"] == n_batches  # exactly one dispatch per batch
    assert binomial_route_fused_2d._cache_size() == before  # zero retraces


def test_route_keys_zero_per_batch_state_uploads():
    """Device fleet state is pinned at event time; route_keys re-uses the
    same buffers — no per-batch host->device rebuild/upload."""
    router = BatchRouter(8, interpret=True, block_rows=8)
    keys = RNG.integers(0, 2**64, size=(2048,), dtype=np.uint64)
    packed, state = router._packed_dev, router._state_dev
    for _ in range(3):
        router.route_keys(keys)
        assert router._packed_dev is packed
        assert router._state_dev is state
    router.fail(3)  # event: state may be re-pinned...
    packed, state = router._packed_dev, router._state_dev
    assert packed is not None and state is not None
    for _ in range(3):  # ...but batches still don't touch it
        router.route_keys(keys)
        assert router._packed_dev is packed
        assert router._state_dev is state


def test_route_keys_jax_in_jax_out():
    """jax.Array in -> jax.Array out, no host round-trip forced; the numpy
    wrapper and the device path agree."""
    import jax

    router = BatchRouter(8)
    router.fail(2)
    keys_np = RNG.integers(0, 2**32, size=(1024,), dtype=np.uint32)
    keys_dev = jnp.asarray(keys_np)
    out_dev = router.route_keys(keys_dev)
    assert isinstance(out_dev, jax.Array)
    out_np = router.route_keys_np(keys_np)
    assert isinstance(out_np, np.ndarray)
    np.testing.assert_array_equal(np.asarray(out_dev), out_np)


def test_fail_last_slot_is_lifo_removal_not_stale_bit():
    """Failing the last slot shrinks the slot space in the control plane;
    the device mask must not keep a stale bit that poisons a later scale-up."""
    router = BatchRouter(8, interpret=True, block_rows=2)
    oracle = SessionRouter(8, engine="binomial32", chain_bits=32)
    keys = RNG.integers(0, 2**64, size=(1024,), dtype=np.uint64)
    for ev in (("fail", 7), ("scale_up", None), ("fail", 3), ("fail", 7)):
        getattr(router, ev[0])(*(() if ev[1] is None else (ev[1],)))
        getattr(oracle, ev[0])(*(() if ev[1] is None else (ev[1],)))
        np.testing.assert_array_equal(
            router.route_keys_np(keys), [oracle.domain.locate(int(k)) for k in keys]
        )


def test_coerce_keys_skips_redundant_conversions():
    router = BatchRouter(4)
    ku32 = np.ascontiguousarray(RNG.integers(0, 2**32, size=64, dtype=np.uint32))
    assert router._coerce_keys(ku32) is ku32  # no u64->u32 double conversion
    kdev = jnp.asarray(ku32)
    assert router._coerce_keys(kdev) is kdev  # no host round-trip at all
    wide = RNG.integers(0, 2**64, size=64, dtype=np.uint64)
    np.testing.assert_array_equal(router._coerce_keys(wide), wide.astype(np.uint32))
