"""Mesh-sharded routing datapath (DESIGN.md §8): a subprocess with 8 fake
host devices checks that the shard_map'd ``BatchRouter`` is bit-exact with
the single-device path and the scalar oracle across fleet events, never
retraces, pads non-divisible batches correctly, and honours key-buffer
donation semantics."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np

from repro.serving.batch_router import BatchRouter
from repro.serving.router import SessionRouter

assert len(jax.devices()) == 8
mesh = jax.make_mesh((8,), ("data",))

rng = np.random.default_rng(9)
keys = rng.integers(0, 2**64, size=(1 << 16,), dtype=np.uint64)

sharded = BatchRouter(16, mesh=mesh)
single = BatchRouter(16)
oracle = SessionRouter(16, engine="binomial32", chain_bits=32, resolve="table")

results = {"parity": True, "sharding_ok": True}

# compile once, then count retraces across the event stream
out0 = sharded.route_keys(keys)
shard_sizes = {s.data.shape for s in out0.addressable_shards}
results["n_output_shards"] = len(out0.addressable_shards)
results["shard_sizes"] = sorted(str(s) for s in shard_sizes)
route_fn = sharded._sharded_route
assert len(route_fn) == 1
jitted = next(iter(route_fn.values()))
traces_before = jitted._cache_size()

EVENTS = [("fail", 3), ("scale_up", None), ("fail", 7), ("scale_down", None),
          ("recover", 3), ("scale_up", None), ("fail", 0), ("recover", 7)]
sample = rng.choice(len(keys), size=256, replace=False)
for ev, arg in EVENTS:
    for r in (sharded, single, oracle):
        getattr(r, ev)(*(() if arg is None else (arg,)))
    a = np.asarray(sharded.route_keys(keys))
    b = single.route_keys_np(keys)
    if not np.array_equal(a, b):
        results["parity"] = False
    expect = [oracle.domain.locate(int(keys[j])) for j in sample]
    if not np.array_equal(a[sample], expect):
        results["parity"] = False
results["retraces"] = jitted._cache_size() - traces_before

# non-divisible batch: 10_001 keys over 8 shards takes the padding path
odd = keys[:10_001]
results["pad_parity"] = bool(
    np.array_equal(np.asarray(sharded.route_keys(odd)), single.route_keys_np(odd))
)

# donation: numpy input buffers are uploaded (and owned) by the router, so
# donation must not break reuse of the caller's numpy array; jax.Array
# inputs are defensively copied before donation.
donating = BatchRouter(16, mesh=mesh, donate_keys=True)
first = np.asarray(donating.route_keys(keys))
second = np.asarray(donating.route_keys(keys))  # same numpy buffer again
results["donate_np_reuse"] = bool(np.array_equal(first, second))
kdev = jax.device_put(keys.astype(np.uint32))
third = np.asarray(donating.route_keys(kdev))
fourth = np.asarray(donating.route_keys(kdev))  # caller buffer must survive
results["donate_jax_reuse"] = bool(np.array_equal(third, fourth))
fresh = BatchRouter(16)  # healthy-fleet reference (no events applied)
results["donate_parity"] = bool(np.array_equal(first, fresh.route_keys_np(keys)))

print("RESULTS " + json.dumps(results))
"""


@pytest.mark.slow
def test_sharded_routing_matches_single_device_and_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS ")]
    assert line, out.stdout
    results = json.loads(line[0][len("RESULTS "):])
    assert results["parity"], results
    assert results["retraces"] == 0, results  # fleet events never retrace
    assert results["n_output_shards"] == 8, results  # keys really split 8 ways
    assert results["pad_parity"], results
    assert results["donate_np_reuse"], results
    assert results["donate_jax_reuse"], results
    assert results["donate_parity"], results
