"""Registry coverage: every ``ENGINES`` entry constructs and routes, the
named comparison lists point at real engines and honour their advertised
properties, and every ``BULK_ENGINES`` device entry is bit-exact against
its scalar oracle across fleet-event streams."""
import numpy as np
import pytest

from repro.core.registry import (
    BULK_ENGINES,
    CONSTANT_TIME,
    ENGINES,
    FULLY_CONSISTENT,
    make,
    make_bulk,
)
from repro.serving.batch_router import BatchRouter
from repro.serving.router import SessionRouter

RNG = np.random.default_rng(23)
KEYS = [int(k) for k in RNG.integers(0, 2**64, size=400, dtype=np.uint64)]


# ---------------------------------------------------------------------------
# scalar registry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ENGINES))
def test_engine_constructs_routes_and_resizes(name):
    eng = ENGINES[name](7)
    assert eng.size == 7
    for k in KEYS[:50]:
        assert 0 <= eng.get_bucket(k) < 7
    new = eng.add_bucket()
    assert new == 7 and eng.size == 8
    assert eng.remove_bucket() == 7 and eng.size == 7
    assert isinstance(eng.name, str) and isinstance(eng.exact, bool)


def test_make_resolves_and_rejects():
    assert make("binomial", 5).size == 5
    with pytest.raises(KeyError, match="unknown engine"):
        make("not-an-engine", 5)
    with pytest.raises(KeyError, match="unknown bulk engine"):
        make_bulk("not-an-engine")


def test_named_lists_are_registry_members():
    assert set(CONSTANT_TIME) <= set(ENGINES)
    assert set(FULLY_CONSISTENT) <= set(ENGINES)
    # the paper's Fig. 5 comparison set and both device-word flavours exist
    for name in ("binomial", "jump", "binomial32", "jump32"):
        assert name in ENGINES


@pytest.mark.parametrize("name", sorted(FULLY_CONSISTENT))
def test_fully_consistent_engines_are_monotone(name):
    """Growing n -> n+1 moves keys only ONTO the new bucket; shrinking
    n+1 -> n moves only the keys OF the removed bucket (the §6 guarantee
    the FULLY_CONSISTENT list advertises), across small n incl. pow2
    boundaries."""
    for n in range(1, 18):
        eng = ENGINES[name](n)
        before = [eng.get_bucket(k) for k in KEYS]
        eng.add_bucket()
        after = [eng.get_bucket(k) for k in KEYS]
        movers = [(a, b) for a, b in zip(before, after) if a != b]
        assert all(b == n for _, b in movers), f"{name} n={n}: non-monotone grow"
        # shrink back: exactly the keys on bucket n return to their old home
        eng.remove_bucket()
        again = [eng.get_bucket(k) for k in KEYS]
        assert again == before, f"{name} n={n}: remove(add(x)) != x"


# ---------------------------------------------------------------------------
# bulk (device) registry: each entry vs its scalar oracle over event streams
# ---------------------------------------------------------------------------

EVENT_STREAM = [
    ("fail", 2),
    ("scale_up", None),
    ("fail", 5),
    ("scale_down", None),
    ("recover", 2),
    ("scale_up", None),
    ("fail", 0),
    ("recover", 0),
]


@pytest.mark.parametrize("name", sorted(BULK_ENGINES))
def test_bulk_engine_entry_is_complete(name):
    eng = make_bulk(name)
    assert eng.name == name
    assert eng.scalar_engine in ENGINES
    assert callable(eng.route)
    # the serving tier's two-pass baseline and the MoE router need these
    assert callable(eng.lookup_dyn) and callable(eng.lookup_vec)


@pytest.mark.parametrize("name", sorted(BULK_ENGINES))
def test_bulk_engine_matches_scalar_oracle_across_events(name):
    """Key-for-key device == scalar parity through a fleet-event stream —
    the protocol contract every registered engine must honour."""
    eng = make_bulk(name)
    router = BatchRouter(8, engine=name)
    oracle = SessionRouter(
        8, engine=eng.scalar_engine, chain_bits=32, resolve="table"
    )
    keys = RNG.integers(0, 2**64, size=(2048,), dtype=np.uint64)
    sample = keys[:256]
    for ev, arg in EVENT_STREAM:
        for r in (router, oracle):
            getattr(r, ev)(*(() if arg is None else (arg,)))
        out = router.route_keys_np(keys)
        expect = [oracle.domain.locate(int(k)) for k in sample]
        np.testing.assert_array_equal(out[: len(sample)], expect)
        # and the router's own scalar control plane agrees with its batch
        assert int(out[0]) == router.domain.locate(int(keys[0]))


@pytest.mark.parametrize("name", sorted(BULK_ENGINES))
def test_bulk_engine_empty_batch(name):
    router = BatchRouter(4, engine=name)
    assert router.route_keys_np(np.empty(0, dtype=np.uint64)).shape == (0,)
    assert router.route_batch([]).shape == (0,)
    if make_bulk(name).ingest is not None:
        assert np.asarray(
            router.route_ids(np.empty(0, dtype=np.uint64))
        ).shape == (0,)
