"""Consistent-hashing properties: the paper's §5 claims, empirically."""
import collections
import math
import random

import numpy as np
import pytest

from repro.core import (
    CONSTANT_TIME,
    FULLY_CONSISTENT,
    ENGINES,
    binomial_lookup32,
    binomial_lookup64,
    make,
)
from repro.core import analysis

random.seed(1234)
KEYS = [random.getrandbits(64) for _ in range(20000)]
KEYS32 = [k & 0xFFFFFFFF for k in KEYS]


# ---------------------------------------------------------------------------
# range + determinism (every engine)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ENGINES))
@pytest.mark.parametrize("n", [1, 2, 3, 7, 8, 11, 16, 17, 100])
def test_range_and_determinism(name, n):
    eng = make(name, n)
    eng2 = make(name, n)
    for k in KEYS[:2000]:
        b = eng.get_bucket(k)
        assert 0 <= b < n
        assert b == eng2.get_bucket(k)


# ---------------------------------------------------------------------------
# monotonicity: n -> n+1 moves keys only onto the new bucket
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", FULLY_CONSISTENT)
@pytest.mark.parametrize("n", [1, 2, 3, 4, 7, 8, 9, 15, 16, 17, 31, 32, 63, 64])
def test_monotonicity(name, n):
    eng = make(name, n)
    before = [eng.get_bucket(k) for k in KEYS[:5000]]
    new = eng.add_bucket()
    after = [eng.get_bucket(k) for k in KEYS[:5000]]
    moved = [(b, a) for b, a in zip(before, after) if b != a]
    assert all(a == new for _, a in moved), f"{name}: moves must target only bucket {new}"
    # movement fraction ~ 1/(n+1)
    frac = len(moved) / 5000
    assert frac < 2.5 / (n + 1) + 0.02, (name, n, frac)


@pytest.mark.parametrize("name", ["fliphash-recon", "powerch-recon", "jumpback-recon"])
@pytest.mark.parametrize("n", [9, 11, 17, 100])  # within a power-of-two block
def test_monotonicity_recons_within_block(name, n):
    """Reconstructions guarantee monotonicity only while E is unchanged
    (documented in DESIGN.md §6)."""
    eng = make(name, n)
    before = [eng.get_bucket(k) for k in KEYS[:3000]]
    new = eng.add_bucket()
    after = [eng.get_bucket(k) for k in KEYS[:3000]]
    moved = [(b, a) for b, a in zip(before, after) if b != a]
    assert all(a == new for _, a in moved), name


# ---------------------------------------------------------------------------
# minimal disruption: removing bucket n-1 moves only its keys
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", FULLY_CONSISTENT)
@pytest.mark.parametrize("n", [2, 3, 8, 9, 16, 17, 33])
def test_minimal_disruption(name, n):
    eng = make(name, n)
    before = {k: eng.get_bucket(k) for k in KEYS[:5000]}
    removed = eng.remove_bucket()
    for k in KEYS[:5000]:
        after = eng.get_bucket(k)
        if before[k] != removed:
            assert after == before[k], f"{name}: keys of surviving buckets must not move"
        else:
            assert after != removed


# ---------------------------------------------------------------------------
# balance: empirical counts close to uniform (paper §5.4)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", CONSTANT_TIME + ["binomial32"])
@pytest.mark.parametrize("n", [11, 16, 60, 100])
def test_balance(name, n):
    eng = make(name, n)
    cnt = collections.Counter(eng.get_bucket(k) for k in KEYS)
    mean = len(KEYS) / n
    rel_std = np.std([cnt.get(i, 0) for i in range(n)]) / mean
    # uniform multinomial gives rel_std ~ sqrt(n/k); allow generous recon slack
    bound = 4 * math.sqrt(n / len(KEYS)) + (0.30 if not make(name, n).exact else 0.06)
    assert rel_std < bound, (name, n, rel_std, bound)


# ---------------------------------------------------------------------------
# paper theory: Eq. (3) imbalance bound and Eq. (5)/(6) std-dev
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("omega", [2, 4, 6])
def test_eq3_imbalance_bound(omega):
    for n in [9, 11, 13, 15]:
        E, M = analysis.tree_bounds(n)
        keys = KEYS
        cnt = collections.Counter(binomial_lookup64(k, n, omega=omega) for k in keys)
        k_minor = np.mean([cnt.get(i, 0) for i in range(M)])
        k_low = np.mean([cnt.get(i, 0) for i in range(M, n)])
        rel_gap = (k_minor - k_low) / (len(keys) / n)
        predicted = analysis.relative_imbalance(n, omega)
        # empirical gap should match the closed form within sampling noise
        assert abs(rel_gap - predicted) < 0.08, (n, omega, rel_gap, predicted)
        assert predicted <= 2 ** -omega + 1e-12


def test_eq3_max_at_n_equals_M():
    for omega in (2, 4, 6, 8):
        vals = [analysis.relative_imbalance(n, omega) for n in range(17, 32)]
        assert all(v <= 2**-omega + 1e-12 for v in vals)
        assert vals == sorted(vals, reverse=True)  # monotonically decreasing in n


def test_eq6_sigma_max():
    q = 1000
    for omega in (2, 5):
        smax = analysis.sigma_max(q, omega)
        M = 64
        sig = [analysis.sigma(n, q * n, omega) for n in range(M, 2 * M)]
        assert max(sig) <= smax * 1.001
        n_star = analysis.sigma_argmax(M, omega)
        assert abs(max(range(M, 2 * M), key=lambda n: analysis.sigma(n, q * n, omega)) - n_star) <= 1
    assert abs(analysis.sigma_max(1.0, 5) - 0.045) < 2e-3  # paper: ~0.045q for ω=5


# ---------------------------------------------------------------------------
# u32 flavour matches u64 semantics (not bitwise — same guarantees)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 3, 8, 9, 17, 100])
def test_u32_properties(n):
    before = [binomial_lookup32(k, n) for k in KEYS32[:3000]]
    after = [binomial_lookup32(k, n + 1) for k in KEYS32[:3000]]
    assert all(0 <= b < n for b in before)
    moved = [(b, a) for b, a in zip(before, after) if b != a]
    assert all(a == n for _, a in moved)
