"""Property-based tests (hypothesis) for the system's core invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import binomial_lookup32, binomial_lookup64
from repro.core.binomial import _relocate_within_level_64
from repro.core.binomial_jax import binomial_lookup_vec, binomial_lookup_dyn
from repro.core.bits import highest_one_bit_index, next_pow2

keys64 = st.integers(min_value=0, max_value=(1 << 64) - 1)
keys32 = st.integers(min_value=0, max_value=(1 << 32) - 1)
sizes = st.integers(min_value=1, max_value=4096)


@given(keys64, sizes)
@settings(max_examples=300, deadline=None)
def test_lookup_in_range(key, n):
    assert 0 <= binomial_lookup64(key, n) < n


@given(keys64, st.integers(min_value=1, max_value=2000))
@settings(max_examples=200, deadline=None)
def test_monotone_single_key(key, n):
    b0 = binomial_lookup64(key, n)
    b1 = binomial_lookup64(key, n + 1)
    assert b1 == b0 or b1 == n  # moves only onto the new bucket


@given(keys64, st.integers(min_value=2, max_value=2000))
@settings(max_examples=200, deadline=None)
def test_minimal_disruption_single_key(key, n):
    b0 = binomial_lookup64(key, n)
    b1 = binomial_lookup64(key, n - 1)
    if b0 != n - 1:
        assert b1 == b0  # survivors stay put


@given(keys64, st.integers(min_value=2, max_value=1 << 40))
@settings(max_examples=200, deadline=None)
def test_relocation_preserves_level(h, b):
    """Alg. 2: the relocated bucket stays within b's tree level."""
    c = _relocate_within_level_64(b, h)
    assert highest_one_bit_index(c) == highest_one_bit_index(b)


@given(keys32, st.integers(min_value=1, max_value=512))
@settings(max_examples=100, deadline=None)
def test_vec_matches_scalar32(key, n):
    v = int(np.asarray(binomial_lookup_vec(np.array([key], np.uint32), n))[0])
    assert v == binomial_lookup32(key, n)


@given(st.integers(min_value=1, max_value=100000))
@settings(max_examples=200, deadline=None)
def test_next_pow2(n):
    E = next_pow2(n)
    assert E >= n and E & (E - 1) == 0
    if n > 1:
        assert E < 2 * n


@given(st.lists(keys32, min_size=1, max_size=64), st.integers(min_value=1, max_value=300))
@settings(max_examples=50, deadline=None)
def test_dyn_matches_static(keys, n):
    ks = np.array(keys, np.uint32)
    a = np.asarray(binomial_lookup_vec(ks, n))
    b = np.asarray(binomial_lookup_dyn(ks, np.uint32(n)))
    assert (a == b).all()
