"""End-to-end behaviour: train -> checkpoint -> crash -> resume -> serve,
with an elastic data fleet — the whole story on a tiny model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.data.pipeline import DataConfig, ShardedDataPipeline
from repro.models import model as M
from repro.serving.engine import Request, ServingTier
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import make_optimizer
from repro.training.train_step import TrainHparams, make_train_state, make_train_step


def test_train_checkpoint_resume_serve(tmp_path):
    cfg = reduced_config("stablelm-3b")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4, num_shards=32)
    hosts = [ShardedDataPipeline(dcfg, 2, h) for h in range(2)]

    def global_batch(step):
        parts = [h.batch(step) for h in hosts]
        return {
            "tokens": jnp.asarray(np.concatenate([p["tokens"] for p in parts])),
            "targets": jnp.asarray(np.concatenate([p["targets"] for p in parts])),
        }

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw", lr=1e-3, warmup=2, total=50)
    hp = TrainHparams()
    state = make_train_state(params, opt, hp)
    step_fn = jax.jit(make_train_step(cfg, opt, hp))
    mgr = CheckpointManager(str(tmp_path), n_nodes=3)

    losses = []
    for step in range(8):
        state, metrics = step_fn(state, global_batch(step))
        losses.append(float(metrics["loss"]))
        if step == 4:
            mgr.save(step, state)
    assert losses[-1] < losses[0]

    # -- crash; a new "process" resumes from step 4 and replays 5..7 --------
    latest = mgr.latest_step()
    assert latest == 4
    restored = mgr.restore(latest, jax.eval_shape(lambda: state))
    state_b = restored
    for step in range(5, 8):
        state_b, metrics_b = step_fn(state_b, global_batch(step))
    d = max(
        jax.tree.leaves(
            jax.tree.map(
                lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
                state["params"],
                state_b["params"],
            )
        )
    )
    assert d == 0.0, "resume must replay identically (deterministic pipeline)"

    # -- elastic data fleet: add a host; shards move minimally --------------
    plans = [h.rescale(3) for h in hosts]
    assert all(p.destinations() <= {2} for p in plans)

    # -- serve the trained weights over a routed replica tier ---------------
    tier = ServingTier(cfg, state_b["params"], n_replicas=2, max_len=32)
    reqs = [Request(f"u{i}", np.arange(4, dtype=np.int32) + i, n_new=3) for i in range(5)]
    out = tier.serve(reqs)
    assert len(out) == 5 and all(v.shape == (3,) for v in out.values())
