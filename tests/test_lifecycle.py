"""Lifecycle robustness layer: journal replay parity, failure detection,
coalescing, degradation modes (DESIGN.md §12).

The journal/replay properties run under hypothesis when available, with
seeded fallback grids so the invariants stay covered either way.
"""
import numpy as np
import pytest

from repro.placement.elastic import FailureDomain
from repro.serving.batch_router import BatchRouter
from repro.serving.lifecycle import (
    ALIVE,
    QUARANTINED,
    REMOVED,
    SUSPECT,
    FailureDetector,
    FleetDegradedError,
    FleetUnavailableError,
    HeartbeatConfig,
    JournalSnapshot,
    LifecycleConfig,
    LifecycleManager,
    ManualClock,
    MembershipEvent,
    MembershipJournal,
    apply_event,
    replay,
    restore,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is in the image
    HAVE_HYPOTHESIS = False


def make_domain(n: int) -> FailureDomain:
    """The flavour the batched datapath's scalar oracle uses."""
    return FailureDomain(
        n, engine="binomial32", chain_bits=32, resolve="table", allow_empty=True
    )


# -- journal basics -----------------------------------------------------------

def test_journal_epochs_are_dense_and_one_based():
    j = MembershipJournal(4)
    assert j.epoch == 0
    e1 = j.record("fail", 2)
    e2 = j.record("recover", 2)
    assert (e1.epoch, e2.epoch) == (1, 2)
    assert j.epoch == 2
    assert j.events() == (e1, e2)
    assert j.events(since=1) == (e2,)


def test_journal_rejects_unknown_kind_and_bad_since():
    j = MembershipJournal(2)
    with pytest.raises(ValueError, match="unknown event kind"):
        j.record("explode", 0)
    with pytest.raises(ValueError, match="since"):
        j.events(since=-1)
    with pytest.raises(ValueError, match="n_initial"):
        MembershipJournal(0)


def test_journal_jsonl_round_trip():
    j = MembershipJournal(6)
    j.record("fail", 1)
    j.record("scale_up", 6)
    j.record("recover", 1)
    j2 = MembershipJournal.from_jsonl(j.to_jsonl())
    assert j2.n_initial == 6
    assert j2.events() == j.events()


def test_journal_jsonl_detects_epoch_corruption():
    j = MembershipJournal(3)
    j.record("fail", 0)
    lines = j.to_jsonl().splitlines()
    tampered = "\n".join([lines[0], lines[1].replace('"epoch": 1', '"epoch": 7')])
    with pytest.raises(ValueError, match="journal corrupt"):
        MembershipJournal.from_jsonl(tampered)
    with pytest.raises(ValueError, match="empty journal"):
        MembershipJournal.from_jsonl("")


def test_snapshot_json_round_trip():
    d = make_domain(5)
    d.fail(2)
    snap = JournalSnapshot.capture(1, d)
    back = JournalSnapshot.from_json(snap.to_json())
    assert back == snap
    assert back.removed == (2,)
    assert back.n_alive == 4


def test_replay_checks_scale_determinism():
    d = make_domain(3)
    with pytest.raises(ValueError, match="replay divergence"):
        apply_event(d, MembershipEvent(epoch=1, kind="scale_up", slot=99))
    with pytest.raises(ValueError, match="replay divergence"):
        apply_event(d, MembershipEvent(epoch=1, kind="scale_down", slot=99))


def test_restore_requires_table_domain():
    d = make_domain(3)
    snap = JournalSnapshot.capture(0, d)

    def chain_factory(n):
        return FailureDomain(n, engine="binomial32", chain_bits=32)

    with pytest.raises(ValueError, match="resolve='table'"):
        restore(snap, chain_factory)


# -- replay parity: arbitrary event streams ----------------------------------

def _drive(domain, journal, decisions) -> None:
    """Interpret a decision stream as valid membership events, mirroring
    each into the journal (exactly what LifecycleManager does)."""
    cap = domain.total_count + 8
    for d in decisions:
        total, removed = domain.total_count, sorted(domain.removed)
        alive = [s for s in range(total) if s not in domain.removed]
        ops = []
        if alive:
            ops.append(("fail", alive[d % len(alive)]))
        if removed:
            ops.append(("recover", removed[d % len(removed)]))
        if total < cap:
            ops.append(("scale_up", None))
        if len(alive) > 1 or (len(alive) == 1 and (total - 1) not in domain.removed):
            ops.append(("scale_down", None))
        kind, slot = ops[d % len(ops)]
        if kind == "fail":
            domain.fail(slot)
        elif kind == "recover":
            domain.recover(slot)
        elif kind == "scale_up":
            slot = domain.scale_up()
        else:
            slot = domain.scale_down()
        journal.record(kind, slot)


def _assert_same_state(a, b) -> None:
    assert a.total_count == b.total_count
    assert a.removed == b.removed
    ra, rb = a.replacement_table, b.replacement_table
    assert ra.slots == rb.slots
    assert ra.pos == rb.pos
    assert ra.n_alive == rb.n_alive


def _check_replay_parity(n_initial, decisions, crash_at):
    live = make_domain(n_initial)
    journal = MembershipJournal(n_initial)
    snapshots = {}
    for i, d in enumerate(decisions):
        _drive(live, journal, [d])
        if i == crash_at:
            snapshots[journal.epoch] = JournalSnapshot.capture(journal.epoch, live)
    # genesis replay == live
    _assert_same_state(replay(journal, make_domain), live)
    # JSONL crash: text is all that survives
    revived = MembershipJournal.from_jsonl(journal.to_jsonl())
    _assert_same_state(replay(revived, make_domain), live)
    # crash at an arbitrary event index: snapshot + tail == live
    for epoch, snap in snapshots.items():
        rebuilt = restore(snap, make_domain, journal.events(since=epoch))
        _assert_same_state(rebuilt, live)
    # prefix replay parity: upto the snapshot epoch reproduces the snapshot
    for epoch, snap in snapshots.items():
        pre = replay(journal, make_domain, upto=epoch)
        assert JournalSnapshot.capture(epoch, pre) == snap


SEEDED_STREAMS = [
    (1, [0]),
    (4, [0, 1, 2, 3, 0, 1]),
    (6, list(np.random.default_rng(7).integers(0, 1 << 16, 40))),
    (3, list(np.random.default_rng(8).integers(0, 1 << 16, 60))),
    (12, list(np.random.default_rng(9).integers(0, 1 << 16, 80))),
]


@pytest.mark.parametrize("n_initial,decisions", SEEDED_STREAMS)
def test_replay_parity_seeded(n_initial, decisions):
    crash_at = len(decisions) // 2
    _check_replay_parity(n_initial, [int(d) for d in decisions], crash_at)


if HAVE_HYPOTHESIS:

    @given(
        st.integers(min_value=1, max_value=12),
        st.lists(st.integers(min_value=0, max_value=(1 << 16) - 1), max_size=40),
        st.integers(min_value=0, max_value=39),
    )
    @settings(max_examples=60, deadline=None)
    def test_replay_parity_property(n_initial, decisions, crash_at):
        _check_replay_parity(n_initial, decisions, min(crash_at, max(len(decisions) - 1, 0)))


# -- failure detector ---------------------------------------------------------

def _beat_all(det, slots, skip=()):
    for s in slots:
        if s not in skip:
            det.heartbeat(s)


def test_detector_quiet_fleet_emits_nothing():
    clk = ManualClock()
    det = FailureDetector(range(4), clock=clk)
    for _ in range(10):
        clk.advance(1.0)
        _beat_all(det, range(4))
        assert det.poll() == []
    assert all(det.state_of(s) == ALIVE for s in range(4))


def test_detector_suspect_hysteresis_no_event():
    clk = ManualClock()
    det = FailureDetector(range(2), clock=clk)
    clk.advance(4.0)  # > suspect_after, < fail_after
    assert det.poll() == []
    assert det.state_of(0) == SUSPECT
    det.heartbeat(0)
    assert det.state_of(0) == ALIVE  # recovered silently
    det.heartbeat(1)
    assert det.poll() == []


def test_detector_fail_emitted_once_then_recover_after_stable_window():
    clk = ManualClock()
    cfg = HeartbeatConfig()
    det = FailureDetector(range(3), cfg, clk)
    clk.advance(cfg.fail_after + 0.5)
    _beat_all(det, range(3), skip=(1,))
    assert det.poll() == [("fail", 1)]
    assert det.state_of(1) == REMOVED
    # still silent: no duplicate event
    clk.advance(1.0)
    _beat_all(det, range(3), skip=(1,))
    assert det.poll() == []
    # beats resume: quarantined, readmitted only after the stable window
    det.heartbeat(1)
    assert det.state_of(1) == QUARANTINED
    t, events = 0.0, []
    while t < cfg.readmit_after + 1.0:
        clk.advance(1.0)
        t += 1.0
        _beat_all(det, range(3))
        events += det.poll()
    assert events == [("recover", 1)]
    assert det.state_of(1) == ALIVE


def test_detector_quarantine_window_restarts_on_gap():
    clk = ManualClock()
    cfg = HeartbeatConfig()
    det = FailureDetector([0], cfg, clk)
    clk.advance(cfg.fail_after + 1)
    assert det.poll() == [("fail", 0)]
    det.heartbeat(0)  # quarantined
    clk.advance(cfg.readmit_after - 1)
    det.heartbeat(0)  # gap > suspect_after: window restarts
    assert det.poll() == []  # NOT readmitted despite wall time elapsed
    assert det.state_of(0) == QUARANTINED
    # now beat steadily through a full window
    events = []
    for _ in range(int(cfg.readmit_after) + 1):
        clk.advance(1.0)
        det.heartbeat(0)
        events += det.poll()
    assert events == [("recover", 0)]


def test_detector_quarantine_silence_returns_to_removed_without_event():
    clk = ManualClock()
    cfg = HeartbeatConfig()
    det = FailureDetector([0], cfg, clk)
    clk.advance(cfg.fail_after + 1)
    assert det.poll() == [("fail", 0)]
    det.heartbeat(0)
    clk.advance(cfg.suspect_after + 1)  # silent during quarantine
    assert det.poll() == []  # no event: downstream already thinks it failed
    assert det.state_of(0) == REMOVED


def test_detector_flap_backoff_doubles_and_caps():
    clk = ManualClock()
    cfg = HeartbeatConfig(
        readmit_after=4.0, flap_window=1000.0, flap_backoff=2.0,
        max_readmit_after=10.0,
    )
    det = FailureDetector([0], cfg, clk)

    def outage_and_recover():
        """Silence past fail_after, then beat steadily until readmission;
        returns (fail->recover latency, events seen)."""
        clk.advance(cfg.fail_after + 0.5)
        evs = det.poll()
        assert evs == [("fail", 0)]
        det.heartbeat(0)
        t0 = clk.now()
        for _ in range(100):
            clk.advance(1.0)
            det.heartbeat(0)
            if det.poll() == [("recover", 0)]:
                return clk.now() - t0
        raise AssertionError("never readmitted")

    first = outage_and_recover()
    second = outage_and_recover()  # re-failed within flap_window: backoff x2
    third = outage_and_recover()   # x4 = 16 -> capped at 10
    assert first < second <= third
    assert second >= 2 * cfg.readmit_after - 1.0
    assert third <= cfg.max_readmit_after + 1.5


def test_detector_register_forget_and_mark_removed():
    clk = ManualClock()
    det = FailureDetector([0, 1], clock=clk)
    det.register(2)
    assert det.slots == (0, 1, 2)
    det.forget(1)
    det.forget(1)  # idempotent
    assert det.slots == (0, 2)
    det.mark_removed(2)
    assert det.state_of(2) == REMOVED
    det.heartbeat(2)
    assert det.state_of(2) == QUARANTINED  # must re-earn admission


def test_heartbeat_config_validation():
    with pytest.raises(ValueError):
        HeartbeatConfig(heartbeat_interval=0)
    with pytest.raises(ValueError):
        HeartbeatConfig(suspect_after=1.0, heartbeat_interval=2.0)
    with pytest.raises(ValueError):
        HeartbeatConfig(fail_after=1.0)
    with pytest.raises(ValueError):
        HeartbeatConfig(readmit_after=0)
    with pytest.raises(ValueError):
        ManualClock().advance(-1)


def _flap_invariants(decisions):
    """Property: whatever the beat pattern, per-slot events strictly
    alternate fail/recover starting with fail."""
    clk = ManualClock()
    det = FailureDetector(range(3), clock=clk)
    last_kind = {s: "recover" for s in range(3)}  # genesis counts as admitted
    for d in decisions:
        clk.advance(0.5 + (d % 8) * 0.5)
        for s in range(3):
            if (d >> (4 + s)) & 1:
                det.heartbeat(s)
        for kind, slot in det.poll():
            assert kind != last_kind[slot], (
                f"slot {slot} emitted consecutive {kind!r} events"
            )
            last_kind[slot] = kind


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_detector_events_alternate_seeded(seed):
    rng = np.random.default_rng(seed)
    _flap_invariants([int(d) for d in rng.integers(0, 1 << 8, 300)])


if HAVE_HYPOTHESIS:

    @given(st.lists(st.integers(min_value=0, max_value=(1 << 8) - 1), max_size=200))
    @settings(max_examples=60, deadline=None)
    def test_detector_events_alternate_property(decisions):
        _flap_invariants(decisions)


# -- lifecycle manager --------------------------------------------------------

def test_manager_rejects_late_attach():
    r = BatchRouter(4, engine="binomial")
    r.fail(1)
    with pytest.raises(ValueError, match="before mutating the fleet"):
        LifecycleManager(r)


def test_lifecycle_config_validation():
    with pytest.raises(ValueError, match="min_alive_floor"):
        LifecycleConfig(min_alive_floor=0)


@pytest.mark.parametrize("engine", ["binomial", "jump"])
def test_manager_coalesces_storm_to_one_upload_bit_exact(engine):
    r = BatchRouter(8, engine=engine)
    mgr = LifecycleManager(r)
    uploads = []
    orig = r._device_put
    r._device_put = lambda tree: (uploads.append(1), orig(tree))[1]
    storm = [("fail", 1), ("fail", 2), ("recover", 1), ("fail", 5), ("fail", 3)]
    mgr.apply(storm)
    assert len(uploads) == 1  # N events, ONE device upload
    assert mgr.epoch == r.routing_epoch == len(storm)
    # final routing is bit-exact vs per-event application
    twin = BatchRouter(8, engine=engine)
    for kind, slot in storm:
        getattr(twin, kind)(slot)
    keys = np.random.default_rng(3).integers(0, 1 << 32, 2048, dtype=np.uint32)
    np.testing.assert_array_equal(r.route_keys_np(keys), twin.route_keys_np(keys))
    mgr.verify_replay()


def test_manager_apply_is_atomic_per_burst_and_journaled():
    r = BatchRouter(6, engine="binomial")
    mgr = LifecycleManager(r)
    recorded = mgr.apply([("fail", 0), ("fail", 4), ("recover", 0)])
    assert [(e.kind, e.slot) for e in recorded] == [
        ("fail", 0), ("fail", 4), ("recover", 0),
    ]
    assert [e.epoch for e in recorded] == [1, 2, 3]
    assert mgr.apply([]) == []
    with pytest.raises(ValueError, match="unknown transition kind"):
        mgr.apply([("teleport", 1)])


def test_manager_modes_and_typed_errors():
    r = BatchRouter(4, engine="binomial")
    mgr = LifecycleManager(r, LifecycleConfig(min_alive_floor=2))
    keys = np.arange(64, dtype=np.uint32)
    assert mgr.mode == "normal"
    batch = mgr.route_keys_np(keys)
    assert batch.mode == "normal" and batch.epoch == 0
    mgr.fail(0)
    mgr.fail(1)
    assert mgr.mode == "normal"  # 2 alive == floor
    mgr.fail(2)
    assert mgr.mode == "degraded"
    batch = mgr.route_keys_np(keys)
    assert batch.mode == "degraded"
    assert set(np.asarray(batch.replicas).tolist()) == {3}
    mgr.fail(3)  # tombstones the last alive replica (allow_empty)
    assert mgr.mode == "unavailable" and mgr.n_alive == 0
    with pytest.raises(FleetUnavailableError) as exc:
        mgr.route_keys_np(keys)
    assert exc.value.epoch == mgr.epoch
    mgr.recover(3)
    assert mgr.mode == "degraded"
    assert np.asarray(mgr.route_keys_np(keys).replicas).tolist() == [3] * 64


def test_manager_strict_floor_raises_degraded():
    r = BatchRouter(4, engine="binomial")
    mgr = LifecycleManager(r, LifecycleConfig(min_alive_floor=3, strict_floor=True))
    mgr.fail(1)
    mgr.fail(2)
    with pytest.raises(FleetDegradedError) as exc:
        mgr.route_keys_np(np.arange(8, dtype=np.uint32))
    assert exc.value.n_alive == 2
    assert exc.value.floor == 3
    assert exc.value.epoch == 2


def test_manager_scale_events_journal_and_detector():
    r = BatchRouter(4, engine="binomial")
    mgr = LifecycleManager(r)
    new = mgr.scale_up()
    assert new == 4
    assert mgr.detector.state_of(4) == ALIVE
    gone = mgr.scale_down()
    assert gone == 4
    assert 4 not in mgr.detector.slots
    mgr.fail(3)  # LIFO retirement: slot space shrinks, detector follows
    assert r.domain.total_count == 3
    assert mgr.detector.slots == (0, 1, 2)
    mgr.verify_replay()
    assert [e.kind for e in mgr.journal.events()] == [
        "scale_up", "scale_down", "fail",
    ]


def test_manager_tick_applies_detector_expiries_coalesced():
    clk = ManualClock()
    r = BatchRouter(6, engine="binomial")
    mgr = LifecycleManager(r, clock=clk)
    cfg = mgr.config.heartbeat
    uploads = []
    orig = r._device_put
    r._device_put = lambda tree: (uploads.append(1), orig(tree))[1]
    # three replicas go silent together -> ONE coalesced update
    clk.advance(cfg.fail_after + 1)
    for s in (0, 2, 5):
        mgr.heartbeat(s)
    events = mgr.tick()
    assert [(e.kind, e.slot) for e in events] == [
        ("fail", 1), ("fail", 3), ("fail", 4),
    ]
    assert len(uploads) == 1
    assert mgr.n_alive == 3
    assert mgr.tick() == []  # no duplicates
    mgr.verify_replay()


def test_manager_route_surfaces_epoch_and_modes():
    r = BatchRouter(5, engine="jump")
    mgr = LifecycleManager(r)
    ids = np.arange(100, dtype=np.uint64)
    b1 = mgr.route_batch([f"sess-{i}" for i in range(32)])
    assert b1.epoch == 0 and b1.mode == "normal"
    mgr.fail(2)
    b2 = mgr.route_keys(np.arange(64, dtype=np.uint32))
    assert b2.epoch == 1
    assert 2 not in set(np.asarray(b2.replicas).tolist())
    b3 = mgr.route_keys_np(ids.astype(np.uint32))
    assert b3.epoch == 1 and b3.mode == "normal"


def test_manager_replay_parity_after_random_churn():
    rng = np.random.default_rng(42)
    r = BatchRouter(8, engine="binomial")
    mgr = LifecycleManager(r)
    for _ in range(60):
        alive = [s for s in range(r.domain.total_count) if s not in r.domain.removed]
        tomb = sorted(r.domain.removed)
        roll = rng.random()
        if roll < 0.45 and alive:
            mgr.fail(int(rng.choice(alive)))
        elif roll < 0.8 and tomb:
            mgr.recover(int(rng.choice(tomb)))
        elif r.domain.total_count < r.spec.capacity:
            mgr.scale_up()
    mgr.verify_replay()
    mgr.verify_replay(mgr.snapshot())
    # crash: only the JSONL text survives
    revived = MembershipJournal.from_jsonl(mgr.journal.to_jsonl())
    rebuilt = replay(revived, mgr._domain_factory)
    assert rebuilt.removed == r.domain.removed
    assert rebuilt.total_count == r.domain.total_count
    assert rebuilt.replacement_table.slots == r.domain.replacement_table.slots


def test_errors_carry_context():
    e = FleetUnavailableError(epoch=7)
    assert e.epoch == 7
    assert "epoch 7" in str(e)
    d = FleetDegradedError(1, 3, epoch=2)
    assert (d.n_alive, d.floor, d.epoch) == (1, 3, 2)
    assert isinstance(d, RuntimeError)
