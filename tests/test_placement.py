"""Placement planners: assignments, elastic migration, failure domains."""
import collections

import pytest

from repro.placement.assignment import Assignment
from repro.placement.elastic import FailureDomain, plan_expert_migration, plan_shard_reassignment


def test_assignment_balance():
    a = Assignment(list(range(4096)), 16)
    loads = a.load()
    assert min(loads) > 0.6 * (4096 / 16)
    assert max(loads) < 1.4 * (4096 / 16)


@pytest.mark.parametrize("old,new", [(16, 17), (16, 20), (17, 16), (16, 8)])
def test_assignment_resize_minimal(old, new):
    a = Assignment(list(range(2048)), old)
    plan = a.resize(new)
    if new > old:
        assert plan.destinations() <= set(range(old, new)), "moves only TO new nodes"
        assert plan.moved_fraction < 1.5 * (new - old) / new + 0.05
    else:
        assert plan.sources() <= set(range(new, old)), "moves only FROM removed nodes"


def test_expert_migration():
    m = plan_expert_migration(256, 16, 18)
    assert m.plan.destinations() <= {16, 17}
    # ~ E/new_devices experts land on each new device
    per_new = collections.Counter(mv.dst for mv in m.plan.moves)
    for d in (16, 17):
        assert 2 <= per_new[d] <= 40


def test_shard_reassignment_shrink():
    plan = plan_shard_reassignment(1024, 8, 6)
    assert plan.sources() <= {6, 7}
    assert plan.moved_fraction < 0.35


def test_failure_domain_minimal_disruption():
    fd = FailureDomain(10)
    keys = list(range(5000))
    before = {k: fd.locate(k) for k in keys}
    fd.fail(3)
    after = {k: fd.locate(k) for k in keys}
    for k in keys:
        if before[k] != 3:
            assert after[k] == before[k], "only keys of the failed node move"
        else:
            assert after[k] != 3
    # recovery: exactly the displaced keys return
    fd.recover(3)
    assert all(fd.locate(k) == before[k] for k in keys)


def test_failure_domain_balance_under_failures():
    fd = FailureDomain(12)
    fd.fail(0)
    fd.fail(5)
    counts = collections.Counter(fd.locate(k) for k in range(12000))
    assert 0 not in counts and 5 not in counts
    loads = [counts[i] for i in range(12) if i not in (0, 5)]
    assert max(loads) < 1.35 * (12000 / 10)
    assert min(loads) > 0.65 * (12000 / 10)


def test_failure_domain_scale_up_down():
    fd = FailureDomain(4)
    keys = list(range(2000))
    before = {k: fd.locate(k) for k in keys}
    new = fd.scale_up()
    moved = {k for k in keys if fd.locate(k) != before[k]}
    assert all(fd.locate(k) == new for k in moved)
    fd.scale_down()
    assert all(fd.locate(k) == before[k] for k in keys)
