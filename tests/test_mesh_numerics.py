"""Numerical equivalence of the SHARDED paths vs single-device reference.

The shard_map / GSPMD code paths never run in plain CPU unit tests (no
mesh), so this test spawns a subprocess with 8 fake host devices, builds a
(2, 4) mesh, and checks that loss/gradients of meshed models match the
unmeshed reference — guarding exactly the class of bug where a sharded
dispatch compiles happily but computes the wrong thing.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import reduced_config
from repro.models import model as M
from repro.sharding import rules

results = {}
for arch, elayout in [("qwen3-moe-235b-a22b", "ep"), ("qwen3-moe-235b-a22b", "tp"),
                      ("deepseek-v3-671b", "ep"), ("stablelm-3b", "ep"),
                      ("mamba2-1.3b", "ep")]:
    cfg = reduced_config(arch)
    if cfg.moe is not None:
        # token counts large enough to exercise the shard_map sort path for
        # "ep", small enough for the dense path check under decode later
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    rng = np.random.default_rng(0)
    B, S = 4, 16
    batch = {"targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))}
    if cfg.input_mode == "tokens":
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    # reference: no mesh
    ref_loss, _ = M.loss_fn(params, batch, cfg)
    ref_grad = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with rules.mesh_context(mesh, fsdp=True, expert_layout=elayout):
        pspecs = rules.params_pspecs(params)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        params_m = jax.device_put(params, psh)
        bsh = jax.tree.map(lambda s: NamedSharding(mesh, s), rules.batch_pspecs(batch),
                           is_leaf=lambda x: isinstance(x, P))
        batch_m = jax.device_put(batch, bsh)
        loss_m, _ = jax.jit(lambda p, b: M.loss_fn(p, b, cfg))(params_m, batch_m)
        grad_m = jax.jit(jax.grad(lambda p, b: M.loss_fn(p, b, cfg)[0]))(params_m, batch_m)

    dl = abs(float(ref_loss) - float(loss_m))
    gerr = max(
        jax.tree.leaves(
            jax.tree.map(
                lambda a, b: float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-6)),
                ref_grad, jax.device_get(grad_m),
            )
        )
    )
    results[f"{arch}/{elayout}"] = {"dloss": dl, "grad_rel_err": gerr}
print("RESULTS " + json.dumps(results))
"""


@pytest.mark.slow
def test_sharded_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True, text=True, timeout=1200
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS ")][-1]
    results = json.loads(line[len("RESULTS "):])
    for key, r in results.items():
        assert r["dloss"] < 2e-3, (key, r)
        assert r["grad_rel_err"] < 0.05, (key, r)
