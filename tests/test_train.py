"""Training substrate: optimizers actually learn; grad-accum is consistent;
compression error feedback is bounded; clipping works."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import model as M
from repro.training import compression as C
from repro.training.optimizer import make_optimizer
from repro.training.train_step import TrainHparams, make_train_state, make_train_step


def _tiny_batch(cfg, B=4, S=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]), "targets": jnp.asarray(toks[:, 1:])}


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_loss_decreases(opt_name):
    cfg = reduced_config("stablelm-3b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(opt_name, lr=1e-3, warmup=5, total=100)
    hp = TrainHparams()
    state = make_train_state(params, opt, hp)
    step = jax.jit(make_train_step(cfg, opt, hp))
    batch = _tiny_batch(cfg)  # overfit one small batch
    losses = []
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
    assert np.isfinite(losses).all()


def test_grad_accum_matches_full_batch():
    cfg = reduced_config("qwen2.5-14b")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    opt = make_optimizer("adamw", lr=1e-3)
    batch = _tiny_batch(cfg, B=8)
    s1 = make_train_state(params, opt, TrainHparams())
    s2 = make_train_state(params, opt, TrainHparams(grad_accum=4))
    s1, m1 = jax.jit(make_train_step(cfg, opt, TrainHparams()))(s1, batch)
    s2, m2 = jax.jit(make_train_step(cfg, opt, TrainHparams(grad_accum=4)))(s2, batch)
    # same data -> same loss and (numerically) same updated params
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), s1["params"], s2["params"])
    assert max(jax.tree.leaves(d)) < 5e-5


@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compression_error_feedback(kind):
    """Error feedback conserves signal: transmitted + residual == sum of the
    true gradients, EXACTLY — nothing is ever silently lost."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    err = {"w": jnp.zeros((64, 64), jnp.float32)}
    total_compressed = jnp.zeros_like(g_true)
    steps = 20
    for i in range(steps):
        comp, err = C.apply_compression({"w": g_true}, err, kind)
        total_compressed = total_compressed + comp["w"]
    recon = total_compressed + err["w"]
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g_true) * steps, rtol=1e-4, atol=1e-3)
    # and the transmitted average converges toward the true gradient
    rel = float(jnp.linalg.norm(total_compressed / steps - g_true) / jnp.linalg.norm(g_true))
    assert rel < (0.05 if kind == "int8" else 0.45), rel


def test_compression_trains():
    cfg = reduced_config("stablelm-3b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw", lr=1e-3, warmup=5, total=100)
    hp = TrainHparams(compression="int8")
    state = make_train_state(params, opt, hp)
    step = jax.jit(make_train_step(cfg, opt, hp))
    batch = _tiny_batch(cfg)
    losses = []
    for _ in range(25):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.4, losses[::6]


def test_clip_norm_applied():
    cfg = reduced_config("musicgen-medium")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw", lr=1e-3)
    hp = TrainHparams(clip_norm=1e-9)  # absurdly small: updates ~ 0
    state = make_train_state(params, opt, hp)
    step = jax.jit(make_train_step(cfg, opt, hp))
    rng = np.random.default_rng(0)
    batch = {
        "embeds": jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32)) * 0.02,
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)),
    }
    p0 = state["params"]
    state, metrics = step(state, batch)
    assert float(metrics["grad_norm"]) > 0
    # movement dominated by weight decay only (tiny)
    d = max(jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p0, state["params"])))
    assert d < 1e-4
