"""Seeded chaos-scenario library for fleet-lifecycle robustness.

Shared by the invariant test suite (``tests/test_chaos_invariants.py``) and
the chaos benchmark (``benchmarks/bench_chaos.py``).  A **scenario** is a
seeded, fully deterministic storyline of fleet trouble driven against a
``LifecycleManager``-wrapped ``BatchRouter``:

* ``storm``          — correlated mass failures + mass recovery, coalesced;
* ``flap``           — a replica blinking through the heartbeat detector;
* ``cascade``        — one-at-a-time failures down to (and through) the
                       last alive replica, then staged recovery;
* ``crash_recover``  — random membership churn with mid-stream snapshots;
                       the "process" then crashes and is rebuilt from the
                       JSONL journal (genesis AND snapshot+tail);
* ``mixed``          — everything above interleaved, plus scale up/down;
* ``replica_loss``   — placement tier: kill up to R-1 holders of the SAME
                       key between repair quiescence points — the key must
                       stay readable (degraded) from the survivors and
                       repair must restore full distinct replication;
* ``repair_race``    — placement tier: a second failure lands DURING an
                       in-flight bounded-bandwidth migration/repair.

After (almost) every step the runner routes a fixed probe-key batch through
the real fused device datapath and checks the paper-level invariants:

1. **alive-only** — no probe ever routes to a removed replica;
2. **minimal disruption** — after a single fail/recover of slot ``b``, a
   key that sat undiverted on its base bucket (and whose base bucket is not
   ``b``) must not move (the paper's minimal-disruption theorem, extended
   to the replacement-table divert: only diverted keys and ``b``'s keys may
   move); after scale-up of an un-tombstoned fleet, movers land ONLY on the
   new replica (monotonicity);
3. **typed degradation** — routing raises ``FleetUnavailableError`` exactly
   when ``n_alive == 0``;
4. **epoch stamping** — every routed batch carries the journal epoch;
5. **replay parity** — at scripted crash points and at scenario end,
   ``replay(journal) == live state`` bit-exactly (scalar control plane AND
   packed device operands), via ``LifecycleManager.verify_replay``.

The two placement storylines drive a ``StorePlacement`` + ``PlacementRepairer``
instead of raw routing and check the DURABILITY invariants on top:

6. **no key ever has zero reachable replicas while n_alive >= 1** (every
   quiescence interval loses at most ``min(r, n_alive) - 1`` replica
   holders, the construction's tolerance);
7. **repair convergence** — once the repairer quiesces, every registered
   key holds exactly ``min(r, n_alive)`` DISTINCT alive replicas;
8. **bounded bandwidth** — no repair batch ever exceeds the per-tick
   budget;
9. **typed degraded reads** — ``n_alive < r`` places in mode
   ``"degraded"``, reads come only from surviving holders, and
   ``n_alive == 0`` stays the typed ``FleetUnavailableError``;
10. **placement replay parity** — the R-way placement recomputed from the
    replayed journal matches the live placement bit-exactly.

Violations are collected (not raised) so the benchmark can count them; the
pytest suite asserts the list is empty.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.observability import (
    LoadConfig,
    LoadMonitor,
    MetricsRegistry,
    SpanTrace,
    expected_peak_over_mean,
)
from repro.placement.elastic import FailureDomain
from repro.placement.store import StorePlacement
from repro.serving.batch_router import BatchRouter
from repro.serving.lifecycle import (
    FleetUnavailableError,
    HeartbeatConfig,
    LifecycleConfig,
    LifecycleManager,
    ManualClock,
    MembershipJournal,
    PlacementRepairer,
    replay,
)
from repro.serving.lifecycle.detector import REMOVED, SUSPECT
from repro.serving.lifecycle.errors import (
    MODE_DEGRADED,
    MODE_NORMAL,
    AdmissionRejectedError,
)
from repro.serving.streaming import (
    BreakerConfig,
    LifecycleDispatch,
    StreamConfig,
    StreamingFrontEnd,
    StreamRequest,
    VirtualClockUs,
)

#: placement-tier storylines: driven by a _PlacementRunner (StorePlacement
#: + PlacementRepairer) instead of a raw-routing _Runner
PLACEMENT_KINDS = ("replica_loss", "repair_race")

#: streaming-tier storylines: driven by a _StreamingRunner (StreamingFrontEnd
#: over lifecycle + placement, virtual-µs clock) — see module docstring
STREAMING_KINDS = ("overload", "latency_spike")

#: scenario storylines (see module docstring)
KINDS = (
    ("storm", "flap", "cascade", "crash_recover", "mixed")
    + PLACEMENT_KINDS
    + STREAMING_KINDS
)

#: fixed probe keys routed after every step — small enough to keep 1000s of
#: scenarios fast, large enough that every replica of a <=32-slot fleet owns
#: many keys
N_PROBE = 256
PROBE_KEYS = np.random.default_rng(0x5EED).integers(
    0, 1 << 32, size=N_PROBE, dtype=np.uint32
)

#: (engine, n_total) -> base-engine bucket per probe key (no tombstones):
#: the "undiverted home" used by the minimal-disruption check
_BASE_CACHE: dict[tuple[str, int], np.ndarray] = {}


def base_buckets(scalar_engine: str, n_total: int) -> np.ndarray:
    out = _BASE_CACHE.get((scalar_engine, n_total))
    if out is None:
        dom = FailureDomain(
            n_total, engine=scalar_engine, chain_bits=32, resolve="table"
        )
        out = np.fromiter(
            (dom.locate(int(k)) for k in PROBE_KEYS), dtype=np.int64, count=N_PROBE
        )
        _BASE_CACHE[(scalar_engine, n_total)] = out
    return out


@dataclasses.dataclass
class ScenarioResult:
    kind: str
    engine: str
    seed: int
    events: int = 0
    route_attempts: int = 0
    route_unavailable: int = 0
    replay_checks: int = 0
    #: repair copies executed (placement storylines only)
    repair_copies: int = 0
    #: ManualClock seconds from each detector "fail" emission to the
    #: matching "recover" emission (detector-driven scenarios only)
    recovery_latencies: list = dataclasses.field(default_factory=list)
    violations: list = dataclasses.field(default_factory=list)

    @property
    def availability(self) -> float:
        if self.route_attempts == 0:
            return 1.0
        return 1.0 - self.route_unavailable / self.route_attempts


class _Runner:
    """Drives one manager through a scenario, checking invariants per step."""

    def __init__(self, kind: str, engine: str, seed: int, n_initial: int):
        self.rng = np.random.default_rng(seed)
        self.clock = ManualClock()
        self.router = BatchRouter(n_initial, engine=engine)
        self.mgr = LifecycleManager(
            self.router, LifecycleConfig(min_alive_floor=1), clock=self.clock
        )
        self.scalar_engine = self.router._bulk.scalar_engine
        self.res = ScenarioResult(kind=kind, engine=engine, seed=seed)
        self.prev_routes: np.ndarray | None = None
        self.probe()

    # -- state helpers ------------------------------------------------------
    @property
    def total(self) -> int:
        return self.router.domain.total_count

    @property
    def removed(self) -> frozenset:
        return self.router.domain.removed

    @property
    def alive_slots(self) -> list:
        rm = self.removed
        return [s for s in range(self.total) if s not in rm]

    def _flag(self, msg: str) -> None:
        self.res.violations.append(
            f"[{self.res.kind}/{self.res.engine}/seed={self.res.seed}] {msg}"
        )

    # -- probing + invariants ------------------------------------------------
    def probe(self, event=None) -> None:
        """Route the probe batch; check invariants vs the previous probe.

        ``event`` is ``(kind, slot)`` when exactly ONE membership event
        happened since the last probe (enables the minimal-disruption
        check); ``None`` means zero-or-many events (alive-only still holds).
        """
        self.res.route_attempts += 1
        n_alive = self.router.domain.alive_count
        try:
            batch = self.mgr.route_keys_np(PROBE_KEYS)
        except FleetUnavailableError:
            self.res.route_unavailable += 1
            if n_alive != 0:
                self._flag(f"FleetUnavailableError with n_alive={n_alive}")
            self.prev_routes = None
            return
        if n_alive == 0:
            self._flag("route succeeded with n_alive == 0")
            return
        routes = np.asarray(batch.replicas, dtype=np.int64)
        if batch.epoch != self.mgr.epoch:
            self._flag(f"batch epoch {batch.epoch} != journal epoch {self.mgr.epoch}")
        dead = set(routes.tolist()) - set(self.alive_slots)
        if dead:
            self._flag(f"routed to removed replica(s) {sorted(dead)}")
        if event is not None and self.prev_routes is not None:
            self._check_minimal_disruption(event, self.prev_routes, routes)
        self.prev_routes = routes

    def _check_minimal_disruption(self, event, prev, now) -> None:
        kind, slot = event
        moved = prev != now
        if kind in ("fail", "recover"):
            base = base_buckets(self.scalar_engine, self.total)
            # keys sitting undiverted on their (still-alive) base bucket
            # are untouchable by a single fail/recover of another slot
            pinned = (prev == base) & (base != slot)
            bad = moved & pinned
            if bad.any():
                i = int(np.flatnonzero(bad)[0])
                self._flag(
                    f"{kind}({slot}) moved pinned key {int(PROBE_KEYS[i])}: "
                    f"{int(prev[i])} -> {int(now[i])} (base {int(base[i])})"
                )
        elif kind == "scale_up" and not self.removed:
            # un-tombstoned fleet: movers land only on the new slot
            bad = moved & (now != slot)
            if bad.any():
                i = int(np.flatnonzero(bad)[0])
                self._flag(
                    f"scale_up({slot}) moved key {int(PROBE_KEYS[i])} to "
                    f"{int(now[i])} instead of the new replica"
                )

    def check_replay(self) -> None:
        self.res.replay_checks += 1
        try:
            self.mgr.verify_replay()
            self.mgr.verify_replay(self.mgr.snapshot())
        except AssertionError as e:
            self._flag(f"replay parity: {e}")

    def crash_and_rebuild(self, snapshot, tail_from: int) -> None:
        """Simulate a crash: rebuild from serialized journal text only."""
        self.res.replay_checks += 1
        try:
            text = self.mgr.journal.to_jsonl()
            journal = MembershipJournal.from_jsonl(text)
            if journal.epoch != self.mgr.epoch:
                raise AssertionError(
                    f"JSONL round-trip lost epochs ({journal.epoch} != "
                    f"{self.mgr.epoch})"
                )
            rebuilt = replay(journal, self.mgr._domain_factory)
            live = self.router.domain
            if (
                rebuilt.total_count != live.total_count
                or rebuilt.removed != live.removed
                or rebuilt.replacement_table.slots
                != live.replacement_table.slots
            ):
                raise AssertionError("genesis replay of JSONL != live state")
            self.mgr.verify_replay(snapshot)  # snapshot + tail path
        except (AssertionError, ValueError) as e:
            self._flag(f"crash recovery (tail from epoch {tail_from}): {e}")

    # -- event vocabulary ----------------------------------------------------
    def fail_one(self, slot: int) -> None:
        before = self.total
        self.mgr.fail(slot)
        self.res.events += 1
        # failing the top slot is a LIFO retirement that shrinks n_total —
        # base buckets are recomputed under the new size, so the
        # single-event pinned-key check does not apply (alive-only does)
        self.probe(("fail", slot) if self.total == before else None)

    def recover_one(self, slot: int) -> None:
        self.mgr.recover(slot)
        self.res.events += 1
        self.probe(("recover", slot))

    def storm(self, transitions) -> None:
        self.mgr.apply(transitions)
        self.res.events += len(transitions)
        self.probe()  # multi-event: alive-only + epoch checks

    def maybe_scale_up(self) -> None:
        if self.total >= self.router.spec.capacity:
            return
        new = self.mgr.scale_up()
        self.res.events += 1
        self.probe(("scale_up", new))

    def maybe_scale_down(self) -> None:
        # valid when >1 alive, or exactly one alive sitting on the top slot
        if self.router.domain.alive_count > 1 or (
            self.router.domain.alive_count == 1
            and (self.total - 1) not in self.removed
        ):
            self.mgr.scale_down()
            self.res.events += 1
            self.probe()


# -- scenario storylines ------------------------------------------------------

def _run_storm(r: _Runner) -> None:
    for _round in range(3):
        alive = r.alive_slots
        if len(alive) < 2:
            break
        k = int(r.rng.integers(1, len(alive)))  # may take out ALL but keep >=... or all
        victims = [int(s) for s in r.rng.choice(alive, size=k, replace=False)]
        r.storm([("fail", s) for s in victims])
        back = [s for s in victims if s in r.removed]
        r.storm([("recover", s) for s in r.rng.permutation(back).tolist()])
    r.check_replay()


def _run_flap(r: _Runner) -> None:
    cfg = r.mgr.config.heartbeat
    slots = r.mgr.detector.slots
    # never the top slot: a deadline-fail there is a LIFO *retirement*
    # (slot space shrinks, the id ceases to exist) — a flapping replica
    # that can come back must hold a non-top slot
    victim = int(r.rng.choice(slots[:-1])) if len(slots) > 1 else int(slots[0])
    fail_at: float | None = None
    for _ in range(200):
        dt = float(r.rng.uniform(0.4, cfg.heartbeat_interval * 1.4))
        r.clock.advance(dt)
        for s in slots:
            if s == victim:
                # the victim blinks: beats arrive only ~45% of the time
                if r.rng.random() < 0.45:
                    r.mgr.heartbeat(s)
            else:
                r.mgr.heartbeat(s)
        events = r.mgr.tick()
        for ev in events:
            r.res.events += 1
            if ev.slot != victim:
                r._flag(f"detector fired for healthy replica {ev.slot}")
            if ev.kind == "fail":
                if fail_at is not None:
                    r._flag("second 'fail' without intervening 'recover'")
                fail_at = r.clock.now()
            elif ev.kind == "recover":
                if fail_at is None:
                    r._flag("'recover' without preceding 'fail'")
                else:
                    r.res.recovery_latencies.append(r.clock.now() - fail_at)
                    fail_at = None
        if events:
            r.probe(
                (events[0].kind, events[0].slot) if len(events) == 1 else None
            )
    # let the victim stabilise and re-admit (bounded by flap backoff cap)
    deadline = r.clock.now() + cfg.max_readmit_after + 4 * cfg.suspect_after
    while fail_at is not None and r.clock.now() < deadline:
        r.clock.advance(cfg.heartbeat_interval * 0.9)
        for s in slots:
            r.mgr.heartbeat(s)
        for ev in r.mgr.tick():
            r.res.events += 1
            if ev.kind == "recover" and ev.slot == victim:
                r.res.recovery_latencies.append(r.clock.now() - fail_at)
                fail_at = None
    if fail_at is not None:
        r._flag("flapping replica never re-admitted after stable beats")
    r.probe()
    r.check_replay()


def _run_cascade(r: _Runner) -> None:
    # fail one at a time all the way to an empty fleet...
    order = r.rng.permutation(r.alive_slots).tolist()
    for s in order:
        if s in r.removed or s >= r.total:
            continue  # a LIFO retirement garbage-collected it already
        r.fail_one(int(s))
    if r.router.domain.alive_count == 0:
        # ...and prove the outage is typed at the router layer too
        try:
            r.router.route_keys_np(PROBE_KEYS[:8])
            r._flag("raw router routed with n_alive == 0")
        except FleetUnavailableError:
            pass
    # ...then staged recovery of everything that still has a slot
    for s in sorted(r.removed):
        r.recover_one(int(s))
    if r.router.domain.alive_count != r.total:
        r._flag("cascade recovery left tombstones behind")
    r.check_replay()


def _run_crash_recover(r: _Runner) -> None:
    crash_points = set(r.rng.integers(2, 30, size=2).tolist())
    snap = None
    snap_epoch = 0
    for step in range(30):
        alive = r.alive_slots
        tomb = sorted(r.removed)
        roll = r.rng.random()
        if tomb and roll < 0.4:
            r.recover_one(int(r.rng.choice(tomb)))
        elif alive and roll < 0.9:
            r.fail_one(int(r.rng.choice(alive)))
        else:
            r.maybe_scale_up()
        if step in crash_points:
            snap = r.mgr.snapshot()
            snap_epoch = snap.epoch
    r.crash_and_rebuild(snap, snap_epoch)
    r.check_replay()


def _run_mixed(r: _Runner) -> None:
    for step in range(24):
        alive = r.alive_slots
        tomb = sorted(r.removed)
        roll = r.rng.random()
        if roll < 0.30 and alive:
            r.fail_one(int(r.rng.choice(alive)))
        elif roll < 0.55 and tomb:
            r.recover_one(int(r.rng.choice(tomb)))
        elif roll < 0.70 and len(alive) > 2:
            k = int(r.rng.integers(2, len(alive)))
            victims = [int(s) for s in r.rng.choice(alive, size=k, replace=False)]
            r.storm([("fail", s) for s in victims])
            back = [s for s in victims if s in r.removed]
            if back:
                r.storm([("recover", s) for s in back])
        elif roll < 0.85:
            r.maybe_scale_up()
        else:
            r.maybe_scale_down()
        if step % 8 == 7:
            r.check_replay()
    r.check_replay()


# -- placement-tier storylines ------------------------------------------------


class _PlacementRunner:
    """Drives an R-way ``StorePlacement`` + ``PlacementRepairer`` through a
    scenario, checking the durability invariants per step."""

    REPAIR_BUDGET = 8

    def __init__(self, kind: str, engine: str, seed: int, n_initial: int,
                 r: int):
        self.rng = np.random.default_rng(seed)
        self.clock = ManualClock()
        self.router = BatchRouter(n_initial, engine=engine)
        self.mgr = LifecycleManager(
            self.router, LifecycleConfig(min_alive_floor=1), clock=self.clock
        )
        self.store = StorePlacement(self.router, r=r)
        self.store.register(PROBE_KEYS)
        self.repairer = PlacementRepairer(
            self.store, self.mgr, budget_per_tick=self.REPAIR_BUDGET
        )
        self.res = ScenarioResult(kind=kind, engine=engine, seed=seed)
        self.check_durability()

    # -- state helpers ------------------------------------------------------
    @property
    def total(self) -> int:
        return self.router.domain.total_count

    @property
    def removed(self) -> frozenset:
        return self.router.domain.removed

    @property
    def n_alive(self) -> int:
        return self.router.domain.alive_count

    @property
    def alive_slots(self) -> list:
        rm = self.removed
        return [s for s in range(self.total) if s not in rm]

    def _flag(self, msg: str) -> None:
        self.res.violations.append(
            f"[{self.res.kind}/{self.res.engine}/seed={self.res.seed}] {msg}"
        )

    # -- invariants ----------------------------------------------------------
    def check_durability(self) -> None:
        """The placement tier's core invariant battery: while
        ``n_alive >= 1`` no registered key drops to zero reachable
        replicas, placements are typed/epoch-stamped with the right
        degradation mode, and every placed replica row is
        ``min(r, n_alive)``-distinct and alive-only."""
        self.res.route_attempts += 1
        n_alive = self.n_alive
        if n_alive == 0:
            self.res.route_unavailable += 1
            try:
                self.store.place(PROBE_KEYS[:8])
                self._flag("place succeeded with n_alive == 0")
            except FleetUnavailableError:
                pass
            return
        counts = self.store.reachable_counts()
        if (counts < 1).any():
            self._flag(
                f"durability lost: {int((counts < 1).sum())} key(s) with "
                f"zero reachable replicas at n_alive={n_alive}"
            )
        n_eff = min(self.store.r, n_alive)
        if (counts > n_eff).any():
            self._flag(f"reachable count above min(r, n_alive)={n_eff}")
        try:
            batch = self.store.place(PROBE_KEYS[:16])
        except FleetUnavailableError:
            self.res.route_unavailable += 1
            self._flag(f"FleetUnavailableError with n_alive={n_alive}")
            return
        expect = MODE_DEGRADED if n_alive < self.store.r else MODE_NORMAL
        if batch.mode != expect:
            self._flag(
                f"mode {batch.mode!r} != {expect!r} at n_alive={n_alive}, "
                f"r={self.store.r}"
            )
        if batch.epoch != self.mgr.epoch:
            self._flag(
                f"placement epoch {batch.epoch} != journal epoch "
                f"{self.mgr.epoch}"
            )
        reps = np.asarray(batch.replicas)
        dead = set(np.unique(reps).tolist()) - set(self.alive_slots)
        if dead:
            self._flag(f"placed on removed replica(s) {sorted(dead)}")
        distinct = np.array([len(set(row.tolist())) for row in reps])
        if (distinct != n_eff).any():
            self._flag(
                f"placement rows not {n_eff}-distinct at n_alive={n_alive}"
            )

    def check_quiesced(self) -> None:
        """Post-repair: every registered key back at full (possibly
        degraded-by-fleet-size) distinct replication."""
        if self.n_alive == 0:
            return
        n_eff = min(self.store.r, self.n_alive)
        counts = self.store.reachable_counts()
        if (counts != n_eff).any():
            self._flag(
                f"post-repair: {int((counts != n_eff).sum())} key(s) not at "
                f"{n_eff} distinct replicas"
            )
        if self.repairer.backlog:
            self._flag(f"quiesce left backlog {self.repairer.backlog}")

    def check_replay(self) -> None:
        self.res.replay_checks += 1
        try:
            self.repairer.verify_placement_replay()
            self.repairer.verify_placement_replay(self.mgr.snapshot())
        except AssertionError as e:
            self._flag(f"placement replay parity: {e}")

    # -- repair bandwidth ----------------------------------------------------
    def tick_repair(self) -> list:
        done = self.repairer.tick()
        if len(done) > self.repairer.budget_per_tick:
            self._flag(
                f"repair batch {len(done)} exceeds budget "
                f"{self.repairer.budget_per_tick}"
            )
        self.res.repair_copies += len(done)
        return done

    def quiesce(self) -> None:
        lost0 = self.repairer.lost
        for _ in range(10_000):
            if not self.repairer.backlog:
                break
            self.tick_repair()
        if self.repairer.backlog:
            self._flag(f"repair backlog failed to drain ({self.repairer.backlog})")
        if self.repairer.lost > lost0 and self.n_alive >= 1:
            self._flag(
                f"{self.repairer.lost - lost0} repair task(s) had no "
                f"reachable source with n_alive={self.n_alive}"
            )
        self.check_quiesced()

    # -- event vocabulary ----------------------------------------------------
    def fail(self, slot: int) -> None:
        self.mgr.fail(slot)  # journaled; the manager re-syncs the repairer
        self.res.events += 1
        self.check_durability()

    def storm(self, transitions) -> None:
        self.mgr.apply(transitions)
        self.res.events += len(transitions)
        self.check_durability()

    def recover_all(self) -> None:
        back = sorted(self.removed)
        if back:
            self.storm([
                ("recover", s) for s in self.rng.permutation(back).tolist()
            ])

    def maybe_scale_up(self) -> bool:
        if self.total >= self.router.spec.capacity:
            return False
        self.mgr.scale_up()
        self.res.events += 1
        self.check_durability()
        return True

    def pick_alive(self) -> int | None:
        alive = self.alive_slots
        return int(self.rng.choice(alive)) if alive else None


def _run_replica_loss(p: _PlacementRunner) -> None:
    """Kill up to r-1 holders of the SAME key between quiescence points:
    the key stays readable (degraded) from the survivors — never from a
    victim — and budgeted repair restores min(r, n_alive)-way distinct
    replication for every key."""
    for _round in range(4):
        if p.n_alive < 2:
            break
        ki = int(p.rng.integers(0, N_PROBE))
        holders, _ = p.store.read(ki)
        kmax = min(p.store.r - 1, int(holders.size), p.n_alive - 1)
        if kmax < 1:
            break
        k = int(p.rng.integers(1, kmax + 1))
        victims = [int(s) for s in p.rng.choice(holders, size=k, replace=False)]
        p.storm([("fail", s) for s in victims])
        try:
            found, _mode = p.store.read(ki)
        except FleetUnavailableError:
            p._flag(
                f"key index {ki} unreadable after {k} of {p.store.r} "
                f"replica holders failed (n_alive={p.n_alive})"
            )
        else:
            hit = set(found.tolist()) & set(victims)
            if hit:
                p._flag(f"degraded read returned failed holder(s) {sorted(hit)}")
        p.quiesce()
        p.recover_all()
        p.quiesce()
    p.check_replay()


def _run_repair_race(p: _PlacementRunner) -> None:
    """A membership change starts a migration; after a few budgeted repair
    ticks — mid-flight, backlog still pending — a SECOND failure lands.
    Total distinct failures per quiescence interval stay <= r-1 (the
    construction's tolerance), so durability must hold through the race
    and repair must still converge."""
    for _round in range(3):
        budget = min(p.store.r, p.n_alive) - 1  # kills tolerable this round
        if budget < 1 or p.n_alive < 2:
            break
        grew = False
        if p.rng.random() < 0.5:
            grew = p.maybe_scale_up()
        if not grew:
            victim = p.pick_alive()
            if victim is None:
                break
            p.fail(victim)
            budget -= 1
        # in-flight: a few bounded repair batches, NOT a full quiesce
        for _ in range(int(p.rng.integers(1, 4))):
            p.tick_repair()
        # the race: another failure DURING the pending migration
        if budget >= 1 and p.n_alive >= 2:
            victim = p.pick_alive()
            if victim is not None:
                p.fail(victim)
                for _ in range(int(p.rng.integers(0, 3))):
                    p.tick_repair()
        p.quiesce()
        p.recover_all()
        p.quiesce()
    p.check_replay()


# -- streaming-tier storylines ------------------------------------------------


class _StreamingRunner:
    """Drives a ``StreamingFrontEnd`` (admission + micro-batch + hedged
    reads + breakers) over a lifecycle-wrapped router and an R-way
    placement, on ONE virtual-µs timeline, checking the SLO invariants:

    11. **bounded deadline miss** — no admitted-and-served request completes
        more than one batch window (``max_wait_us``) past its deadline;
    12. **monotone shedding** — shed fraction never *decreases* as offered
        load steps up (overload ramp);
    13. **holder-only hedging** — a (possibly hedged) read returns a shard
        that actually holds the key, never a non-holder;
    14. **telemetry fidelity** — the shared registry/trace/load-monitor
        agree with ground truth at quiescence: served counter == requests
        consumed == ``request`` span count, the device load accumulator
        drains to exactly the number of keys dispatched, observed
        peak/mean stays inside the balance envelope, and no theory-bound
        alarm (balance drift / disruption bound) fired mid-storyline.
    """

    #: detector thresholds compressed to a sub-second virtual timescale so
    #: suspect/fail/readmit transitions land inside a short storyline
    HB = HeartbeatConfig(
        heartbeat_interval=0.05,
        suspect_after=0.15,
        fail_after=0.35,
        readmit_after=0.2,
    )
    BASE_SERVICE_US = 800
    SERVICE_BOUND_US = 2_000
    MAX_BATCH = 16
    MAX_WAIT_US = 1_000

    def __init__(self, kind: str, engine: str, seed: int, n_initial: int):
        self.rng = np.random.default_rng(seed)
        self.clock = VirtualClockUs()
        self.router = BatchRouter(n_initial, engine=engine)
        self.mgr = LifecycleManager(
            self.router,
            LifecycleConfig(min_alive_floor=1, heartbeat=self.HB),
            clock=self.clock.seconds_view(),
        )
        self.store = StorePlacement(self.router, r=min(3, n_initial - 1))
        self.store.register(PROBE_KEYS)
        self.repairer = PlacementRepairer(
            self.store, self.mgr, budget_per_tick=64
        )
        # one shared telemetry plane across every front end the storyline
        # builds: registry on the virtual clock, one span trace, and the
        # device-side load accumulator drained only at explicit checkpoints
        self.metrics = MetricsRegistry(clock=self.clock)
        self.trace = SpanTrace(capacity=1 << 15)
        self.alarms: list = []
        self.monitor = LoadMonitor(
            self.router,
            metrics=self.metrics,
            config=LoadConfig(drain_every=1 << 30),
            on_alarm=self.alarms.append,
        )
        self.total_served = 0
        self.res = ScenarioResult(kind=kind, engine=engine, seed=seed)
        #: service multiplier scripted by the storyline (latency spikes)
        self.spike_mult = 1.0

    def _flag(self, msg: str) -> None:
        self.res.violations.append(
            f"[{self.res.kind}/{self.res.engine}/seed={self.res.seed}] {msg}"
        )

    # -- state helpers ------------------------------------------------------
    @property
    def n_alive(self) -> int:
        return self.router.domain.alive_count

    @property
    def alive_slots(self) -> list:
        rm = self.router.domain.removed
        return [s for s in range(self.router.domain.total_count) if s not in rm]

    # -- injected transports -------------------------------------------------
    def _service_model(self, _n: int) -> int:
        # spikes never exceed the declared bound: the bound is the SLO
        # capacity statement the miss guarantee reasons against
        return min(
            int(self.BASE_SERVICE_US * self.spike_mult), self.SERVICE_BOUND_US
        )

    def _probe(self, shard: int) -> int:
        try:
            slow = self.mgr.detector.state_of(int(shard)) == SUSPECT
        except KeyError:
            slow = False
        return 900 if slow else 120

    def make_frontend(self, rate_per_s=None) -> StreamingFrontEnd:
        def on_events(events):
            self.res.events += len(events)

        return StreamingFrontEnd(
            self.mgr,
            store=self.store,
            config=StreamConfig(
                max_batch=self.MAX_BATCH,
                max_wait_us=self.MAX_WAIT_US,
                service_bound_us=self.SERVICE_BOUND_US,
                hedge_after_us=300,
                tenant_rate_per_s=rate_per_s,
            ),
            clock=self.clock,
            breaker_config=BreakerConfig(
                trip_after=3, window_us=30_000_000, cooldown_us=2_000_000
            ),
            dispatch_fn=LifecycleDispatch(self.mgr, on_events=on_events),
            service_model=self._service_model,
            probe=self._probe,
            metrics=self.metrics,
            tracer=self.trace,
        )

    # -- invariant checks -----------------------------------------------------
    def _consume(self, results) -> int:
        self.total_served += len(results)
        for r in results:
            self.res.route_attempts += 1
            if r.deadline_miss_us > self.MAX_WAIT_US:
                self._flag(
                    f"served request missed its deadline by "
                    f"{r.deadline_miss_us}us > one batch window "
                    f"({self.MAX_WAIT_US}us)"
                )
        return len(results)

    def drive(
        self, fe: StreamingFrontEnd, n_requests: int, gap_us: int,
        slo_us: int, jitter: float = 0.2,
    ) -> tuple[int, int]:
        """Open-loop arrivals at ~1/gap_us req/µs; returns (served, shed)."""
        served = shed = 0
        for _ in range(n_requests):
            req = StreamRequest(
                key=int(self.rng.integers(0, 1 << 32)),
                deadline_us=self.clock.now_us() + slo_us,
                tenant=f"t{int(self.rng.integers(0, 4))}",
            )
            try:
                fe.submit(req)
            except AdmissionRejectedError:
                shed += 1
            lo, hi = (1 - jitter) * gap_us, (1 + jitter) * gap_us
            self.clock.advance_us(max(1, int(self.rng.uniform(lo, hi))))
            served += self._consume(fe.pump())
        served += self._consume(fe.drain())
        return served, shed

    def read_probe(self, fe: StreamingFrontEnd, ki: int):
        try:
            out = fe.read(ki)
        except FleetUnavailableError:
            if self.n_alive > 0 and self.store.reachable_counts()[ki] > 0:
                self._flag(
                    f"read of key index {ki} unavailable with reachable "
                    f"copies at n_alive={self.n_alive}"
                )
            return None
        if out.shard not in out.holders:
            self._flag(
                f"hedged read returned non-holder {out.shard} "
                f"(holders {list(out.holders)})"
            )
        if out.shard not in self.alive_slots:
            self._flag(f"hedged read returned dead shard {out.shard}")
        return out

    def keys_with_primary(self, shard: int, limit: int = 8) -> list:
        """Registered key indices whose FIRST reachable holder is ``shard``
        (the reads that elect it primary)."""
        mask = self.store.reachable_mask()
        out = []
        for ki in range(mask.shape[0]):
            cols = np.flatnonzero(mask[ki])
            if cols.size and int(self.store.holders[ki, cols[0]]) == shard:
                out.append(ki)
                if len(out) >= limit:
                    break
        return out

    def quiesce(self) -> None:
        for _ in range(10_000):
            if not self.repairer.backlog:
                break
            self.repairer.tick()
        n_eff = min(self.store.r, self.n_alive)
        counts = self.store.reachable_counts()
        if (counts != n_eff).any():
            self._flag(
                f"post-quiesce: {int((counts != n_eff).sum())} key(s) not "
                f"at {n_eff} distinct replicas"
            )

    def check_replay(self) -> None:
        self.res.replay_checks += 1
        try:
            self.mgr.verify_replay()
            self.repairer.verify_placement_replay()
        except AssertionError as e:
            self._flag(f"replay parity: {e}")

    def check_telemetry(self) -> None:
        """Invariant 14: registry, trace and device load accumulator agree
        with ground truth at quiescence; no theory-bound alarm fired."""
        self.monitor.drain()
        served = self.metrics.total("stream_served_total")
        if served != self.total_served:
            self._flag(
                f"registry served counter {served} != requests consumed "
                f"{self.total_served}"
            )
        if self.trace.count("request") != self.total_served:
            self._flag(
                f"request span count {self.trace.count('request')} != "
                f"requests consumed {self.total_served}"
            )
        if self.monitor.total_keys != self.total_served:
            self._flag(
                f"device load accumulator drained {self.monitor.total_keys} "
                f"keys != {self.total_served} dispatched"
            )
        ratio = self.monitor.peak_over_mean()
        if ratio is not None and self.monitor.total_keys >= 256:
            cfg = self.monitor.config
            envelope = cfg.balance_mult * expected_peak_over_mean(
                self.monitor.total_keys, self.n_alive
            )
            if ratio > envelope:
                self._flag(
                    f"post-quiesce peak/mean {ratio:.3f} outside the "
                    f"balance envelope {envelope:.3f}"
                )
        for alarm in self.alarms:
            self._flag(f"theory-bound alarm fired: {alarm}")


def _run_overload(s: _StreamingRunner) -> None:
    """Offered load ramps from half capacity to 4x: below capacity nothing
    sheds, above it the shed fraction grows monotonically while every
    SERVED request still lands within one batch window of its deadline."""
    capacity_gap = s.BASE_SERVICE_US / s.MAX_BATCH  # µs/request at capacity
    fractions = []
    for mult in (0.5, 1.0, 2.0, 4.0):
        fe = s.make_frontend()
        gap = max(1, int(capacity_gap / mult))
        served, shed = s.drive(fe, n_requests=240, gap_us=gap, slo_us=4_000)
        total = served + shed
        fractions.append(shed / total if total else 0.0)
        for _ in range(6):
            s.read_probe(fe, int(s.rng.integers(0, N_PROBE)))
    if fractions[0] > 0.02:
        s._flag(f"shed fraction {fractions[0]:.3f} at half capacity")
    for a, b in zip(fractions, fractions[1:]):
        if b < a - 0.02:
            s._flag(
                f"shed fraction not monotone in offered load: {fractions}"
            )
            break
    # membership churn mid-stream: an operator fail lands between ramps,
    # the serve path's dispatch ticks meter the repairs out, recovery heals
    victims = [v for v in s.alive_slots[:-1]]
    if victims:
        victim = int(s.rng.choice(victims))
        s.monitor.drain()  # baseline the disruption tracker pre-fail
        s.mgr.fail(victim)
        s.res.events += 1
        fe = s.make_frontend()
        s.drive(fe, n_requests=80, gap_us=int(capacity_gap * 2), slo_us=4_000)
        for _ in range(6):
            s.read_probe(fe, int(s.rng.integers(0, N_PROBE)))
        # epoch advanced: this drain scores the live moved fraction of the
        # probe set against the delta/n disruption bound
        s.monitor.drain()
        s.mgr.recover(victim)
        s.res.events += 1
    s.quiesce()
    s.check_replay()
    s.check_telemetry()


def _run_latency_spike(s: _StreamingRunner) -> None:
    """A service-time spike + a flapping shard: served requests stay inside
    the miss bound through the spike, reads whose primary turns suspect
    hedge to another holder, the breaker trips on the flapper BEFORE the
    detector removes it, and a later full outage + return flows through
    fail/recover with repair converging — all on one virtual timeline."""
    fe = s.make_frontend()
    slots = list(s.mgr.detector.slots)
    victim = int(s.rng.choice(slots[:-1])) if len(slots) > 1 else int(slots[0])
    round_us = 50_000  # 0.05 virtual seconds — one heartbeat interval
    gap = int(s.BASE_SERVICE_US / s.MAX_BATCH * 2)  # half capacity
    hedged_seen = 0

    def beat_all(skip_victim: bool):
        for slot in s.mgr.detector.slots:
            if skip_victim and slot == victim:
                continue
            s.mgr.heartbeat(slot)

    for rnd in range(28):
        # scripted flap: the victim beats every 4th round only — silence
        # runs of 0.2s > suspect_after (0.15s) but < fail_after (0.35s),
        # so it oscillates alive<->suspect without EVER formally failing
        flapping = 6 <= rnd < 22
        beat_all(skip_victim=flapping and rnd % 4 != 0)
        if rnd == 10:
            s.spike_mult = 2.5  # capped at the declared bound by the model
        if rnd == 18:
            s.spike_mult = 1.0
        s.drive(fe, n_requests=10, gap_us=gap, slo_us=5_000, jitter=0.1)
        if flapping:
            for ki in s.keys_with_primary(victim, limit=2):
                out = s.read_probe(fe, ki)
                if out is not None and out.hedged:
                    hedged_seen += 1
        # pad the round out to the heartbeat cadence
        s.clock.advance_us(round_us)
        s._consume(fe.pump())
    try:
        if s.mgr.detector.state_of(victim) == REMOVED:
            s._flag("flapping shard was formally removed despite hysteresis")
    except KeyError:
        s._flag("flapping shard fell out of the detector")
    if fe.breakers.trips == 0:
        s._flag("breaker never tripped on a scripted 4-flap pattern")
    elif not hedged_seen and fe.reader.hedge_launched == 0:
        # breaker-open primaries are excluded from candidacy pre-hedge, so
        # either hedges fired or the breaker rerouted reads — reads of
        # victim-primary keys must not still elect the victim
        for ki in s.keys_with_primary(victim, limit=2):
            out = s.read_probe(fe, ki)
            if out is not None and out.shard == victim and len(out.holders) > 1:
                s._flag(
                    "breaker open but read still elected the flapping "
                    f"primary {victim}"
                )
    # full outage: silence past fail_after -> ONE detector fail (journaled
    # via the dispatch tick), repairs metered by the serve path itself
    for _ in range(10):
        beat_all(skip_victim=True)
        s.drive(fe, n_requests=8, gap_us=gap, slo_us=5_000, jitter=0.1)
        s.clock.advance_us(round_us)
        s._consume(fe.pump())
    if victim in s.alive_slots:
        s._flag("silenced shard never declared failed under serve traffic")
    # the shard returns: stable beats through quarantine -> ONE recover
    for _ in range(12):
        beat_all(skip_victim=False)
        s.drive(fe, n_requests=8, gap_us=gap, slo_us=5_000, jitter=0.1)
        s.clock.advance_us(round_us)
        s._consume(fe.pump())
    if victim not in s.alive_slots:
        s._flag("recovered shard never readmitted under serve traffic")
    s.quiesce()
    s.check_replay()
    s.check_telemetry()


_STORYLINES = {
    "storm": _run_storm,
    "flap": _run_flap,
    "cascade": _run_cascade,
    "crash_recover": _run_crash_recover,
    "mixed": _run_mixed,
    "replica_loss": _run_replica_loss,
    "repair_race": _run_repair_race,
    "overload": _run_overload,
    "latency_spike": _run_latency_spike,
}


def run_scenario(kind: str, engine: str, seed: int) -> ScenarioResult:
    """Run one seeded scenario; returns the result (violations collected)."""
    if kind not in _STORYLINES:
        raise ValueError(f"unknown scenario kind {kind!r}; expected {KINDS}")
    rng = np.random.default_rng(seed)
    n_initial = int(rng.integers(4, 17))
    if kind in STREAMING_KINDS:
        runner = _StreamingRunner(kind, engine, seed, max(n_initial, 6))
        _STORYLINES[kind](runner)
        return runner.res
    if kind in PLACEMENT_KINDS:
        rep = 3 if kind == "repair_race" else 2 + seed % 2
        runner = _PlacementRunner(
            kind, engine, seed, max(n_initial, rep + 2), rep
        )
        _STORYLINES[kind](runner)
        return runner.res
    r = _Runner(kind, engine, seed, n_initial)
    _STORYLINES[kind](r)
    return r.res
