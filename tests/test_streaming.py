"""Streaming front end: admission, micro-batching, hedging, breakers.

Tier-1 coverage for ``repro.serving.streaming`` (DESIGN.md §14) plus the
serve-path regressions it rides on: tier-level fleet events flow through
the lifecycle manager (journaled + repairer-synced), and a backwards clock
is a typed error, never silent timer corruption.
"""
import numpy as np
import pytest

from repro.placement.store import StorePlacement
from repro.serving.batch_router import BatchRouter
from repro.serving.lifecycle import (
    REMOVED,
    SUSPECT,
    AdmissionRejectedError,
    ClockWentBackwardsError,
    FailureDetector,
    HeartbeatConfig,
    LifecycleConfig,
    LifecycleManager,
    ManualClock,
    PlacementRepairer,
)
from repro.serving.lifecycle.errors import (
    SHED_INFEASIBLE,
    SHED_LATE,
    SHED_PAST_DEADLINE,
    SHED_RATE_LIMITED,
)
from repro.serving.streaming import (
    BreakerBoard,
    BreakerConfig,
    HedgedReader,
    LifecycleDispatch,
    MicroBatcher,
    StreamConfig,
    StreamingFrontEnd,
    StreamRequest,
    TokenBucket,
    VirtualClockUs,
)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


class _EchoHandle:
    def __init__(self, reps):
        self._reps = reps

    def result(self):
        return self._reps, 0, "normal"


def _echo_dispatch(keys_u32):
    """Dispatch stub: replica = key % 4 (deterministic, device-free)."""
    return _EchoHandle(np.asarray(keys_u32, np.int64) % 4)


def _batcher(service_us=500, **cfg):
    clock = VirtualClockUs()
    config = StreamConfig(**{
        "max_batch": 8, "max_wait_us": 1_000, "service_bound_us": 1_000,
        **cfg,
    })
    b = MicroBatcher(
        _echo_dispatch, config=config, clock=clock,
        service_model=lambda n: service_us,
    )
    return b, clock


def _req(clock, slo_us=10_000, key=7, tenant="default"):
    return StreamRequest(
        key=key, deadline_us=clock.now_us() + slo_us, tenant=tenant
    )


# ---------------------------------------------------------------------------
# micro-batcher core
# ---------------------------------------------------------------------------


def test_batch_closes_at_max_batch_and_window():
    b, clock = _batcher(max_batch=4)
    for _ in range(4):
        b.submit(_req(clock))
    assert b.dispatches == 1  # size-triggered close
    b.submit(_req(clock))
    assert b.open_depth == 1
    clock.advance_us(1_001)
    out = b.pump()
    assert b.dispatches == 2  # window-triggered close
    clock.advance_us(10_000)
    out += b.pump() + b.drain()
    assert len(out) == 5
    assert all(r.deadline_miss_us == 0 for r in out)


def test_results_carry_routing_and_timing():
    b, clock = _batcher(max_batch=2, service_us=400)
    b.submit(StreamRequest(key=9, deadline_us=clock.now_us() + 5_000))
    b.submit(StreamRequest(key=10, deadline_us=clock.now_us() + 5_000))
    clock.advance_us(400)
    (r9, r10) = b.pump()
    assert (r9.replica, r10.replica) == (9 % 4, 10 % 4)
    assert r9.t_complete_us == r9.t_dispatch_us + 400
    assert r9.latency_us == 400


def test_pipeline_overlaps_one_deep():
    b, clock = _batcher(max_batch=2, service_us=2_000)
    b.submit(_req(clock))
    b.submit(_req(clock))
    assert b.inflight_depth == 2
    b.submit(_req(clock))  # fills while the previous batch "computes"
    assert b.open_depth == 1 and b.inflight_depth == 2
    clock.advance_us(500)
    b.pump()
    # window expired but the pipeline slot is busy: adaptive sizing keeps
    # the open batch filling instead of dispatching a sliver
    clock.advance_us(600)
    b.pump()
    assert b.dispatches == 1 and b.open_depth == 1
    clock.advance_us(1_000)  # in-flight ETA passes
    b.pump()
    assert b.dispatches == 2


def test_deadline_miss_bounded_by_one_window_under_backlog():
    b, clock = _batcher(max_batch=4, service_us=900, service_bound_us=1_000)
    rng = np.random.default_rng(3)
    served = []
    for _ in range(300):
        try:
            b.submit(_req(clock, slo_us=2_500, key=int(rng.integers(1 << 32))))
        except AdmissionRejectedError:
            pass
        clock.advance_us(60)  # ~4x over capacity
        served.extend(b.pump())
    served.extend(b.drain())
    assert served, "over capacity but nothing served"
    assert max(r.deadline_miss_us for r in served) <= 1_000
    assert b.admission.shed_total > 0


# ---------------------------------------------------------------------------
# degenerate edges (ISSUE satellite: empty window, max_batch=1, DOA, bucket)
# ---------------------------------------------------------------------------


def test_zero_request_batch_window_is_noop():
    b, clock = _batcher()
    for _ in range(5):
        clock.advance_us(2_000)
        assert b.pump() == []
    assert b.drain() == []
    assert b.dispatches == 0 and b.served == 0


def test_max_batch_one_dispatches_every_submit():
    b, clock = _batcher(max_batch=1, service_us=100)
    for i in range(3):
        b.submit(_req(clock, key=i))
        clock.advance_us(150)
    out = b.pump() + b.drain()
    assert [r.request.key for r in out] == [0, 1, 2]
    assert b.dispatches == 3


def test_all_requests_past_deadline_on_arrival():
    b, clock = _batcher()
    clock.advance_us(5_000)
    for _ in range(4):
        with pytest.raises(AdmissionRejectedError) as ei:
            b.submit(
                StreamRequest(key=1, deadline_us=clock.now_us() - 1)
            )
        assert ei.value.reason == SHED_PAST_DEADLINE
    assert b.dispatches == 0
    assert b.admission.shed_by_reason[SHED_PAST_DEADLINE] == 4


def test_single_tenant_bucket_exhaustion():
    b, clock = _batcher(
        max_batch=64, tenant_rate_per_s=10.0, tenant_burst=2.0
    )
    ok = shed = 0
    for _ in range(5):
        try:
            b.submit(_req(clock, tenant="hog"))
            ok += 1
        except AdmissionRejectedError as e:
            assert e.reason == SHED_RATE_LIMITED
            assert e.tenant == "hog"
            shed += 1
    assert (ok, shed) == (2, 3)
    # an unrelated tenant is not starved by the hog's empty bucket
    b.submit(_req(clock, tenant="quiet"))
    # and the hog's bucket refills with time (10/s -> one per 100ms)
    clock.advance_us(150_000)
    b.submit(_req(clock, tenant="hog"))
    assert b.admission.shed_by_tenant[("hog", SHED_RATE_LIMITED)] == 3


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_infeasible_deadline_shed_at_admission():
    b, clock = _batcher(service_bound_us=2_000)
    with pytest.raises(AdmissionRejectedError) as ei:
        b.submit(_req(clock, slo_us=500))  # bound 2000 > 500 + window 1000
    assert ei.value.reason == SHED_INFEASIBLE


def test_late_requests_shed_typed_at_batch_close():
    b, clock = _batcher(max_batch=4, service_us=1_500, service_bound_us=1_500)
    b.submit(_req(clock, slo_us=1_700))  # feasible NOW: 0+1500 <= 1700+1000
    clock.advance_us(1_300)  # ...but the close ran late: 1300+1500 > 2700
    assert b.pump() == []
    assert b.dispatches == 0  # the whole batch was shed, nothing dispatched
    assert b.admission.shed_by_reason[SHED_LATE] == 1
    assert b.drain() == []


def test_token_bucket_refill_and_burst_cap():
    tb = TokenBucket(rate_per_s=100.0, burst=5.0)
    assert all(tb.try_take(0) for _ in range(5))
    assert not tb.try_take(0)
    assert tb.try_take(10_000)  # +1 token after 10ms at 100/s
    assert not tb.try_take(10_001)
    tb2 = TokenBucket(rate_per_s=100.0, burst=5.0)
    tb2.try_take(10_000_000)  # long idle: capped at burst, not rate*dt
    assert tb2.tokens == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# the real dispatch path (lifecycle-wrapped router)
# ---------------------------------------------------------------------------


def test_lifecycle_dispatch_routes_alive_only_and_ticks_repairs():
    clock = VirtualClockUs()
    router = BatchRouter(6, engine="binomial")
    mgr = LifecycleManager(
        router, LifecycleConfig(min_alive_floor=1),
        clock=clock.seconds_view(),
    )
    store = StorePlacement(router, r=3)
    keys = np.random.default_rng(0).integers(0, 1 << 32, 256, np.uint32)
    store.register(keys)
    PlacementRepairer(store, mgr, budget_per_tick=512)
    fe = StreamingFrontEnd(
        mgr, store=store,
        config=StreamConfig(max_batch=8, max_wait_us=500,
                            service_bound_us=2_000),
        clock=clock, service_model=lambda n: 300,
    )
    mgr.fail(2)
    backlog0 = mgr._placement.backlog
    assert backlog0 > 0
    rng = np.random.default_rng(1)
    served = []
    for _ in range(40):
        fe.submit(StreamRequest(
            key=int(rng.integers(0, 1 << 32)),
            deadline_us=clock.now_us() + 10_000,
        ))
        clock.advance_us(400)
        served.extend(fe.pump())
    served.extend(fe.drain())
    assert len(served) == 40
    alive = set(range(6)) - {2}
    assert {r.replica for r in served} <= alive
    assert all(r.epoch == mgr.epoch for r in served)
    # the DISPATCHES drove the repairs — no manual repairer ticks anywhere
    assert mgr._placement.backlog == 0
    assert (store.reachable_counts() == 3).all()


# ---------------------------------------------------------------------------
# hedged reads + breakers
# ---------------------------------------------------------------------------


def _hedging_rig(r=3, n=6):
    clock = VirtualClockUs()
    router = BatchRouter(n, engine="binomial")
    mgr = LifecycleManager(router, clock=clock.seconds_view())
    store = StorePlacement(router, r=r)
    keys = np.random.default_rng(7).integers(0, 1 << 32, 64, np.uint32)
    store.register(keys)
    return clock, router, mgr, store


def test_suspect_primary_hedges_to_next_holder():
    clock, router, mgr, store = _hedging_rig()
    primary = int(store.holders[0, 0])
    board = BreakerBoard(mgr.detector, clock)
    reader = HedgedReader(
        store, mgr.detector, board, hedge_after_us=300,
        probe=lambda s: 900 if s == primary else 120,
    )
    healthy = reader.read(0)
    assert not healthy.hedged and healthy.shard == primary
    # silence the primary past suspect_after; poll via tick
    for s in mgr.detector.slots:
        if s != primary:
            mgr.heartbeat(s)
    clock.advance_us(4_000_000)  # 4s > suspect_after (3s), < fail (6s)
    mgr.tick()
    assert mgr.detector.state_of(primary) == SUSPECT
    out = reader.read(0)
    assert out.hedged
    assert out.shard in out.holders
    assert out.shard != primary  # hedge won: 300 + 120 < 900
    assert out.latency_us == 420


def test_breaker_trips_on_flaps_and_reroutes_before_removal():
    clock, router, mgr, store = _hedging_rig()
    primary = int(store.holders[0, 0])
    board = BreakerBoard(
        mgr.detector, clock,
        BreakerConfig(trip_after=3, window_us=60_000_000,
                      cooldown_us=5_000_000),
    )
    reader = HedgedReader(
        store, mgr.detector, board, hedge_after_us=300,
        probe=lambda s: 120,  # primary FAST: only the breaker can reroute
    )
    # three scripted alive->suspect flips (each healed by a beat: the
    # detector's hysteresis never emits a formal fail)
    for _ in range(3):
        for s in mgr.detector.slots:
            if s != primary:
                mgr.heartbeat(s)
        clock.advance_us(4_000_000)
        mgr.tick()
        assert mgr.detector.state_of(primary) == SUSPECT
        board.observe()
        mgr.heartbeat(primary)  # heals: suspect -> alive, no event
        mgr.tick()
        board.observe()  # sees the healed state between flips
    assert board.trips == 1
    assert board.is_open(primary)
    assert mgr.detector.state_of(primary) != REMOVED
    out = reader.read(0)
    # breaker-open primary is out of the ballot entirely — no hedge needed
    assert out.shard != primary and out.shard in out.holders
    clock.advance_us(5_000_001)  # cooldown: half-open, candidate again
    assert not board.is_open(primary)
    assert reader.read(0).shard == primary


# ---------------------------------------------------------------------------
# satellite regressions: detector clock + tier events through lifecycle
# ---------------------------------------------------------------------------


class _Warpable:
    def __init__(self, t=100.0):
        self.t = t

    def now(self):
        return self.t


def test_backwards_clock_is_typed_error():
    clk = _Warpable()
    det = FailureDetector([0, 1, 2], HeartbeatConfig(), clk)
    clk.t = 101.0
    det.heartbeat(0)
    det.poll()
    clk.t = 42.0  # the warp
    with pytest.raises(ClockWentBackwardsError) as ei:
        det.poll()
    assert ei.value.now == 42.0 and ei.value.last == 101.0
    with pytest.raises(ClockWentBackwardsError):
        det.heartbeat(1)
    with pytest.raises(ClockWentBackwardsError):
        det.register(9)
    # time restored: the detector resumes (state was never corrupted)
    clk.t = 102.0
    det.heartbeat(1)
    assert det.poll() == []


def test_manual_clock_still_rejects_negative_advance():
    with pytest.raises(ValueError, match="backwards"):
        ManualClock().advance(-0.5)
