"""Example: serve batched requests across replicas with BinomialHash session
routing, then kill a replica and watch only its sessions move.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "stablelm-3b", "--replicas", "3", "--requests", "18",
                     "--fail-replica", "1"]
    main()
