"""Example: elastic-cluster walkthrough — the paper's guarantees driving
every placement layer of the framework.

    PYTHONPATH=src python examples/elastic_cluster.py
"""
import jax

from repro.configs import reduced_config
from repro.data.pipeline import DataConfig, ShardedDataPipeline
from repro.models import model as M
from repro.placement.elastic import FailureDomain, plan_expert_migration
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import make_optimizer
from repro.training.train_step import TrainHparams, make_train_state

print("=== 1. data fleet: 16 -> 20 hosts ===")
pipe = ShardedDataPipeline(DataConfig(1000, 32, 32, num_shards=1024), 16, 0)
plan = pipe.rescale(20)
print(f"shards moved: {plan.moved_fraction:.4f} (ideal ~{4/20:.4f}); "
      f"all to new hosts: {plan.destinations() <= {16,17,18,19}}")

print("=== 2. MoE expert-parallel group: 16 -> 24 devices ===")
m = plan_expert_migration(256, 16, 24)
print(f"experts moved: {len(m.plan.moves)}/256 "
      f"(ideal ~{256*8//24}); only to new devices: {m.plan.destinations() <= set(range(16,24))}")

print("=== 3. serving fleet: failure storm with Memento wrapper ===")
fd = FailureDomain(32)
keys = list(range(10000))
before = {k: fd.locate(k) for k in keys}
fd.fail(7); fd.fail(19)
moved = sum(1 for k in keys if fd.locate(k) != before[k])
print(f"2 replicas failed: {moved/len(keys):.4f} of sessions moved (ideal ~{2/32:.4f})")
fd.recover(7); fd.recover(19)
print(f"recovered: placement restored = {all(fd.locate(k)==before[k] for k in keys)}")

print("=== 4. checkpoint storage: 8 -> 10 nodes ===")
cfg = reduced_config("mamba2-1.3b")
params = M.init_params(jax.random.PRNGKey(0), cfg)
state = make_train_state(params, make_optimizer("adamw"), TrainHparams())
mgr = CheckpointManager("/tmp/repro_elastic_ckpt", n_nodes=8)
moves = mgr.plan_resize(jax.eval_shape(lambda: state), 10)
n_leaves = len(jax.tree.leaves(state))
print(f"checkpoint leaves to move: {len(moves)}/{n_leaves} "
      f"(ideal ~{n_leaves*2//10}); targets new nodes only: "
      f"{all(dst >= 8 for _, _, dst in moves)}")
