"""Streaming front-end walkthrough: ramp -> shed -> flap -> hedge ->
fail -> repair-through-serve -> recovery (DESIGN.md §14).

Run:  PYTHONPATH=src python examples/streaming_demo.py

Everything runs on ONE virtual µs timeline (`VirtualClockUs` +
`seconds_view()` for the failure detector), so the walkthrough is
deterministic: the same sheds, the same breaker trip, the same repair
arc every run — while every closed micro-batch still routes through the
real fused device dispatch.
"""
import numpy as np

from repro.placement.store import StorePlacement
from repro.serving.batch_router import BatchRouter
from repro.serving.lifecycle import (
    AdmissionRejectedError,
    LifecycleManager,
    PlacementRepairer,
)
from repro.serving.streaming import (
    StreamConfig,
    StreamingFrontEnd,
    StreamRequest,
    VirtualClockUs,
)

N_SHARDS = 8
MAX_BATCH = 16
SERVICE_US = 800       # simulated per-dispatch service time
BOUND_US = 1_000       # declared SLO bound (capacity = 16k req/s)
SLO_US = 4_000


def offer(fe, clock, n, gap_us, tag):
    """Open-loop arrivals: submit n requests one gap apart, pumping the
    batcher as virtual time advances; report served/shed."""
    served, shed = [], 0
    rng = np.random.default_rng(hash(tag) % (1 << 32))
    for _ in range(n):
        clock.advance_us(gap_us)
        served.extend(fe.pump())
        req = StreamRequest(
            key=int(rng.integers(0, 1 << 32)),
            deadline_us=clock.now_us() + SLO_US,
            tenant=f"tenant-{int(rng.integers(0, 4))}",
        )
        try:
            fe.submit(req)
        except AdmissionRejectedError:
            shed += 1
    for _ in range(8):
        clock.advance_us(BOUND_US)
        served.extend(fe.pump())
    served.extend(fe.drain())
    miss = max((r.deadline_miss_us for r in served), default=0)
    print(f"  {tag}: offered {n}, served {len(served)}, "
          f"shed-at-admission {shed}, worst deadline overshoot {miss}us "
          f"(one batch window = {fe.config.max_wait_us}us)")
    return served


def main() -> None:
    router = BatchRouter(N_SHARDS, engine="binomial")
    clock = VirtualClockUs()
    mgr = LifecycleManager(router, clock=clock.seconds_view())
    store = StorePlacement(router, r=3)
    store.register(
        np.random.default_rng(0).integers(0, 1 << 32, 1024, np.uint32)
    )
    repairer = PlacementRepairer(store, mgr, budget_per_tick=256)
    victim = 2  # the shard phase 3 will flap and phase 4 will kill

    def victim_suspect_probe(shard):
        # simulated transport: the flapping shard answers slowly
        return 900 if shard == victim else 120

    fe = StreamingFrontEnd(
        mgr,
        store=store,
        config=StreamConfig(
            max_batch=MAX_BATCH,
            max_wait_us=1_000,
            service_bound_us=BOUND_US,
            hedge_after_us=300,
        ),
        clock=clock,
        service_model=lambda n: SERVICE_US,
        probe=victim_suspect_probe,
    )

    # -- phase 1: half capacity — nothing sheds ------------------------------
    print("phase 1: offered load at 0.5x declared capacity")
    offer(fe, clock, 200, gap_us=125, tag="steady")

    # -- phase 2: 3x capacity — admission sheds, served stay in bound --------
    print("\nphase 2: offered load at 3x declared capacity")
    offer(fe, clock, 600, gap_us=21, tag="overload")
    print(f"  typed shed reasons: {dict(fe.admission.shed_by_reason)}")

    # -- phase 3: a flapping shard trips its breaker -------------------------
    det = mgr.detector
    for s in det.slots:
        det.heartbeat(s)
    print(f"\nphase 3: shard {victim} flaps (3x silent past suspect_after, "
          "returning before fail_after each time)")
    primaries = np.asarray(store.holders)[:, 0]
    key_idx = int(np.nonzero(primaries == victim)[0][0])
    for flap in range(3):
        for _ in range(7):  # 3.5s of silence: suspect, not yet failed
            clock.advance_us(500_000)
            for s in det.slots:
                if s != victim:
                    det.heartbeat(s)
            mgr.tick()
            fe.pump()
        if flap == 0:
            # suspect primary, breaker still closed: the hedge fires
            r = fe.read(key_idx)
            print(f"  suspect primary, breaker closed — read key {key_idx}: "
                  f"won by shard {r.shard}, hedged={r.hedged}, "
                  f"latency {r.latency_us}us, holders {list(r.holders)}")
        det.heartbeat(victim)  # back just under the fail_after wire
        mgr.tick()
        fe.pump()
    print(f"  breaker trips: {fe.breakers.trips}, "
          f"open: {list(fe.breakers.open_slots)} "
          f"(no formal membership event: epoch still {mgr.epoch})")

    # -- with the breaker open the primary is re-elected outright ------------
    r = fe.read(key_idx)
    print(f"  breaker open — read key {key_idx} (flapping primary {victim}): "
          f"won by shard {r.shard}, hedged={r.hedged}, "
          f"latency {r.latency_us}us, holders {list(r.holders)}")

    # -- phase 4: formal failure; serve traffic IS the repair cadence --------
    print(f"\nphase 4: shard {victim} formally fails")
    mgr.fail(victim)
    print(f"  repair backlog: {repairer.backlog} under-replicated copies")
    rounds = 0
    while repairer.backlog and rounds < 20:
        offer_n = MAX_BATCH
        rounds += 1
        offer(fe, clock, offer_n, gap_us=125, tag=f"serve round {rounds}")
    counts = store.reachable_counts()
    print(f"  backlog drained by serve dispatches alone: "
          f"{repairer.backlog == 0}; replicas now "
          f"{counts.min()}..{counts.max()}")

    # -- recovery + replay parity --------------------------------------------
    mgr.recover(victim)
    repairer.quiesce()
    mgr.verify_replay()
    repairer.verify_placement_replay()
    print(f"\nrecovered shard {victim}; journal and placement replay "
          f"bit-exactly; final stats: {fe.stats()}")


if __name__ == "__main__":
    main()
