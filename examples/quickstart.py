"""Quickstart: the paper's algorithm in 30 seconds, host-side and in-graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import collections

import jax.numpy as jnp
import numpy as np

from repro.core import BinomialHash, binomial_lookup_vec
from repro.kernels.ops import binomial_bulk_lookup

# -- host-side: route 100k data units onto 11 nodes ---------------------------
engine = BinomialHash(n=11)
keys = [hash(f"object-{i}") & (2**63 - 1) for i in range(100_000)]
table = {k: engine.get_bucket(k) for k in keys}
load = collections.Counter(table.values())
print("load per node   :", [load[i] for i in range(11)])

# -- scale up: node 11 joins; only ~1/12 of keys move, all onto node 11 -------
engine.add_bucket()
moves = {k: engine.get_bucket(k) for k in keys if engine.get_bucket(k) != table[k]}
print(f"scale 11->12    : moved {len(moves)/len(keys):.4f} (ideal {1/12:.4f}), "
      f"targets={set(moves.values())}")

# -- scale down: LIFO removal; only node 11's keys move -----------------------
engine.remove_bucket()
back = {k: engine.get_bucket(k) for k in keys}
print("scale back 12->11: restored exactly =", back == table)

# -- in-graph: the vectorised u32 device path (MoE router datapath) ----------
tokens = jnp.asarray(np.random.default_rng(0).integers(0, 2**31, 1 << 16), jnp.uint32)
experts = binomial_lookup_vec(tokens, 256, omega=16)
counts = np.bincount(np.asarray(experts), minlength=256)
print(f"in-graph routing: 64k tokens -> 256 experts, max/mean load "
      f"{counts.max()/counts.mean():.3f}")

# -- the Pallas TPU kernel (interpret mode on CPU) ----------------------------
buckets = binomial_bulk_lookup(tokens[:8192], 256, interpret=True)
print("pallas kernel   : matches jnp path =",
      bool((np.asarray(buckets) == np.asarray(experts)[:8192]).all()))
