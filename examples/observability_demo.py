"""Observability tier walkthrough: metrics, spans, device load counts,
theory-bound alarms, and the two export formats (DESIGN.md §15).

Run:  PYTHONPATH=src python examples/observability_demo.py

Four acts, all on ONE virtual µs timeline so every number reproduces:

1. a streaming front end serves traffic with the telemetry plane wired
   through admit -> batch close -> dispatch -> read -> complete;
2. a bulk router with a ``LoadMonitor`` attached routes exact and
   stride-sampled batches through the instrumented fused dispatch, then
   drains the device accumulator and compares peak/mean against the
   balls-into-bins envelope;
3. the theory-bound alarms fire on demand: a seeded pathological remap
   breaks the delta/n disruption bound, a rigged skew breaks the balance
   envelope — both delivered as typed alarm objects, not log lines;
4. the whole plane exports as a Prometheus exposition and a JSON
   snapshot.
"""
import json

import numpy as np

from repro.observability import (
    LoadConfig,
    LoadMonitor,
    MetricsRegistry,
    SpanTrace,
    disruption_bound,
    expected_peak_over_mean,
    to_json,
    to_prometheus,
)
from repro.serving.batch_router import BatchRouter
from repro.serving.lifecycle import AdmissionRejectedError, LifecycleManager
from repro.serving.streaming import (
    StreamConfig,
    StreamingFrontEnd,
    StreamRequest,
    VirtualClockUs,
)

N_SHARDS = 8
N_BULK_SHARDS = 32


def act_1_streaming(clock, metrics, trace):
    print("act 1: streaming front end with the telemetry plane attached")
    router = BatchRouter(N_SHARDS, engine="binomial")
    mgr = LifecycleManager(router, clock=clock.seconds_view())
    fe = StreamingFrontEnd(
        mgr,
        config=StreamConfig(max_batch=16, max_wait_us=1_000,
                            service_bound_us=1_000),
        clock=clock,
        service_model=lambda n: 800,
        metrics=metrics,
        tracer=trace,
    )
    rng = np.random.default_rng(42)
    served, shed = 0, 0
    for i in range(120):
        clock.advance_us(60 if i < 60 else 15)  # ramp up the arrival rate
        served += len(fe.pump())
        req = StreamRequest(
            key=int(rng.integers(0, 1 << 32)),
            deadline_us=clock.now_us() + 4_000,
            tenant=f"tenant-{i % 3}",
        )
        try:
            fe.submit(req)
        except AdmissionRejectedError:
            shed += 1
    for _ in range(8):
        clock.advance_us(1_000)
        served += len(fe.pump())
    served += len(fe.drain())
    lat = metrics.family("stream_request_latency_us")
    total_lat = sum(h.count for h in lat.values())
    print(f"  served {served}, shed {shed}; latency histogram holds "
          f"{total_lat} samples across {len(lat)} tenants")
    for name in ("admit", "batch_close", "dispatch", "request"):
        print(f"  spans[{name:>11}] = {trace.count(name)}")


def act_2_load_monitor(metrics):
    print("\nact 2: device-side load accumulator on the bulk router")
    router = BatchRouter(N_BULK_SHARDS, engine="binomial")
    alarms = []
    mon = LoadMonitor(
        router,
        metrics=metrics,
        # sample batches past 16k keys at 1/2^4 — small numbers so the
        # demo stays quick; production defaults are 32k and 1/64
        config=LoadConfig(drain_every=1 << 30, exact_cutoff=1 << 14,
                          sample_shift=4),
        on_alarm=alarms.append,
    )
    rng = np.random.default_rng(7)
    router.route_keys(rng.integers(0, 1 << 32, 4_096, np.uint32))   # exact
    router.route_keys(rng.integers(0, 1 << 32, 1 << 16, np.uint32))  # sampled
    window = mon.drain()
    ratio = mon.peak_over_mean()
    envelope = expected_peak_over_mean(mon.total_keys, N_BULK_SHARDS)
    print(f"  drained {int(window.sum())} key-units over {N_BULK_SHARDS} "
          f"shards (one exact batch, one 1/16-sampled batch)")
    print(f"  peak/mean {ratio:.3f} vs balls-into-bins envelope "
          f"{envelope:.3f} (alarm threshold {2.0 * envelope:.3f})")
    assert not alarms, "uniform traffic must not alarm"
    return router, mon, alarms


def act_3_alarms(router, mon, alarms):
    print("\nact 3: both theory-bound alarms, fired on demand")
    # disruption: score a rigged remap where EVERY probe moved after one
    # membership event — far past the delta/n bound
    probes = np.zeros(256, np.int32)
    moved = mon.tracker.observe(probes, probes + 1, delta_events=1,
                                n_before=16, n_after=16, epoch=99)
    bound = disruption_bound(1, 16, 16, slack=mon.config.disruption_slack)
    a = alarms[-1]
    print(f"  pathological remap: moved {moved:.2f} > bound {bound:.3f} "
          f"-> {type(a).__name__}")
    # balance: rig the host totals so one shard holds half the keys
    mon.totals[:] = 0
    mon.totals[0] = 50_000
    mon.totals[1:] = 50_000 // (N_BULK_SHARDS - 1)
    ratio = mon.peak_over_mean()
    mon._check_balance(ratio, mon._alive_slots())
    a = alarms[-1]
    print(f"  rigged skew: peak/mean {ratio:.1f} -> {type(a).__name__}")
    print(f"  ({a})")


def act_4_export(metrics, trace, mon):
    print("\nact 4: exports")
    prom = to_prometheus(metrics)
    lines = prom.splitlines()
    print(f"  Prometheus exposition: {len(lines)} lines; first five:")
    for line in lines[:5]:
        print(f"    {line}")
    snap = json.loads(to_json(metrics, trace=trace, monitor=mon))
    print(f"  JSON snapshot sections: {sorted(snap)}; "
          f"{len(snap['metrics'])} metric families")


def main() -> None:
    clock = VirtualClockUs()
    metrics = MetricsRegistry(clock=clock)
    trace = SpanTrace(capacity=1 << 12)
    act_1_streaming(clock, metrics, trace)
    router, mon, alarms = act_2_load_monitor(metrics)
    act_3_alarms(router, mon, alarms)
    act_4_export(metrics, trace, mon)


if __name__ == "__main__":
    main()
