"""Replicated placement walkthrough: fail -> degraded read -> repair ->
restored replication (DESIGN.md §13).

Run:  PYTHONPATH=src python examples/placement_demo.py

A 3-way replicated store over an 8-shard fleet: every key lives on three
distinct alive shards.  We kill two of one key's three holders, read it
degraded from the survivor, let the budgeted repairer re-materialise the
missing copies, and verify the journal replays to the same placement
bit-exactly.
"""
import numpy as np

from repro.placement.store import StorePlacement
from repro.serving.batch_router import BatchRouter
from repro.serving.lifecycle import (
    LifecycleConfig,
    LifecycleManager,
    PlacementRepairer,
)


def main() -> None:
    router = BatchRouter(8, engine="binomial")
    mgr = LifecycleManager(router, LifecycleConfig(min_alive_floor=1))
    store = StorePlacement(router, r=3)

    keys = np.random.default_rng(0).integers(
        0, 1 << 32, size=4096, dtype=np.uint32
    )
    batch = store.register(keys)
    print(f"registered {keys.size} keys on {mgr.n_alive} shards, "
          f"mode={batch.mode}, {batch.n_distinct} replicas each")

    repairer = PlacementRepairer(store, mgr, budget_per_tick=256)

    # -- failure: two of key 0's three holders die ---------------------------
    holders = store.holders[0].tolist()
    victims = holders[:2]
    print(f"\nkey 0 holders: {holders}; killing {victims}")
    for s in victims:
        mgr.fail(int(s))

    found, mode = store.read(0)
    print(f"degraded read of key 0: holders={found.tolist()}, mode={mode}")
    counts = store.reachable_counts()
    print(f"fleet-wide reachable replicas: min={counts.min()}, "
          f"mean={counts.mean():.2f} (no key at zero: {bool((counts >= 1).all())})")

    # -- repair: budgeted batches, oldest epoch first ------------------------
    print(f"\nrepair backlog: {repairer.backlog} under-replicated copies")
    ticks = 0
    while repairer.backlog:
        done = repairer.tick()
        ticks += 1
        print(f"  tick {ticks}: copied {len(done)} replicas "
              f"(backlog {repairer.backlog})")
    counts = store.reachable_counts()
    print(f"after repair: every key at {counts.min()}..{counts.max()} "
          f"distinct replicas (target min(r, n_alive) = "
          f"{min(store.r, mgr.n_alive)})")

    # -- recovery: the failed shards return ----------------------------------
    for s in victims:
        if s in router.domain.removed:
            mgr.recover(int(s))
    repairer.quiesce()
    found, mode = store.read(0)
    print(f"\nafter recovery + quiesce: key 0 holders={found.tolist()}, "
          f"mode={mode}")
    print(f"replication restored: "
          f"{bool((store.reachable_counts() == store.r).all())}")

    # -- crash safety: journal replay reproduces the placement ---------------
    repairer.verify_placement_replay()
    print("journal replay reproduces the live placement bit-exactly")


if __name__ == "__main__":
    main()
