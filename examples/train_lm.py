"""Example: train a ~100M-param LM for a few hundred steps with the full
substrate (CH-sharded data, checkpoint/auto-resume, cosine schedule).

    PYTHONPATH=src python examples/train_lm.py            # quick demo
    PYTHONPATH=src python examples/train_lm.py --steps 300 --preset 100m

This is a thin veneer over the production driver (repro.launch.train) so the
example exercises exactly the deployed code path.
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += [
            "--arch", "qwen2.5-14b", "--preset", "smoke", "--steps", "30",
            "--batch", "8", "--seq", "64", "--ckpt-dir", "/tmp/repro_example_ckpt",
        ]
    main()
