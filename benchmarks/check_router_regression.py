"""CI perf-regression guard for the routing datapath.

Compares a fresh ``bench_router`` run against the committed
``BENCH_router.json`` baseline and fails (exit 1) on a >30% regression of
the fused datapath:

* **storm-severity ratio** (always): ``speedup.fused_worst_severity_over_
  healthy`` — the worst fixed-removed-fraction batch time over the healthy
  batch time — must not regress more than the tolerance over the
  baseline's.  Both sides of the ratio are same-size batches with no
  event-handling in the timed region, so the check is scale-invariant: it
  works even when the current run is a ``--smoke`` (small-batch) run on a
  machine far slower than the one that produced the baseline.  This is the
  guard for the storm-proofing property itself.
* **event-storm ratio and absolute keys/s** (only when batch sizes match,
  i.e. full run vs full baseline): the end-to-end
  ``event_storm/steady`` ratio, plus fused steady and storm
  ``keys_per_sec``, must each stay within the tolerance of the baseline.
  The event-storm ratio carries a fixed per-event cost that only amortises
  at full batch size, and absolute throughput across different CI machines
  is meaningless — so a batch-size mismatch skips these with a note.
* **ingest speedup** (when both records carry the ``end_to_end`` section):
  the ``vectorized_over_host_loop`` sessions/s ratio — both tiers run the
  same batch on the same machine, so the ratio is machine-portable.  At
  matching session-batch sizes it must stay within the tolerance of the
  baseline's; at smoke sizes (where fixed dispatch overhead compresses the
  ratio) it must clear an absolute sanity floor instead — the vectorised
  ingest beating the host loop at all is the property being guarded.
* **engine comparison** (when both records carry the ``engines`` section):
  the section must still report at least two device engines (the
  ``BULK_ENGINES`` protocol is the point of it), every baseline engine must
  still be present, each engine's storm/steady ratio must stay within the
  tolerance of the baseline's (scale-invariant: both sides of the ratio
  share the batch and the machine), and — at matching batch sizes only —
  each engine's absolute steady keys/s must too.  On top of the relative
  drift check, EVERY engine's storm/steady ratio is held under a **hard
  1.25x cap** at full batch sizes (>= 1M keys, where per-dispatch overhead
  has amortised out): the committed baseline must satisfy it
  unconditionally, and any full-size current run must too — a storm batch
  through the divert path costs at most 25% over a healthy one.
* **chaos record** (``--chaos-current``, from ``bench_chaos``): zero
  invariant violations is a HARD gate (alive-only routing, minimal
  disruption, typed unavailability, journal replay parity, replica
  durability, repair convergence — a violation is a correctness bug, not a
  perf regression), overall availability has a floor, and flap scenarios
  must have produced recovery-latency samples.
* **serving record** (``--serving-current``, from ``bench_serving``): the
  streaming tier's SLO contract — >= 3 load points with one above
  capacity, ZERO shed at/below capacity, served p99 under the
  ``slo + max_wait`` invariant cap (no request misses its deadline by
  more than one batch window), and shed fraction monotone in offered
  load.  All hard gates: each is a correctness property of the admission
  /batching design, not a machine-speed number.
* **placement record** (``--placement-current``, from ``bench_placement``):
  every measured migration transition's moved-pair fraction must sit
  within the theoretical consistent-hashing bound (``within_bound`` — a
  breach means the R-way tier lost the paper's minimal-disruption
  property, a correctness bug), and both engines must report positive
  placement throughput.
* **observability record** (``--observability-current``, from
  ``bench_observability``): the instrumented fused route (the load
  monitor's per-shard bincount riding in the same dispatch) costs at most
  3% over the bare route at full batch sizes (>= 1M keys); at smoke sizes
  only a loose sanity cap applies, since fixed dispatch overhead sits in
  both sides of the ratio.

The CANONICAL records: full runs (run.py) write the tracked
``BENCH_router.json`` at the repo root; ``--smoke`` runs write the
gitignored ``benchmarks/out/BENCH_router_smoke.json`` — which are exactly
this tool's default ``--current`` and ``--baseline``.

Usage (the CI bench smoke step):

    PYTHONPATH=src python -m benchmarks.bench_router --smoke
    python benchmarks/check_router_regression.py \
        --current benchmarks/out/BENCH_router_smoke.json \
        --baseline BENCH_router.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _fused(payload: dict, stream: str, key: str) -> float:
    return float(payload[stream]["fused"][key])


def check(current: dict, baseline: dict, tolerance: float) -> list[str]:
    """Return a list of failure messages (empty = pass)."""
    failures: list[str] = []

    cur_sev = float(current["speedup"]["fused_worst_severity_over_healthy"])
    base_sev = float(baseline["speedup"]["fused_worst_severity_over_healthy"])
    limit = base_sev * (1 + tolerance)
    print(
        f"worst-severity/healthy ratio: current {cur_sev:.3f} vs baseline "
        f"{base_sev:.3f} (limit {limit:.3f})"
    )
    if cur_sev > limit:
        failures.append(
            f"fused storm-severity ratio regressed: {cur_sev:.3f} > "
            f"{base_sev:.3f} * (1 + {tolerance:.0%})"
        )

    if current.get("batch_keys") == baseline.get("batch_keys"):
        cur_ratio = _fused(current, "event_storm", "us_per_batch") / _fused(
            current, "steady", "us_per_batch"
        )
        base_ratio = _fused(baseline, "event_storm", "us_per_batch") / _fused(
            baseline, "steady", "us_per_batch"
        )
        print(
            f"event-storm/steady ratio: current {cur_ratio:.3f} vs baseline "
            f"{base_ratio:.3f} (limit {base_ratio * (1 + tolerance):.3f})"
        )
        if cur_ratio > base_ratio * (1 + tolerance):
            failures.append(
                f"fused event-storm/steady ratio regressed: {cur_ratio:.3f} > "
                f"{base_ratio:.3f} * (1 + {tolerance:.0%})"
            )
        for stream in ("steady", "event_storm"):
            cur = _fused(current, stream, "keys_per_sec")
            base = _fused(baseline, stream, "keys_per_sec")
            floor = base * (1 - tolerance)
            print(
                f"{stream} fused keys/s: current {cur:,.0f} vs baseline "
                f"{base:,.0f} (floor {floor:,.0f})"
            )
            if cur < floor:
                failures.append(
                    f"fused {stream} keys/s regressed: {cur:,.0f} < "
                    f"{base:,.0f} * (1 - {tolerance:.0%})"
                )
    else:
        print(
            f"batch sizes differ (current {current.get('batch_keys')} vs "
            f"baseline {baseline.get('batch_keys')}): event-storm and "
            "absolute keys/s checks skipped, the severity ratio above is "
            "the gate"
        )

    failures += _check_end_to_end(current, baseline, tolerance)
    failures += _check_engines(current, baseline, tolerance)
    return failures


#: smoke-size sanity floor for the vectorised-ingest speedup: at tiny
#: session batches fixed dispatch overhead compresses the ratio, so the
#: gate only insists the vectorised path still clearly beats the host loop
E2E_SMOKE_FLOOR = 2.0


def _check_end_to_end(current: dict, baseline: dict, tolerance: float) -> list[str]:
    if "end_to_end" not in baseline:
        print("baseline has no end_to_end section (pre-ingest record): skipped")
        return []
    if "end_to_end" not in current:
        return ["current run is missing the end_to_end ingest section"]
    cur, base = current["end_to_end"], baseline["end_to_end"]
    cur_spd = float(cur["speedup"]["vectorized_over_host_loop"])
    base_spd = float(base["speedup"]["vectorized_over_host_loop"])
    if cur.get("batch_sessions") == base.get("batch_sessions"):
        floor = base_spd * (1 - tolerance)
        print(
            f"ingest vectorized/host-loop speedup: current {cur_spd:.2f}x vs "
            f"baseline {base_spd:.2f}x (floor {floor:.2f}x)"
        )
        if cur_spd < floor:
            return [
                f"vectorized ingest speedup regressed: {cur_spd:.2f}x < "
                f"{base_spd:.2f}x * (1 - {tolerance:.0%})"
            ]
    else:
        print(
            f"ingest session-batch sizes differ (current "
            f"{cur.get('batch_sessions')} vs baseline {base.get('batch_sessions')}): "
            f"speedup {cur_spd:.2f}x gated on the {E2E_SMOKE_FLOOR:.1f}x sanity floor"
        )
        if cur_spd < E2E_SMOKE_FLOOR:
            return [
                f"vectorized ingest no longer beats the host loop: "
                f"{cur_spd:.2f}x < {E2E_SMOKE_FLOOR:.1f}x sanity floor"
            ]
    return []


#: hard ceiling on every engine's storm/steady batch-time ratio — the
#: constant-time divert's whole point.  Enforced at full batch sizes only
#: (>= CAP_MIN_BATCH keys): below that, fixed per-dispatch overhead sits in
#: both numerator and denominator and the ratio stops being a property of
#: the datapath
STORM_RATIO_CAP = 1.25
CAP_MIN_BATCH = 1 << 20


def _check_engines(current: dict, baseline: dict, tolerance: float) -> list[str]:
    if "engines" not in baseline:
        print("baseline has no engines section (pre-protocol record): skipped")
        return []
    if "engines" not in current:
        return ["current run is missing the engines comparison section"]
    cur, base = current["engines"], baseline["engines"]
    failures: list[str] = []
    if len(cur["per_engine"]) < 2:
        failures.append(
            f"engines section reports {len(cur['per_engine'])} device "
            "engine(s); the comparison needs at least 2"
        )
    missing = sorted(set(base["per_engine"]) - set(cur["per_engine"]))
    if missing:
        failures.append(f"device engines dropped from the comparison: {missing}")
    sizes_match = cur.get("batch_keys") == base.get("batch_keys")
    if not sizes_match:
        print(
            f"engines batch sizes differ (current {cur.get('batch_keys')} vs "
            f"baseline {base.get('batch_keys')}): per-engine keys/s floors "
            "skipped; the storm/steady ratios (both sides of each ratio share "
            "the batch and the machine, so they are scale-invariant) still gate"
        )
    for name in sorted(set(base["per_engine"]) & set(cur["per_engine"])):
        c, b = cur["per_engine"][name], base["per_engine"][name]
        if sizes_match:
            floor = float(b["steady"]["keys_per_sec"]) * (1 - tolerance)
            got = float(c["steady"]["keys_per_sec"])
            print(
                f"engine '{name}' steady keys/s: current {got:,.0f} vs baseline "
                f"{float(b['steady']['keys_per_sec']):,.0f} (floor {floor:,.0f})"
            )
            if got < floor:
                failures.append(
                    f"engine '{name}' steady keys/s regressed: {got:,.0f} < "
                    f"floor {floor:,.0f}"
                )
        ratio_limit = float(b["storm_over_steady"]) * (1 + tolerance)
        ratio = float(c["storm_over_steady"])
        print(
            f"engine '{name}' storm/steady ratio: current {ratio:.3f} vs "
            f"baseline {float(b['storm_over_steady']):.3f} (limit {ratio_limit:.3f})"
        )
        if ratio > ratio_limit:
            failures.append(
                f"engine '{name}' storm/steady ratio regressed: {ratio:.3f} > "
                f"{float(b['storm_over_steady']):.3f} * (1 + {tolerance:.0%})"
            )
        # the hard cap: the tracked baseline always answers for it, and so
        # does any full-size current run
        for label, record, r in (
            ("baseline", base, float(b["storm_over_steady"])),
            ("current", cur, ratio),
        ):
            if int(record.get("batch_keys") or 0) >= CAP_MIN_BATCH and r > STORM_RATIO_CAP:
                failures.append(
                    f"engine '{name}' {label} storm/steady ratio {r:.3f} "
                    f"breaks the hard {STORM_RATIO_CAP:.2f}x cap"
                )
    return failures


#: chaos-record gates: violations are correctness bugs (hard zero);
#: availability dips only because cascade scenarios drive the fleet through
#: a (typed, correct) n_alive == 0 — the floor catches anything worse
CHAOS_AVAILABILITY_FLOOR = 0.90


def check_chaos(chaos: dict) -> list[str]:
    failures: list[str] = []
    viol = int(chaos["invariant_violations"])
    avail = float(chaos["availability"])
    lat = chaos["recovery_latency_s"]
    print(
        f"chaos: {chaos['scenarios']} scenarios, {chaos['events']} events, "
        f"{viol} violation(s), availability {avail:.4f}, "
        f"recovery p50 {lat['p50']}s p99 {lat['p99']}s ({lat['samples']} samples)"
    )
    if viol:
        failures.append(
            f"chaos harness reports {viol} invariant violation(s): "
            + "; ".join(chaos.get("violation_samples", [])[:3])
        )
    if avail < CHAOS_AVAILABILITY_FLOOR:
        failures.append(
            f"chaos availability {avail:.4f} below the "
            f"{CHAOS_AVAILABILITY_FLOOR:.2f} floor"
        )
    if not lat["samples"]:
        failures.append(
            "chaos record has no recovery-latency samples (flap scenarios "
            "never re-admitted a failed replica)"
        )
    return failures


def check_placement(plc: dict) -> list[str]:
    failures: list[str] = []
    transitions = plc.get("transitions", [])
    print(
        f"placement: r={plc.get('r')} n_keys={plc.get('n_keys')}, "
        f"{len(transitions)} transition(s), "
        f"all_within_bound={plc.get('all_within_bound')}"
    )
    for t in transitions:
        if not t.get("within_bound"):
            failures.append(
                f"placement migration {t['engine']}/{t['label']} moved "
                f"fraction {t['moved_fraction']:.4f} breaches the "
                f"theoretical bound {t['bound']:.4f}"
            )
    if not transitions:
        failures.append("placement record has no migration transitions")
    for engine, thr in plc.get("throughput", {}).items():
        if thr.get("keys_per_s", 0) <= 0:
            failures.append(
                f"placement throughput for {engine} is not positive"
            )
    return failures


#: tiny slack on the "monotone shed" comparison: two below-capacity points
#: both at exactly 0 must not fail on float fuzz
SHED_MONOTONE_TOL = 1e-9


def check_serving(serv: dict) -> list[str]:
    """Gate a ``bench_serving`` record: the streaming tier's SLO contract.

    * every engine reports >= 3 load points with >= 1 above capacity;
    * shed fraction is exactly 0 at every point at or below capacity;
    * p99 served latency respects the invariant cap ``slo + max_wait`` (an
      admitted-and-served request misses its deadline by at most one batch
      window — breaking this is a correctness bug, not a perf regression);
    * shed fraction is monotone non-decreasing in offered load.
    """
    failures: list[str] = []
    window = int(serv["max_wait_us"])
    for engine, rec in serv.get("per_engine", {}).items():
        points = rec.get("points", [])
        slo = int(rec["slo_us"])
        cap = slo + window
        print(
            f"serving[{engine}]: {len(points)} load points, slo {slo}us, "
            f"p99 cap {cap}us, "
            + "; ".join(
                f"x{p['load_mult']:g}: shed {p['shed_fraction']:.3f} "
                f"p99 {p['p99_us'] or 0:.0f}us"
                for p in points
            )
        )
        if len(points) < 3:
            failures.append(
                f"serving[{engine}] has {len(points)} load points; need >= 3"
            )
        if not any(p["above_capacity"] for p in points):
            failures.append(
                f"serving[{engine}] has no above-capacity load point"
            )
        prev_shed = 0.0
        for p in sorted(points, key=lambda q: q["offered_rps"]):
            tag = f"serving[{engine}] x{p['load_mult']:g}"
            if not p["above_capacity"] and p["shed_fraction"] > 0:
                failures.append(
                    f"{tag} sheds {p['shed_fraction']:.4f} at/below capacity"
                )
            if p["p99_us"] is not None and p["p99_us"] > cap:
                failures.append(
                    f"{tag} p99 {p['p99_us']:.0f}us breaks the slo+window "
                    f"cap {cap}us (deadline-miss bound violated)"
                )
            if p["deadline_miss_max_us"] > window:
                failures.append(
                    f"{tag} served a request {p['deadline_miss_max_us']}us "
                    f"past deadline (> one {window}us batch window)"
                )
            if p["shed_fraction"] + SHED_MONOTONE_TOL < prev_shed:
                failures.append(
                    f"{tag} shed fraction {p['shed_fraction']:.4f} below the "
                    f"previous (lower) load's {prev_shed:.4f}: not monotone"
                )
            prev_shed = max(prev_shed, p["shed_fraction"])
    if not serv.get("per_engine"):
        failures.append("serving record has no per_engine section")
    return failures


#: hard cap on the instrumented/bare fused-route overhead at full batch
#: sizes (>= OBS_CAP_MIN_BATCH keys, where per-dispatch overhead has
#: amortised out): the load bincount rides inside the same fused dispatch,
#: so telemetry may cost at most 3%.  At smoke sizes fixed dispatch
#: overhead dominates both sides, so only a loose sanity cap applies.
OBS_OVERHEAD_CAP = 1.03
OBS_SMOKE_OVERHEAD_CAP = 1.50
OBS_CAP_MIN_BATCH = 1 << 20


def check_observability(obs: dict) -> list[str]:
    """Gate a ``bench_observability`` record: instrumented-route overhead."""
    failures: list[str] = []
    batch = int(obs.get("batch_keys") or 0)
    full = batch >= OBS_CAP_MIN_BATCH
    cap = OBS_OVERHEAD_CAP if full else OBS_SMOKE_OVERHEAD_CAP
    per_engine = obs.get("per_engine", {})
    if not per_engine:
        return ["observability record has no per_engine section"]
    for engine, rec in sorted(per_engine.items()):
        ratio = float(rec["overhead_ratio"])
        print(
            f"observability[{engine}]: instrumented/bare ratio {ratio:.4f} "
            f"at {batch} keys (cap {cap:.2f}"
            + ("" if full else ", smoke sanity cap")
            + ")"
        )
        if ratio > cap:
            failures.append(
                f"observability[{engine}] instrumented route overhead "
                f"{ratio:.4f} breaks the {cap:.2f}x cap at {batch} keys"
            )
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="benchmarks/out/BENCH_router_smoke.json")
    ap.add_argument("--baseline", default="BENCH_router.json")
    ap.add_argument("--tolerance", type=float, default=0.30)
    ap.add_argument(
        "--chaos-current", default=None,
        help="bench_chaos record to gate (e.g. benchmarks/out/"
             "BENCH_chaos_smoke.json in CI, BENCH_chaos.json for full runs)",
    )
    ap.add_argument(
        "--placement-current", default=None,
        help="bench_placement record to gate (e.g. benchmarks/out/"
             "BENCH_placement_smoke.json in CI, BENCH_placement.json for "
             "full runs)",
    )
    ap.add_argument(
        "--serving-current", default=None,
        help="bench_serving record to gate (e.g. benchmarks/out/"
             "BENCH_serving_smoke.json in CI, BENCH_serving.json for "
             "full runs)",
    )
    ap.add_argument(
        "--observability-current", default=None,
        help="bench_observability record to gate (e.g. benchmarks/out/"
             "BENCH_observability_smoke.json in CI, "
             "BENCH_observability.json for full runs)",
    )
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures = check(current, baseline, args.tolerance)
    if args.chaos_current:
        with open(args.chaos_current) as f:
            failures += check_chaos(json.load(f))
    if args.placement_current:
        with open(args.placement_current) as f:
            failures += check_placement(json.load(f))
    if args.serving_current:
        with open(args.serving_current) as f:
            failures += check_serving(json.load(f))
    if args.observability_current:
        with open(args.observability_current) as f:
            failures += check_observability(json.load(f))
    if failures:
        for msg in failures:
            print(f"REGRESSION: {msg}", file=sys.stderr)
        return 1
    print("router perf within tolerance of the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
