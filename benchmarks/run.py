"""Benchmark aggregator — one bench per paper table/figure + framework-level
benches. Prints ``name,us_per_call,derived`` CSV rows; per-bench CSVs land in
benchmarks/out/.

Full-size runs through here write the CANONICAL tracked perf records
(``BENCH_<name>.json`` at the repo root, e.g. the router bench's record the
CI regression guard compares against); smoke runs write distinct
``benchmarks/out/BENCH_<name>_smoke.json`` files instead — one name, one
place each."""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        bench_balance,
        bench_disruption,
        bench_elastic,
        bench_kernel,
        bench_lookup,
        bench_moe_routing,
        bench_observability,
        bench_placement,
        bench_roofline,
        bench_router,
        bench_serving,
        bench_theory,
    )

    benches = [
        ("lookup (paper Fig. 5)", bench_lookup),
        ("balance (paper Figs. 6-8)", bench_balance),
        ("disruption (paper §5.2/5.3)", bench_disruption),
        ("theory (paper §5.4 Eqs. 1/3/5/6)", bench_theory),
        ("kernel (bulk lookup)", bench_kernel),
        ("moe routing (hash vs topk)", bench_moe_routing),
        ("session routing (scalar vs batched)", bench_router),
        ("elastic placement", bench_elastic),
        ("replicated store placement (R-way tier)", bench_placement),
        ("streaming serving tier (micro-batch + admission)", bench_serving),
        ("observability tier (instrumented route overhead)", bench_observability),
        ("roofline table (from dry-run)", bench_roofline),
    ]
    failures = 0
    for title, mod in benches:
        print(f"# === {title} ===", flush=True)
        t0 = time.time()
        try:
            mod.main()
        except Exception:
            traceback.print_exc()
            failures += 1
        print(f"# --- done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
