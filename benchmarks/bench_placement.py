"""Placement-tier benchmark: R-way placement throughput, migration-plan
rate, and moved-pairs-vs-theory (DESIGN.md §13).

Three measurements, both fused engines:

* **placement throughput** — keys/s through ``route_replicas_bulk`` (the
  one-pass R-way distinct placement) on a healthy fleet;
* **migration plan rate** — keys/s through ``StorePlacement.
  plan_migration`` (old AND new placement of every registered key plus the
  membership-based transfer mask, ONE device pass);
* **moved fraction vs theory** — for a grid of membership transitions
  (single/multi scale-up, single/mass failure), the measured moved-PAIR
  fraction of the migration plan must stay within the consistent-hashing
  bound.  Per replica column the paper/JumpHash bound is ``delta / n``
  keys moved; the R-way tier adds re-salt collision churn (a key whose
  later column collided re-resolves when the alive set changes), bounded
  by ``(R-1) / min(n0, n1)``.  The gate is
  ``SLACK * (delta / max(n0, n1) + (R-1) / min(n0, n1))`` — loose enough
  for hash noise, far below the ~1.0 of a full reshuffle.

Full runs write the tracked ``BENCH_placement.json`` at the repo root;
``--smoke`` (CI) writes ``benchmarks/out/BENCH_placement_smoke.json`` —
the two-name discipline of the router bench.  ``check_router_regression.py
--placement-current`` gates ``within_bound`` (hard) on either record.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import emit, rows_to_csv, time_loop, write_bench_json

ENGINES = ("binomial", "jump")
R = 3
SLACK = 1.5

N_FULL = 1 << 20
N_SMOKE = 1 << 14

#: (label, n0, capacity, events) — events drive a journaled
#: LifecycleManager; the moved fraction is measured on the registered
#: keys' migration plan across the whole event group
TRANSITIONS = (
    ("scale_up_1", 64, 128, (("scale_up", None),)),
    ("scale_up_8", 64, 128, tuple(("scale_up", None) for _ in range(8))),
    ("fail_1", 64, 64, (("fail", 13),)),
    ("fail_4", 64, 64, (("fail", 3), ("fail", 17), ("fail", 29), ("fail", 41))),
    ("scale_up_small", 16, 32, (("scale_up", None),)),
    ("fail_small", 8, 8, (("fail", 2), ("fail", 5))),
)


def movement_bound(n0: int, n1: int, r: int) -> float:
    """SLACK * (per-column minimal-disruption bound + re-salt churn)."""
    delta = abs(n1 - n0)
    return SLACK * (delta / max(n0, n1) + (r - 1) / min(n0, n1))


def _store(engine: str, n: int, capacity: int, keys: np.ndarray):
    from repro.placement.store import StorePlacement
    from repro.serving.batch_router import BatchRouter
    from repro.serving.lifecycle import LifecycleConfig, LifecycleManager

    router = BatchRouter(n, engine=engine, capacity=capacity)
    mgr = LifecycleManager(router, LifecycleConfig(min_alive_floor=1))
    store = StorePlacement(router, r=R)
    store.register(keys)
    return router, mgr, store


def measure_throughput(engine: str, n_keys: int, iters: int) -> dict:
    import jax

    from repro.kernels import ops

    keys = np.random.default_rng(7).integers(
        0, 1 << 32, size=n_keys, dtype=np.uint32
    )
    router, _mgr, store = _store(engine, 64, 64, keys[:1])
    fleet = store._fleet_dev()
    ku = router._coerce_keys(keys)

    def call():
        jax.block_until_ready(ops.route_replicas_bulk(ku, fleet, store.spec))

    call()  # compile
    us = time_loop(call, iters)
    out = {"us_per_call": us, "keys_per_s": n_keys / (us * 1e-6)}
    emit(f"placement/route_replicas/{engine}", us,
         f"n={n_keys};r={R};keys_per_s={out['keys_per_s']:.3e}")
    return out


def measure_transition(engine: str, label: str, n0: int, capacity: int,
                       events, n_keys: int, iters: int) -> dict:
    keys = np.random.default_rng(11).integers(
        0, 1 << 32, size=n_keys, dtype=np.uint32
    )
    _router, mgr, store = _store(engine, n0, capacity, keys)
    for kind, slot in events:
        if kind == "scale_up":
            mgr.scale_up()
        else:
            mgr.fail(slot)
    plan = store.plan_migration()  # compile + the measured artifact
    us = time_loop(lambda: store.plan_migration(), iters)
    n1 = mgr.n_alive
    bound = movement_bound(n0, n1, R)
    frac = plan.moved_fraction
    row = {
        "engine": engine,
        "label": label,
        "n0": n0,
        "n1": n1,
        "moved_pairs": plan.moved_pairs,
        "total_pairs": plan.total_pairs,
        "moved_fraction": frac,
        "bound": bound,
        "within_bound": bool(frac <= bound),
        "plan_us_per_call": us,
        "plan_keys_per_s": n_keys / (us * 1e-6),
    }
    emit(f"placement/migrate/{engine}/{label}", us,
         f"moved={frac:.4f};bound={bound:.4f};within={row['within_bound']}")
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="reduced key count for CI; writes the gitignored smoke record",
    )
    ap.add_argument("--keys", type=int, default=None,
                    help="override keys per measurement")
    args = ap.parse_args(argv)
    n_keys = args.keys or (N_SMOKE if args.smoke else N_FULL)
    iters = 3 if not args.smoke else 2

    throughput = {e: measure_throughput(e, n_keys, iters) for e in ENGINES}
    transitions = [
        measure_transition(e, label, n0, cap, events, n_keys, iters)
        for e in ENGINES
        for (label, n0, cap, events) in TRANSITIONS
    ]
    all_within = all(t["within_bound"] for t in transitions)

    payload = {
        "bench": "placement",
        "schema": 1,
        "smoke": args.smoke,
        "r": R,
        "slack": SLACK,
        "n_keys": n_keys,
        "engines": list(ENGINES),
        "throughput": throughput,
        "transitions": transitions,
        "all_within_bound": all_within,
    }
    path = write_bench_json("placement", payload, tracked=not args.smoke)
    print(f"wrote {path}")
    rows = [
        [t["engine"], t["label"], t["n0"], t["n1"],
         f"{t['moved_fraction']:.4f}", f"{t['bound']:.4f}",
         t["within_bound"]]
        for t in transitions
    ]
    rows_to_csv("bench_placement",
                ["engine", "label", "n0", "n1", "moved_frac", "bound",
                 "within"], rows)
    if not all_within:
        print("MOVED FRACTION OUT OF BOUND", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
