"""Bulk-lookup kernel benchmark: vectorised u32 JAX path vs scalar python,
Pallas-interpret correctness, and the kernel's analytic TPU roofline.

Wall-clock Pallas timing on CPU interpret mode is meaningless; the TPU story
is the analytic roofline: ~8 bytes/key HBM traffic (u32 in, i32 out) vs
~obs_int_ops integer VPU ops/key — the kernel is firmly memory-bound on
v5e, so the right metric is fraction of HBM bandwidth."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, rows_to_csv, time_loop
from repro.core.binomial import binomial_lookup32
from repro.core.binomial_jax import binomial_lookup_vec
from repro.kernels.binomial_hash import binomial_bulk_lookup_pallas
from repro.kernels.ref import binomial_bulk_lookup_ref
from repro.roofline import hw


def main() -> list[list]:
    rows = []
    rng = np.random.default_rng(0)
    kv = rng.integers(0, 2**32, size=(1 << 18,), dtype=np.uint32)

    # scalar python baseline
    it = iter(range(10**9))
    us_scalar = time_loop(lambda: binomial_lookup32(int(kv[next(it) % len(kv)]), 1000), 3000)
    emit("kernel/scalar-py/n=1000", us_scalar, "per_key")

    # vectorised u32 (the ref / CPU path)
    for n in (16, 1000, 100_000):
        f = lambda n=n: binomial_lookup_vec(kv, n, omega=16).block_until_ready()
        us = time_loop(f, 10)
        kps = len(kv) / (us * 1e-6)
        rows.append(["vec-u32", n, round(us, 1), f"{kps:.3e}"])
        emit(f"kernel/vec-u32/n={n}", us, f"{kps:.3e}_keys_per_s")

    # pallas interpret: correctness at benchmark scale
    out = binomial_bulk_lookup_pallas(kv[: 1 << 16], 1000, interpret=True)
    ref = binomial_bulk_lookup_ref(kv[: 1 << 16], 1000)
    ok = bool((np.asarray(out) == np.asarray(ref)).all())
    emit("kernel/pallas-interpret/n=1000", 0.0, f"matches_ref={ok}")
    assert ok

    # analytic TPU roofline for the kernel (per key, omega=16)
    bytes_per_key = 8.0  # u32 in + i32 out
    int_ops_per_key = 16 * 40 + 60  # ~40 VPU int ops per unrolled iter + fold
    t_mem = bytes_per_key / hw.HBM_BW
    t_cmp = int_ops_per_key / hw.PEAK_FLOPS_BF16  # VPU int throughput ~ flops peak proxy
    bound = "memory" if t_mem > t_cmp else "compute"
    keys_per_s_roof = 1.0 / max(t_mem, t_cmp)
    rows.append(["pallas-roofline", 0, 0, f"{keys_per_s_roof:.3e}"])
    emit(
        "kernel/pallas-tpu-roofline", 0.0,
        f"bound={bound};roof={keys_per_s_roof:.3e}_keys_per_s_per_chip",
    )
    rows_to_csv("bench_kernel", ["impl", "n", "us_per_call", "keys_per_s"], rows)
    return rows


if __name__ == "__main__":
    main()
