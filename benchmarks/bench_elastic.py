"""Framework-level elasticity: data-shard / expert / checkpoint movement on
fleet resizes and failure storms (the system-level face of the paper)."""
from __future__ import annotations

from benchmarks.common import emit, rows_to_csv
from repro.placement.assignment import Assignment
from repro.placement.elastic import FailureDomain, plan_expert_migration


def main() -> list[list]:
    rows = []
    # data-shard reassignment across fleet transitions
    for old, new in ((64, 65), (64, 80), (256, 512), (512, 256), (256, 255)):
        a = Assignment(list(range(8192)), old)
        plan = a.resize(new)
        ideal = abs(new - old) / max(new, old)
        rows.append(["shards", old, new, round(plan.moved_fraction, 4), round(ideal, 4)])
        emit(
            f"elastic/shards/{old}->{new}", 0.0,
            f"moved={plan.moved_fraction:.4f};ideal~{ideal:.4f}",
        )
    # expert migration for EP-group rescales
    for old, new in ((8, 16), (16, 24), (16, 12)):
        m = plan_expert_migration(256, old, new)
        rows.append(["experts", old, new, round(m.plan.moved_fraction, 4), ""])
        emit(f"elastic/experts/{old}->{new}", 0.0, f"moved={m.plan.moved_fraction:.4f}")
    # failure storm: kill 10% of a 100-node serving fleet one by one
    fd = FailureDomain(100)
    keys = list(range(20000))
    base = {k: fd.locate(k) for k in keys}
    cumulative_moved = set()
    for victim in range(0, 10):
        before = {k: fd.locate(k) for k in keys}
        fd.fail(victim)
        moved = {k for k in keys if fd.locate(k) != before[k]}
        assert all(before[k] == victim for k in moved), "only victim's keys move"
        cumulative_moved |= moved
    frac = len(cumulative_moved) / len(keys)
    rows.append(["failure-storm", 100, 90, round(frac, 4), "0.10"])
    emit("elastic/failure-storm/100->90", 0.0, f"cumulative_moved={frac:.4f};ideal~0.10")
    rows_to_csv("bench_elastic", ["kind", "old", "new", "moved_frac", "ideal"], rows)
    return rows


if __name__ == "__main__":
    main()
