"""Paper Fig. 5 analogue: lookup time vs cluster size, per algorithm.

Scalar host-side ns/lookup for every constant-time engine (the paper's
comparison set), plus the vectorised device-path throughput (keys/s) of the
u32 BinomialHash.  Absolute numbers are CPython, not Java — the paper-
relevant signal is the SHAPE (flat in n) and the integer-vs-float ordering.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, keyset, rows_to_csv, time_loop
from repro.core import make
from repro.core.binomial_jax import binomial_lookup_vec

ENGINES = ["binomial", "jump", "fliphash-recon", "powerch-recon", "jumpback-recon", "anchor-lifo", "dx-lifo"]
SIZES = [10, 100, 1000, 10_000, 100_000]


def main() -> list[list]:
    keys = keyset(2000)
    rows = []
    for name in ENGINES:
        for n in SIZES:
            eng = make(name, n)
            it = iter(range(10**9))

            def call(eng=eng, keys=keys, it=it):
                k = keys[next(it) % len(keys)]
                eng.get_bucket(k)

            us = time_loop(call, iters=2000)
            rows.append([name, n, round(us * 1000, 1)])  # ns per lookup
            emit(f"lookup/{name}/n={n}", us, "ns_scalar_lookup")

    # vectorised u32 path (the MoE-router datapath)
    kv = np.random.default_rng(0).integers(0, 2**32, size=(1 << 16,), dtype=np.uint32)
    for n in SIZES:
        f = lambda kv=kv, n=n: binomial_lookup_vec(kv, n, omega=16).block_until_ready()
        us = time_loop(f, iters=20)
        keys_per_s = (1 << 16) / (us * 1e-6)
        rows.append(["binomial-vec-u32", n, round(us, 1)])
        emit(f"lookup-vec/binomial/n={n}", us, f"{keys_per_s:.3e}_keys_per_s")
    rows_to_csv("bench_lookup", ["engine", "n", "ns_or_us"], rows)
    return rows


if __name__ == "__main__":
    main()
