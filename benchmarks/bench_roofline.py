"""Render the §Roofline table from the dry-run artifact (benchmarks/out/dryrun.json).

Requires ``python -m repro.launch.dryrun`` to have been run (any subset);
skips gracefully otherwise.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import OUT_DIR, emit, rows_to_csv

DRYRUN_JSON = os.path.join(OUT_DIR, "dryrun.json")


def main() -> list[list]:
    if not os.path.exists(DRYRUN_JSON):
        emit("roofline/missing", 0.0, "run repro.launch.dryrun first")
        return []
    with open(DRYRUN_JSON) as f:
        results = json.load(f)
    rows = []
    for key in sorted(results):
        r = results[key]
        if r.get("status") != "ok":
            if r.get("status") == "skipped":
                arch, shape, mesh = key.split("|")
                rows.append([arch, shape, mesh, "skipped", "", "", "", "", "", ""])
            continue
        roof = r["roofline"]
        rows.append(
            [
                r["arch"], r["shape"], r["mesh"], roof["dominant"],
                f"{roof['compute_s']:.4f}", f"{roof['memory_s']:.4f}",
                f"{roof['collective_s']:.4f}", f"{roof['useful_ratio']:.3f}",
                f"{roof['flops']:.3e}", f"{roof['coll_bytes']:.3e}",
            ]
        )
        emit(
            f"roofline/{key}", 0.0,
            f"dominant={roof['dominant']};compute_s={roof['compute_s']:.4f};"
            f"memory_s={roof['memory_s']:.4f};coll_s={roof['collective_s']:.4f};"
            f"useful={roof['useful_ratio']:.3f}",
        )
    rows_to_csv(
        "bench_roofline",
        ["arch", "shape", "mesh", "dominant", "compute_s", "memory_s", "collective_s",
         "useful_ratio", "flops_per_dev", "coll_bytes_per_dev"],
        rows,
    )
    return rows


if __name__ == "__main__":
    main()
