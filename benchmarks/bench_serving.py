"""Streaming serving-tier benchmark: open-loop zipf multi-tenant load
through the micro-batching front end (DESIGN.md §14).

A zipf-skewed multi-tenant open-loop generator offers requests at FIXED
loads (fractions of the declared capacity) to a ``StreamingFrontEnd``
whose dispatch is the REAL lifecycle-wrapped fused router on the actual
device.  Timeline discipline:

* every closed batch routes through ``LifecycleDispatch`` and the
  materialisation block is wall-measured — the bench's service times are
  real device dispatch times, not a synthetic model;
* those measured times are replayed onto a ``VirtualClockUs`` timeline
  (clamped to the declared ``service_bound_us``, clamp count reported),
  so arrivals, batching windows, deadlines and shedding are exactly
  reproducible while the datapath cost is measured, not assumed.

Per engine the bench first CALIBRATES: it times real max-batch dispatches
and declares ``service_bound_us`` (the SLO capacity statement) as a
margin over the observed p95.  Declared capacity is then
``max_batch / service_bound_us`` requests/s and the load grid is fixed
multipliers of it — at least one point above capacity, per the record's
contract.  Each point reports p50/p99 served latency, goodput
(in-SLO served requests/s of virtual makespan), and the shed fraction.

Invariants the record must witness (gated by
``check_router_regression.py --serving-current``):

* shed fraction is 0 at every point at or below capacity;
* p99 served latency never exceeds ``slo_us + max_wait_us`` — an
  admitted-and-served request misses its deadline by at most one batch
  window (the streaming tier's core guarantee);
* shed fraction is monotone non-decreasing in offered load.

Full runs write the tracked ``BENCH_serving.json`` at the repo root;
``--smoke`` (CI) writes ``benchmarks/out/BENCH_serving_smoke.json``.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import emit, rows_to_csv, write_bench_json

ENGINES = ("binomial", "jump")

N_SLOTS = 16
MAX_BATCH = 64
MAX_WAIT_US = 1_000
#: declared bound = BOUND_MARGIN x calibrated p95 (an SLO statement with
#: headroom for dispatch jitter, not a best-case measurement)
BOUND_MARGIN = 2.0
#: per-request SLO, in declared service bounds
SLO_BOUNDS = 4
#: below-capacity offered loads, as multiples of DECLARED capacity
LOAD_MULTS_BELOW = (0.5, 0.9)
#: the overload point is anchored on MEASURED capacity (max_batch / p50):
#: declared capacity is a deliberately padded SLO statement, so a fixed
#: multiple of it can still sit inside what the device actually sustains —
#: the overload point must exceed the real datapath, not the declaration
OVERLOAD_X_MEASURED = 2.0
#: the overload point's arrival span, in SLO horizons (slo + one window):
#: shedding only starts once the backlog outgrows the horizon, so the run
#: must cover several of them to reach the shedding steady state
OVERLOAD_SPAN_HORIZONS = 8

N_TENANTS = 8
ZIPF_S = 1.1
KEYSPACE_PER_TENANT = 1 << 14

N_REQ_FULL = 3_000
N_REQ_SMOKE = 400
CAL_FULL = 40
CAL_SMOKE = 12


class _MeasuredDispatch:
    """Real fused dispatch, wall-measured.

    Each closed batch goes through the lifecycle-wrapped router on the
    device and is materialised HERE, inside the dispatch call, so the
    measured block is the true device cost.  The measurement (clamped to
    the declared bound so the deadline guarantee stays well-defined)
    becomes that dispatch's service time on the virtual timeline via the
    ``service_model`` hook.
    """

    def __init__(self, mgr, bound_us: int):
        from repro.serving.streaming import LifecycleDispatch

        self._inner = LifecycleDispatch(mgr)
        self.bound_us = int(bound_us)
        self.samples_us: list[int] = []
        self.clamped = 0
        self.last_us = 1

    def __call__(self, keys_u32):
        # pad to the fixed dispatch shape: micro-batches close at varying
        # sizes, and every new shape would recompile the fused route —
        # fixed-shape dispatch is the serving norm and keeps the measured
        # block a datapath cost, not an XLA compile
        n = len(keys_u32)
        padded = np.zeros(MAX_BATCH, dtype=np.uint32)
        padded[:n] = keys_u32
        t0 = time.perf_counter_ns()
        replicas, epoch, mode = self._inner(padded).result()
        payload = (replicas[:n], epoch, mode)
        us = max(1, (time.perf_counter_ns() - t0) // 1_000)
        self.samples_us.append(int(us))
        if us > self.bound_us:
            self.clamped += 1
            us = self.bound_us
        self.last_us = int(us)
        return _Done(payload)

    def service_model(self, _n: int) -> int:
        return self.last_us


class _Done:
    def __init__(self, payload):
        self._payload = payload

    def result(self):
        return self._payload


def _fresh_stack(engine: str):
    from repro.serving.batch_router import BatchRouter
    from repro.serving.lifecycle import LifecycleManager

    router = BatchRouter(N_SLOTS, engine=engine, capacity=N_SLOTS * 2)
    return LifecycleManager(router)


def calibrate(engine: str, n_dispatches: int) -> dict:
    """Time real max-batch dispatches; declare the service bound off p95."""
    mgr = _fresh_stack(engine)
    dispatch = _MeasuredDispatch(mgr, bound_us=1 << 30)  # no clamp here
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 1 << 32, size=MAX_BATCH, dtype=np.uint32)
    dispatch(keys)  # compile
    dispatch.samples_us.clear()
    for _ in range(n_dispatches):
        dispatch(keys)
    s = np.asarray(dispatch.samples_us, dtype=np.float64)
    bound_us = int(np.ceil(np.percentile(s, 95) * BOUND_MARGIN))
    out = {
        "dispatches": int(n_dispatches),
        "p50_us": float(np.percentile(s, 50)),
        "p95_us": float(np.percentile(s, 95)),
        "p99_us": float(np.percentile(s, 99)),
        "service_bound_us": bound_us,
        "capacity_rps": MAX_BATCH / (bound_us * 1e-6),
        "measured_capacity_rps": float(MAX_BATCH / (np.percentile(s, 50) * 1e-6)),
    }
    emit(f"serving/calibrate/{engine}", out["p50_us"],
         f"bound_us={bound_us};capacity_rps={out['capacity_rps']:.0f}")
    return out


def _tenant_weights() -> np.ndarray:
    w = 1.0 / np.arange(1, N_TENANTS + 1, dtype=np.float64) ** ZIPF_S
    return w / w.sum()


def _gen_requests(rng: np.random.Generator, n: int, gap_us: float,
                  slo_us: int):
    """Open-loop arrival plan: (arrival_us, tenant, key, deadline_us)."""
    tenants = rng.choice(N_TENANTS, size=n, p=_tenant_weights())
    # zipf-skewed per-tenant key popularity, mixed into a uint32 keyspace
    ranks = np.minimum(rng.zipf(1.2, size=n), KEYSPACE_PER_TENANT - 1)
    keys = (
        ((tenants.astype(np.uint64) << np.uint64(20)) ^ ranks.astype(np.uint64))
        * np.uint64(2654435761)
    ) & np.uint64(0xFFFFFFFF)
    # open loop: the generator never waits for responses; jittered gaps
    gaps = gap_us * rng.uniform(0.5, 1.5, size=n)
    arrivals = np.cumsum(gaps).astype(np.int64)
    return [
        (int(arrivals[i]), f"tenant-{int(tenants[i])}", int(keys[i]),
         int(arrivals[i]) + slo_us)
        for i in range(n)
    ]


def run_point(engine: str, offered_rps: float, bound_us: int, n_req: int,
              seed: int) -> dict:
    from repro.serving.lifecycle import SHED_LATE, AdmissionRejectedError
    from repro.serving.streaming import (
        StreamConfig,
        StreamingFrontEnd,
        StreamRequest,
        VirtualClockUs,
    )

    capacity_rps = MAX_BATCH / (bound_us * 1e-6)
    offered_rps = float(offered_rps)
    mult = offered_rps / capacity_rps
    gap_us = 1e6 / offered_rps
    slo_us = SLO_BOUNDS * bound_us
    if mult > 1.0:
        horizon_us = slo_us + MAX_WAIT_US
        span_floor = int(offered_rps * 1e-6 * OVERLOAD_SPAN_HORIZONS * horizon_us)
        n_req = max(n_req, span_floor)

    mgr = _fresh_stack(engine)
    clock = VirtualClockUs()
    dispatch = _MeasuredDispatch(mgr, bound_us)
    cfg = StreamConfig(
        max_batch=MAX_BATCH,
        max_wait_us=MAX_WAIT_US,
        service_bound_us=bound_us,
        tenant_rate_per_s=None,
    )
    fe = StreamingFrontEnd(
        mgr,
        config=cfg,
        clock=clock,
        dispatch_fn=dispatch,
        service_model=dispatch.service_model,
    )
    # warm the compile cache outside the measured timeline
    dispatch(np.zeros(MAX_BATCH, dtype=np.uint32))
    dispatch.samples_us.clear()
    dispatch.clamped = 0

    rng = np.random.default_rng(seed)
    plan = _gen_requests(rng, n_req, gap_us, slo_us)
    served = []
    shed = 0
    for arrival_us, tenant, key, deadline_us in plan:
        clock.advance_us(arrival_us - clock.now_us())
        served.extend(fe.pump())
        try:
            fe.submit(StreamRequest(key=key, deadline_us=deadline_us,
                                    tenant=tenant))
        except AdmissionRejectedError:
            shed += 1
    # let the pipeline run dry on the virtual timeline
    for _ in range(4 * SLO_BOUNDS):
        clock.advance_us(bound_us)
        served.extend(fe.pump())
    served.extend(fe.drain())
    shed += fe.admission.shed_by_reason.get(SHED_LATE, 0)

    assert len(served) + shed == n_req, (len(served), shed, n_req)
    lat = np.asarray([r.latency_us for r in served], dtype=np.float64)
    miss = np.asarray([r.deadline_miss_us for r in served], dtype=np.int64)
    makespan_s = max(r.t_complete_us for r in served) * 1e-6 if served else 0.0
    in_slo = int((miss == 0).sum())
    stats = fe.stats()
    row = {
        "load_mult": round(mult, 4),
        "offered_rps": offered_rps,
        "above_capacity": bool(mult > 1.0),
        "n_offered": n_req,
        "served": len(served),
        "shed": shed,
        "shed_fraction": shed / n_req,
        "shed_by_reason": dict(fe.admission.shed_by_reason),
        "p50_us": float(np.percentile(lat, 50)) if served else None,
        "p99_us": float(np.percentile(lat, 99)) if served else None,
        "deadline_miss_max_us": int(miss.max()) if served else 0,
        "served_rps": len(served) / makespan_s if makespan_s else 0.0,
        "goodput_rps": in_slo / makespan_s if makespan_s else 0.0,
        "dispatches": stats["dispatches"],
        "mean_batch": len(served) / stats["dispatches"]
        if stats["dispatches"] else 0.0,
        "clamped_dispatches": dispatch.clamped,
        "measured_dispatch_p50_us": float(np.percentile(
            np.asarray(dispatch.samples_us), 50)) if dispatch.samples_us
        else None,
    }
    emit(
        f"serving/point/{engine}/x{mult:g}",
        row["p99_us"] or 0.0,
        f"offered_rps={offered_rps:.0f};shed={row['shed_fraction']:.3f};"
        f"goodput_rps={row['goodput_rps']:.0f}",
    )
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="reduced request count for CI; writes the gitignored smoke "
             "record",
    )
    ap.add_argument("--requests", type=int, default=None,
                    help="override offered requests per load point")
    args = ap.parse_args(argv)
    n_req = args.requests or (N_REQ_SMOKE if args.smoke else N_REQ_FULL)
    n_cal = CAL_SMOKE if args.smoke else CAL_FULL

    per_engine = {}
    for engine in ENGINES:
        cal = calibrate(engine, n_cal)
        bound_us = cal["service_bound_us"]
        offered = [m * cal["capacity_rps"] for m in LOAD_MULTS_BELOW]
        offered.append(OVERLOAD_X_MEASURED * cal["measured_capacity_rps"])
        points = [
            run_point(engine, rps, bound_us, n_req, seed=17 + i)
            for i, rps in enumerate(offered)
        ]
        per_engine[engine] = {
            "calibration": cal,
            "slo_us": SLO_BOUNDS * bound_us,
            "points": points,
        }

    payload = {
        "bench": "serving",
        "schema": 1,
        "smoke": args.smoke,
        "engines": list(ENGINES),
        "n_slots": N_SLOTS,
        "max_batch": MAX_BATCH,
        "max_wait_us": MAX_WAIT_US,
        "slo_bounds": SLO_BOUNDS,
        "bound_margin": BOUND_MARGIN,
        "n_tenants": N_TENANTS,
        "zipf_s": ZIPF_S,
        "requests_per_point": n_req,
        "load_mults_below": list(LOAD_MULTS_BELOW),
        "overload_x_measured": OVERLOAD_X_MEASURED,
        "per_engine": per_engine,
    }
    path = write_bench_json("serving", payload, tracked=not args.smoke)
    print(f"wrote {path}")
    rows = [
        [e, p["load_mult"], f"{p['offered_rps']:.0f}", p["served"],
         f"{p['shed_fraction']:.4f}",
         f"{p['p50_us']:.0f}" if p["p50_us"] is not None else "-",
         f"{p['p99_us']:.0f}" if p["p99_us"] is not None else "-",
         f"{p['goodput_rps']:.0f}"]
        for e in ENGINES for p in per_engine[e]["points"]
    ]
    rows_to_csv("bench_serving",
                ["engine", "load_mult", "offered_rps", "served", "shed_frac",
                 "p50_us", "p99_us", "goodput_rps"], rows)

    # self-check the record's own contract so a full run fails loudly
    rc = 0
    for e in ENGINES:
        pts = per_engine[e]["points"]
        for p in pts:
            if not p["above_capacity"] and p["shed_fraction"] > 0:
                print(f"SHED BELOW CAPACITY: {e} x{p['load_mult']}",
                      file=sys.stderr)
                rc = 1
        if pts[-1]["shed_fraction"] <= 0:
            print(f"OVERLOAD POINT DID NOT SHED: {e}", file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
